"""Runtime-conformance invariants, checked against recorded event traces.

The single source of truth for what "the runtime behaved correctly" means —
shared by the conformance test suite (``tests/conformance``) and the chaos
benchmark (``benchmarks.chaos_sweep``), so the invariants CI enforces and
the invariants the committed ``BENCH_chaos.json`` reports are the same
code.

Each checker raises :class:`AssertionError` with a diagnostic message on
violation; :func:`holds` wraps a full check into a bool for reporting.

The invariants (schedule-independent — they hold for *any* consumption
mode under *any* variability, which is the paper's §3 correctness claim):

* **exactly-once** — every task in the spec is dispatched and completed
  exactly once, even when chaos duplicates every envelope;
* **dependency order** — by logical clock, all of a task's predecessors
  (including every DAG fan-in predecessor) complete before the task is
  dispatched;
* **fan-in admission** — a multi-predecessor task is enqueued only after a
  delivery from *every* incoming edge on *every* TP rank: the mailbox's
  edge gate never admits a task on a partial branch set;
* **w_defer_cap** — the backlog of un-executed W tasks (each holding a
  stashed activation pair) never exceeds the cap (hint mode);
* **backpressure** — the App. C F/B imbalance never exceeds
  ``buffer_limit`` + 1 (Thm C.1; non-interleaved hint mode);
* **hint faithfulness** — a hint-path dispatch deviates from the hint
  order only when the hinted task is unready: no ready task of a preferred
  direction is skipped, and within a direction the App. A minimum ready
  candidate is picked;
* **table faithfulness** — under an (adaptively re-synthesized) rank
  table, each table-path dispatch serves the minimum-rank ready task of
  the table active at that logical clock (initial table from trace meta,
  mid-run swaps replayed from ``HINT_SWAP`` events);
* **wcap path** — dispatches forced by the W cap actually retire a W;
* **recovery exactly-once** — on a trace with recovery windows
  (:meth:`Trace.recovery_windows`), no microbatch is lost or doubled across
  the recovery boundary: every task still completes, repeats occur only on
  a failed stage with each completion in a distinct recovery epoch (one per
  incarnation — re-execution, never duplication), and every fenced envelope
  was genuinely stale.  ``check_all`` dispatches between the plain and the
  recovery-aware exactly-once form automatically.

Deadlock-freedom is checked by construction: a run either completes or
raises :class:`~repro.core.engine.DeadlockError`.
"""
from __future__ import annotations

from collections import Counter

from repro.core.hints import pick
from repro.core.taskgraph import Kind, PipelineSpec

from repro.runtime.rrfp import trace as tr


def check_exactly_once(trace: tr.Trace, spec: PipelineSpec) -> None:
    """Every task dispatched and completed exactly once (dup-proof)."""
    want = set(spec.tasks())
    dispatched = Counter(ev.task for ev in trace.select(tr.DISPATCH))
    completed = Counter(ev.task for ev in trace.select(tr.COMPLETE))
    assert set(dispatched) == want, (
        f"dispatch set mismatch: missing={want - set(dispatched)} "
        f"extra={set(dispatched) - want}")
    assert set(completed) == want, (
        f"complete set mismatch: missing={want - set(completed)}")
    multi = {t: n for t, n in dispatched.items() if n != 1}
    assert not multi, f"tasks dispatched != once: {multi}"
    multi = {t: n for t, n in completed.items() if n != 1}
    assert not multi, f"tasks completed != once: {multi}"


def check_dependency_order(trace: tr.Trace, spec: PipelineSpec) -> None:
    """By logical clock, predecessors complete before a task dispatches.

    Every dispatch of a task (a recovered stage may dispatch a task once
    per incarnation) must come after the *first* completion of each
    predecessor: data a re-execution consumes was produced no later than
    that."""
    first_complete: dict = {}
    for ev in trace.select(tr.COMPLETE):
        first_complete.setdefault(ev.task, ev.lc)
    preds = {t: spec.predecessors(t) for t in spec.tasks()}
    for ev in trace.select(tr.DISPATCH):
        for p in preds[ev.task]:
            assert first_complete[p] < ev.lc, (
                f"{ev.task} dispatched (lc={ev.lc}) before predecessor "
                f"{p} completed (lc={first_complete[p]})")


def check_recovery_exactly_once(trace: tr.Trace, spec: PipelineSpec) -> None:
    """Recovery-aware exactly-once: nothing lost, nothing doubled.

    On a trace with recovery windows: (1) every spec task completes at
    least once — the failure lost no microbatch; (2) a task completes more
    than once only on a stage that failed, with every completion in a
    distinct recovery epoch — one execution per incarnation (the thread
    substrate re-executes from scratch; duplicated *effects* are dropped by
    the TP admission gate and idempotent per-task slots, so one completion
    per incarnation is re-execution, not double application); (3) repeat
    dispatches likewise only on failed stages; (4) every fenced envelope
    carried an epoch strictly older than its mailbox's — fencing never
    drops a live message."""
    want = set(spec.tasks())
    failed_stages = {w["stage"] for w in trace.recovery_windows()}
    completes: dict = {}
    for ev in trace.select(tr.COMPLETE):
        completes.setdefault(ev.task, []).append(ev)
    missing = want - set(completes)
    assert not missing, (
        f"{len(missing)} task(s) lost across recovery: "
        f"{sorted(missing)[:6]}")
    extra = set(completes) - want
    assert not extra, f"completed tasks outside the spec: {sorted(extra)[:6]}"
    for t, evs in completes.items():
        if len(evs) == 1:
            continue
        assert t.stage in failed_stages, (
            f"{t} completed {len(evs)}x on a stage that never failed")
        epochs = [e.epoch for e in evs]
        assert len(set(epochs)) == len(epochs), (
            f"{t} completed twice within one incarnation "
            f"(epochs={epochs}): a genuine duplicate, not a re-execution")
    dispatched = Counter(ev.task for ev in trace.select(tr.DISPATCH))
    missing = want - set(dispatched)
    assert not missing, f"tasks never dispatched: {sorted(missing)[:6]}"
    for t, n in dispatched.items():
        assert n == 1 or t.stage in failed_stages, (
            f"{t} dispatched {n}x on a stage that never failed")
    for ev in trace.select(tr.FENCE):
        assert ev.info["env_epoch"] < ev.info["mailbox_epoch"], (
            f"lc={ev.lc}: fenced a live envelope for {ev.task} "
            f"(env_epoch={ev.info['env_epoch']} >= "
            f"mailbox_epoch={ev.info['mailbox_epoch']})")


def check_fanin_admission(trace: tr.Trace, spec: PipelineSpec,
                          tp_degree: int = 1) -> None:
    """DAG fan-in: enqueue strictly after every edge's (per-rank) delivery."""
    enqueue_lc = {
        ev.task: ev.lc for ev in trace.select(tr.ENQUEUE)
        if ev.info.get("src") == "message"}
    first_deliver: dict[tuple, int] = {}
    for ev in trace.select(tr.DELIVER):
        key = (ev.task, int(ev.info.get("src", -1)), ev.rank)
        first_deliver.setdefault(key, ev.lc)
    for t in spec.tasks():
        mps = spec.message_predecessors(t)
        if len(mps) < 2:
            continue
        assert t in enqueue_lc, f"fan-in task {t} never enqueued"
        for p in mps:
            for rank in range(max(1, tp_degree)):
                key = (t, p.stage, rank)
                assert key in first_deliver, (
                    f"{t} enqueued with no delivery from edge "
                    f"{p.stage}->{t.stage} rank {rank}")
                assert first_deliver[key] < enqueue_lc[t], (
                    f"{t} enqueued (lc={enqueue_lc[t]}) before edge "
                    f"{p.stage}->{t.stage} rank {rank} delivered "
                    f"(lc={first_deliver[key]})")


def check_w_cap(trace: tr.Trace, cap: int, mode: str) -> None:
    """Deferred-W backlog (stashed activation pairs) never exceeds the cap."""
    if mode != "hint" or cap <= 0:
        return
    for ev in trace.select(tr.COMPLETE):
        backlog = ev.info.get("w_backlog")
        if backlog is not None:
            assert backlog <= cap, (
                f"w_defer_cap={cap} exceeded: backlog={backlog} after "
                f"{ev.task} (lc={ev.lc})")


def check_backpressure(trace: tr.Trace, spec: PipelineSpec, limit: int,
                       mode: str) -> None:
    """App. C: per-stage F/B imbalance bounded by buffer_limit (+1 in
    flight) — non-interleaved hint mode (Thm C.1)."""
    if mode != "hint" or spec.num_chunks != 1:
        return
    depth: Counter = Counter()
    for ev in trace.events:
        if ev.kind == tr.RECOVERY_BEGIN:
            # a respawned incarnation starts from a clean F/B ledger; its
            # completions are a fresh consistent sequence, so summing them
            # onto the dead incarnation's would double count
            depth[ev.stage] = 0
            continue
        if ev.kind != tr.COMPLETE:
            continue
        if ev.task.kind == Kind.F:
            depth[ev.stage] += 1
        elif ev.task.kind == Kind.B:
            depth[ev.stage] -= 1
        assert depth[ev.stage] <= limit + 1, (
            f"stage {ev.stage} F/B imbalance {depth[ev.stage]} > "
            f"limit+1={limit + 1} at lc={ev.lc}")


def check_hint_faithful(trace: tr.Trace, spec: PipelineSpec) -> None:
    """Hint-path dispatches deviate from the hint only through unreadiness.

    For each dispatch on the ``hint`` arbitration path, with the recorded
    kind-preference order (k1, k2, ...): no task of a kind preferred over
    the dispatched kind may be in the recorded ready snapshot, and the
    dispatched task must be the App. A minimum among ready tasks of its own
    kind.  Together these imply the paper-level property: whenever the
    dispatch differs from the hint's global preference over the stage's
    remaining tasks, that preferred task was unready.

    Ready snapshots come from :meth:`Trace.ready_sets`, which decodes both
    the verbose per-dispatch ``ready`` lists and the default incremental
    ``radd`` diff encoding.
    """
    snapshots = trace.ready_sets()
    for ev in trace.select(tr.DISPATCH):
        if ev.info.get("path") != "hint":
            continue
        order = [Kind(k) for k in ev.info["order"]]
        ready = snapshots[ev.lc]
        kind = ev.task.kind
        assert kind in order, (ev.task, order)
        for k in order[:order.index(kind)]:
            skipped = pick(ready, k)
            assert skipped is None, (
                f"lc={ev.lc}: dispatched {ev.task} while preferred-direction "
                f"task {skipped} was ready (order={order})")
        best = pick(ready, kind)
        assert best == ev.task, (
            f"lc={ev.lc}: dispatched {ev.task} but within-direction "
            f"priority prefers ready {best}")


def check_table_faithful(trace: tr.Trace, spec: PipelineSpec) -> None:
    """Table-path dispatches serve the minimum-rank ready task.

    The active rank table is reconstructed from the trace itself: the
    meta-recorded initial ``hint_table`` plus every HINT_SWAP event (each
    carries the stage's full new order), applied in logical-clock
    sequence — so the check is exact across mid-run hot-swaps and
    recovery re-adoptions.  For each dispatch on the ``table`` path the
    dispatched task must be the minimum of the recorded ready snapshot
    under the active table's total order (ranked tasks by position,
    unranked ones after, by the App. A key) — i.e. the table, like the
    directional hints, is deviated from only through unreadiness.
    """
    from repro.core.hints import _table_key, table_ranks

    active: dict[int, dict] = {}
    meta_tbl = trace.meta.get("hint_table")
    if meta_tbl is not None:
        for s, order in enumerate(meta_tbl):
            active[s] = table_ranks([tr.task_from_key(k) for k in order])
    snapshots = None
    for ev in trace.events:
        if ev.kind == tr.HINT_SWAP:
            active[ev.stage] = table_ranks(
                [tr.task_from_key(k) for k in ev.info["order"]])
            continue
        if ev.kind != tr.DISPATCH or ev.info.get("path") != "table":
            continue
        ranks = active.get(ev.stage)
        assert ranks is not None, (
            f"lc={ev.lc}: table-path dispatch on stage {ev.stage} with no "
            f"active table (no meta hint_table, no prior HINT_SWAP)")
        if snapshots is None:
            snapshots = trace.ready_sets()
        ready = snapshots[ev.lc]
        best = min(ready, key=lambda t: _table_key(ranks, t))
        assert best == ev.task, (
            f"lc={ev.lc}: dispatched {ev.task} but the active rank table "
            f"(version {ev.info.get('tv')}) prefers ready {best}")


def check_wcap_path(trace: tr.Trace) -> None:
    """Dispatches forced by the W cap must actually retire a W task."""
    for ev in trace.select(tr.DISPATCH):
        if ev.info.get("path") == "wcap":
            assert ev.task.kind == Kind.W, (
                f"lc={ev.lc}: wcap path dispatched non-W task {ev.task}")


def check_reliable_delivery(trace: tr.Trace, spec: PipelineSpec) -> None:
    """Exactly-once delivery under a lossy wire (reliable transport on).

    Keys on the per-edge sequence number (``eseq``) the reliable channel
    stamps into SEND / DELIVER / FENCE / RDUP records; recovery *replay*
    envelopes carry no eseq and are governed by the epoch-fencing checks
    instead.  Asserts:

    1. **dedup** — each (src, dst, eseq) reaches the destination mailbox at
       most once (DELIVER + FENCE combined): redundant transmissions never
       survive past the channel's dedup set;
    2. **completeness** — every reliable SEND reaches the mailbox exactly
       once, unless its edge escalated to LINK_FAIL or its destination
       stage failed (recovery replay re-covers those);
    3. **retransmit sanity** — RETRANSMIT records carry attempt >= 1;
    4. **dup sanity** — every RDUP names a key that was first admitted;
    5. **escalation** — every LINK_FAIL's destination stage has a FAIL
       record (the fault was handed to recovery, not swallowed).
    """
    landed = Counter()
    for kind in (tr.DELIVER, tr.FENCE):
        for ev in trace.select(kind):
            if "eseq" in ev.info:
                landed[(int(ev.info["src"]), ev.stage,
                        int(ev.info["eseq"]))] += 1
    dups = {k: n for k, n in landed.items() if n > 1}
    assert not dups, (
        f"reliable dedup violated: {len(dups)} eseq key(s) reached a "
        f"mailbox more than once: {sorted(dups)[:6]}")

    failed_stages = {ev.stage for ev in trace.select(tr.FAIL)}
    dead_edges = {(int(ev.info["src"]) if "src" in ev.info else ev.stage,
                   int(ev.info["dst"]))
                  for ev in trace.select(tr.LINK_FAIL)}
    for ev in trace.select(tr.SEND):
        if "eseq" not in ev.info:
            continue
        key = (ev.stage, ev.task.stage, int(ev.info["eseq"]))
        if key in landed:
            continue
        assert (ev.stage, ev.task.stage) in dead_edges \
            or ev.task.stage in failed_stages, (
            f"reliable send lost: {key} ({ev.task}) never reached the "
            f"mailbox and its edge never escalated")

    for ev in trace.select(tr.RETRANSMIT):
        assert int(ev.info["attempt"]) >= 1, (
            f"lc={ev.lc}: RETRANSMIT with attempt "
            f"{ev.info['attempt']} (first attempts are not retransmits)")
    for ev in trace.select(tr.RDUP):
        key = (int(ev.info["src"]), ev.stage, int(ev.info["eseq"]))
        assert key in landed, (
            f"lc={ev.lc}: duplicate {key} dropped but the key was never "
            f"admitted in the first place")
    for ev in trace.select(tr.LINK_FAIL):
        assert int(ev.info["dst"]) in failed_stages, (
            f"lc={ev.lc}: edge {ev.stage}->{ev.info['dst']} declared "
            f"unhealable but stage {ev.info['dst']} has no FAIL record")


def check_all(trace: tr.Trace, spec: PipelineSpec, config) -> None:
    """Every invariant, against one run's trace.  ``config`` is any object
    with ``mode`` / ``w_defer_cap`` / ``buffer_limit`` attributes
    (``ActorConfig`` in practice; kept duck-typed to avoid a driver
    dependency).  Traces containing recovery windows get the
    recovery-aware exactly-once form; every other invariant applies
    unchanged across the recovery boundary."""
    if trace.recovery_windows():
        check_recovery_exactly_once(trace, spec)
    else:
        check_exactly_once(trace, spec)
    check_dependency_order(trace, spec)
    check_fanin_admission(trace, spec, getattr(config, "tp_degree", 1))
    check_w_cap(trace, config.w_defer_cap, config.mode)
    check_backpressure(trace, spec, config.buffer_limit, config.mode)
    check_hint_faithful(trace, spec)
    check_table_faithful(trace, spec)
    check_wcap_path(trace)
    if trace.meta.get("reliable"):
        check_reliable_delivery(trace, spec)


def holds(trace: tr.Trace, spec: PipelineSpec, config) -> bool:
    """Bool wrapper over :func:`check_all` for reporting/benchmarks."""
    try:
        check_all(trace, spec, config)
    except AssertionError:
        return False
    return True
