"""Quickstart: the RRFP runtime in 60 seconds.

1. Simulate a jittery, imbalanced 8-stage pipeline with the faithful engine:
   pre-committed 1F1B vs readiness-first RRFP (the paper's contrast).
2. Synthesize the RRFP-realized order into a static schedule table and train
   a tiny model with the compiled SPMD executor on forced host devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax.numpy as jnp

from repro.core import (
    CostModel, EngineConfig, HintKind, PipelineSpec,
    multimodal_stage_flops, run_iteration,
)

S, M = 8, 32
spec = PipelineSpec(S, M)
costs = CostModel.from_stage_flops(
    multimodal_stage_flops(5e12, 2e12, S), comm_base=2e-3, seed=0)

r_fixed = run_iteration(spec, costs, EngineConfig(mode="precommitted",
                                                  fixed_order="1f1b"))
r_rrfp = run_iteration(spec, costs, EngineConfig(mode="hint",
                                                 hint=HintKind.BF))
print("== engine: one iteration under jitter + stage imbalance ==")
print(f"pre-committed 1F1B: {r_fixed.makespan:.3f}s  "
      f"(blocking {r_fixed.breakdown()['blocking']:.3f}s)")
print(f"RRFP (BF hint):     {r_rrfp.makespan:.3f}s  "
      f"(blocking {r_rrfp.breakdown()['blocking']:.3f}s)  "
      f"speedup {r_fixed.makespan / r_rrfp.makespan:.2f}x")

print("\n== compiled executor: train a tiny LM with the RRFP table ==")
from repro.launch.train import build_trainer
from repro.data.synthetic import synth_batch

t = build_trainer("deepseek-7b", data=2, stages=4, layers=8, mb_rows=1,
                  microbatches=8, seq=64, schedule="rrfp")
sp, io, opt = t["stage_params"], t["io_params"], t["opt_state"]
for step in range(5):
    batch = synth_batch(t["cfg"], t["batch_size"], t["seq"], step=step)
    sp, io, opt, m = t["train_step"](sp, io, opt, batch,
                                     jnp.asarray(step, jnp.int32))
    print(f"step {step}  loss {float(m['loss']):.4f}")
print("table bubble fraction:", round(t["table"].bubble_fraction(), 3))
