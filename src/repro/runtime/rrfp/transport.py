"""Pluggable message transports for the actor runtime (§4.1).

* :class:`SimTransport` — in-process queue transport with *injectable*
  heavy-tailed latency: each envelope's arrival is delayed by a sample from
  the :class:`~repro.core.costs.CostModel` communication jitter (per TP
  rank), delivered on the driver's virtual clock.  Sampling is keyed by
  (seed, task, rank) rather than drawn from a shared stream, so two runs in
  different consumption modes see the *same* realized latencies — common
  random numbers for apples-to-apples hint-vs-precommitted comparisons.

* :class:`ThreadTransport` — wall-clock transport between thread-per-stage
  actors in one process: ``send`` delivers straight into the destination
  mailbox (the Python-object hand-off is the wire), waking the receiver's
  condition variable.
"""
from __future__ import annotations

import zlib
from typing import Callable, Protocol

import numpy as np

from repro.core.costs import CostModel

from repro.runtime.rrfp.mailbox import Mailbox
from repro.runtime.rrfp.messages import Envelope


class Transport(Protocol):
    def send(self, env: Envelope, now: float = 0.0) -> None:
        """Hand one envelope to the network; delivery is asynchronous."""
        ...


def rng_for(seed: int, env: Envelope) -> np.random.Generator:
    """Deterministic per-(task, rank) generator: the CRN keying."""
    t = env.task
    return np.random.default_rng(
        [seed & 0x7FFFFFFF, zlib.crc32(b"rrfp-comm"),
         int(t.kind), t.stage, t.mb, t.chunk, env.rank])


class SimTransport:
    """Virtual-time transport with sampled heavy-tailed latency.

    ``schedule(time, env)`` is the driver's event-loop hook; the transport
    never blocks and never touches wall time.
    """

    def __init__(
        self,
        costs: CostModel,
        schedule: Callable[[float, Envelope], None],
        seed: int = 0,
        on_send: Callable[[Envelope, float], None] | None = None,
    ):
        self.costs = costs
        self.schedule = schedule
        self.seed = seed
        self.on_send = on_send
        self.sent = 0

    def send(self, env: Envelope, now: float = 0.0) -> None:
        lat = self.costs.sample_comm(rng_for(self.seed, env))
        self.sent += 1
        if self.on_send is not None:
            self.on_send(env, lat)
        self.schedule(now + lat, env)


class ThreadTransport:
    """Direct mailbox-to-mailbox delivery between actor threads."""

    def __init__(self, mailboxes: dict[int, Mailbox],
                 on_send: Callable[[Envelope, float], None] | None = None):
        self.mailboxes = mailboxes
        self.on_send = on_send
        self.sent = 0

    def send(self, env: Envelope, now: float = 0.0) -> None:
        self.sent += 1
        if self.on_send is not None:
            self.on_send(env, now)
        self.mailboxes[env.dst_stage].deliver(env, now=now)
