"""Dispatch-overhead microbenchmark: the cost of one scheduling decision.

The paper's third pillar is ready-set arbitration for *low-overhead
dispatch*; this module measures that overhead directly and pins the
incremental `ReadySet` index (``core.hints``) against the reference
sort-then-rank path it replaced:

* **per-decision arbitration cost** — ns per ``HintArbiter.select`` across
  ready-set sizes and hints, reference (``select(sorted(ready))``: O(n log
  n) sort + O(n) rank scan per decision) vs. incremental (heap peek +
  lazy-deletion churn: O(log n) insert / amortized O(1) peek);
* **end-to-end DES throughput** — simulator events/sec of the same engine
  run with ``EngineConfig.reference_arbitration`` on vs. off, on a chain
  and a fan-in DAG workload (the engine is the workhorse behind the
  chaos/multimodal sweeps and the conformance suite, so this is CI
  wall-clock, not just a fidelity number);
* **trace identity** — the non-negotiable invariant: on the same seed the
  fast and reference paths must make *identical* arbitration decisions.
  Checked end to end by recording both runs' event traces through the
  actor runtime and comparing the serialized JSON-lines files byte for
  byte, on one chain and one DAG workload;
* **metrics overhead** — the telemetry shards (``repro.obs``) attach to
  the same hot path; paired metrics-on vs. metrics-off actor runs must
  stay within ``METRICS_OVERHEAD_MAX`` (default 1.10x) per decision.

    PYTHONPATH=src python -m benchmarks.run --backend actor --dispatch

Writes ``BENCH_dispatch.json``.  Set ``REPRO_SMOKE=1`` to shrink the sweep
for CI smoke runs; the summary thresholds (``min speedup at ready-set size
>= 32`` and byte-identical traces) are enforced in both modes — the CI
smoke step fails on a dispatch-cost regression.
"""
from __future__ import annotations

import gc
import json
import os
import tempfile
import time

from repro.core import (
    CostModel,
    EngineConfig,
    HintKind,
    Kind,
    PipelineSpec,
    StageGraph,
    Task,
    run_iteration,
)
from repro.core.hints import HintArbiter, ReadySet
from repro.runtime.rrfp import ActorConfig, ActorDriver

#: Generous regression gate for CI: the committed full-size numbers are
#: >= 3x at size >= 32, so tripping 1.5x on a noisy CI host is a real
#: regression, not jitter.  Override via DISPATCH_SPEEDUP_MIN.
SPEEDUP_FLOOR = float(os.environ.get("DISPATCH_SPEEDUP_MIN", "1.5"))

#: Telemetry must be pay-for-what-you-use: enabling the metrics shards may
#: not add more than this ratio to per-decision runtime cost (median of
#: paired on/off runs).  Override via METRICS_OVERHEAD_MAX.
METRICS_OVERHEAD_MAX = float(os.environ.get("METRICS_OVERHEAD_MAX", "1.10"))

#: Smoke-mode ceiling for the same gate.  Like SPEEDUP_FLOOR it is
#: deliberately generous: shared CI runners (and microVM hosts, where even
#: process_time absorbs hypervisor steal) scatter short paired runs by a
#: few percent either way, so a 1.10x hard gate would flake while the real
#: overhead sits at ~1.06-1.08x (the committed full-size artifact gates at
#: METRICS_OVERHEAD_MAX proper).  Tripping 1.25x in smoke means the hooks
#: genuinely leaked onto the hot path.  Override via
#: METRICS_OVERHEAD_MAX_SMOKE.
METRICS_OVERHEAD_MAX_SMOKE = float(
    os.environ.get("METRICS_OVERHEAD_MAX_SMOKE", "1.25"))


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_SMOKE"))


# ---------------------------------------------------------------------------
# per-decision arbitration cost
# ---------------------------------------------------------------------------

def _task_pool(n: int, split: bool) -> list[Task]:
    """n distinct single-stage tasks with the kind mix of a busy ready set."""
    kinds = [Kind.F, Kind.B] + ([Kind.W] if split else [])
    out: list[Task] = []
    i = 0
    while len(out) < n:
        out.append(Task(kinds[i % len(kinds)], 0, i // 4, i % 4))
        i += 1
    return out


def _time_per_call(fn, reps: int) -> float:
    t0 = time.perf_counter_ns()
    for _ in range(reps):
        fn()
    return (time.perf_counter_ns() - t0) / reps


def per_decision_rows(sizes: list[int], reps: int) -> list[dict]:
    """ns/decision for reference vs. incremental arbitration, per hint."""
    rows = []
    for hint in (HintKind.BF, HintKind.BFW):
        split = hint == HintKind.BFW
        for n in sizes:
            pool = _task_pool(n, split)
            ready_set = set(pool)

            ref_arb = HintArbiter(hint)

            def ref_select():
                # the replaced hot path: sort the live set, rank-scan it
                ref_arb.select(sorted(ready_set))

            fast_arb = HintArbiter(hint)
            rs = ReadySet(pool)

            def fast_select():
                # the new hot path, including the incremental maintenance a
                # real dispatch pays (consume the winner, a successor lands).
                # The interleaved peek surfaces the winner's stale heap entry
                # so every rep pays the lazy-deletion pop churn too — without
                # it the re-add would shadow the stale entry and the heap
                # would grow by one per rep instead of staying at size n.
                t = fast_arb.select(rs)
                rs.discard(t)
                rs.peek(t.kind)
                rs.add(t)

            # warmup (also surfaces any stale-entry churn), then measure
            _time_per_call(ref_select, reps // 10 + 1)
            _time_per_call(fast_select, reps // 10 + 1)
            ref_ns = _time_per_call(ref_select, reps)
            fast_ns = _time_per_call(fast_select, reps)
            rows.append({
                "hint": hint.value,
                "ready_size": n,
                "reference_ns_per_decision": ref_ns,
                "incremental_ns_per_decision": fast_ns,
                "speedup": ref_ns / max(fast_ns, 1e-9),
            })
    return rows


# ---------------------------------------------------------------------------
# end-to-end DES events/sec + paired trace identity
# ---------------------------------------------------------------------------

def _dag_spec(num_mb: int) -> PipelineSpec:
    """Branch+fusion DAG: two encoder roots -> fusion -> 3-stage LM chain."""
    g = StageGraph(6, ((0, 2), (1, 2), (2, 3), (3, 4), (4, 5)))
    return PipelineSpec(6, num_mb, graph=g)


def _sim_events(spec: PipelineSpec) -> int:
    """Heap events one engine run processes: completions + deliveries."""
    return spec.total_tasks() + sum(
        len(spec.message_successors(t)) for t in spec.tasks())


def engine_throughput_rows(num_mb: int, iters: int) -> list[dict]:
    """DES events/sec, reference vs. incremental arbitration.

    ``buffer_limit=64`` with a deep microbatch count keeps the per-stage
    ready sets large — the regime where per-decision cost dominates the
    simulator (and the regime the paper's dispatch claim is about).
    Best-of-``iters`` timing discards scheduler noise.
    """
    rows = []
    for name, spec in (("chain", PipelineSpec(8, num_mb)),
                       ("dag", _dag_spec(num_mb))):
        cm = CostModel.uniform(spec.num_stages)
        events = _sim_events(spec)
        eps = {}
        for label, ref in (("reference", True), ("incremental", False)):
            cfg = EngineConfig(mode="hint", hint=HintKind.BF,
                               buffer_limit=64, reference_arbitration=ref)
            run_iteration(spec, cm, cfg)  # warmup
            best = float("inf")
            for _ in range(iters):
                t0 = time.perf_counter()
                run_iteration(spec, cm, cfg)
                best = min(best, time.perf_counter() - t0)
            eps[label] = events / best
        rows.append({
            "workload": name,
            "stages": spec.num_stages,
            "microbatches": num_mb,
            "sim_events_per_run": events,
            "reference_events_per_sec": eps["reference"],
            "incremental_events_per_sec": eps["incremental"],
            "throughput_ratio": eps["incremental"] / eps["reference"],
        })
    return rows


def trace_identity_rows(num_mb: int) -> list[dict]:
    """Same seed, fast vs. reference arbitration -> byte-identical traces."""
    rows = []
    for name, spec in (("chain", PipelineSpec(6, num_mb)),
                       ("dag", _dag_spec(num_mb))):
        cm = CostModel.uniform(spec.num_stages)
        paths, n_events = [], 0
        for ref in (False, True):
            cfg = ActorConfig(mode="hint", hint=HintKind.BF, seed=7,
                              record_trace=True, reference_arbitration=ref)
            res = ActorDriver(spec, cm, cfg).run()
            n_events = len(res.trace.events)
            fd, path = tempfile.mkstemp(suffix=".jsonl")
            os.close(fd)
            res.trace.save(path)
            paths.append(path)
        with open(paths[0], "rb") as a, open(paths[1], "rb") as b:
            identical = a.read() == b.read()
        for p in paths:
            os.unlink(p)
        rows.append({
            "workload": name,
            "events": n_events,
            "byte_identical": identical,
        })
    return rows


# ---------------------------------------------------------------------------
# telemetry overhead: metrics shards on vs. off, same seed, same workloads
# ---------------------------------------------------------------------------

def metrics_overhead_rows(num_mb: int, iters: int) -> list[dict]:
    """Per-decision cost of the actor runtime with metrics shards on vs. off.

    The telemetry hooks (``repro.obs.MetricsRegistry`` sharded per stage)
    sit on the dispatch/complete/enqueue hot path guarded by a single
    ``is None`` check; this times whole ``ActorDriver`` sim runs both ways
    (fresh registry per timed run so shard state never accumulates) and
    reports CPU time / dispatch decisions.  The off/on runs are timed as
    *alternating pairs* (order flipped every other pair) and the gated
    statistic is the **median of the per-pair on/off ratios**: slow host
    drift (CPU frequency, background load) hits both sides of a pair
    roughly equally and cancels in the ratio, and the median discards the
    pairs a stray interrupt did land in — a best-of-N ratio instead
    couples two independent extremes and swings far more between runs.
    ``dispatch_rows`` gates the median at :data:`METRICS_OVERHEAD_MAX`.
    """
    from repro.obs import MetricsRegistry

    rows = []
    for name, spec in (("chain", PipelineSpec(8, num_mb)),
                       ("dag", _dag_spec(num_mb))):
        cm = CostModel.uniform(spec.num_stages)
        decisions = spec.total_tasks()

        def timed(metrics) -> float:
            cfg = ActorConfig(mode="hint", hint=HintKind.BF, seed=7,
                              metrics=metrics)
            # CPU time, not wall: the sim pump is single-threaded pure
            # compute, so process_time excludes preemption by other
            # processes — the dominant noise source on short runs.
            t0 = time.process_time()
            ActorDriver(spec, cm, cfg).run()
            return time.process_time() - t0

        timed(None)
        timed(MetricsRegistry())  # warmup both paths
        ratios, best = [], {"off": float("inf"), "on": float("inf")}
        gc_was_enabled = gc.isenabled()
        gc.disable()  # collector scatter would swamp a few-percent delta
        try:
            for i in range(iters):
                if i % 2 == 0:
                    off = timed(None)
                    on = timed(MetricsRegistry())
                else:
                    on = timed(MetricsRegistry())
                    off = timed(None)
                ratios.append(on / max(off, 1e-12))
                best["off"] = min(best["off"], off)
                best["on"] = min(best["on"], on)
        finally:
            if gc_was_enabled:
                gc.enable()
        ratios.sort()
        median = ratios[len(ratios) // 2]
        ns_per = {k: v / decisions * 1e9 for k, v in best.items()}
        rows.append({
            "workload": name,
            "stages": spec.num_stages,
            "microbatches": num_mb,
            "decisions_per_run": decisions,
            "pairs": iters,
            "metrics_off_ns_per_decision": ns_per["off"],
            "metrics_on_ns_per_decision": ns_per["on"],
            "overhead_ratio": median,
        })
    return rows


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def run_dispatch_benchmark() -> dict:
    smoke = _smoke()
    sizes = [8, 32, 64] if smoke else [8, 32, 128, 512]
    reps = 1000 if smoke else 6000
    num_mb = 64 if smoke else 256
    iters = 2 if smoke else 5

    decisions = per_decision_rows(sizes, reps)
    throughput = engine_throughput_rows(num_mb, iters)
    identity = trace_identity_rows(8 if smoke else 24)
    # odd pair counts -> the median is a real observed pair, not a midpoint.
    # The overhead section keeps a larger microbatch count in smoke mode:
    # at num_mb=64 a sim run is short enough that host jitter swamps the
    # few-percent delta the gate is trying to resolve.
    metrics = metrics_overhead_rows(max(num_mb, 192), 11 if smoke else 21)

    at_32 = [r["speedup"] for r in decisions if r["ready_size"] >= 32]
    summary = {
        "min_speedup_at_ready_size_32plus": min(at_32),
        "speedup_floor": SPEEDUP_FLOOR,
        "all_traces_byte_identical": all(
            r["byte_identical"] for r in identity),
        "min_des_throughput_ratio": min(
            r["throughput_ratio"] for r in throughput),
        "max_metrics_overhead_ratio": max(
            r["overhead_ratio"] for r in metrics),
        "metrics_overhead_max": (
            METRICS_OVERHEAD_MAX_SMOKE if smoke else METRICS_OVERHEAD_MAX),
    }
    return {
        "meta": {"smoke": smoke, "sizes": sizes, "reps": reps,
                 "microbatches": num_mb, "engine_iters": iters},
        "per_decision": decisions,
        "des_throughput": throughput,
        "trace_identity": identity,
        "metrics_overhead": metrics,
        "summary": summary,
    }


def emit_json(path: str = "BENCH_dispatch.json") -> dict:
    report = run_dispatch_benchmark()
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def dispatch_rows(
    json_path: str = "BENCH_dispatch.json",
) -> list[tuple[str, float, str]]:
    """CSV rows for ``benchmarks.run``; raises on a dispatch regression."""
    report = emit_json(json_path)
    out = []
    for r in report["per_decision"]:
        out.append((
            f"dispatch/{r['hint']}/n{r['ready_size']}",
            r["incremental_ns_per_decision"] / 1e3,
            f"speedup={r['speedup']:.2f}x",
        ))
    for r in report["des_throughput"]:
        out.append((
            f"dispatch/engine/{r['workload']}",
            1e6 / max(r["incremental_events_per_sec"], 1e-9),
            f"events_per_sec={r['incremental_events_per_sec']:.0f},"
            f"ratio={r['throughput_ratio']:.2f}x",
        ))
    for r in report["trace_identity"]:
        out.append((
            f"dispatch/trace-identity/{r['workload']}", 0.0,
            f"byte_identical={r['byte_identical']}",
        ))
    for r in report["metrics_overhead"]:
        out.append((
            f"dispatch/metrics-overhead/{r['workload']}",
            r["metrics_on_ns_per_decision"] / 1e3,
            f"ratio={r['overhead_ratio']:.3f}x",
        ))
    s = report["summary"]
    if not s["all_traces_byte_identical"]:
        raise SystemExit(
            "dispatch benchmark: fast vs reference arbitration produced "
            "different traces — the incremental ReadySet changed a decision")
    if s["min_speedup_at_ready_size_32plus"] < SPEEDUP_FLOOR:
        raise SystemExit(
            f"dispatch benchmark: per-decision speedup "
            f"{s['min_speedup_at_ready_size_32plus']:.2f}x at ready-set "
            f"size >= 32 fell below the {SPEEDUP_FLOOR:.2f}x floor "
            f"(set DISPATCH_SPEEDUP_MIN to adjust)")
    ceiling = s["metrics_overhead_max"]
    if s["max_metrics_overhead_ratio"] > ceiling:
        raise SystemExit(
            f"dispatch benchmark: enabling metrics shards cost "
            f"{s['max_metrics_overhead_ratio']:.3f}x per decision, above "
            f"the {ceiling:.2f}x ceiling — the telemetry "
            f"hooks leaked onto the hot path "
            f"(set METRICS_OVERHEAD_MAX / METRICS_OVERHEAD_MAX_SMOKE "
            f"to adjust)")
    return out


if __name__ == "__main__":
    for name, us, derived in dispatch_rows():
        print(f"{name},{us:.3f},{derived}")
