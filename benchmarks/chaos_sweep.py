"""Chaos sweep: correctness + makespan of both consumption modes under
escalating fault injection (emits ``BENCH_chaos.json``).

For each chaos level C0..C3 (none → heavy: per-edge latency, reorder,
duplication, a straggler stage, transient stalls) and each consumption mode
(hint BF vs precommitted 1F1B), runs seeded iterations through the actor
runtime with trace recording and reports:

* mean/std makespan (CRN-keyed: both modes see identical fault draws);
* the count of runs on which *all* conformance invariants held
  (``repro.runtime.rrfp.conformance`` — the same checkers the test suite
  enforces) — the "robust under variability" claim as a measured quantity,
  not an anecdote;
* duplicate-suppression and rank-deferral counters from the traces.

    PYTHONPATH=src python -m benchmarks.run --backend actor --chaos

Set ``REPRO_SMOKE=1`` to shrink the sweep for CI smoke runs.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import CostModel, HintKind, PipelineSpec, multimodal_stage_flops
from repro.runtime.rrfp import CHAOS_LEVELS, ActorConfig, ActorDriver
from repro.runtime.rrfp.conformance import holds as invariants_hold

S, M = 8, 32
ITERS = 4


def _base_costs(seed: int = 0) -> CostModel:
    return CostModel.from_stage_flops(
        multimodal_stage_flops(4e12, 2e12, S), comm_base=2e-3, seed=seed)


def run_chaos_sweep() -> dict:
    spec = PipelineSpec(S, M)
    smoke = bool(os.environ.get("REPRO_SMOKE"))
    iters = 1 if smoke else ITERS
    levels = ["C0", "C2"] if smoke else list(CHAOS_LEVELS)
    modes = {
        "hint_bf": ActorConfig(mode="hint", hint=HintKind.BF,
                               record_trace=True),
        "precommitted_1f1b": ActorConfig(mode="precommitted",
                                         fixed_order="1f1b",
                                         record_trace=True),
    }
    rows = []
    for level in levels:
        base_chaos = CHAOS_LEVELS[level]
        per_mode: dict[str, dict] = {}
        for mode_name, base_cfg in modes.items():
            spans, ok, dups, defers = [], 0, 0, 0
            for i in range(iters):
                chaos = (dataclasses.replace(base_chaos, seed=100 + i)
                         if base_chaos.active() else None)
                cfg = dataclasses.replace(base_cfg, seed=1000 * i,
                                          chaos=chaos)
                driver = ActorDriver(spec, _base_costs(), cfg)
                result = driver.run()
                spans.append(result.makespan)
                trace = driver.trace
                if invariants_hold(trace, spec, cfg):
                    ok += 1
                dups += sum(1 for ev in trace.events if ev.kind == "tp_dup")
                defers += sum(s.deferrals for s in result.stage_stats)
            xs = np.array(spans)
            per_mode[mode_name] = {
                "makespan_s": float(xs.mean()),
                "makespan_std": float(xs.std()),
                "invariants_ok": ok,
                "runs": iters,
                "tp_dups_suppressed": dups,
                "rank_deferrals": defers,
            }
        rows.append({
            "level": level,
            "chaos": base_chaos.to_json(),
            **{k: v for k, v in per_mode.items()},
            "speedup": (per_mode["precommitted_1f1b"]["makespan_s"]
                        / max(per_mode["hint_bf"]["makespan_s"], 1e-12)),
        })
    return {
        "spec": {"stages": S, "microbatches": M, "iters": iters},
        "rows": rows,
    }


def emit_json(path: str = "BENCH_chaos.json") -> dict:
    report = run_chaos_sweep()
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def chaos_rows(json_path: str = "BENCH_chaos.json") -> list[tuple[str, float, str]]:
    """CSV rows for ``benchmarks.run``."""
    report = emit_json(json_path)
    out = []
    for r in report["rows"]:
        for mode in ("precommitted_1f1b", "hint_bf"):
            m = r[mode]
            out.append((
                f"chaos/{r['level']}/{mode}",
                m["makespan_s"] * 1e6,
                f"invariants={m['invariants_ok']}/{m['runs']},"
                f"speedup={r['speedup']:.2f}x" if mode == "hint_bf"
                else f"invariants={m['invariants_ok']}/{m['runs']}"))
    return out
