"""Adaptive hot-swap conformance: HINT_SWAP record/replay determinism.

The adaptive runtime's claim is that swapping the hint table mid-run is a
*recorded scheduling decision* like any other: the swap happens at a
quiesce point, is stamped into the trace as per-stage ``HINT_SWAP`` events,
and therefore

* a sim-substrate run with a mid-run swap replays **time-exactly** (the
  replayed trace is bit-for-bit the recorded one, surviving a save/load
  roundtrip);
* a thread-substrate run with a mid-run swap replays **order-exactly**,
  reproducing an eager float32 reduction's loss and weight-gradient bits;
* every table-path dispatch obeys the table that was active at its logical
  clock (``check_table_faithful``), across the swap boundary.
"""
import dataclasses

import pytest

from harness import NumpyStageProgram, make_scenario, sim_costs

from repro.core.hints import HintKind
from repro.core.synthesis import synthesize
from repro.core.taskgraph import PipelineSpec
from repro.runtime.rrfp import ActorConfig, ActorDriver, Trace
from repro.runtime.rrfp import trace as _tr
from repro.runtime.rrfp.conformance import check_all, check_table_faithful

# one fused (BF) and one split-backward (BFW) scenario; both num_chunks == 1
# (schedule synthesis does not price interleaved baselines)
SWAP_SEEDS = [9, 17]


def _tables(spec, seed):
    """Two genuinely different tables: synthesized on the base costs and on
    a drifted copy (one stage 2x slower)."""
    costs = sim_costs(spec, seed)
    hint = HintKind.BFW if spec.split_backward else HintKind.BF
    drifted = dataclasses.replace(
        costs, b_cost=costs.b_cost * [
            2.0 if s == spec.num_stages // 2 else 1.0
            for s in range(spec.num_stages)])
    old = synthesize(spec, costs, hint=hint).stage_orders
    new = synthesize(spec, drifted, hint=hint).stage_orders
    return costs, old, new


def _swap_scenario(seed):
    """A hint-mode scenario armed with a mid-run table swap."""
    sc = make_scenario(seed)
    spec = sc.spec
    costs, old, new = _tables(spec, seed)
    probe = ActorDriver(spec, costs, dataclasses.replace(
        sc.config, mode="hint", hint_table=old, record_trace=False)).run()
    cfg = dataclasses.replace(
        sc.config, mode="hint", hint_table=old, hint_table_version=0,
        swap_table=new, swap_at=probe.makespan * 0.5,
        swap_after=spec.num_microbatches // 2)
    return spec, costs, cfg


@pytest.mark.parametrize("seed", SWAP_SEEDS)
def test_sim_hint_swap_replays_exactly(tmp_path, seed):
    spec, costs, cfg = _swap_scenario(seed)
    driver = ActorDriver(spec, costs, cfg)
    result = driver.run()
    trace = driver.trace
    swaps = trace.select(_tr.HINT_SWAP)
    assert len(swaps) == spec.num_stages
    assert all(ev.info["version"] == 1 for ev in swaps)

    path = tmp_path / "swap_trace.jsonl"
    trace.save(str(path))
    loaded = Trace.load(str(path))
    assert loaded.signature() == trace.signature()

    rdriver = ActorDriver(
        spec, None, ActorConfig(record_trace=True, replay=loaded))
    replayed = rdriver.run()
    assert replayed.makespan == result.makespan
    assert rdriver.trace.signature(include_time=True) == \
        trace.signature(include_time=True)


@pytest.mark.parametrize("seed", SWAP_SEEDS)
def test_thread_hint_swap_replay_reproduces_loss_bits(seed):
    sc = make_scenario(seed, substrate="thread")
    spec = sc.spec
    S = spec.num_stages
    _, old, new = _tables(spec, seed)
    cfg = dataclasses.replace(
        sc.config, mode="hint",
        hint=HintKind.BFW if spec.split_backward else HintKind.BF,
        hint_table=old, swap_table=new,
        swap_after=max(1, spec.num_microbatches // 2))

    first = [NumpyStageProgram(s, spec, seed, deterministic=False)
             for s in range(S)]
    driver = ActorDriver(spec, None, cfg)
    driver.run_threaded(list(first))
    trace = driver.trace
    assert len(trace.select(_tr.HINT_SWAP)) == S
    assert any(ev.info.get("path") == "table"
               for ev in trace.select(_tr.DISPATCH))

    second = [NumpyStageProgram(s, spec, seed, deterministic=False)
              for s in range(S)]
    rdriver = ActorDriver(
        spec, None,
        ActorConfig(record_trace=True, replay=trace,
                    deadlock_timeout=sc.config.deadlock_timeout))
    rdriver.run_threaded(list(second))
    assert rdriver.trace.dispatch_orders(S) == trace.dispatch_orders(S)
    for a, b in zip(first, second):
        assert a.loss.tobytes() == b.loss.tobytes()
        assert a.d_w.tobytes() == b.d_w.tobytes()


@pytest.mark.parametrize("seed", SWAP_SEEDS)
def test_table_faithfulness_across_swap(seed):
    spec, costs, cfg = _swap_scenario(seed)
    driver = ActorDriver(spec, costs, cfg)
    driver.run()
    check_all(driver.trace, spec, cfg)  # includes check_table_faithful


def test_table_faithfulness_detects_violation():
    """Corrupting one table-path dispatch must trip the checker."""
    spec = PipelineSpec(3, 6)
    costs = sim_costs(spec, 5)
    table = synthesize(spec, costs, hint=HintKind.BF).stage_orders
    driver = ActorDriver(spec, costs, ActorConfig(
        mode="hint", hint_table=table, record_trace=True))
    driver.run()
    trace = driver.trace
    check_table_faithful(trace, spec)

    dispatches = [i for i, ev in enumerate(trace.events)
                  if ev.kind == _tr.DISPATCH
                  and ev.info.get("path") == "table"
                  and len(ev.info.get("radd", ())) > 1]
    assert dispatches, "need a contended dispatch to corrupt"
    i = dispatches[-1]
    ev = trace.events[i]
    other = next(_tr.task_from_key(k) for k in ev.info["radd"]
                 if _tr.task_from_key(k) != ev.task)
    trace.events[i] = dataclasses.replace(ev, task=other)
    with pytest.raises(AssertionError):
        check_table_faithful(trace, spec)
