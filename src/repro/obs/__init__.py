"""Runtime observability: metrics, bubble attribution, Perfetto export,
online cost tables.

Layered strictly *on top of* the runtime (``repro.runtime.rrfp`` never
imports this package except lazily from ``Trace.to_perfetto``):

  metrics     -- per-stage single-writer shards: counters, gauges,
                 log-bucketed histograms; aggregated at sync points
  cost_table  -- per-(stage, op) duration EWMAs -> CostModel snapshots
                 (the online input for ROADMAP item 3 hint re-synthesis)
  bubbles     -- idle-time decomposition over recorded traces: warmup,
                 dependency-wait, starvation, TP-gate, backpressure, drain
  critpath    -- critical-path engine: the execution DAG whose longest
                 path reconstructs the makespan exactly, with per-node
                 slack and a 100%-accounted category decomposition
  whatif      -- Coz-style causal what-if profiling: virtual speedups on
                 the critical-path graph predict the new makespan
  report      -- one-shot explain(trace) health report + CLI
  export      -- Chrome trace-event / Perfetto JSON rendering of traces

See ``docs/observability.md`` for the metric catalogue and semantics.
"""
from repro.obs.bubbles import (
    CATEGORIES,
    BubbleReport,
    StageBubbles,
    compare,
    decompose,
    spec_from_meta,
)
from repro.obs.cost_table import Ewma, OnlineCostTable
from repro.obs.critpath import (
    CP_CATEGORIES,
    CritPathReport,
    ExecGraph,
)
from repro.obs.export import export_perfetto, to_perfetto, validate_chrome_trace
from repro.obs.metrics import (
    DEPTH_EDGES,
    DURATION_EDGES,
    Histogram,
    MetricsRegistry,
    StageShard,
    log_edges,
)
from repro.obs.report import ExplainReport, explain
from repro.obs.whatif import (
    Speedup,
    apply_to_cost_model,
    candidate_speedups,
    predict,
)

__all__ = [
    "BubbleReport",
    "CATEGORIES",
    "CP_CATEGORIES",
    "CritPathReport",
    "DEPTH_EDGES",
    "DURATION_EDGES",
    "Ewma",
    "ExecGraph",
    "ExplainReport",
    "Histogram",
    "MetricsRegistry",
    "OnlineCostTable",
    "Speedup",
    "StageBubbles",
    "StageShard",
    "apply_to_cost_model",
    "candidate_speedups",
    "compare",
    "decompose",
    "explain",
    "export_perfetto",
    "log_edges",
    "predict",
    "spec_from_meta",
    "to_perfetto",
    "validate_chrome_trace",
]
