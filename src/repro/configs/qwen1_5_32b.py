"""Qwen1.5-32B — dense with QKV bias.  [hf:Qwen/Qwen1.5 family; hf]"""
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,      # full MHA
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
)
