"""JAX version compatibility shims.

The repo targets the current jax API (``jax.shard_map`` with ``check_vma``,
``jax.sharding.AxisType``); older installs (<= 0.4.x) expose the same
functionality as ``jax.experimental.shard_map.shard_map`` with ``check_rep``
and meshes without axis types.  Route every use through here so version skew
stays in one file.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new jax, experimental fallback on old."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit-Auto axis types when supported."""
    axis_type = getattr(getattr(jax, "sharding"), "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
