"""Tensor-parallel group consistency barrier (§4.2, Appendix D).

A stage with tp_degree K is K ranks executing in lockstep; the group can only
agree to dispatch a task once *all* ranks hold its input message.  The
:class:`TPGroup` tracks per-rank arrivals and admits a task at the arrival of
its last rank.  Whenever the per-rank arrival spread is nonzero the group has
been *deferred* by rank divergence — the paper's App. D counter.

Each collective-relevant dispatch additionally pays a scalar all-gather
(``coordination_cost``), calibrated to Table 3 like the DES engine.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.taskgraph import Kind, Task

from repro.runtime.rrfp import trace as _tr
from repro.runtime.rrfp.messages import Envelope


@dataclasses.dataclass
class Admission:
    """Result of the last-rank arrival that completed a task's message set."""

    task: Task
    admit_time: float
    spread: float  # max - min per-rank arrival time

    @property
    def deferred(self) -> bool:
        return self.spread > 0.0


class TPGroup:
    """All-ranks readiness gate for one pipeline stage."""

    def __init__(self, stage: int, tp_degree: int = 1, recorder=None,
                 metrics=None):
        self.stage = stage
        self.tp_degree = max(1, tp_degree)
        self.recorder = recorder
        #: per-stage metric shard (:class:`repro.obs.metrics.StageShard`)
        self.metrics = metrics
        #: per-edge rank holds: (task, src_stage) -> {rank: arrival time}.
        #: DAG fan-in stages receive one message per incoming edge for the
        #: same task; each edge's rank set completes independently.
        self._held: dict[tuple[Task, int], dict[int, float]] = {}
        self._admitted: set[tuple[Task, int]] = set()
        self.deferrals = 0
        self.admitted = 0
        self.duplicates = 0

    def was_admitted(self, task: Task, src_stage: int) -> bool:
        return (task, src_stage) in self._admitted

    def offer(self, env: Envelope, now: float) -> Admission | None:
        """Record one rank's copy; return an Admission when the set completes.

        Duplicate deliveries are idempotent at two levels: a repeated rank
        copy is ignored (first arrival wins, matching a receive-side buffer
        that holds the message), and an edge whose rank set already completed
        is never re-admitted — a full set of chaos-duplicated envelopes must
        not re-enqueue an already-buffered task.
        """
        if env.dst_stage != self.stage:
            raise ValueError(
                f"envelope for stage {env.dst_stage} offered to group "
                f"{self.stage}")
        if not 0 <= env.rank < self.tp_degree:
            raise ValueError(f"rank {env.rank} out of range for K={self.tp_degree}")
        key = (env.task, env.src_stage)
        if key in self._admitted:
            self.duplicates += 1
            if self.metrics is not None:
                self.metrics.on_tp_dup()
            self._record(_tr.TP_DUP, env, now, reason="post_admission")
            return None
        holds = self._held.setdefault(key, {})
        if env.rank in holds:
            self.duplicates += 1
            if self.metrics is not None:
                self.metrics.on_tp_dup()
            self._record(_tr.TP_DUP, env, now, reason="rank_held")
            return None
        holds[env.rank] = now
        if len(holds) < self.tp_degree:
            if self.metrics is not None:
                self.metrics.on_tp_hold()
            self._record(_tr.TP_HOLD, env, now,
                         missing=self.tp_degree - len(holds))
            return None
        del self._held[key]
        self._admitted.add(key)
        times = sorted(holds.values())
        spread = times[-1] - times[0]
        if spread > 0:
            self.deferrals += 1
        self.admitted += 1
        if self.metrics is not None:
            self.metrics.on_tp_admit(spread)
        self._record(_tr.TP_ADMIT, env, now, spread=spread)
        return Admission(task=env.task, admit_time=now, spread=spread)

    def _record(self, kind: str, env: Envelope, now: float, **info) -> None:
        if self.recorder is not None:
            self.recorder.record(kind, self.stage, env.task, rank=env.rank,
                                 t=now, src=env.src_stage, **info)

    def pending(self) -> dict[tuple[Task, int], int]:
        """Edges with an incomplete rank set -> number of ranks still missing."""
        return {
            k: self.tp_degree - len(h) for k, h in self._held.items()
        }

    def coordination_cost(self, task: Task, base: float) -> float:
        """Per-dispatch scalar all-gather overhead (F/B only, like the engine)."""
        if self.tp_degree <= 1 or task.kind == Kind.W:
            return 0.0
        return base * (1.0 + math.log2(self.tp_degree))
