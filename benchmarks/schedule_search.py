"""Beyond-paper benchmark: schedule-as-data search on the compiled executor.

For each train cell, price every candidate table (1F1B, GPipe, RRFP from
uniform costs, RRFP from *measured* per-stage op costs) with the static
tick-timing model over the measured per-op rooflines, and report the best —
the TPU materialization of the paper's thesis that schedules should be
consumed flexibly: the winning table is swapped in without recompilation.

    PYTHONPATH=src:. python -m benchmarks.run schedule_search
"""
from __future__ import annotations

import numpy as np

from repro.analysis.roofline import (
    ProductionMeshShape,
    _t,
    per_op_costs,
    roofline_cell,
)
from repro.core.costs import CostModel, JitterModel
from repro.core.synthesis import synthesize
from repro.core.taskgraph import PipelineSpec
from repro.launch.cells import plan_cell
from repro.pipeline import schedules
from repro.pipeline.spec import from_stage_orders

ARCHS = ("deepseek-7b", "granite-34b", "deepseek-moe-16b")


def candidate_tables(spec: PipelineSpec, f: np.ndarray, b: np.ndarray):
    cm = CostModel(f_cost=f, b_cost=b, w_cost=0 * f, comm_base=1e-5,
                   compute_jitter=JitterModel(), comm_jitter=JitterModel())
    yield "1f1b", schedules.one_f_one_b(spec)
    yield "gpipe", schedules.gpipe(spec)
    yield "rrfp-uniform", schedules.rrfp(spec)
    yield "rrfp-measured", from_stage_orders(
        spec, synthesize(spec, cm).stage_orders)


def schedule_search():
    rows = []
    for arch in ARCHS:
        plan = plan_cell(arch, "train_4k", ProductionMeshShape())
        oc = per_op_costs(plan)
        # derive S from the planned cell: first/last-stage adjustments
        # (embed / CE) must land on the plan's actual boundary stages
        S, M = plan.model.num_stages, plan.num_microbatches
        f = np.full(S, _t(oc["F"]))
        b = np.full(S, _t(oc["B"]))
        f[0] = _t(oc["F"], oc["embed"])
        b[0] = _t(oc["B"], oc["embed"], oc["embed"])
        f[-1] = _t(oc["F"], oc["ce"])
        b[-1] = _t(oc["B_last"])
        assert f.shape == b.shape == (S,), (f.shape, b.shape, S)
        spec = PipelineSpec(S, M)
        results = {}
        for name, table in candidate_tables(spec, f, b):
            table.validate()
            r = roofline_cell(arch, "train_4k", table=table, op_costs=oc,
                              schedule=name)
            results[name] = r
        base = results["1f1b"]
        best_name = min(results, key=lambda k: results[k].est_step_s)
        for name, r in results.items():
            tag = " <-best" if name == best_name else ""
            rows.append((
                f"sched/{arch}/{name}",
                r.est_step_s * 1e6,
                f"MFU={r.projected_mfu:.3f}"
                f" vs1f1b={base.est_step_s / r.est_step_s:.2f}x{tag}",
            ))
    return rows
