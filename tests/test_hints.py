"""Focused coverage for core/hints.py (Algorithm 1 / Appendix A).

Complements the engine-level tests: exercises round alternation, the
within-direction priority of ``pick``, BFW's empty-round filling, and the
shared Appendix C drain helper directly.
"""
import pytest

from repro.core.hints import (
    FIXED_ORDERS,
    HintArbiter,
    HintKind,
    backpressure_drain,
    pick,
)
from repro.core.taskgraph import Kind, PipelineSpec, Task


def F(stage, mb, chunk=0):
    return Task(Kind.F, stage, mb, chunk)


def B(stage, mb, chunk=0):
    return Task(Kind.B, stage, mb, chunk)


def W(stage, mb, chunk=0):
    return Task(Kind.W, stage, mb, chunk)


# ---------------------------------------------------------------------------
# pick(): within-direction tie-breaking (App. A)
# ---------------------------------------------------------------------------
class TestPick:
    def test_forward_prefers_smaller_chunk_then_smaller_mb(self):
        ready = [F(0, 2, 1), F(0, 5, 0), F(0, 3, 0)]
        assert pick(ready, Kind.F) == F(0, 3, 0)

    def test_backward_prefers_larger_chunk_then_smaller_mb(self):
        ready = [B(0, 1, 0), B(0, 7, 1), B(0, 4, 1)]
        assert pick(ready, Kind.B) == B(0, 4, 1)

    def test_w_inherits_backward_rule(self):
        ready = [W(0, 3, 0), W(0, 1, 1)]
        assert pick(ready, Kind.W) == W(0, 1, 1)

    def test_empty_direction_returns_none(self):
        assert pick([F(0, 0)], Kind.B) is None
        assert pick([], Kind.F) is None


# ---------------------------------------------------------------------------
# HintArbiter.select(): round alternation
# ---------------------------------------------------------------------------
class TestRoundAlternation:
    def test_bf_rounds(self):
        """BF: each round tries B then F; after dispatching one direction the
        same round's other direction runs next."""
        arb = HintArbiter(HintKind.BF)
        assert arb.select([B(0, 0), F(0, 0)]) == B(0, 0)
        assert arb.select([B(0, 1), F(0, 0)]) == F(0, 0)  # same round: F next
        assert arb.select([B(0, 1), F(0, 1)]) == B(0, 1)  # new round: B first

    def test_fb_rounds(self):
        arb = HintArbiter(HintKind.FB)
        assert arb.select([B(0, 0), F(0, 0)]) == F(0, 0)
        assert arb.select([B(0, 0), F(0, 1)]) == B(0, 0)  # same round: B next
        assert arb.select([B(0, 1), F(0, 1)]) == F(0, 1)  # new round

    def test_alternation_skips_missing_direction_without_blocking(self):
        """A hint ranks ready candidates; it never forces waiting."""
        arb = HintArbiter(HintKind.BF)
        assert arb.select([F(0, 0)]) == F(0, 0)
        assert arb.select([F(0, 1)]) == F(0, 1)  # still no B ready: F again
        assert arb.select([B(0, 0), F(0, 2)]) == B(0, 0)

    def test_priority_hints_have_no_round_state(self):
        arb = HintArbiter(HintKind.B_PRIORITY)
        assert arb.select([B(0, 0), F(0, 0)]) == B(0, 0)
        assert arb.select([B(0, 1), F(0, 0)]) == B(0, 1)  # B again: no rounds
        arb_f = HintArbiter(HintKind.F_PRIORITY)
        assert arb_f.select([B(0, 0), F(0, 0)]) == F(0, 0)
        assert arb_f.select([B(0, 0), F(0, 1)]) == F(0, 1)

    def test_reset_clears_round_state(self):
        arb = HintArbiter(HintKind.BF)
        assert arb.select([B(0, 0), F(0, 0)]) == B(0, 0)
        arb.reset()
        assert arb.select([B(0, 1), F(0, 0)]) == B(0, 1)  # fresh round: B


# ---------------------------------------------------------------------------
# BFW: weight-update tasks fill empty rounds
# ---------------------------------------------------------------------------
class TestBFW:
    def test_w_only_when_no_compute_direction_ready(self):
        arb = HintArbiter(HintKind.BFW)
        assert arb.select([W(0, 0), F(0, 0), B(0, 0)]) == B(0, 0)
        assert arb.select([W(0, 0), F(0, 0)]) == F(0, 0)
        assert arb.select([W(0, 0)]) == W(0, 0)

    def test_w_dispatch_does_not_consume_the_round(self):
        """After a W fills an empty round, the next round still opens with B."""
        arb = HintArbiter(HintKind.BFW)
        assert arb.select([B(0, 0), F(0, 0)]) == B(0, 0)
        assert arb.select([W(0, 0)]) == W(0, 0)  # empty round: W fills
        # last_dir still reflects the B: the interrupted round's F comes next
        assert arb.select([B(0, 1), F(0, 0)]) == F(0, 0)

    def test_w_priority_follows_backward_rule(self):
        arb = HintArbiter(HintKind.BFW)
        assert arb.select([W(0, 2, 0), W(0, 5, 1)]) == W(0, 5, 1)


# ---------------------------------------------------------------------------
# Appendix C drain helper (shared by engine and actor runtime)
# ---------------------------------------------------------------------------
class TestBackpressureDrain:
    def test_non_interleaved_backward_only(self):
        spec = PipelineSpec(2, 4)
        ready = [F(0, 2), B(0, 0), B(0, 1)]
        task, focus = backpressure_drain(spec, 0, ready, set(), 0)
        assert task == B(0, 0) and focus == 0

    def test_non_interleaved_no_backward_ready_waits(self):
        spec = PipelineSpec(2, 4)
        task, _ = backpressure_drain(spec, 0, [F(0, 2)], set(), 0)
        assert task is None

    def test_interleaved_follows_completion_order(self):
        spec = PipelineSpec(2, 2, num_chunks=2)
        done = {F(0, 0, 0)}
        # next required for mb 0 is F chunk 1; it is ready -> dispatched
        task, focus = backpressure_drain(
            spec, 0, [F(0, 0, 1), F(0, 1, 0)], done, 0)
        assert task == F(0, 0, 1) and focus == 0
        # mb 0 fully done -> focus advances to mb 1
        done = {F(0, 0, 0), F(0, 0, 1), B(0, 0, 1), B(0, 0, 0)}
        task, focus = backpressure_drain(spec, 0, [F(0, 1, 0)], done, 0)
        assert task == F(0, 1, 0) and focus == 1

    def test_interleaved_waits_for_required_task(self):
        spec = PipelineSpec(2, 2, num_chunks=2)
        done = {F(0, 0, 0)}
        # required next is F(0,0,1); only mb1 work is ready -> wait
        task, _ = backpressure_drain(spec, 0, [F(0, 1, 0)], done, 0)
        assert task is None


# ---------------------------------------------------------------------------
# Fixed orders registry sanity
# ---------------------------------------------------------------------------
def test_fixed_orders_registry_complete():
    spec = PipelineSpec(4, 6)
    for name in ("gpipe", "1f1b"):
        for s in range(4):
            order = FIXED_ORDERS[name](spec, s)
            assert len(order) == spec.num_tasks_per_stage()
    specw = PipelineSpec(4, 6, split_backward=True)
    for s in range(4):
        assert len(FIXED_ORDERS["zb"](specw, s)) == specw.num_tasks_per_stage()


def test_zb_order_requires_split_backward():
    with pytest.raises(ValueError):
        FIXED_ORDERS["zb"](PipelineSpec(4, 6), 0)
