"""Multimodal DAG sweep: readiness-driven vs. pre-committed fixed order.

The paper's headline claim (up to 2.77× on multimodal workloads) lives in
the regime this benchmark reproduces: branch+fusion DAG pipelines whose
encoder stages are cheap, *variable-length* and misaligned with the
LM-decoder stages.  Two skewed workloads, derived from the registered
full-size multimodal archs via ``repro.multimodal``:

* ``qwen2-vl-2b/vision-variance`` — dynamic-resolution vision branch
  matching the LM chain on mean cost, but with large per-microbatch
  length variance (sigma 0.6) making it the intermittent bottleneck;
* ``seamless-m4t-large-v2/heavy-encoder`` — long audio-frame encoder
  branch dominating a light text decoder.

Methods per (workload, jitter level), all on the actor runtime's
virtual-clock substrate with CRN-keyed sampling (same realized
variability for every mode):

  - ``pre_1f1b``      precommitted depth-generalized 1F1B, fused backward
  - ``pre_modality``  precommitted ``modality_balanced_order`` (the
                      Cornstarch-like cost-aware planner), fused
  - ``pre_zb``        precommitted ZB-H1, split backward
  - ``hint_bf``       readiness-driven BF hint, fused
  - ``hint_bfw``      readiness-driven BFW hint, split backward, capped W

Plus a **real threaded smoke**: both archs reduced, real jitted DAG
stage callables through the thread-per-stage runtime, with conformance
invariants and hint-vs-fixed-order bitwise loss parity checked.

    PYTHONPATH=src python -m benchmarks.multimodal_compare
    REPRO_SMOKE=1 PYTHONPATH=src python -m benchmarks.multimodal_compare

Emits ``BENCH_multimodal.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import INJECTION_LEVELS, HintKind, PipelineSpec
from repro.core.hints import modality_balanced_order
from repro.multimodal import multimodal_config, multimodal_dag_costs
from repro.runtime.rrfp import ActorConfig, average_makespan_actor

S_ENC, S_LM = 3, 4
M = 24
ITERS = 4
W_DEFER_CAP = 4


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_SMOKE"))


def workload_configs() -> dict:
    """The two skewed encoder/decoder workloads (full-size widths)."""
    return {
        # dynamic-resolution multi-image mix: the encoder branch matches the
        # decoder on MEAN cost but its per-microbatch lognormal spread
        # (sigma 0.6 -> 4-5x spikes) makes it the intermittent bottleneck —
        # the §2.1 regime where pre-committed orders serialize on spikes
        "qwen2-vl-2b/vision-variance": multimodal_config(
            "qwen2-vl-2b", enc_stages=S_ENC, lm_stages=S_LM,
            enc_layers_per_stage=4, lm_layers_per_stage=4,
            text_seq=2048, mean_enc_tokens=16384,
            buckets=(8192, 16384, 32768), reduced=False),
        "seamless-m4t-large-v2/heavy-encoder": multimodal_config(
            "seamless-m4t-large-v2", enc_stages=S_ENC, lm_stages=S_LM,
            enc_layers_per_stage=8, lm_layers_per_stage=3,
            text_seq=256, mean_enc_tokens=12288,
            buckets=(8192, 12288, 16384), reduced=False),
    }


def _mean(spec, cm, cfg, iters):
    m, _, _ = average_makespan_actor(spec, cm, cfg, iters)
    return m


def sweep_rows(iters: int = ITERS) -> list[dict]:
    levels = ["J0", "J2"] if _smoke() else list(INJECTION_LEVELS)
    iters = 1 if _smoke() else iters
    microbatches = 8 if _smoke() else M
    out = []
    for wname, mm in workload_configs().items():
        graph = mm.stage_graph()
        fused = PipelineSpec(mm.num_stages, microbatches, graph=graph)
        split = PipelineSpec(mm.num_stages, microbatches,
                             split_backward=True, graph=graph)
        base = multimodal_dag_costs(mm, seed=0)
        mod_orders = [
            modality_balanced_order(fused, s, list(base.f_cost))
            for s in range(mm.num_stages)]
        for level in levels:
            cm_f = dataclasses.replace(base,
                                       injection=INJECTION_LEVELS[level])
            cm_s = cm_f.with_split_backward()
            ms = {
                "pre_1f1b": _mean(fused, cm_f, ActorConfig(
                    mode="precommitted", fixed_order="1f1b"), iters),
                "pre_modality": _mean(fused, cm_f, ActorConfig(
                    mode="precommitted", custom_orders=mod_orders), iters),
                "pre_zb": _mean(split, cm_s, ActorConfig(
                    mode="precommitted", fixed_order="zb"), iters),
                "hint_bf": _mean(fused, cm_f, ActorConfig(
                    mode="hint", hint=HintKind.BF), iters),
                "hint_bfw": _mean(split, cm_s, ActorConfig(
                    mode="hint", hint=HintKind.BFW,
                    w_defer_cap=W_DEFER_CAP), iters),
            }
            best_pre = min(ms["pre_1f1b"], ms["pre_modality"], ms["pre_zb"])
            out.append({
                "workload": wname,
                "modality": mm.modality,
                "level": level,
                "stages": mm.num_stages,
                "graph": [list(e) for e in graph.edges],
                "makespan_s": ms,
                "speedups": {
                    "bfw_vs_1f1b": ms["pre_1f1b"] / ms["hint_bfw"],
                    "bfw_vs_modality": ms["pre_modality"] / ms["hint_bfw"],
                    "bfw_vs_zb": ms["pre_zb"] / ms["hint_bfw"],
                    "bf_vs_1f1b": ms["pre_1f1b"] / ms["hint_bf"],
                    "bfw_vs_best_precommitted": best_pre / ms["hint_bfw"],
                },
            })
    return out


def real_threaded_dag(steps: int = 2) -> dict:
    """Real jitted DAG stage callables through the threaded actor runtime:
    completion, conformance invariants, and bitwise hint-vs-fixed-order
    loss parity (deterministic reduction) on both registered archs."""
    import jax

    from repro.data.synthetic import multimodal_batch
    from repro.multimodal import (
        MultimodalStageFns, MultimodalStageProgram, multimodal_model)
    from repro.multimodal.stagefn import MultimodalStageOptions
    from repro.runtime.rrfp import ActorDriver
    from repro.runtime.rrfp.conformance import check_all

    out = {}
    for arch in ("qwen2-vl-2b", "seamless-m4t-large-v2"):
        model = multimodal_model(
            arch, enc_stages=2, lm_stages=2, enc_layers_per_stage=1,
            lm_layers_per_stage=1, text_seq=16, fusion_slots=4,
            mean_enc_tokens=14, buckets=(8, 16, 24))
        cfg = model.cfg
        mm, rows = 4, 1
        params = model.init_stage_params(jax.random.key(0))
        fns = MultimodalStageFns(model, MultimodalStageOptions(
            mb_rows=rows, loss_scale=1.0 / (mm * rows * 16)))

        def run(mode: str, step: int):
            batch = multimodal_batch(cfg, mm, rows, seed=0, step=step)
            progs = [
                MultimodalStageProgram(fns, s, params[s], batch,
                                       deterministic_reduction=True)
                for s in range(cfg.num_stages)
            ]
            spec = cfg.spec(mm)
            acfg = ActorConfig(mode=mode, hint=HintKind.BF,
                               fixed_order="1f1b", deadlock_timeout=300.0,
                               record_trace=True)
            res = ActorDriver(spec, None, acfg).run_threaded(list(progs))
            check_all(res.trace, spec, acfg)
            assert len(res.end) == spec.total_tasks()
            for p in progs:
                p.finalize()
            loss = float(sum(p.loss_acc for p in progs))
            return loss, res.makespan * 1e3

        losses_h, losses_p, step_ms = [], [], []
        for step in range(steps):
            lh, msh = run("hint", step)
            lp, _ = run("precommitted", step)
            assert np.float32(lh).tobytes() == np.float32(lp).tobytes(), (
                f"{arch}: hint loss bits diverged from fixed order")
            losses_h.append(lh)
            losses_p.append(lp)
            step_ms.append(msh)
        out[arch] = {
            "stages": cfg.num_stages,
            "graph": [list(e) for e in cfg.stage_graph().edges],
            "tasks": cfg.spec(mm).total_tasks(),
            "loss": losses_h,
            "step_ms": step_ms,
            "loss_parity_vs_fixed_order": True,
            "conformance": True,
        }
    return out


def run_multimodal_benchmark() -> dict:
    rows = sweep_rows()
    jittered = [r for r in rows if r["level"] != "J0"]
    wins = all(r["speedups"]["bfw_vs_best_precommitted"] > 1.0
               for r in jittered)
    per_workload = {}
    for r in jittered:
        per_workload.setdefault(r["workload"], []).append(
            r["speedups"]["bfw_vs_best_precommitted"])
    return {
        "spec": {"enc_stages": S_ENC, "lm_stages": S_LM,
                 "microbatches": 8 if _smoke() else M,
                 "iters": 1 if _smoke() else ITERS,
                 "w_defer_cap": W_DEFER_CAP, "smoke": _smoke()},
        "sweep": rows,
        "real_threaded": real_threaded_dag(),
        "summary": {
            "hint_beats_best_precommitted_on_all_jittered_cells": wins,
            "mean_speedup_vs_best_precommitted_per_workload": {
                w: float(np.mean(v)) for w, v in per_workload.items()},
        },
    }


def emit_json(path: str = "BENCH_multimodal.json") -> dict:
    report = run_multimodal_benchmark()
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def multimodal_rows(
    json_path: str = "BENCH_multimodal.json",
) -> list[tuple[str, float, str]]:
    """CSV rows for ``benchmarks.run`` (name, us_per_call, derived)."""
    report = emit_json(json_path)
    out = []
    for r in report["sweep"]:
        tag = f"multimodal/{r['workload']}/{r['level']}"
        ms, sp = r["makespan_s"], r["speedups"]
        out.append((f"{tag}/hint-bfw", ms["hint_bfw"] * 1e6,
                    f"vs_best_pre={sp['bfw_vs_best_precommitted']:.2f}x"))
        out.append((f"{tag}/pre-modality", ms["pre_modality"] * 1e6,
                    f"vs_1f1b={sp['bfw_vs_1f1b']:.2f}x"))
    for arch, rt in report["real_threaded"].items():
        out.append((f"multimodal/real-threaded/{arch}",
                    float(np.mean(rt["step_ms"])) * 1e3,
                    f"loss_parity={rt['loss_parity_vs_fixed_order']}"))
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in multimodal_rows():
        print(f"{name},{us:.1f},{derived}")
