"""DES cost models of the branch+fusion topology (simulation substrate).

Maps a :class:`~repro.multimodal.model.MultimodalConfig` onto per-stage
F/B/W costs for the engine/actor simulation substrate, with the
per-microbatch skew drawn from the *same* shared length sampler that
generates the real variable-length batches (``repro.data.lengths``):
encoder-branch stage cost scales with the sampled token count of the
microbatch, decoder-chain cost barely moves.  This is the §2.1 workload
dynamicity that makes fixed-order consumption pay its price on
multimodal pipelines.
"""
from __future__ import annotations

import numpy as np

from repro.core.costs import CostModel, JitterModel
from repro.data.lengths import TEXT_SIGMA, length_skew
from repro.multimodal.model import MultimodalConfig

#: nominal chip throughput for turning FLOPs into seconds (RTX-4090-class,
#: matching benchmarks/workloads.py)
CHIP_FLOPS = 165e12 * 0.35


def _layer_flops(d_model: int, d_ff: int, tokens: int) -> float:
    """Forward FLOPs of one pre-norm transformer layer (per sample)."""
    attn = 4 * d_model * d_model  # qkvo projections
    ffn = 3 * d_model * d_ff      # glu
    return 2.0 * (attn + ffn) * tokens


def multimodal_dag_costs(
    cfg: MultimodalConfig,
    *,
    mb_rows: int = 1,
    seed: int = 0,
    num_mb_skew: int = 64,
    comm_base: float = 2e-3,
) -> CostModel:
    """Per-stage cost model of ``cfg``'s DAG pipeline.

    Encoder stages process ``mean_enc_tokens`` at width ``d_enc``; the
    text stage and LM chain process ``text_seq`` / ``fused_seq`` tokens at
    ``d_model``; the sink additionally pays the vocab head.  Per-microbatch
    skew: encoder stages follow the modality length distribution
    (correlated across the branch — the same sample's tokens), decoder
    stages the residual text spread.
    """
    S = cfg.num_stages
    enc_ff = cfg.enc_cfg.d_ff
    lm_ff = cfg.lm_cfg.d_ff
    flops = np.zeros(S)
    for s in range(S):
        role = cfg.role_of(s)
        if role == "encoder":
            flops[s] = cfg.enc_layers_per_stage * _layer_flops(
                cfg.d_enc, enc_ff, cfg.mean_enc_tokens)
        elif role == "text":
            flops[s] = cfg.lm_layers_per_stage * _layer_flops(
                cfg.d_model, lm_ff, cfg.text_seq)
        else:  # fusion / lm
            flops[s] = cfg.lm_layers_per_stage * _layer_flops(
                cfg.d_model, lm_ff, cfg.fused_seq)
    # vocab head + CE live on the sink (the Fig. 6 last-stage dominance)
    flops[S - 1] += 2.0 * cfg.d_model * cfg.vocab_size * cfg.text_seq
    flops *= mb_rows

    rng = np.random.default_rng(seed)
    per_mb_enc = length_skew(num_mb_skew, cfg.enc_sigma, rng)
    per_mb_lm = length_skew(num_mb_skew, TEXT_SIGMA, rng)
    skew = np.ones((S, num_mb_skew))
    for s in range(S):
        skew[s] = per_mb_enc if cfg.role_of(s) == "encoder" else per_mb_lm

    return CostModel.from_stage_flops(
        flops, chip_flops=CHIP_FLOPS, efficiency=1.0,
        comm_base=comm_base, mb_skew=skew, seed=seed,
        comm_jitter=JitterModel(sigma=0.35, spike_prob=0.03,
                                spike_scale=20.0))
