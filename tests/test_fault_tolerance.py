"""Fault-tolerance integration: crash/restart continuity and elastic re-mesh
restore (the 1000-node runbook, exercised at reduced scale)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.ckpt.store import CheckpointStore
from repro.configs import registry
from repro.models.build import build
from repro.runtime.elastic import plan_remesh, relayout_stage_params


def _run_train(tmp, steps, resume=False, extra=()):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "deepseek-7b",
           "--devices", "8", "--stages", "4", "--layers", "8",
           "--seq", "64", "--microbatches", "4", "--schedule", "rrfp",
           "--steps", str(steps), "--ckpt-dir", str(tmp), "--ckpt-every", "4",
           *extra]
    if resume:
        cmd.append("--resume")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
    return {
        int(line.split()[1]): float(line.split("loss")[1].split()[0])
        for line in r.stdout.splitlines() if line.startswith("step")
    }


def test_crash_restart_resumes_identically(tmp_path):
    """Train 8 steps straight vs 4-steps-crash-resume-4: identical losses.

    Proves checkpoint + deterministic data stream give exact continuity —
    the property node-failure recovery relies on.
    """
    full = _run_train(tmp_path / "a", 8)
    part1 = _run_train(tmp_path / "b", 4)
    part2 = _run_train(tmp_path / "b", 8, resume=True)
    for s in (4, 5, 6, 7):
        assert s in part2
        np.testing.assert_allclose(part2[s], full[s], rtol=1e-4), (s, part2, full)


def test_elastic_remesh_roundtrip(tmp_path):
    """Checkpoint on a 4-stage layout, re-mesh to 2 stages, verify the model
    computes the same function (stage relayout preserves every layer)."""
    import jax.numpy as jnp

    cfg = registry.reduced_config("deepseek-7b", num_layers=6)
    m4 = build(cfg, num_stages=4)
    key = jax.random.key(0)
    sp4 = m4.init_stage_params(key)
    io = m4.init_io_params(jax.random.fold_in(key, 1))
    store = CheckpointStore(str(tmp_path))
    store.save(1, {"sp": sp4, "io": io}, meta={"stages": 4})
    restored, meta = store.restore(1, {"sp": sp4, "io": io})
    assert meta["stages"] == 4

    m2, sp2 = relayout_stage_params(
        m4, 2, jax.tree.map(np.asarray, restored["sp"]))
    batch = {"tokens": jax.random.randint(jax.random.key(2), (2, 16), 0,
                                          cfg.vocab_size)}
    aux = {"positions": jnp.broadcast_to(jnp.arange(16)[None], (2, 16)),
           "data_size": 1, "moe_layout": "none"}
    y4 = m4.reference_forward(restored["sp"], io, batch, aux)
    y2 = m2.reference_forward(jax.tree.map(jnp.asarray, sp2), io, batch, aux)
    np.testing.assert_allclose(np.asarray(y4, np.float32),
                               np.asarray(y2, np.float32), atol=2e-4)


def test_shrink_restore_regrow_restore_bitwise_roundtrip(tmp_path):
    """Shrink -> restore -> regrow -> restore is *bitwise* lossless.

    The elastic path a recovery takes when capacity drops and later returns:
    checkpoint on 4 stages, re-layout to 2 (shrink), checkpoint, re-layout
    back to 4 (regrow), checkpoint — then restore the final checkpoint on
    the host and require every *live layer slot* equal the original *bit
    for bit* (``np.array_equal`` on host arrays, no tolerance; padding
    slots — stage/layer positions with no layer assigned — carry no model
    state and are zeroed by re-layout).  Stage re-layout is a pure
    permutation of per-layer slots, so any drift in a live slot would mean
    the relayout or the store corrupted a value."""
    from repro.models.common import global_layer_index
    cfg = registry.reduced_config("deepseek-7b", num_layers=6)
    m4 = build(cfg, num_stages=4)
    sp4 = jax.tree.map(np.asarray, m4.init_stage_params(jax.random.key(3)))
    store = CheckpointStore(str(tmp_path))

    store.save(1, {"sp": sp4}, meta={"stages": 4})
    host1, meta1 = store.restore_host(1, {"sp": sp4})
    assert meta1["stages"] == 4

    m2, sp2 = relayout_stage_params(m4, 2, host1["sp"])  # shrink
    store.save(2, {"sp": sp2}, meta={"stages": 2})
    host2, meta2 = store.restore_host(2, {"sp": sp2})
    assert meta2["stages"] == 2

    m4b, sp4b = relayout_stage_params(m2, 4, host2["sp"])  # regrow
    store.save(3, {"sp": sp4b}, meta={"stages": 4})
    host3, _ = store.restore_host(3, {"sp": sp4b})

    live = global_layer_index(m4.counts) >= 0  # [S, l_max] live-slot mask
    orig = jax.tree.leaves(sp4)
    back = jax.tree.leaves(host3["sp"])
    assert len(orig) == len(back)
    for a, b in zip(orig, back):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.array_equal(a[live], b[live]), (
            "round-trip changed live-layer parameter bits")


def test_remesh_plans_degrade_gracefully():
    """Losing nodes still yields a runnable grid; pipeline depth prefers 16."""
    assert plan_remesh(512, prefer_model=16).devices == 512
    for alive in (256, 255, 240, 128, 17):
        p = plan_remesh(alive)
        assert p.devices <= alive
        assert p.devices >= alive // 2  # never waste more than half
