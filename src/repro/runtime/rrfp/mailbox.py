"""Per-stage mailbox: message intake + per-kind ready buffers (§4.1).

The mailbox is the only shared state between a stage actor and the transport
that feeds it, so it is fully thread-safe (one lock + condition per mailbox).
Incoming envelopes pass the TP-group admission gate; admitted tasks land in
per-kind *arrival buffers* — the host analog of the paper's four per-stage
message buffers — in FIFO arrival order.  The actor consumes them under the
same lock when it arbitrates.

In simulation mode the driver calls ``deliver`` from the virtual-clock pump
(single thread, the lock is uncontended); in thread mode each sender's actor
thread calls it concurrently.

With a :class:`~repro.runtime.rrfp.trace.TraceRecorder` attached, every
delivery, admission (enqueue) and consumption (dequeue) is logged with the
logical clock — the record side of record/replay.
"""
from __future__ import annotations

import threading
import time as _time

from repro.core.taskgraph import Kind, Task

from repro.runtime.rrfp import trace as _tr
from repro.runtime.rrfp.messages import Envelope
from repro.runtime.rrfp.tp_group import Admission, TPGroup


class Mailbox:
    """Arrival buffers for one stage actor.

    ``fan_in`` (usually ``PipelineSpec.fan_in``) tells the mailbox how many
    distinct source-stage messages a task needs before it is buffered.  On a
    chain that is always 1; on a DAG a fan-in stage's task is buffered only
    once *every* incoming edge has passed the TP admission gate.
    """

    def __init__(self, stage: int, tp_degree: int = 1, recorder=None,
                 fan_in=None, metrics=None):
        self.stage = stage
        self.recorder = recorder
        #: per-stage metric shard (:class:`repro.obs.metrics.StageShard`);
        #: written only under ``cond`` (or the sim pump), never contended
        self.metrics = metrics
        self.fan_in = fan_in or (lambda task: 1)
        self.group = TPGroup(stage, tp_degree, recorder=recorder,
                             metrics=metrics)
        self.cond = threading.Condition()
        #: admitted-but-unconsumed arrivals, FIFO per kind
        self.buffers: dict[Kind, list[Task]] = {k: [] for k in Kind}
        #: tasks buffered since the consumer's last ``drain_arrivals`` (in
        #: admission order): the hand-off that lets ``sync_mailbox`` stop
        #: rescanning already-seen envelopes
        self._fresh: list[Task] = []
        #: admitted payloads per task, keyed by source stage (thread mode)
        self.payloads: dict[Task, dict[int, object]] = {}
        #: source stages whose edge for a task has been TP-admitted
        self._edges: dict[Task, set[int]] = {}
        self.stopped = False
        #: minimum acceptable envelope epoch.  A respawned stage's mailbox
        #: starts at the post-recovery epoch, so any pre-failure straggler
        #: (epoch < this) is *fenced*: dropped before the TP gate, never
        #: admitted.  Survivor mailboxes keep their incarnation's epoch and
        #: still accept in-flight messages from before the failure.
        self.epoch = 0
        #: fenced-envelope count (diagnostics / property tests)
        self.fenced = 0
        #: monotonic wall time of the last admission/consumption (thread-mode
        #: starvation detection)
        self.last_progress = _time.monotonic()
        self.high_water = {k: 0 for k in Kind}

    # ---- producer side ----------------------------------------------------
    def deliver(self, env: Envelope, now: float = 0.0) -> Admission | None:
        """Offer one envelope; buffer the task once its full message set
        (all TP ranks × all fan-in edges) is admitted.  Returns the *edge*
        admission (or None), so callers poke the actor only on progress.

        Envelopes from a recovery epoch older than the mailbox's are fenced
        (dropped, recorded as FENCE) — the total-fencing guarantee that
        makes a respawned incarnation's state independent of pre-failure
        stragglers still in flight."""
        with self.cond:
            # reliable-transport envelopes stamp their per-edge sequence into
            # the record (conformance's check_reliable_delivery keys on it);
            # omitted when -1 so pre-reliable traces stay byte-identical
            rel = {"eseq": env.eseq} if env.eseq >= 0 else {}
            if env.epoch < self.epoch:
                self.fenced += 1
                if self.recorder is not None:
                    self.recorder.record(_tr.FENCE, self.stage, env.task,
                                         rank=env.rank, t=now, seq=env.seq,
                                         src=env.src_stage,
                                         env_epoch=env.epoch,
                                         mailbox_epoch=self.epoch, **rel)
                return None
            if self.recorder is not None:
                self.recorder.record(_tr.DELIVER, self.stage, env.task,
                                     rank=env.rank, t=now, seq=env.seq,
                                     src=env.src_stage, **rel)
            adm = self.group.offer(env, now)
            # Late duplicates of an already-admitted message must not re-stash
            # a payload the consumer has already popped (or never will pop).
            fresh = adm is not None or not self.group.was_admitted(
                env.task, env.src_stage)
            if env.payload is not None and fresh:
                self.payloads.setdefault(env.task, {})[env.src_stage] = \
                    env.payload
            if adm is not None:
                srcs = self._edges.setdefault(env.task, set())
                srcs.add(env.src_stage)
                need = self.fan_in(env.task)
                if len(srcs) < need:
                    # fan-in edge admitted, task still waiting on a branch
                    self.last_progress = _time.monotonic()
                    if self.metrics is not None:
                        self.metrics.on_fanin_hold()
                    if self.recorder is not None:
                        self.recorder.record(
                            _tr.FANIN_HOLD, self.stage, env.task, t=now,
                            src=env.src_stage, missing=need - len(srcs))
                    return adm
                del self._edges[env.task]
                buf = self.buffers[adm.task.kind]
                buf.append(adm.task)
                self._fresh.append(adm.task)
                self.high_water[adm.task.kind] = max(
                    self.high_water[adm.task.kind], len(buf))
                self.last_progress = _time.monotonic()
                if self.metrics is not None:
                    # fused enqueue + transport-latency sample (the latency
                    # of the envelope that completed the message set)
                    self.metrics.on_admitted(adm.task.kind, len(buf),
                                             now - env.send_time)
                if self.recorder is not None:
                    self.recorder.record(_tr.ENQUEUE, self.stage, adm.task,
                                         t=now, src="message")
                self.cond.notify_all()
            return adm

    def deliver_local(self, task: Task, now: float = 0.0) -> None:
        """Buffer a task whose input is locally produced (no message needed):
        stage-0/chunk-0 forwards at iteration start, and the last stage's
        loss gradient."""
        with self.cond:
            self.buffers[task.kind].append(task)
            self._fresh.append(task)
            self.high_water[task.kind] = max(
                self.high_water[task.kind], len(self.buffers[task.kind]))
            self.last_progress = _time.monotonic()
            if self.metrics is not None:
                self.metrics.on_enqueue(task.kind,
                                        len(self.buffers[task.kind]))
            if self.recorder is not None:
                self.recorder.record(_tr.ENQUEUE, self.stage, task, t=now,
                                     src="local")
            self.cond.notify_all()

    def touch(self) -> None:
        """Record actor progress (call under ``cond``).  Task completions
        count against starvation even when no message moved — e.g. a stage
        draining locally-enabled W tasks never touches its buffers but is
        anything but starved."""
        self.last_progress = _time.monotonic()

    def stop(self) -> None:
        """Shut the mailbox down and wake *every* waiter.

        With event-driven actor wakeups there is no poll period to fall
        back on: a blocked actor only wakes on a notify (or its distant
        starvation deadline), so ``notify_all`` here is what makes actor
        threads exit promptly on shutdown/abort."""
        with self.cond:
            self.stopped = True
            self.cond.notify_all()

    # ---- consumer side (call under ``cond``) ------------------------------
    def arrived_tasks(self) -> list[Task]:
        """All buffered tasks in FIFO-per-kind order (F, B, W).

        Diagnostic/test view; the consumer hot path uses
        :meth:`drain_arrivals` so each sync touches only new admissions."""
        out: list[Task] = []
        for k in Kind:
            out.extend(self.buffers[k])
        return out

    def drain_arrivals(self) -> list[Task]:
        """Tasks buffered since the last drain, in admission order.

        The actor's ``sync_mailbox`` memory (its ``arrived`` set) persists
        across drains, so handing each admission over exactly once is
        sufficient — and turns per-sync cost from O(buffered) rescans into
        O(new)."""
        out = self._fresh
        if out:
            self._fresh = []
        return out

    def consume(self, task: Task, now: float = 0.0) -> object:
        """Remove a dispatched task from its buffer; return its payload.

        Single-predecessor tasks get the raw payload (chain behavior);
        fan-in tasks get a ``{src_stage: payload}`` dict — one entry per
        incoming edge — which the stage program routes to its inputs.
        """
        self.buffers[task.kind].remove(task)
        self.last_progress = _time.monotonic()
        if self.metrics is not None:
            self.metrics.on_dequeue(task.kind)
        if self.recorder is not None:
            self.recorder.record(_tr.DEQUEUE, self.stage, task, t=now)
        by_src = self.payloads.pop(task, None)
        if by_src is None:
            return None
        if self.fan_in(task) <= 1:
            return next(iter(by_src.values()))
        return by_src

    def wait_for_work(self, timeout: float | None = None) -> bool:
        """Block until new work arrives or ``stop``; False on timeout."""
        return self.cond.wait(timeout)

    def starved_for(self) -> float:
        """Seconds since the mailbox last made progress (thread mode)."""
        return _time.monotonic() - self.last_progress
