"""Deterministic fallback for ``hypothesis`` when it is not installed.

The tier-1 environment does not always ship ``hypothesis``; importing it at
module scope used to kill collection of three test modules (and, under
``-x``, the whole run).  Test modules now do

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hyp_stub import given, settings, strategies as st

This stub implements the tiny subset the suite uses (``integers``,
``sampled_from``, ``@given``, ``@settings``) by drawing a fixed number of
examples from a seeded RNG, so the property tests still execute —
deterministically — instead of being skipped wholesale.  It does no
shrinking and no database; it is a smoke-level stand-in, not a replacement.
"""
from __future__ import annotations

import os
import zlib

import numpy as np

#: Cap on examples per property when running under the stub (real hypothesis
#: honours the test's own ``max_examples``).  Override via env for CI.
STUB_MAX_EXAMPLES = int(os.environ.get("HYP_STUB_MAX_EXAMPLES", "8"))


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))


strategies = _Strategies()
st = strategies


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = min(max_examples, STUB_MAX_EXAMPLES)
        return fn

    return deco


def given(**strats):
    def deco(fn):
        def wrapper(*args):
            n = getattr(wrapper, "_stub_max_examples", STUB_MAX_EXAMPLES)
            # Seed from the test's qualified name: stable across runs and
            # independent of execution order.
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                kwargs = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {i}: {kwargs}"
                    ) from e

        # Copy identity but NOT __wrapped__: pytest must see the zero-arg
        # signature, or it mistakes property arguments for fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
