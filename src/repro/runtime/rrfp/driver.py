"""Actor-runtime driver: builds the actors, pumps messages, records traces.

Two execution substrates behind one configuration:

* ``run()`` — :class:`~repro.runtime.rrfp.transport.SimTransport` on a
  virtual clock.  Arrivals and completions are heap events; actors make
  every dispatch decision reactively (no schedule-table tick).  Compute and
  communication samples are keyed per task (common random numbers), so hint
  vs. precommitted runs on the same seed experience the same realized
  variability — the paper's one-schedule-two-consumption-modes contrast
  isolated from sampling noise.

* ``run_threaded(work_fn)`` — thread-per-stage actors over the
  :class:`~repro.runtime.rrfp.transport.ThreadTransport`, executing real
  work callables (e.g. jitted stage functions from
  ``repro.pipeline.stagefn``) on the wall clock.

Both return the DES engine's :class:`~repro.core.engine.RunResult`, so
``benchmarks/``, the Theorem 6.1 bound checker and
``runtime.straggler`` consume actor traces unchanged.

Record / chaos / replay (the conformance machinery):

* ``ActorConfig.record_trace`` threads a
  :class:`~repro.runtime.rrfp.trace.TraceRecorder` through every mailbox,
  TP gate, transport and actor; after a run the full event log is on
  ``driver.trace`` (and ``RunResult.trace``).
* ``ActorConfig.chaos`` plugs a :class:`~repro.runtime.rrfp.chaos.ChaosEngine`
  into the delivery and compute paths of both substrates: per-edge latency,
  message reorder/duplication, stage stragglers and transient stalls, all
  CRN-keyed so the same scenario hits every consumption mode identically.
* ``ActorConfig.replay`` re-executes a recorded trace.  On the sim
  substrate replay is *time-exact*: a
  :class:`~repro.runtime.rrfp.trace.ReplayOracle` substitutes the recorded
  delivery times and task durations for every sample, so the event heap
  evolves identically and the replayed trace is bit-for-bit the recorded
  one.  On the thread substrate replay is *order-exact*: the recorded
  per-stage dispatch orders are consumed as a pre-committed schedule, which
  pins the floating-point reduction order and therefore the loss/grad bits.

Elastic fault recovery (``ActorConfig.recover``):

A chaos ``kill`` / ``permanent_stall`` fault becomes a *recoverable event*
instead of a dead run.  The driver detects the death (heartbeat deadline on
the sim virtual clock; a died thread or a stale execution heartbeat on the
thread substrate), then a recovery coordinator: (1) bumps the recovery
*epoch* and fences the failed stage's mailbox — any pre-failure straggler
still in flight is dropped, never admitted; (2) respawns the stage (or
re-maps it onto a surviving neighbor's device,
``recovery_mode="remap"``, feasibility-checked by
:func:`repro.runtime.elastic.plan_remesh`); (3) restores the stage's
progress — on the sim substrate from the recorded completion set ("replay
from trace", modeled restore latency ``restore_cost``), on the thread
substrate by full re-execution with state rebuilt via ``respawn`` (e.g.
params from :class:`repro.ckpt.store.CheckpointStore`); and (4) replays the
in-flight microbatches destined to the dead stage from the send log, tagged
with the new epoch.  Exactly-once is preserved end to end: re-sent messages
are idempotently dropped by the TP gate, re-executed contributions
overwrite their per-task slot, and the conformance suite checks the
resulting trace (``check_recovery_exactly_once``).  Without ``recover``,
the fault is promoted to a fail-fast
:class:`~repro.runtime.rrfp.chaos.StageFailure`.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import zlib
from typing import Any, Callable

import numpy as np

from repro.core.costs import CostModel
from repro.core.engine import DeadlockError, RunResult, StageStats
from repro.core.hints import FIXED_ORDERS, HintKind
from repro.core.taskgraph import Kind, PipelineSpec, Task

from repro.runtime.rrfp import trace as _tr
from repro.runtime.rrfp.actor import StageActor
from repro.runtime.rrfp.chaos import (
    ChaosConfig,
    ChaosEngine,
    ChaosThreadTransport,
    StageFailure,
)
from repro.runtime.rrfp.mailbox import Mailbox
from repro.runtime.rrfp.messages import Envelope, envelopes_for, reset_seq
from repro.runtime.rrfp.trace import ReplayOracle, Trace, TraceRecorder
from repro.runtime.rrfp.transport import (
    ReliableChannel,
    ReliableConfig,
    ReliableThreadTransport,
    SimTransport,
    ThreadTransport,
    rng_for,
)


class _StageDeath(Exception):
    """Internal thread-substrate signal: the chaos layer killed this stage.

    Distinct from user-code exceptions so the runner can route it to the
    recovery coordinator (``ActorConfig.recover``) or promote it to
    :class:`StageFailure` instead of the generic abort path."""

    def __init__(self, stage: int, fail_kind: str, task: Task | None = None,
                 t_fail: float = 0.0):
        self.stage = stage
        self.fail_kind = fail_kind
        self.task = task
        self.t_fail = t_fail
        super().__init__(f"stage {stage} died ({fail_kind})")


@dataclasses.dataclass
class ActorConfig:
    """Runtime configuration (mirrors ``EngineConfig`` where they overlap)."""

    mode: str = "hint"  # "hint" (RRFP) | "precommitted" (fixed-order baselines)
    hint: HintKind = HintKind.BF
    fixed_order: str = "1f1b"  # precommitted mode: key into FIXED_ORDERS
    custom_orders: list[list[Task]] | None = None  # overrides fixed_order
    buffer_limit: int = 32  # App. C backpressure limit
    #: BFW: max outstanding un-executed W tasks per stage (each holds one
    #: stashed (x, g_in) activation pair); 0 = unbounded deferral
    w_defer_cap: int = 0
    tp_degree: int = 1
    tp_coord_base: float = 75e-6  # scalar all-gather cost (Table 3)
    seed: int = 0
    #: thread mode: seconds of mailbox starvation before DeadlockError
    deadlock_timeout: float = 30.0
    #: fault injection scenario (None = no chaos)
    chaos: ChaosConfig | None = None
    #: reliable-delivery layer (per-edge sequence numbers, checksums,
    #: ACK/NACK, CRN-keyed retransmission, receiver-side dedup).  Required
    #: whenever the chaos scenario is *lossy* (drop/corrupt/partition):
    #: without retransmission a dropped message is a silent deadlock.
    reliable: ReliableConfig | None = None
    #: record a structured event trace (driver.trace / RunResult.trace)
    record_trace: bool = False
    #: re-execute a recorded trace (time-exact on sim, order-exact threaded)
    replay: Trace | None = None
    #: record full sorted ready-set snapshots on every dispatch instead of
    #: the cheap incremental diff encoding (``Trace.ready_sets()`` decodes
    #: both) — opt-in, for human-readable traces
    trace_full_ready: bool = False
    #: verification/benchmark knob: arbitrate via the reference
    #: sort-then-rank path instead of the incremental ReadySet index
    #: (decision-identical by construction; only per-decision cost differs)
    reference_arbitration: bool = False
    #: observability: a :class:`repro.obs.metrics.MetricsRegistry` whose
    #: per-stage shards the runtime feeds (None = zero-cost).  Reuse one
    #: registry across steps to accumulate and keep cost EWMAs warm.
    #: Metrics never alter scheduling decisions (CI's paired-trace check);
    #: with a recorder also attached they add info annotations (e.g.
    #: ``ewma`` on COMPLETE) that replay tolerates.
    metrics: Any | None = None
    #: ---- elastic fault recovery ----------------------------------------
    #: arm the recovery coordinator: chaos kill/permanent_stall faults are
    #: survived (quiesce -> respawn/re-map -> restore -> replay) instead of
    #: raising :class:`~repro.runtime.rrfp.chaos.StageFailure`
    recover: bool = False
    #: heartbeat deadline, in substrate time (virtual seconds on sim, wall
    #: seconds on threads): how long a stage may be silent before the
    #: coordinator declares it dead — the detection-latency half of MTTR
    hb_deadline: float = 5e-3
    #: sim substrate: modeled virtual-time cost of restoring the respawned
    #: stage's params/optimizer from the last committed checkpoint — the
    #: restore half of MTTR
    restore_cost: float = 1e-3
    #: "respawn" = fresh actor on the failed stage's own device;
    #: "remap" = no spare device — the stage re-hosts on a surviving
    #: neighbor (repro.runtime.elastic.remap_stages) and the pair
    #: time-share it (sim substrate)
    recovery_mode: str = "respawn"
    #: thread substrate: ``respawn(stage) -> work_fn`` rebuilds the dead
    #: stage's program (e.g. params restored via CheckpointStore); None
    #: reuses the original work_fn (stateless programs)
    respawn: Callable[[int], Any] | None = None
    #: an :class:`repro.runtime.adaptive.AdaptiveScheduler` (or None): on an
    #: elastic re-map the driver calls ``note_remap(host_of)`` and, if the
    #: re-synthesized table prices better on the degraded topology, hot-swaps
    #: it into every live actor (recorded as HINT_SWAP events)
    adaptive: Any | None = None
    #: ---- adaptive scheduling (schedules are data; docs/adaptive.md) -----
    #: hint-mode rank table: per-stage synthesized orders consumed as a
    #: *non-binding* priority table from t=0 (dispatch path "table").
    #: Replaces the directional hint without recompilation.
    hint_table: list[list[Task]] | None = None
    #: version stamp of hint_table (bumped by the adaptive re-synthesizer
    #: across iteration-boundary swaps; recorded in trace meta)
    hint_table_version: int = 0
    #: mid-run hot-swap target: per-stage orders every live stage adopts
    #: at its quiesce point, recorded as HINT_SWAP trace events
    swap_table: list[list[Task]] | None = None
    #: sim substrate: virtual time of the swap (a dedicated heap event)
    swap_at: float | None = None
    #: thread substrate: per-stage completion count triggering the swap
    swap_after: int | None = None


def _compute_rng(seed: int, task: Task) -> np.random.Generator:
    return np.random.default_rng(
        [seed & 0x7FFFFFFF, zlib.crc32(b"rrfp-compute"),
         int(task.kind), task.stage, task.mb, task.chunk])


class ActorDriver:
    """One training iteration through the actor runtime."""

    def __init__(self, spec: PipelineSpec, costs: CostModel | None,
                 config: ActorConfig):
        if costs is not None and costs.num_stages != spec.num_stages:
            raise ValueError("cost model / spec stage mismatch")
        if (spec.split_backward and config.mode == "hint"
                and config.replay is None
                and config.hint != HintKind.BFW
                and config.hint_table is None):
            raise ValueError(
                f"hint mode on a split-backward spec requires HintKind.BFW "
                f"(got {config.hint}): only the BFW hint dispatches W tasks")
        for name in ("hint_table", "swap_table"):
            tbl = getattr(config, name)
            if tbl is not None and len(tbl) != spec.num_stages:
                raise ValueError(
                    f"{name} has {len(tbl)} stage orders for a "
                    f"{spec.num_stages}-stage spec")
        if (config.swap_table is not None and config.replay is None
                and config.swap_at is None and config.swap_after is None):
            raise ValueError(
                "swap_table needs a quiesce trigger: swap_at (sim virtual "
                "time) or swap_after (thread per-stage completion count)")
        if (config.chaos is not None and config.chaos.lossy()
                and config.reliable is None and config.replay is None):
            raise ValueError(
                "lossy chaos (drop_prob/corrupt_prob/partitions) requires "
                "ActorConfig.reliable: without retransmission a dropped "
                "message is a silent deadlock, not a detectable fault")
        self.spec = spec
        self.costs = costs
        self.config = config
        #: event log of the last run (when record_trace was set)
        self.trace: Trace | None = None

    # ------------------------------------------------------------------
    def _meta(self, cfg: ActorConfig, substrate: str) -> dict:
        spec = self.spec
        return {
            "substrate": substrate,
            "mode": cfg.mode,
            "hint": cfg.hint.value,
            "fixed_order": cfg.fixed_order,
            "buffer_limit": cfg.buffer_limit,
            "w_defer_cap": cfg.w_defer_cap,
            "tp_degree": cfg.tp_degree,
            "seed": cfg.seed,
            "num_stages": spec.num_stages,
            "num_microbatches": spec.num_microbatches,
            "num_chunks": spec.num_chunks,
            "split_backward": spec.split_backward,
            "graph": ([list(e) for e in spec.graph.edges]
                      if spec.graph is not None else None),
            "chaos": cfg.chaos.to_json() if cfg.chaos is not None else None,
            "trace_ready": "full" if cfg.trace_full_ready else "diff",
            **({"reliable": dataclasses.asdict(cfg.reliable)}
               if cfg.reliable is not None else {}),
            **({"recover": True, "recovery_mode": cfg.recovery_mode,
                "hb_deadline": cfg.hb_deadline,
                "restore_cost": cfg.restore_cost} if cfg.recover else {}),
            **({"hint_table": [[_tr.task_key(t) for t in o]
                               for o in cfg.hint_table],
                "hint_table_version": cfg.hint_table_version}
               if cfg.hint_table is not None else {}),
            **({"swap_table": [[_tr.task_key(t) for t in o]
                               for o in cfg.swap_table],
                "swap_at": cfg.swap_at, "swap_after": cfg.swap_after}
               if cfg.swap_table is not None else {}),
        }

    def _effective_config(self, substrate: str) -> ActorConfig:
        """Resolve replay: adopt the recorded run's scheduling parameters.

        Sim replays keep the recorded consumption mode (decisions re-derive
        identically from the replayed arrivals); thread replays consume the
        realized dispatch orders as a pre-committed schedule.
        """
        cfg = self.config
        if cfg.replay is None:
            return cfg
        meta = cfg.replay.meta
        def _orders(key: str) -> list[list[Task]] | None:
            v = meta.get(key)
            if v is None:
                return None
            return [[_tr.task_from_key(k) for k in o] for o in v]

        cfg = dataclasses.replace(
            cfg,
            mode=meta.get("mode", cfg.mode),
            hint=HintKind(meta.get("hint", cfg.hint.value)),
            buffer_limit=meta.get("buffer_limit", cfg.buffer_limit),
            w_defer_cap=meta.get("w_defer_cap", cfg.w_defer_cap),
            tp_degree=meta.get("tp_degree", cfg.tp_degree),
            chaos=None,  # realized durations/arrivals already include chaos
            reliable=None,  # recorded DELIVERs are post-dedup admissions
            # adaptive tables: the recorded run's active table (+ any
            # mid-run swap) re-derives the same decisions on sim replay
            hint_table=_orders("hint_table"),
            hint_table_version=meta.get("hint_table_version", 0),
            swap_table=_orders("swap_table"),
            swap_at=meta.get("swap_at"),
            swap_after=meta.get("swap_after"),
        )
        if substrate == "thread" or cfg.mode == "precommitted":
            # order-exact replay: realized orders become the schedule
            cfg = dataclasses.replace(
                cfg, mode="precommitted",
                custom_orders=cfg.replay.dispatch_orders(self.spec.num_stages))
        return cfg

    def _make_stage(
        self, s: int, cfg: ActorConfig, recorder: TraceRecorder | None,
        epoch: int = 0,
    ) -> tuple[Mailbox, StageActor]:
        """Build one stage's mailbox + actor (initial build and respawn).

        A respawned incarnation passes the post-recovery ``epoch``: its
        mailbox fences every envelope from an earlier epoch."""
        spec = self.spec
        order = None
        if cfg.mode == "precommitted":
            if cfg.custom_orders is not None:
                order = cfg.custom_orders[s]
            else:
                order = FIXED_ORDERS[cfg.fixed_order](spec, s)
        shard = (cfg.metrics.shard(s)
                 if cfg.metrics is not None else None)
        mb = Mailbox(s, cfg.tp_degree, recorder=recorder,
                     fan_in=spec.fan_in, metrics=shard)
        mb.epoch = epoch
        table = (cfg.hint_table[s]
                 if cfg.hint_table is not None and cfg.mode == "hint"
                 else None)
        actor = StageActor(
            s, spec, mb, mode=cfg.mode, hint=cfg.hint, order=order,
            buffer_limit=cfg.buffer_limit, w_defer_cap=cfg.w_defer_cap,
            reference_arbitration=cfg.reference_arbitration,
            trace_full_ready=cfg.trace_full_ready, metrics=shard,
            table=table, table_version=cfg.hint_table_version)
        return mb, actor

    def _build_actors(
        self, cfg: ActorConfig, recorder: TraceRecorder | None,
    ) -> tuple[list[Mailbox], list[StageActor]]:
        mailboxes, actors = [], []
        for s in range(self.spec.num_stages):
            mb, actor = self._make_stage(s, cfg, recorder)
            mailboxes.append(mb)
            actors.append(actor)
        return mailboxes, actors

    def _restore_progress(self, actor: StageActor, done: set) -> None:
        """Seed a respawned actor with the progress the coordinator restored
        from the trace: completed tasks never re-execute (sim substrate),
        and every locally-enabled not-yet-done task re-enters the ready set.
        Message-fed tasks re-arrive via the coordinator's replay."""
        actor.done = set(done)
        for t in done:
            if t.kind == Kind.F:
                actor.n_f += 1
            elif t.kind == Kind.B:
                actor.n_b += 1
            else:
                actor.n_w += 1
        if actor.mode == "precommitted":
            # a fixed order executes strictly in sequence, so the restored
            # position is the done prefix
            while (actor.order_pos < len(actor.order)
                   and actor.order[actor.order_pos] in done):
                actor.order_pos += 1
        for t in self.spec.tasks():
            if t.stage == actor.idx and t not in done:
                actor._maybe_enqueue(t)

    def _seed_inputs(self, mailboxes: list[Mailbox]) -> None:
        """Source stages' chunk-0 forward inputs are locally available at
        t=0 (stage 0 on a chain; every branch root on a DAG)."""
        for s in self.spec.source_stages():
            for j in range(self.spec.num_microbatches):
                mailboxes[s].deliver_local(Task(Kind.F, s, j, 0))

    # ---- simulation substrate -----------------------------------------
    def run(self) -> RunResult:
        spec = self.spec
        reset_seq()  # envelope seqs are run-local: traces stay byte-stable
        cfg = self._effective_config("sim")
        oracle = ReplayOracle(cfg.replay) if cfg.replay is not None else None
        if oracle is not None and cfg.replay.recovery_windows():
            raise ValueError(
                "time-exact replay of a recovered trace is not supported: "
                "replay the unfailed run and re-inject the fault instead")
        if self.costs is None and oracle is None:
            raise ValueError("simulation mode requires a CostModel")
        costs = self.costs
        recorder = (TraceRecorder(self._meta(cfg, "sim"))
                    if cfg.record_trace else None)
        chaos = (ChaosEngine(cfg.chaos)
                 if cfg.chaos is not None and cfg.chaos.active() else None)
        mailboxes, actors = self._build_actors(cfg, recorder)

        # fail-stop fault plan: a pure (CRN) function of the chaos config.
        # Each stage carries a *list* of planned faults in dispatch order —
        # the multi-fault generalization (concurrent deaths and
        # death-during-recovery are just overlapping entries).
        fails: dict[int, list[tuple[str, int]]] = {}
        if chaos is not None:
            for s in range(spec.num_stages):
                fps = chaos.fail_points(s, spec.num_tasks_per_stage())
                if fps:
                    fails[s] = fps
        epoch = 0  # recovery generation; stamps every outgoing envelope
        dead: set[int] = set()
        #: per-stage incarnation counter: a "complete" heap event carries the
        #: incarnation that scheduled it, so an in-flight completion of a
        #: stage killed *mid-execution* (link failure on a live stage) is
        #: discarded instead of committing zombie state
        incarnation = [0] * spec.num_stages
        n_disp = [0] * spec.num_stages
        fail_time: dict[int, float] = {}
        fail_kind_of: dict[int, str] = {}
        recoveries: list[dict] = []
        #: stages whose hosting device has been lost (cumulative across
        #: overlapping recovery windows): the re-map fold's dead set
        remapped: set[int] = set()
        #: (task, rank, src) of every envelope handed to the transport —
        #: the recovery coordinator's replay source (sim payloads are the
        #: fact of arrival, so identity is the whole message)
        sent_log: set[tuple[Task, int, int]] = set()
        host_of = list(range(spec.num_stages))  # stage -> hosting device

        events: list = []  # (time, seq, kind, payload)
        seq = 0

        def push(t: float, ekind: str, payload) -> None:
            nonlocal seq
            heapq.heappush(events, (t, seq, ekind, payload))
            seq += 1

        def schedule_delivery(t: float, env: Envelope) -> None:
            """Transport hook; the chaos layer perturbs the arrival here."""
            if chaos is None:
                push(t, "deliver", env)
                return
            for copy in range(chaos.copies(env)):
                push(t + chaos.comm_delay(env, copy), "deliver", env)

        def record_send(env: Envelope, _lat: float) -> None:
            if recorder is not None:
                rel = {"eseq": env.eseq} if env.eseq >= 0 else {}
                recorder.record(_tr.SEND, env.src_stage, env.task,
                                rank=env.rank, t=env.send_time, seq=env.seq,
                                **rel)

        transport = SimTransport(
            costs, schedule=schedule_delivery, seed=cfg.seed,
            on_send=record_send) if oracle is None else None

        # ---- reliable-delivery layer over a lossy virtual wire ----------
        def link_fail(src: int, dst: int, env: Envelope, now: float) -> None:
            """Retry budget exhausted on src->dst: escalate to a stage fault
            on the unreachable receiver, detected immediately (the transport
            itself is the failure detector — no heartbeat wait)."""
            if dst in dead:
                return  # already under recovery; its replay covers this edge
            dead.add(dst)
            fail_time[dst] = now
            fail_kind_of[dst] = "link"
            incarnation[dst] += 1  # discard any in-flight completion
            busy_until[host_of[dst]] = float("inf")
            if recorder is not None:
                recorder.record(_tr.FAIL, dst, env.task, t=now,
                                fail_kind="link", src=src)
            if not cfg.recover:
                if recorder is not None:
                    self.trace = recorder.trace()
                raise StageFailure(
                    dst, "link",
                    f"edge {src}->{dst} unhealable at t={now:.6g}")
            push(now, "detect", dst)

        def wire_transmit(env: Envelope, attempt: int, now: float) -> None:
            copies = chaos.copies(env) if chaos is not None else 1
            for copy in range(copies):
                if chaos is not None and chaos.dropped(env, now, attempt,
                                                       copy):
                    if recorder is not None:
                        recorder.record(_tr.DROP, env.src_stage, env.task,
                                        rank=env.rank, t=now,
                                        dst=env.dst_stage, eseq=env.eseq,
                                        attempt=attempt, copy=copy)
                    continue
                arriving = env
                if chaos is not None and chaos.corrupted(env, attempt):
                    arriving = dataclasses.replace(
                        env, checksum=env.checksum ^ (attempt + 1))
                lat = costs.sample_comm(rng_for(cfg.seed, env))
                if chaos is not None:
                    lat += chaos.comm_delay(env, copy)
                push(now + lat, "rdeliver", (arriving, attempt))

        def wire_ack(ack, env: Envelope, now: float) -> None:
            if chaos is not None and chaos.ack_dropped(env, now,
                                                       ack.attempt):
                return  # sender's RTO covers it; receiver dedups the retry
            push(now + cfg.reliable.ack_latency, "call",
                 lambda t, a=ack: channel.on_ack(a, t))

        def wire_deliver(env: Envelope, now: float) -> None:
            s = env.dst_stage
            adm = mailboxes[s].deliver(env, now=now)
            if adm is not None:
                actors[s].sync_mailbox()
                try_dispatch(s, now)

        #: current virtual time (updated at every heap pop): the reliable
        #: channel's RTO timers anchor to it when they re-arm
        simnow = [0.0]

        channel = None
        if cfg.reliable is not None and oracle is None:
            channel = ReliableChannel(
                cfg.reliable,
                transmit=wire_transmit,
                send_ack=wire_ack,
                set_timer=lambda delay, fn: push(
                    simnow[0] + delay, "call", fn),
                deliver=wire_deliver,
                on_link_fail=link_fail,
                recorder=recorder,
                on_send=record_send,
                seed=cfg.seed,
            )

        def send_messages(succ: Task, src: int, now: float) -> None:
            for env in envelopes_for(succ, src, cfg.tp_degree, send_time=now,
                                     epoch=epoch):
                if fails or dead or channel is not None:
                    sent_log.add((env.task, env.rank, env.src_stage))
                if channel is not None:
                    channel.send(env, now=now)
                elif oracle is None:
                    transport.send(env, now=now)
                else:
                    record_send(env, 0.0)
                    for at in oracle.delivery_times(env.task, env.rank,
                                                    env.src_stage):
                        push(at, "deliver", env)

        inj_states = [
            costs.injection.make_state() if costs is not None else None
            for _ in range(spec.num_stages)]
        busy_until = [0.0] * spec.num_stages
        idle_since = [0.0] * spec.num_stages
        start: dict[Task, float] = {}
        end: dict[Task, float] = {}
        n_done = 0
        total = spec.total_tasks()

        self._seed_inputs(mailboxes)
        for a in actors:
            a.sync_mailbox()

        def task_duration(s: int, task: Task) -> float:
            if oracle is not None:
                return oracle.duration(task)
            rng = _compute_rng(cfg.seed, task)
            dur = costs.sample_compute(task.kind, s, task.mb, rng)
            if task.kind != Kind.W:
                dur += costs.injection.sample_delay(inj_states[s], dur, rng)
            if chaos is not None:
                # straggler slowdown + transient stall, folded into the
                # realized duration (and therefore into recorded traces)
                dur = dur * chaos.compute_scale(s) + chaos.stall(task)
            return dur

        def try_dispatch(s: int, now: float) -> None:
            if s in dead:
                return
            actor = actors[s]
            h = host_of[s]
            if busy_until[h] > now:
                return
            task, sel_info = actor.select_traced()
            if task is None:
                return
            actor.begin(task, now=now, info=sel_info)
            k = n_disp[s]
            n_disp[s] += 1
            fps = fails.get(s)
            if fps and k >= fps[0][1]:
                # fail-stop: the stage dies executing this task — no
                # COMPLETE, no outgoing messages, in-memory state lost.
                # ``n_disp`` counts across incarnations, so a second entry
                # on the same stage fires on the *respawned* incarnation
                # (death-during-recovery).
                kind_f = fps.pop(0)[0]
                if not fps:
                    del fails[s]
                dead.add(s)
                fail_time[s] = now
                fail_kind_of[s] = kind_f
                busy_until[h] = float("inf")
                if recorder is not None:
                    recorder.record(_tr.FAIL, s, task, t=now,
                                    fail_kind=kind_f)
                if not cfg.recover:
                    if recorder is not None:
                        self.trace = recorder.trace()
                    raise StageFailure(
                        s, kind_f, f"t={now:.6g}, dispatch #{k}")
                # heartbeat deadline: the coordinator declares the stage
                # dead only after hb_deadline of silence
                push(now + cfg.hb_deadline, "detect", s)
                return
            coord = mailboxes[s].group.coordination_cost(task, cfg.tp_coord_base)
            dur = task_duration(s, task)
            actor.stats.blocking += max(0.0, now - idle_since[h])
            actor.stats.tp_coord += coord
            actor.stats.compute += dur
            begin = now + coord
            start[task] = begin
            busy_until[h] = begin + dur
            push(busy_until[h], "complete", (task, incarnation[s]))

        def co_hosted(h: int) -> list[int]:
            return [s2 for s2 in range(spec.num_stages) if host_of[s2] == h]

        swap_done = False
        if (cfg.mode == "hint" and cfg.swap_table is not None
                and cfg.swap_at is not None):
            # pushed before the first dispatch so the event's heap seq (and
            # therefore its order among same-time events) is replay-stable
            push(cfg.swap_at, "hint_swap", None)

        for s in range(spec.num_stages):
            try_dispatch(s, 0.0)

        while events:
            now, _, ekind, payload = heapq.heappop(events)
            simnow[0] = now
            if ekind == "complete":
                task, inc = payload
                s = task.stage
                if inc != incarnation[s]:
                    # a completion scheduled by an incarnation that was
                    # since killed mid-execution (link failure): zombie
                    # state, never committed — the successor incarnation
                    # re-executes the task
                    continue
                end[task] = now
                n_done += 1
                succs = actors[s].complete(task, now=now, dur=now - start[task])
                for succ in succs:
                    send_messages(succ, s, now)
                h = host_of[s]
                idle_since[h] = now
                for s2 in co_hosted(h):
                    try_dispatch(s2, now)
            elif ekind == "call":
                # reliable-transport timer/ack hop: invoke with fire time
                payload(now)
            elif ekind == "rdeliver":
                # one wire transmission survived drop/partition: the channel
                # verifies the checksum, dedups, acks, and (first admission
                # only) delivers into the mailbox
                env, attempt = payload
                channel.on_wire(env, attempt, now)
            elif ekind == "deliver":
                env: Envelope = payload
                s = env.dst_stage
                adm = mailboxes[s].deliver(env, now=now)
                if adm is not None:
                    actors[s].sync_mailbox()
                    try_dispatch(s, now)
            elif ekind == "hint_swap":
                # quiesce point: between heap events no stage holds an
                # un-completed decision — adopt the new table everywhere,
                # then re-arbitrate (priorities changed, readiness didn't)
                swap_done = True
                for s2 in range(spec.num_stages):
                    if s2 not in dead:
                        actors[s2].set_hint_table(
                            cfg.swap_table[s2], now=now,
                            version=cfg.hint_table_version + 1)
                for s2 in range(spec.num_stages):
                    try_dispatch(s2, now)
            elif ekind == "detect":
                # ---- recovery coordinator -----------------------------
                s = payload
                if recorder is not None:
                    recorder.record(_tr.RECOVERY_BEGIN, s, t=now,
                                    epoch_from=epoch, epoch_to=epoch + 1)
                epoch += 1
                incarnation[s] += 1
                if recorder is not None:
                    recorder.epoch = epoch
                if cfg.recovery_mode == "remap":
                    # no spare device: fold the dead stage onto a surviving
                    # neighbor (feasibility-checked MeshPlan re-layout).
                    # The dead set is cumulative across overlapping windows
                    # — a second concurrent death folds onto a device that
                    # is actually still alive, never onto a dead neighbor.
                    from repro.runtime.elastic import remap_stages

                    remapped.add(s)
                    host_of = remap_stages(spec.num_stages, remapped)
                # respawn: fresh mailbox (fenced at the new epoch) + actor
                mb, actor = self._make_stage(s, cfg, recorder, epoch=epoch)
                mailboxes[s] = mb
                actors[s] = actor
                # restore progress from the last committed state: completed
                # tasks never re-execute; the doomed + undispatched remainder
                # re-enter through local enablement and message replay
                done_s = {t for t in end if t.stage == s}
                self._restore_progress(actor, done_s)
                if swap_done and cfg.swap_table is not None:
                    # the fleet swapped while this stage was down: the new
                    # incarnation adopts the active table, not the stale one
                    actor.set_hint_table(cfg.swap_table[s], now=now,
                                         version=cfg.hint_table_version + 1)
                if (cfg.recovery_mode == "remap"
                        and cfg.adaptive is not None and cfg.mode == "hint"):
                    # re-synthesize against the post-remap topology: stages
                    # now time-sharing a device price slower, and the
                    # recovery cost folds into the candidate's pricing
                    d = cfg.adaptive.note_remap(
                        host_of, recovery_cost=cfg.restore_cost)
                    if d.swapped:
                        for s2 in range(spec.num_stages):
                            a2 = actors[s2] if s2 != s else actor
                            if s2 == s or s2 not in dead:
                                a2.set_hint_table(
                                    cfg.adaptive.table[s2], now=now)
                t_up = now + cfg.restore_cost
                for task_, rank_, src_ in sorted(
                        e for e in sent_log
                        if e[0].stage == s and e[0] not in done_s):
                    push(t_up, "deliver", Envelope(
                        task=task_, src_stage=src_, dst_stage=s, rank=rank_,
                        send_time=now, epoch=epoch))
                h = host_of[s]
                if cfg.recovery_mode == "remap":
                    busy_until[h] = max(busy_until[h], t_up)
                else:
                    busy_until[h] = t_up
                    idle_since[h] = t_up
                recoveries.append({
                    "stage": s, "fail_kind": fail_kind_of[s],
                    "t_fail": fail_time[s], "t_detect": now, "t_up": t_up,
                    "epoch": epoch, "mode": cfg.recovery_mode,
                    "mttr": t_up - fail_time[s]})
                push(t_up, "respawned", s)
            else:  # respawned: the new incarnation is back in service
                s = payload
                dead.discard(s)
                if recorder is not None:
                    recorder.record(_tr.RECOVERY_END, s, t=now,
                                    mode=cfg.recovery_mode,
                                    mttr=now - fail_time[s])
                if cfg.metrics is not None:
                    # incarnation boundary: old-speed samples become a
                    # weak prior so re-synthesis tracks the new regime
                    cfg.metrics.on_recovery(s)
                actors[s].sync_mailbox()
                try_dispatch(s, now)

        if recorder is not None:
            self.trace = recorder.trace()
        if n_done != total:
            starved = {
                a.idx: a.waiting_on()[:4] for a in actors if not a.finished()
            }
            raise DeadlockError(
                f"actor runtime stalled with {total - n_done} tasks "
                f"unexecuted (mode={cfg.mode}); starved stages -> first "
                f"missing messages: {starved}")
        makespan = max(end.values())
        for s, a in enumerate(actors):
            a.stats.blocking += max(0.0, makespan - busy_until[host_of[s]])
            a.stats.deferrals = mailboxes[s].group.deferrals
        if recorder is not None:
            recorder.meta["makespan"] = makespan
            if recoveries:
                recorder.meta["recoveries"] = recoveries
            if channel is not None:
                recorder.meta["reliable_stats"] = channel.stats()
            self.trace = recorder.trace()
        return RunResult(
            makespan=makespan,
            stage_stats=[a.stats for a in actors],
            start=start,
            end=end,
            spec=spec,
            trace=self.trace,
            metrics=cfg.metrics,
        )

    # ---- thread-per-stage substrate ------------------------------------
    def run_threaded(
        self,
        work_fn: Callable[[Task, Any], Any] | list[Callable[[Task, Any], Any]],
    ) -> RunResult:
        """Drive real per-stage callables with thread actors (wall clock).

        ``work_fn(task, payload)`` (or one callable per stage) performs the
        actual computation and returns the payload for the outgoing message.
        """
        import queue as _queue
        import time as _time

        spec = self.spec
        reset_seq()  # envelope seqs are run-local: traces stay byte-stable
        cfg = self._effective_config("thread")
        recorder = (TraceRecorder(self._meta(cfg, "thread"))
                    if cfg.record_trace else None)
        chaos = (ChaosEngine(cfg.chaos)
                 if cfg.chaos is not None and cfg.chaos.active() else None)
        mailboxes, actors = self._build_actors(cfg, recorder)
        if (cfg.mode == "hint" and cfg.swap_table is not None
                and cfg.swap_after is not None):
            for a in actors:
                a.swap_table = cfg.swap_table[a.idx]
                a.swap_after = cfg.swap_after
        t0 = _time.perf_counter()
        clock = lambda: _time.perf_counter() - t0  # noqa: E731

        # fail-stop fault plan (CRN: a pure function of the chaos config).
        # Per-stage *lists* of planned faults in dispatch order: overlapping
        # entries express concurrent deaths and death-during-recovery.
        fail_points: dict[int, list[tuple[str, int]]] = {}
        if chaos is not None:
            for s in range(spec.num_stages):
                fps = chaos.fail_points(s, spec.num_tasks_per_stage())
                if fps:
                    fail_points[s] = fps
        rcfg = cfg.reliable
        #: recovery generation; the transport shim stamps it on every
        #: outgoing envelope under ``gate``, so no send can interleave with
        #: a coordinator epoch bump
        gate = threading.RLock()
        epoch_box = [0]
        #: (task, rank, src) -> last payload sent — the coordinator's replay
        #: source for messages destined to a respawned stage
        send_log: dict[tuple[Task, int, int], Any] = {}
        all_actors: list[StageActor] = list(actors)
        fail_time: dict[int, float] = {}
        recoveries: list[dict] = []
        #: set once every stage thread has joined: late transport timers
        #: (an RTO escalating after the run drained) must not wake the
        #: recovery coordinator for a run that already finished
        run_done = threading.Event()
        abort = threading.Event()
        errors: list[BaseException] = []
        fail_q: _queue.Queue = _queue.Queue()
        #: stage -> hosting device, and the cumulative lost-device set
        #: (thread-substrate elastic remap)
        host_of = list(range(spec.num_stages))
        remapped: set[int] = set()
        #: per-stage host lock: stages folded onto one device time-share it
        #: by serializing their work_fns (assigned at remap time; absent =
        #: the stage still has its own device, no serialization)
        host_locks: dict[int, threading.Lock] = {}

        def record_send(env: Envelope, now: float) -> None:
            if recorder is not None:
                rel = {"eseq": env.eseq} if env.eseq >= 0 else {}
                recorder.record(_tr.SEND, env.src_stage, env.task,
                                rank=env.rank, t=now, seq=env.seq, **rel)

        def thread_link_fail(src: int, dst: int, env: Envelope,
                             now: float) -> None:
            """Reliable transport exhausted its retry budget on src->dst:
            the unreachable receiver is treated as a failed stage."""
            if run_done.is_set():
                return  # the run already completed; nothing left to heal
            fail_time[dst] = now
            if recorder is not None:
                recorder.record(_tr.FAIL, dst, env.task, t=now,
                                fail_kind="link", src=src)
            if cfg.recover:
                fail_q.put(_StageDeath(dst, "link", env.task, t_fail=now))
                return
            errors.append(StageFailure(
                dst, "link", f"edge {src}->{dst} unhealable at t={now:.6g}"))
            abort.set()
            for m in mailboxes:
                m.stop()

        mb_map = {m.stage: m for m in mailboxes}
        if rcfg is not None:
            base_transport = ReliableThreadTransport(
                mb_map, rcfg, chaos=chaos, seed=cfg.seed, clock=clock,
                recorder=recorder, on_send=record_send,
                on_link_fail=thread_link_fail)
        elif chaos is not None:
            base_transport = ChaosThreadTransport(mb_map, chaos,
                                                  on_send=record_send)
        else:
            base_transport = ThreadTransport(mb_map, on_send=record_send)

        #: log sends whenever recovery might need to replay them: planned
        #: faults, or a reliable transport whose link failures can escalate
        #: into unplanned ones
        log_sends = bool(fail_points) or rcfg is not None

        class _EpochTransport:
            """Stamp the current recovery epoch on every envelope (and log
            it for replay) before handing off to the real transport.  The
            gate serializes sends against the coordinator's epoch bump +
            mailbox swap, so an envelope either predates a recovery (old
            epoch -> fenced at the respawned mailbox) or fully follows it."""

            def send(self, env: Envelope, now: float = 0.0):
                with gate:
                    if env.epoch != epoch_box[0]:
                        env = dataclasses.replace(env, epoch=epoch_box[0])
                    if log_sends:
                        send_log[(env.task, env.rank, env.src_stage)] = \
                            env.payload
                    base_transport.send(env, now=now)

        transport = _EpochTransport() if log_sends else base_transport
        base_fns = list(work_fn) if isinstance(work_fn, list) \
            else [work_fn] * spec.num_stages
        if chaos is not None:
            def chaotic(fn):
                def wrapped(task, payload):
                    d = chaos.thread_delay(task)
                    if d > 0:
                        if recorder is not None:
                            recorder.record(_tr.STALL, task.stage, task,
                                            t=clock(), dur=d)
                        _time.sleep(d)
                    return fn(task, payload)
                return wrapped
        else:
            chaotic = None

        # fail-stop wrapper: a doomed dispatch never completes.  ``kill``
        # raises immediately; ``permanent_stall`` hangs inside work_fn until
        # the watchdog notices the stale execution heartbeat and releases it
        # (the release is the moment of *detection*, not of death).  The
        # execution counter is shared across incarnations, so a later entry
        # in a stage's fault list fires on the respawned incarnation —
        # death-during-recovery and repeated deaths fall out naturally.
        exec_n = {s: 0 for s in fail_points}
        fail_remaining = {s: list(pts) for s, pts in fail_points.items()}
        stall_stages = {s for s, pts in fail_points.items()
                        if any(k == "permanent_stall" for k, _ in pts)}
        stall_release = {s: threading.Event() for s in stall_stages}

        def failing(fn, s: int):
            def wrapped(task, payload):
                i = exec_n[s]
                exec_n[s] = i + 1
                rem = fail_remaining[s]
                if rem and i >= rem[0][1]:
                    kind_ = rem.pop(0)[0]
                    t_fail = clock()
                    if kind_ == "permanent_stall":
                        stall_release[s].wait()
                        stall_release[s] = threading.Event()  # re-arm
                    raise _StageDeath(s, kind_, task, t_fail=t_fail)
                return fn(task, payload)
            return wrapped

        def hosted(fn, s: int):
            """Serialize this stage's work_fn with its host's cohabitants
            after an elastic remap folds stages onto one device.  Late-bound:
            before any remap ``host_locks`` has no entry and the wrapper is
            pass-through."""
            def wrapped(task, payload):
                lk = host_locks.get(s)
                if lk is None:
                    return fn(task, payload)
                with lk:
                    return fn(task, payload)
            return wrapped

        def stage_fn(s: int, respawned: bool = False):
            fn = base_fns[s]
            if respawned and cfg.respawn is not None:
                fn = cfg.respawn(s)
            if chaotic is not None:
                fn = chaotic(fn)
            fn = hosted(fn, s)
            # the failing wrapper stays armed on respawn: remaining entries
            # in the stage's fault list target later incarnations
            if s in fail_points:
                fn = failing(fn, s)
            return fn

        def runner(actor: StageActor, fn):
            try:
                actor.run_thread(
                    fn, transport, clock,
                    tp_degree=cfg.tp_degree,
                    deadlock_timeout=cfg.deadlock_timeout,
                    abort=abort,
                )
            except _StageDeath as d:
                fail_time[d.stage] = d.t_fail
                if recorder is not None:
                    recorder.record(_tr.FAIL, d.stage, d.task, t=d.t_fail,
                                    fail_kind=d.fail_kind)
                if cfg.recover:
                    fail_q.put(d)  # hand off to the recovery coordinator
                    return
                errors.append(StageFailure(
                    d.stage, d.fail_kind, f"t={d.t_fail:.6g}"))
                abort.set()
                for m in mailboxes:
                    m.stop()
            except BaseException as e:  # noqa: BLE001 - reraised on join
                errors.append(e)
                abort.set()
                # Event-driven wakeups have no poll period to fall back on:
                # sibling actors blocked on their mailbox condition must be
                # notified, or they sleep until their starvation deadline.
                for m in mailboxes:
                    m.stop()

        self._seed_inputs(mailboxes)
        threads = [
            threading.Thread(target=runner, args=(a, stage_fn(a.idx)),
                             name=f"stage-{a.idx}", daemon=True)
            for a in actors
        ]

        def recover_stage(death: _StageDeath) -> None:
            s = death.stage
            t_detect = clock()
            with gate:
                if run_done.is_set():
                    return  # late escalation: the run already finished
                # Halt the old incarnation BEFORE the epoch bump.  A link
                # failure can kill a *live* stage whose thread is mid-
                # work_fn; halting under the old mailbox's condition makes
                # any racing completion either see ``halted`` and abandon,
                # or land entirely at the old epoch — never a zombie
                # COMPLETE stamped with the new incarnation's epoch.
                old_actor = actors[s]
                old_mb = mb_map[s]
                with old_mb.cond:
                    old_actor.halted = True
                    old_mb.cond.notify_all()
                if recorder is not None:
                    recorder.record(_tr.RECOVERY_BEGIN, s, t=t_detect,
                                    epoch_from=epoch_box[0],
                                    epoch_to=epoch_box[0] + 1)
                epoch_box[0] += 1
                if recorder is not None:
                    recorder.epoch = epoch_box[0]
                mb, actor = self._make_stage(s, cfg, recorder,
                                             epoch=epoch_box[0])
                mailboxes[s] = mb
                mb_map[s] = mb
                actors[s] = actor
                all_actors.append(actor)
                old_mb.stop()
                if cfg.recovery_mode == "remap":
                    # elastic remap on the thread substrate: the dead
                    # stage's device is gone for good; fold the respawned
                    # actor onto the nearest survivor and serialize the
                    # cohabitants' work_fns via a shared host lock
                    from repro.runtime.elastic import remap_stages

                    remapped.add(s)
                    host_of[:] = remap_stages(spec.num_stages, remapped)
                    for h in set(host_of):
                        cohab = [s2 for s2 in range(spec.num_stages)
                                 if host_of[s2] == h]
                        if len(cohab) < 2:
                            continue  # sole resident: no serialization
                        lk = next((host_locks[s2] for s2 in cohab
                                   if s2 in host_locks), None) \
                            or threading.Lock()
                        for s2 in cohab:
                            host_locks[s2] = lk
                # In-memory state (stashed activations) died with the stage:
                # the incarnation re-executes from scratch.  Re-seed local
                # inputs, then replay every logged send destined here at the
                # new epoch; late duplicates of the originals are fenced.
                nowc = clock()
                if s in spec.source_stages():
                    for j in range(spec.num_microbatches):
                        mb.deliver_local(Task(Kind.F, s, j, 0), now=nowc)
                for (task_, rank_, src_), payload in sorted(
                        send_log.items(), key=lambda kv: kv[0]):
                    if task_.stage == s:
                        mb.deliver(Envelope(
                            task=task_, src_stage=src_, dst_stage=s,
                            rank=rank_, payload=payload, send_time=nowc,
                            epoch=epoch_box[0]), now=nowc)
            th = threading.Thread(
                target=runner, args=(actor, stage_fn(s, respawned=True)),
                name=f"stage-{s}-r{epoch_box[0]}", daemon=True)
            th.start()  # start before publishing: the join loop may see it
            threads.append(th)
            t_up = clock()
            mttr = t_up - fail_time.get(s, t_detect)
            mode = cfg.recovery_mode
            host = host_of[s] if mode == "remap" else s
            if recorder is not None:
                recorder.record(_tr.RECOVERY_END, s, t=t_up, mode=mode,
                                mttr=mttr, host=host)
            if cfg.metrics is not None:
                cfg.metrics.on_recovery(s)
            recoveries.append({
                "stage": s, "fail_kind": death.fail_kind,
                "t_fail": fail_time.get(s, t_detect), "t_detect": t_detect,
                "t_up": t_up, "epoch": epoch_box[0], "mode": mode,
                "host": host, "mttr": mttr})
            if (mode == "remap" and cfg.adaptive is not None
                    and cfg.mode == "hint"):
                # re-price the hint table against the degraded (co-hosted)
                # topology; adopt immediately on improvement — each live
                # actor swaps under its own mailbox condition (its thread
                # only touches the arbiter/ready-set under that lock)
                d = cfg.adaptive.note_remap(
                    host_of, recovery_cost=cfg.restore_cost)
                if d.swapped:
                    nowh = clock()
                    for s2 in range(spec.num_stages):
                        a2 = actors[s2]
                        with a2.mailbox.cond:
                            if not a2.halted:
                                a2.set_hint_table(cfg.adaptive.table[s2],
                                                  now=nowh)

        def coordinator() -> None:
            """Failure detection + recovery: drains the death queue (kills
            and link failures announce themselves) and runs a heartbeat
            watchdog for armed permanent stalls (silent deaths detected by
            staleness).  Persistent — it outlives its planned fault list,
            because reliable-transport link failures and later entries in a
            stage's fault list can arrive at any time until the run ends."""
            while not run_done.is_set() and not abort.is_set():
                try:
                    death = fail_q.get(
                        timeout=max(cfg.hb_deadline / 4, 0.002))
                except _queue.Empty:
                    for s2 in stall_stages:
                        es = actors[s2].exec_since
                        if (es is not None
                                and _time.monotonic() - es > cfg.hb_deadline):
                            stall_release[s2].set()
                    continue
                recover_stage(death)
                fail_q.task_done()

        coord_th = None
        # the coordinator doubles as the stall watchdog, so it also runs
        # without ``recover``: a released stall is then promoted to a
        # fail-fast StageFailure instead of a silent hang
        if (fail_points and (cfg.recover or stall_release)) or \
                (rcfg is not None and cfg.recover):
            coord_th = threading.Thread(
                target=coordinator, name="recovery-coordinator", daemon=True)
            coord_th.start()
        for th in list(threads):  # snapshot: a respawn may append
            th.start()
        i = 0
        while True:
            while i < len(threads):
                threads[i].join()
                i += 1
            if i == len(threads) and (
                    coord_th is None or abort.is_set()
                    or fail_q.unfinished_tasks == 0):
                # every started thread joined and no recovery is queued or
                # in flight (a recovery may still append a thread, which
                # the outer loop then picks up)
                break
            _time.sleep(0.002)
        with gate:
            run_done.set()  # under gate: no recovery can start after this
        if coord_th is not None:
            coord_th.join()
            while i < len(threads):
                # a recovery that slipped in between the break above and
                # run_done still spawned a thread; sweep it up
                threads[i].join()
                i += 1
        if isinstance(base_transport, ReliableThreadTransport):
            # land outstanding ACKs/retransmissions, then cancel timers so
            # none outlives the run
            base_transport.drain(timeout=cfg.deadlock_timeout)
            base_transport.close()
        elif isinstance(base_transport, ChaosThreadTransport):
            # chaos duplicates may still be in flight; land them before
            # stopping so no timer outlives the run
            base_transport.drain(timeout=cfg.deadlock_timeout)
        for m in mailboxes:
            m.stop()
        if recorder is not None:
            self.trace = recorder.trace()
        if errors:
            raise errors[0]
        # later incarnations override: a re-executed task's times are its
        # post-recovery ones (all_actors is in creation order)
        start: dict[Task, float] = {}
        end: dict[Task, float] = {}
        for a in all_actors:
            for tr in a.traces:
                start[tr.task] = tr.start
                end[tr.task] = tr.end
        if len(end) != spec.total_tasks():
            raise DeadlockError(
                f"threaded run finished {len(end)}/{spec.total_tasks()} tasks")
        makespan = max(end.values())
        for a in actors:
            a.stats.blocking += max(
                0.0, makespan - max(tr.end for tr in a.traces))
            a.stats.deferrals = a.mailbox.group.deferrals
        if recorder is not None:
            recorder.meta["makespan"] = makespan
            if recoveries:
                recorder.meta["recoveries"] = recoveries
            if isinstance(base_transport, ReliableThreadTransport):
                recorder.meta["reliable_stats"] = base_transport.stats()
            self.trace = recorder.trace()
        return RunResult(
            makespan=makespan,
            stage_stats=[a.stats for a in actors],
            start=start,
            end=end,
            spec=spec,
            trace=self.trace,
            metrics=cfg.metrics,
        )


# --------------------------------------------------------------------------
def run_actor_iteration(
    spec: PipelineSpec, costs: CostModel, config: ActorConfig
) -> RunResult:
    return ActorDriver(spec, costs, config).run()


def average_makespan_actor(
    spec: PipelineSpec,
    costs: CostModel,
    config: ActorConfig,
    iters: int = 10,
) -> tuple[float, float, list[RunResult]]:
    """Mean/std of makespan over independently-seeded iterations (CRN per seed)."""
    results = []
    for i in range(iters):
        cfg = dataclasses.replace(config, seed=config.seed + 1000 * i)
        results.append(ActorDriver(spec, costs, cfg).run())
    xs = np.array([r.makespan for r in results])
    return float(xs.mean()), float(xs.std()), results
