"""Hint orders (§5, Appendix A) and fixed pre-committed execution orders.

A hint order ranks *currently ready* candidates; it never forces waiting.  The
same objects can also be consumed in ``PRECOMMITTED`` mode by the engine, which
is how the 1F1B / GPipe / ZeroBubble baselines are expressed: an explicit
per-stage task sequence that the stage must follow in order, waiting on any
not-yet-ready entry.  That "one schedule, two consumption modes" contrast is
the paper's central claim.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

from repro.core.taskgraph import Kind, PipelineSpec, Task


class HintKind(enum.Enum):
    BF = "bf"              # default: backward, then forward, each round
    FB = "fb"              # forward, then backward, each round
    B_PRIORITY = "b_priority"  # backward whenever any backward is ready
    F_PRIORITY = "f_priority"  # forward whenever any forward is ready
    BFW = "bfw"            # BF + weight-update tasks fill empty rounds


def _within_direction_key(t: Task):
    """Appendix A within-direction priority.

    Forward prefers the *smaller* model-chunk index, backward the *larger*;
    ties break on the smaller microbatch index.  (W inherits backward's rule.)
    """
    if t.kind == Kind.F:
        return (t.chunk, t.mb)
    return (-t.chunk, t.mb)


def pick(ready: Sequence[Task], kind: Kind) -> Task | None:
    """NextByPriority(L_r, Pi) restricted to one direction."""
    cands = [t for t in ready if t.kind == kind]
    if not cands:
        return None
    return min(cands, key=_within_direction_key)


@dataclasses.dataclass
class HintArbiter:
    """Algorithm 1's arbitration: stateful round structure per stage.

    ``last_dir`` implements the round alternation of the BF/FB hints: after a
    B executes, the same round's F check runs next (and vice versa for FB).
    """

    hint: HintKind = HintKind.BF
    last_dir: Kind | None = None

    def try_order(self) -> tuple[Kind, ...]:
        """The kind preference the *next* ``select`` will scan, in order.

        Exposed so the runtime can record each dispatch's arbitration order
        in the event trace: the conformance checker replays it against the
        stage's remaining tasks to verify that the hint order is violated
        only when the hinted task is unready.
        """
        if self.hint == HintKind.B_PRIORITY:
            order: tuple[Kind, ...] = (Kind.B, Kind.F)
        elif self.hint == HintKind.F_PRIORITY:
            order = (Kind.F, Kind.B)
        elif self.hint == HintKind.FB:
            order = (Kind.B, Kind.F) if self.last_dir == Kind.F else (Kind.F, Kind.B)
        elif self.hint in (HintKind.BF, HintKind.BFW):
            order = (Kind.F, Kind.B) if self.last_dir == Kind.B else (Kind.B, Kind.F)
        else:  # pragma: no cover
            raise ValueError(self.hint)
        if self.hint == HintKind.BFW:
            # Weight-update tasks fill rounds with no ready compute direction.
            order += (Kind.W,)
        return order

    def select(self, ready: Sequence[Task]) -> Task | None:
        """Return the dispatched task for the current ready set (or None)."""
        for k in self.try_order():
            t = pick(ready, k)
            if t is not None:
                # A W dispatch fills an empty round without consuming it:
                # round alternation tracks compute directions only.
                if k != Kind.W and self.hint in (
                        HintKind.BF, HintKind.FB, HintKind.BFW):
                    self.last_dir = t.kind
                return t
        return None

    def reset(self) -> None:
        self.last_dir = None


def backpressure_drain(
    spec: PipelineSpec,
    stage: int,
    ready: Sequence[Task],
    done: set[Task],
    drain_focus: int,
) -> tuple[Task | None, int]:
    """Appendix C drain orders, shared by the DES engine and the actor runtime.

    Non-interleaved pipelines drain backward-only; interleaved pipelines
    follow the deterministic per-microbatch completion order
    F_0..F_{C-1}, B_{C-1}..B_0 focused on microbatches in index order.
    Returns (task-or-None, updated drain focus).
    """
    if spec.num_chunks == 1:
        return pick(sorted(ready), Kind.B), drain_focus
    C = spec.num_chunks
    ready_set = set(ready)
    j = drain_focus
    while j < spec.num_microbatches:
        seq_order = [Task(Kind.F, stage, j, c) for c in range(C)] + [
            Task(Kind.B, stage, j, c) for c in reversed(range(C))
        ]
        for t in seq_order:
            if t in done:
                continue
            return (t if t in ready_set else None), j
        j += 1
    return None, j


# --------------------------------------------------------------------------
# Fixed per-stage execution orders (pre-committed baselines + synthesis grid).
# --------------------------------------------------------------------------

def gpipe_order(spec: PipelineSpec, stage: int) -> list[Task]:
    """All forwards, then all backwards (GPipe; also the DeepSpeed-like mode)."""
    fs = [
        Task(Kind.F, stage, j, c)
        for c in range(spec.num_chunks)
        for j in range(spec.num_microbatches)
    ]
    bs = [
        Task(Kind.B, stage, j, c)
        for c in reversed(range(spec.num_chunks))
        for j in range(spec.num_microbatches)
    ]
    out = fs + bs
    if spec.split_backward:
        out += [
            Task(Kind.W, stage, j, c)
            for c in reversed(range(spec.num_chunks))
            for j in range(spec.num_microbatches)
        ]
    return out


def one_f_one_b_order(spec: PipelineSpec, stage: int) -> list[Task]:
    """Standard non-interleaved 1F1B (PipeDream-flush / Megatron default).

    Warmup: dist-to-sink forwards (S-1-s on a chain; the longest forward
    path to a loss stage on a DAG); steady state: alternate 1F/1B;
    cooldown: drain backwards.  Only defined for num_chunks == 1.
    """
    if spec.num_chunks != 1:
        raise NotImplementedError("interleaved 1F1B handled by synthesis")
    M = spec.num_microbatches
    warmup = min(spec.dist_to_sink(stage), M)
    order: list[Task] = [Task(Kind.F, stage, j) for j in range(warmup)]
    nf, nb = warmup, 0
    while nb < M:
        if nf < M:
            order.append(Task(Kind.F, stage, nf))
            nf += 1
        order.append(Task(Kind.B, stage, nb))
        nb += 1
    if spec.split_backward:
        raise NotImplementedError("use zero_bubble_order for split backward")
    return order


def zero_bubble_order(spec: PipelineSpec, stage: int) -> list[Task]:
    """ZB-H1-style fixed order: 1F1B over (F, B-dX) with W deferred.

    W for microbatch j is scheduled as late as the memory argument allows:
    early W fill the warmup-asymmetry bubbles, the rest drain in the cooldown.
    This is the representative fixed-order ZB baseline of §7 (not a full ILP
    ZB-V reimplementation).
    """
    if spec.num_chunks != 1:
        raise NotImplementedError
    if not spec.split_backward:
        raise ValueError("zero_bubble_order requires split_backward=True")
    M = spec.num_microbatches
    depth = spec.dist_to_sink(stage)
    warmup = min(depth, M)
    order: list[Task] = [Task(Kind.F, stage, j) for j in range(warmup)]
    nf, nb, nw = warmup, 0, 0
    while nb < M:
        if nf < M:
            order.append(Task(Kind.F, stage, nf))
            nf += 1
        order.append(Task(Kind.B, stage, nb))
        nb += 1
        # ZB: defer W unless we've run out of F's to issue (cooldown), in
        # which case W fills what would otherwise be a bubble slot.
        if nf >= M and nw < nb - depth:
            order.append(Task(Kind.W, stage, nw))
            nw += 1
    while nw < M:
        order.append(Task(Kind.W, stage, nw))
        nw += 1
    return order


def modality_balanced_order(
    spec: PipelineSpec, stage: int, stage_cost: Sequence[float]
) -> list[Task]:
    """Cornstarch-like baseline: cost-aware warmup depth, still pre-committed.

    Uses per-stage relative cost to shift the warmup depth (heavier stages get
    fewer in-flight microbatches), emulating a modality-aware planner that
    still commits to its order ahead of execution.  On a DAG the base depth
    is the stage's longest forward path to the loss stage, so encoder-branch
    stages (cheap, far from the sink) warm up deep while decoder stages stay
    shallow — the planner's view of the modality imbalance.

    Feasibility: with asynchronous sends, a set of per-stage 1F1B-style
    orders is deadlock-free iff every forward edge (s -> u) satisfies
    ``warmup(s) >= warmup(u) + 1`` (a stage must stay a microbatch ahead of
    each consumer before it starts waiting on backwards).  The cost-aware
    depths are therefore clamped by a reverse-topological pass; a stage
    pinned at ``M`` (GPipe-like, all forwards first) releases its
    predecessors from the constraint only if they are pinned at ``M`` too.
    """
    if spec.num_chunks != 1:
        raise NotImplementedError
    S, M = spec.num_stages, spec.num_microbatches

    def desired(s: int) -> int:
        rel = stage_cost[s] / max(max(stage_cost), 1e-12)
        return min(max(1, round(spec.dist_to_sink(s) * (1.5 - rel))), M, S)

    warmups: dict[int, int] = {}
    order_rev = (spec.graph.topological_order() if spec.graph is not None
                 else tuple(range(S)))
    for s in reversed(order_rev):
        need = max((warmups[u] + 1 for u in spec.stage_successors(s)),
                   default=0)
        warmups[s] = min(M, max(desired(s), need))
    warmup = warmups[stage]
    order: list[Task] = [Task(Kind.F, stage, j) for j in range(warmup)]
    nf, nb = warmup, 0
    while nb < M:
        if nf < M:
            order.append(Task(Kind.F, stage, nf))
            nf += 1
        order.append(Task(Kind.B, stage, nb))
        nb += 1
    if spec.split_backward:
        order += [Task(Kind.W, stage, j) for j in range(M)]
    return order


FIXED_ORDERS = {
    "gpipe": gpipe_order,
    "1f1b": one_f_one_b_order,
    "zb": zero_bubble_order,
}
