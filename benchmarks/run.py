# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: engine-level reproduction of every paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [table1 table6 ...]
    PYTHONPATH=src python -m benchmarks.run --backend actor
    PYTHONPATH=src python -m benchmarks.run --backend actor --hint bfw --split-backward
    PYTHONPATH=src python -m benchmarks.run --backend actor --chaos

``--backend des`` (default) drives the discrete-event engine tables;
``--backend actor`` drives the host actor runtime (``repro.runtime.rrfp``)
and writes ``BENCH_actor_runtime.json`` comparing hint vs. precommitted
makespan under injected jitter.  Adding ``--hint bfw --split-backward``
switches to the BFW sweep (``benchmarks.bfw_compare``): split-backward W
deferral across hints × jitter levels × workloads × backends, plus a
real-jitted-callable threaded run, emitting ``BENCH_bfw.json``.  Adding
``--chaos`` instead runs the fault-injection sweep (``benchmarks.
chaos_sweep``): both consumption modes across chaos levels C0..C3 with
per-run conformance-invariant checks, emitting ``BENCH_chaos.json``.
``--bubbles`` runs the bubble-decomposition report (``benchmarks.
bubble_decomposition``, emits ``BENCH_bubbles.json``); ``--adaptive`` runs
the adaptive-scheduling benchmark (``benchmarks.adaptive_compare``): static
hint decay vs online re-synthesis + hot-swap under drifting costs, emitting
``BENCH_adaptive.json``; ``--critpath`` runs the critical-path benchmark
(``benchmarks.critical_path``): exact makespan reconstruction plus the
causal what-if prediction gate, emitting ``BENCH_critpath.json``;
``--metrics-report`` / ``--export-perfetto PATH`` run a single
metrics-instrumented probe and print the telemetry table / write a
Chrome-trace JSON; ``--explain TRACE`` prints the one-shot critical-path
health report for a recorded trace (same output as
``python -m repro.obs.report``).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("tables", nargs="*", help="table names (default: all)")
    ap.add_argument("--backend", default="des", choices=("des", "actor"),
                    help="des: discrete-event engine; actor: host actor "
                         "runtime (emits BENCH_actor_runtime.json)")
    ap.add_argument("--hint", default="bf", choices=("bf", "bfw"),
                    help="actor backend: bf (default sweep) or bfw "
                         "(split-backward sweep, needs --split-backward)")
    ap.add_argument("--split-backward", action="store_true",
                    help="actor backend: run the BFW split-backward sweep "
                         "(emits BENCH_bfw.json)")
    ap.add_argument("--chaos", action="store_true",
                    help="actor backend: run the fault-injection sweep "
                         "with conformance checks (emits BENCH_chaos.json)")
    ap.add_argument("--recovery", action="store_true",
                    help="actor backend: run the fail-stop recovery sweep — "
                         "MTTR, post-recovery throughput and exactly-once "
                         "conformance across chaos levels × workloads × "
                         "respawn/remap (emits BENCH_recovery.json)")
    ap.add_argument("--lossy", action="store_true",
                    help="actor backend: run the lossy-network sweep — "
                         "goodput vs drop rate, MTTR under partitions and "
                         "concurrent double-kill, exactly-once + bitwise "
                         "parity gates on every cell (emits "
                         "BENCH_lossy.json)")
    ap.add_argument("--multimodal", action="store_true",
                    help="actor backend: run the multimodal DAG sweep — "
                         "readiness-driven vs pre-committed fixed order on "
                         "skewed encoder/decoder branch+fusion pipelines "
                         "(emits BENCH_multimodal.json)")
    ap.add_argument("--dispatch", action="store_true",
                    help="actor backend: dispatch-overhead microbenchmark — "
                         "per-decision arbitration cost, DES events/sec, and "
                         "the fast-vs-reference trace-identity check "
                         "(emits BENCH_dispatch.json; exits nonzero on a "
                         "dispatch-cost regression)")
    ap.add_argument("--bubbles", action="store_true",
                    help="actor backend: bubble-decomposition report — "
                         "attribute every stage's idle time to "
                         "warmup/dependency-wait/starvation/tp-gate/"
                         "backpressure/drain for BFW vs pre-committed 1F1B "
                         "on the multimodal workloads (emits "
                         "BENCH_bubbles.json; exits nonzero if attribution "
                         "is lossy)")
    ap.add_argument("--adaptive", action="store_true",
                    help="actor backend: adaptive-scheduling benchmark — "
                         "static-hint decay vs online re-synthesis + "
                         "hot-swap under drifting costs, with swap-trace "
                         "conformance (emits BENCH_adaptive.json; exits "
                         "nonzero if adaptive fails to beat static on a "
                         "drifting cell or flaps on a stationary one)")
    ap.add_argument("--critpath", action="store_true",
                    help="actor backend: critical-path benchmark — exact "
                         "makespan reconstruction across chain/DAG x chaos "
                         "x recovery cells, plus the causal what-if "
                         "predicted-vs-realized gate (emits "
                         "BENCH_critpath.json; exits nonzero if any cell "
                         "is inexact or the median prediction error "
                         "exceeds the gate)")
    ap.add_argument("--explain", metavar="TRACE", default=None,
                    help="print the critical-path health report for a "
                         "recorded trace (.jsonl) and exit — bottleneck, "
                         "what-if ranking, stragglers, bubble cross-check")
    ap.add_argument("--metrics-report", action="store_true",
                    help="actor backend: run one metrics-instrumented probe "
                         "(heavy-encoder DAG under BFW) and print the "
                         "per-stage telemetry table")
    ap.add_argument("--export-perfetto", metavar="PATH", default=None,
                    help="actor backend: with the telemetry probe, also "
                         "write a Chrome/Perfetto trace JSON to PATH")
    ap.add_argument("--json-out", default=None,
                    help="actor backend: where to write the JSON report "
                         "(default BENCH_actor_runtime.json, or "
                         "BENCH_bfw.json for the BFW sweep)")
    args = ap.parse_args()

    if args.explain:
        from repro.obs.report import main as report_main

        raise SystemExit(report_main([args.explain]))

    if args.backend == "actor":
        if args.tables:
            print(f"# --backend actor ignores table names {args.tables}",
                  file=sys.stderr)
        bfw = args.split_backward or args.hint == "bfw"
        if bfw and not (args.split_backward and args.hint == "bfw"):
            raise SystemExit(
                "--hint bfw and --split-backward go together: the BFW hint "
                "needs W tasks, which only exist under split backward")
        probe = args.metrics_report or args.export_perfetto
        if sum([args.chaos, args.recovery, args.lossy, bfw,
                args.multimodal, args.dispatch, args.bubbles, args.adaptive,
                args.critpath, bool(probe)]) > 1:
            raise SystemExit("--chaos, --recovery, --lossy, the BFW sweep, "
                             "--multimodal, --dispatch, --bubbles, "
                             "--adaptive, --critpath and the telemetry "
                             "probe (--metrics-report/--export-perfetto) "
                             "are separate reports; run them as separate "
                             "invocations")
        if probe:
            from benchmarks.bubble_decomposition import telemetry_probe

            t0 = time.time()
            print("name,us_per_call,derived")
            for row_name, us, derived in telemetry_probe(
                    export_path=args.export_perfetto,
                    metrics_report=args.metrics_report):
                print(f"{row_name},{us:.1f},{derived}")
            print(f"# telemetry probe done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
            return
        if args.bubbles:
            from benchmarks.bubble_decomposition import bubble_rows as rows_fn

            json_out = args.json_out or "BENCH_bubbles.json"
            label = "bubbles"
        elif args.dispatch:
            from benchmarks.dispatch_overhead import dispatch_rows as rows_fn

            json_out = args.json_out or "BENCH_dispatch.json"
            label = "dispatch"
        elif args.multimodal:
            from benchmarks.multimodal_compare import (
                multimodal_rows as rows_fn)

            json_out = args.json_out or "BENCH_multimodal.json"
            label = "multimodal"
        elif args.adaptive:
            from benchmarks.adaptive_compare import adaptive_rows as rows_fn

            json_out = args.json_out or "BENCH_adaptive.json"
            label = "adaptive"
        elif args.critpath:
            from benchmarks.critical_path import critpath_rows as rows_fn

            json_out = args.json_out or "BENCH_critpath.json"
            label = "critpath"
        elif args.chaos:
            from benchmarks.chaos_sweep import chaos_rows as rows_fn

            json_out = args.json_out or "BENCH_chaos.json"
            label = "chaos"
        elif args.recovery:
            from benchmarks.recovery import recovery_rows as rows_fn

            json_out = args.json_out or "BENCH_recovery.json"
            label = "recovery"
        elif args.lossy:
            from benchmarks.lossy_network import lossy_rows as rows_fn

            json_out = args.json_out or "BENCH_lossy.json"
            label = "lossy"
        elif bfw:
            from benchmarks.bfw_compare import bfw_rows as rows_fn

            json_out = args.json_out or "BENCH_bfw.json"
            label = "bfw"
        else:
            from benchmarks.actor_compare import actor_runtime_rows as rows_fn

            json_out = args.json_out or "BENCH_actor_runtime.json"
            label = "actor_runtime"
        t0 = time.time()
        print("name,us_per_call,derived")
        for row_name, us, derived in rows_fn(json_out):
            print(f"{row_name},{us:.1f},{derived}")
        print(f"# {label} done in {time.time() - t0:.1f}s "
              f"-> {json_out}", file=sys.stderr)
        return

    from benchmarks.paper_tables import ALL_TABLES

    wanted = args.tables or list(ALL_TABLES)
    unknown = [n for n in wanted if n not in ALL_TABLES]
    if unknown:
        raise SystemExit(
            f"unknown table(s) {unknown}; available: {list(ALL_TABLES)}")
    print("name,us_per_call,derived")
    for name in wanted:
        fn = ALL_TABLES[name]
        t0 = time.time()
        rows = fn()
        dt = time.time() - t0
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{derived}")
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
