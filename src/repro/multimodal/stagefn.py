"""Jitted per-stage callables + actor-runtime adapter for the DAG pipeline.

``MultimodalStageFns`` mirrors ``pipeline.stagefn.StageFns`` for the
branch+fusion topology: one independently-jitted callable per (stage, op)
that a host thread dispatches the moment the stage's message set arrives.
Backward re-runs the stage forward under ``jax.grad`` of a scalarized
objective (CE at the sink, <y, g_in> elsewhere); under BFW decomposition
the backward splits into a dX-only B and a deferrable W, exactly like the
linear-chain path.

**Shape bucketing.**  Encoder microbatches are variable-length; the batch
builder pads each one up to a bucket from a small fixed set, so jax's jit
cache retraces once per (stage, bucket) — the compile count is bounded by
the bucket count, not the number of distinct lengths (asserted by
``compile_cache_sizes`` in the bucketing tests).  The encoder math is
bitwise padding-invariant (see ``multimodal.model``), so bucketed and
unbucketed execution produce identical loss and gradient bits.

``MultimodalStageProgram`` adapts the callables to the actor runtime's
``work_fn(task, payload)`` protocol, handling the DAG payload routing: the
fusion stage's F consumes a ``{src_stage: payload}`` dict (one activation
per incoming edge) and its B returns ``EdgePayloads`` (one input gradient
per branch).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.taskgraph import Kind, Task
from repro.multimodal.model import MultimodalModel
from repro.runtime.rrfp.messages import EdgePayloads


@dataclasses.dataclass(frozen=True)
class MultimodalStageOptions:
    mb_rows: int             # microbatch rows
    loss_scale: float = 1.0  # applied to the CE objective


class MultimodalStageFns:
    """Jitted forward/backward per stage of the branch+fusion pipeline."""

    def __init__(self, model: MultimodalModel, opts: MultimodalStageOptions):
        self.model = model
        self.cfg = model.cfg
        self.opts = opts
        self._jit: dict[tuple[str, int], Any] = {}

    # ---- shared scalarized objective -----------------------------------
    def _objective(self, stage: int, p, inputs: tuple, g_in, labels):
        """CE at the sink stage; <y, g_in> elsewhere.  ``inputs`` is the
        stage's differentiable input tuple (see ``_forward_y``)."""
        y = self._forward_y(stage, p, inputs)
        if stage == self.cfg.num_stages - 1:
            return self.model.loss_sum(p, y, labels) * self.opts.loss_scale
        return jnp.sum(y.astype(jnp.float32) * g_in.astype(jnp.float32))

    def _forward_y(self, stage: int, p, inputs: tuple):
        role = self.cfg.role_of(stage)
        if role == "encoder":
            (x,), length = inputs[:-1], inputs[-1]
            return self.model.encoder_forward(stage, p, x, length)
        if role == "text":
            (tokens,) = inputs
            return self.model.text_forward(p, tokens)
        if role == "fusion":
            x_enc, x_txt, length = inputs
            return self.model.fusion_forward(p, x_enc, length, x_txt)
        (x,) = inputs
        return self.model.lm_forward(p, x)

    def _diff_inputs(self, stage: int, inputs: tuple) -> tuple:
        """The subset of ``inputs`` that carries input gradients (drops the
        integer length / token operands)."""
        role = self.cfg.role_of(stage)
        if role == "encoder":
            return (inputs[0],)
        if role == "text":
            return ()
        if role == "fusion":
            return (inputs[0], inputs[1])
        return (inputs[0],)

    def _rebuild(self, stage: int, diff: tuple, inputs: tuple) -> tuple:
        role = self.cfg.role_of(stage)
        if role == "encoder":
            return (diff[0], inputs[-1])
        if role == "text":
            return inputs
        if role == "fusion":
            return (diff[0], diff[1], inputs[2])
        return (diff[0],)

    def _get(self, op: str, stage: int, builder):
        key = (op, stage)
        if key not in self._jit:
            self._jit[key] = jax.jit(builder())
        return self._jit[key]

    # ---- public ops ----------------------------------------------------
    def forward(self, stage: int):
        """f(p, *inputs, labels) -> (y, loss_sum) — loss nonzero at sink."""
        last = stage == self.cfg.num_stages - 1

        def build():
            def f(p, inputs, labels):
                y = self._forward_y(stage, p, inputs)
                # unscaled CE sum (loss_scale seeds the backward only, like
                # the linear-chain StageFns)
                loss = (self.model.loss_sum(p, y, labels)
                        if last else jnp.zeros((), jnp.float32))
                return y, loss
            return f

        return self._get("fwd", stage, build)

    def backward(self, stage: int):
        """Fused backward: f(p, inputs, g_in, labels) -> (dxs, dp)."""
        def build():
            def b(p, inputs, g_in, labels):
                diff = self._diff_inputs(stage, inputs)

                def obj(p_, diff_):
                    return self._objective(
                        stage, p_, self._rebuild(stage, diff_, inputs),
                        g_in, labels)

                dp, dxs = jax.grad(obj, argnums=(0, 1))(p, diff)
                return dxs, dp
            return b

        return self._get("bwd", stage, build)

    def backward_dx(self, stage: int):
        """dX-only backward (the B task of the BFW decomposition)."""
        def build():
            def b(p, inputs, g_in, labels):
                diff = self._diff_inputs(stage, inputs)

                def obj(diff_):
                    return self._objective(
                        stage, p, self._rebuild(stage, diff_, inputs),
                        g_in, labels)

                return jax.grad(obj)(diff)
            return b

        return self._get("bwd_dx", stage, build)

    def weight_grad(self, stage: int):
        """Per-microbatch weight gradient (the deferrable W task)."""
        def build():
            def w(p, inputs, g_in, labels):
                def obj(p_):
                    return self._objective(stage, p_, inputs, g_in, labels)

                return jax.grad(obj)(p)
            return w

        return self._get("wgrad", stage, build)

    # ---- bucketing observability ---------------------------------------
    def compile_cache_sizes(self) -> dict[tuple[str, int], int]:
        """Live jit-cache entry count per (op, stage): the number of
        distinct input shapes traced — bounded by the bucket count for the
        variable-length encoder/fusion stages."""
        return {k: f._cache_size() for k, f in self._jit.items()}


# ---------------------------------------------------------------------------
# actor-runtime adapter
# ---------------------------------------------------------------------------
class MultimodalStageProgram:
    """``work_fn(task, payload)`` for one DAG stage driving real callables.

    Payload protocol (set by the runtime's fan-in/fan-out rules):

    * single-predecessor F tasks receive the upstream activation array;
      the fusion stage's F receives ``{src_stage: activation}``;
    * the fusion stage's B returns ``EdgePayloads`` with one input
      gradient per incoming branch; every other B returns its dx (or None
      at branch roots, whose input gradient nobody consumes);
    * W is stage-local and returns None.

    With ``deterministic_reduction=True`` per-microbatch loss/grad
    contributions are stashed and :meth:`finalize` folds them in microbatch
    order, making the final bits independent of the runtime's dispatch
    order (the conformance-parity property).
    """

    def __init__(self, fns: MultimodalStageFns, stage: int, params,
                 batch: dict, *, split_backward: bool = False,
                 deterministic_reduction: bool = False):
        self.fns = fns
        self.cfg = fns.cfg
        self.stage = stage
        self.params = params
        self.batch = batch
        self.split_backward = split_backward
        self.deterministic_reduction = deterministic_reduction
        self.residual: dict[int, tuple] = {}   # mb -> stage input tuple
        #: BFW: mb -> (inputs, g_in) held from B-time until W fires
        self.w_pending: dict[int, tuple] = {}
        self.w_high_water = 0
        self.d_params = jax.tree.map(jnp.zeros_like, params)
        self.loss_acc = jnp.zeros((), jnp.float32)
        self._mb_loss: dict[int, Any] = {}
        self._mb_grads: dict[int, Any] = {}
        self._loss_folded: int | None = None
        self._grads_folded: int | None = None

    # ---- batch slicing -------------------------------------------------
    def _mb_tokens(self, mb: int):
        r = self.fns.opts.mb_rows
        return self.batch["tokens"][mb * r:(mb + 1) * r]

    def _mb_labels(self, mb: int):
        r = self.fns.opts.mb_rows
        return self.batch["labels"][mb * r:(mb + 1) * r]

    def _mb_enc(self, mb: int):
        return self.batch["enc_embeds"][mb]

    def _mb_len(self, mb: int):
        return jnp.asarray(self.batch["enc_lens"][mb], jnp.int32)

    # ---- inputs per role -----------------------------------------------
    def _f_inputs(self, mb: int, payload) -> tuple:
        role = self.cfg.role_of(self.stage)
        if role == "encoder":
            x = payload if self.stage > 0 else jnp.asarray(self._mb_enc(mb))
            return (x, self._mb_len(mb))
        if role == "text":
            return (jnp.asarray(self._mb_tokens(mb)),)
        if role == "fusion":
            enc_src = self.cfg.enc_stages - 1
            return (payload[enc_src], payload[self.cfg.text_stage],
                    self._mb_len(mb))
        return (payload,)

    # ---- accumulation ---------------------------------------------------
    def _add_grads(self, mb: int, dp) -> None:
        if self.deterministic_reduction:
            self._mb_grads[mb] = dp
            return
        self.d_params = jax.tree.map(jnp.add, self.d_params, dp)

    def finalize(self) -> "MultimodalStageProgram":
        """Fold stashed contributions in microbatch order (idempotent; a
        fold below an already-folded microbatch raises — see
        ``ActorStageProgram.finalize`` for why mid-run folds are unsafe)."""
        def fold_guard(kind: str, folded: int | None, keys) -> int | None:
            if folded is not None and keys and min(keys) < folded:
                raise RuntimeError(
                    f"stage {self.stage}: deterministic {kind} fold of "
                    f"microbatch {min(keys)} after microbatch {folded} was "
                    f"already folded — finalize()/loss_sum was read mid-run")
            return max(keys, default=folded) if keys else folded

        self._loss_folded = fold_guard(
            "loss", self._loss_folded, list(self._mb_loss))
        for mb in sorted(self._mb_loss):
            self.loss_acc = self.loss_acc + self._mb_loss[mb]
        self._mb_loss.clear()
        self._grads_folded = fold_guard(
            "grad", self._grads_folded, list(self._mb_grads))
        for mb in sorted(self._mb_grads):
            self.d_params = jax.tree.map(
                jnp.add, self.d_params, self._mb_grads[mb])
        self._mb_grads.clear()
        return self

    @property
    def loss_sum(self) -> float:
        """Materialized loss total (forces one device sync per read)."""
        self.finalize()
        return float(self.loss_acc)

    def w_outstanding(self) -> int:
        return len(self.w_pending)

    # ---- work_fn ---------------------------------------------------------
    def __call__(self, task: Task, payload: Any) -> Any:
        cfg, fns = self.cfg, self.fns
        last = self.stage == cfg.num_stages - 1
        labels = jnp.asarray(self._mb_labels(task.mb)) if last else \
            jnp.zeros((1, 1), jnp.int32)
        if task.kind == Kind.F:
            inputs = self._f_inputs(task.mb, payload)
            y, loss = fns.forward(self.stage)(self.params, inputs, labels)
            self.residual[task.mb] = inputs
            if last:
                if self.deterministic_reduction:
                    self._mb_loss[task.mb] = loss
                else:
                    self.loss_acc = self.loss_acc + loss
            return y
        if task.kind == Kind.B:
            inputs = self.residual.pop(task.mb)
            g_in = payload if payload is not None else \
                jnp.zeros((1,), jnp.float32)
            if self.split_backward:
                self.w_pending[task.mb] = (inputs, g_in)
                self.w_high_water = max(self.w_high_water,
                                        len(self.w_pending))
                return self._emit_dx(task, inputs, g_in, labels)
            dxs, dp = fns.backward(self.stage)(
                self.params, inputs, g_in, labels)
            self._add_grads(task.mb, dp)
            return self._route_dx(dxs)
        if task.kind == Kind.W:
            if not self.split_backward:
                raise ValueError(
                    f"{task!r} dispatched to a fused-backward stage program")
            inputs, g_in = self.w_pending.pop(task.mb)
            dp = fns.weight_grad(self.stage)(
                self.params, inputs, g_in, labels)
            self._add_grads(task.mb, dp)
            return None
        raise ValueError(f"multimodal stage program cannot run {task!r}")

    def _emit_dx(self, task: Task, inputs, g_in, labels):
        """Split-backward B: dX only (skipped at branch roots — nobody
        consumes a source stage's input gradient)."""
        role = self.cfg.role_of(self.stage)
        if role == "text" or (role == "encoder" and self.stage == 0):
            return None
        dxs = self.fns.backward_dx(self.stage)(
            self.params, inputs, g_in, labels)
        return self._route_dx(dxs)

    def _route_dx(self, dxs: tuple):
        """Map the dX tuple onto the outgoing-edge payload protocol."""
        role = self.cfg.role_of(self.stage)
        if role == "fusion":
            return EdgePayloads({
                self.cfg.enc_stages - 1: dxs[0],
                self.cfg.text_stage: dxs[1],
            })
        if role == "text" or not dxs:
            return None
        return dxs[0]
