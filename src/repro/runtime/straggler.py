"""Straggler mitigation: the RRFP readiness loop at host timescale.

On GPU the paper's runtime reacts to realized readiness per task; an XLA step
is atomic, so the reaction point moves to step boundaries: per-stage step
timings update an EMA cost model (the paper's e_t estimator, RQ4) and a
sustained skew triggers schedule re-synthesis — the new table is data, so no
recompilation happens.  On persistent device loss, ``runtime.elastic`` plans
a re-mesh from the last checkpoint instead.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costs import CostModel
from repro.core.engine import RunResult
from repro.core.hints import HintKind
from repro.core.synthesis import ema_update_costs, synthesize
from repro.core.taskgraph import Kind, PipelineSpec
from repro.pipeline.spec import ScheduleTable, from_stage_orders


@dataclasses.dataclass
class StragglerMonitor:
    spec: PipelineSpec
    costs: CostModel
    threshold: float = 1.25  # re-plan when max/median stage EMA exceeds this
    decay: float = 0.9
    hint: HintKind = HintKind.BF
    min_steps_between_replans: int = 10
    _steps_since: int = 0
    replans: int = 0

    def observe(self, stage_f_times: np.ndarray,
                stage_b_times: np.ndarray) -> ScheduleTable | None:
        """Feed per-stage measured times; returns a new table when skew
        warrants re-synthesis, else None."""
        self.costs = ema_update_costs(
            self.costs, stage_f_times, stage_b_times, decay=self.decay)
        self._steps_since += 1
        skew = float(self.costs.f_cost.max() / max(np.median(self.costs.f_cost), 1e-12))
        if (skew > self.threshold
                and self._steps_since >= self.min_steps_between_replans):
            self._steps_since = 0
            self.replans += 1
            syn = synthesize(self.spec, self.costs, hint=self.hint)
            return from_stage_orders(self.spec, syn.stage_orders)
        return None

    def observe_result(self, result: RunResult) -> ScheduleTable | None:
        """EMA feedback from realized actor-runtime (or DES) task timings.

        Collapses a :class:`RunResult` trace to per-stage mean F/B durations
        and feeds :meth:`observe` — the paper's e_t estimator driven by the
        host runtime's own dispatch records instead of external profiling.
        """
        f = result.durations(Kind.F).mean(axis=1)
        b = result.durations(Kind.B).mean(axis=1)
        return self.observe(f, b)
