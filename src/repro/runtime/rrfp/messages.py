"""Message types of the host actor runtime (§4.1).

Everything that moves between stage actors is an :class:`Envelope`: a
task-readiness notification addressed to the (stage, rank) that will hold the
payload.  With tensor parallelism each logical message fans out into one
envelope per TP rank; the receiving :class:`~repro.runtime.rrfp.tp_group.TPGroup`
re-assembles them and only then admits the task into the stage's ready
buffers (§4.2).

Envelopes are deliberately payload-free in simulation mode — the payload is
the *fact of arrival*.  In thread mode the payload slot carries the actual
activation / gradient array produced by the sender's jitted stage callable.
"""
from __future__ import annotations

import dataclasses
import itertools
import zlib
from typing import Any

from repro.core.taskgraph import Task

_seq = itertools.count()


def reset_seq() -> None:
    """Rewind the global envelope sequence to zero (driver run start).

    ``seq`` only feeds trace records, but a process-global counter made a
    recorded trace depend on how many envelopes *earlier* runs in the same
    process had created.  Resetting per run makes traces deterministic
    artifacts: same seed -> byte-identical event logs, across runs and
    processes (the dispatch benchmark's paired identity check relies on
    this)."""
    global _seq
    _seq = itertools.count()


@dataclasses.dataclass(frozen=True)
class Envelope:
    """One task-readiness message in flight.

    ``task`` is receiver-side: the task this message makes ready (not the
    sender task that produced it).  ``seq`` is a global monotone id used for
    FIFO tie-breaking and tracing.

    ``epoch`` is the recovery generation the envelope was sent in.  A
    mailbox whose stage has been respawned fences every envelope from an
    earlier epoch (see :meth:`~repro.runtime.rrfp.mailbox.Mailbox.deliver`),
    so pre-failure stragglers — including chaos-delayed duplicates still in
    flight when their destination died — can never contaminate the restored
    incarnation's state.
    """

    task: Task
    src_stage: int
    dst_stage: int
    rank: int = 0
    send_time: float = 0.0
    payload: Any = None
    epoch: int = 0
    seq: int = dataclasses.field(default_factory=lambda: next(_seq))
    #: reliable-transport per-edge sequence number (-1 = the envelope is not
    #: travelling over a :class:`~repro.runtime.rrfp.transport.ReliableChannel`)
    eseq: int = -1
    #: CRC32 over the envelope's identity tuple (see :func:`envelope_checksum`);
    #: a lossy wire may corrupt it in flight, and the reliable receiver
    #: verifies it before admission (mismatch -> NACK, never delivered)
    checksum: int = 0


def envelope_checksum(env: "Envelope") -> int:
    """Deterministic integrity checksum over the envelope identity.

    Covers everything that determines what the receiver *does* with the
    message (task, edge, rank, per-edge sequence, epoch).  The payload is
    excluded: in simulation it is the fact of arrival, and on the thread
    substrate hashing a device array per send would dominate the wire — the
    identity tuple is what a corrupted header would scramble."""
    t = env.task
    return zlib.crc32(repr((
        int(t.kind), t.stage, t.mb, t.chunk,
        env.src_stage, env.dst_stage, env.rank, env.eseq, env.epoch,
    )).encode())


class EdgePayloads(dict):
    """Per-destination-stage payloads for a fan-out completion.

    A ``work_fn`` that feeds multiple successors (a DAG fan-out: e.g. the
    fusion stage's backward producing one input gradient per incoming
    branch) returns ``EdgePayloads({dst_stage: payload, ...})`` and each
    outgoing envelope carries only its edge's entry.  Any other return type
    (including a plain dict — batches are dicts) is broadcast unchanged to
    every successor.
    """


def payload_for_edge(out_payload, dst_stage: int):
    """Resolve one successor's payload from a work_fn return value."""
    if isinstance(out_payload, EdgePayloads):
        return out_payload.get(dst_stage)
    return out_payload


def envelopes_for(
    task: Task,
    src_stage: int,
    tp_degree: int,
    send_time: float = 0.0,
    payload: Any = None,
    epoch: int = 0,
) -> list[Envelope]:
    """Fan one logical message out into per-TP-rank envelopes."""
    return [
        Envelope(
            task=task,
            src_stage=src_stage,
            dst_stage=task.stage,
            rank=r,
            send_time=send_time,
            payload=payload,
            epoch=epoch,
        )
        for r in range(max(1, tp_degree))
    ]
