"""Pluggable message transports for the actor runtime (§4.1).

* :class:`SimTransport` — in-process queue transport with *injectable*
  heavy-tailed latency: each envelope's arrival is delayed by a sample from
  the :class:`~repro.core.costs.CostModel` communication jitter (per TP
  rank), delivered on the driver's virtual clock.  Sampling is keyed by
  (seed, task, rank) rather than drawn from a shared stream, so two runs in
  different consumption modes see the *same* realized latencies — common
  random numbers for apples-to-apples hint-vs-precommitted comparisons.

* :class:`ThreadTransport` — wall-clock transport between thread-per-stage
  actors in one process: ``send`` delivers straight into the destination
  mailbox (the Python-object hand-off is the wire), waking the receiver's
  condition variable.

* :class:`ReliableChannel` — a substrate-neutral reliable-delivery state
  machine layered over a lossy wire: per-edge sequence numbers, checksummed
  envelopes (:func:`~repro.runtime.rrfp.messages.envelope_checksum`),
  ACK/NACK, CRN-keyed retransmission with exponential backoff + jitter, and
  receiver-side dedup — delivery is exactly-once under arbitrary
  drop/duplicate/reorder, and a retry budget exhausting escalates the edge
  to a *link failure* the recovery coordinator handles like a stage fault.
  The channel owns only the protocol state; the substrate injects its wire
  primitives (how to transmit, how to time out, how to deliver), so the sim
  driver's virtual clock and :class:`ReliableThreadTransport`'s wall-clock
  timers run the identical protocol with identical CRN draws.
"""
from __future__ import annotations

import dataclasses
import threading
import zlib
from typing import Callable, Protocol

import numpy as np

from repro.core.costs import CostModel

from repro.runtime.rrfp import trace as tr
from repro.runtime.rrfp.mailbox import Mailbox
from repro.runtime.rrfp.messages import Envelope, envelope_checksum


class Transport(Protocol):
    def send(self, env: Envelope, now: float = 0.0) -> None:
        """Hand one envelope to the network; delivery is asynchronous."""
        ...


def rng_for(seed: int, env: Envelope) -> np.random.Generator:
    """Deterministic per-(task, rank) generator: the CRN keying."""
    t = env.task
    return np.random.default_rng(
        [seed & 0x7FFFFFFF, zlib.crc32(b"rrfp-comm"),
         int(t.kind), t.stage, t.mb, t.chunk, env.rank])


class SimTransport:
    """Virtual-time transport with sampled heavy-tailed latency.

    ``schedule(time, env)`` is the driver's event-loop hook; the transport
    never blocks and never touches wall time.
    """

    def __init__(
        self,
        costs: CostModel,
        schedule: Callable[[float, Envelope], None],
        seed: int = 0,
        on_send: Callable[[Envelope, float], None] | None = None,
    ):
        self.costs = costs
        self.schedule = schedule
        self.seed = seed
        self.on_send = on_send
        self.sent = 0

    def send(self, env: Envelope, now: float = 0.0) -> None:
        lat = self.costs.sample_comm(rng_for(self.seed, env))
        self.sent += 1
        if self.on_send is not None:
            self.on_send(env, lat)
        self.schedule(now + lat, env)


class ThreadTransport:
    """Direct mailbox-to-mailbox delivery between actor threads."""

    def __init__(self, mailboxes: dict[int, Mailbox],
                 on_send: Callable[[Envelope, float], None] | None = None):
        self.mailboxes = mailboxes
        self.on_send = on_send
        self.sent = 0

    def send(self, env: Envelope, now: float = 0.0) -> None:
        self.sent += 1
        if self.on_send is not None:
            self.on_send(env, now)
        self.mailboxes[env.dst_stage].deliver(env, now=now)


# ---------------------------------------------------------------------------
# Reliable delivery over a lossy wire
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReliableConfig:
    """Retry policy for the reliable-delivery layer.

    The RTO for attempt ``k`` is ``rto * backoff**k * (1 + jitter * U)``
    with ``U`` a CRN-keyed uniform draw per (envelope, attempt) — the same
    scenario retries at the same virtual/wall offsets in every run.  After
    ``max_retries`` unacknowledged attempts the edge is declared dead and
    escalated to the recovery coordinator as a link failure."""

    rto: float = 5e-3
    backoff: float = 2.0
    jitter: float = 0.1
    max_retries: int = 6
    #: sim substrate: virtual latency of an ACK/NACK hop
    ack_latency: float = 1e-4


@dataclasses.dataclass(frozen=True)
class Ack:
    """Receiver -> sender acknowledgement for one (edge, eseq).

    ``src``/``dst`` are the *data* edge's endpoints (the ACK travels
    dst -> src).  ``nack`` means the transmission arrived mangled
    (checksum mismatch) and the sender should retransmit immediately.
    """

    src: int
    dst: int
    eseq: int
    rank: int = 0
    attempt: int = 0
    nack: bool = False


class _Inflight:
    """One unacknowledged envelope awaiting ACK (mutable attempt counter)."""

    __slots__ = ("env", "attempt")

    def __init__(self, env: Envelope):
        self.env = env
        self.attempt = -1  # no attempt transmitted yet


class ReliableChannel:
    """Substrate-neutral exactly-once delivery state machine.

    The channel owns the protocol — per-edge sequence assignment, checksum
    stamping/verification, ACK/NACK bookkeeping, retransmission scheduling,
    receiver-side dedup, link-failure escalation — and delegates the wire to
    injected primitives:

    * ``transmit(env, attempt, now)`` — put one attempt on the (lossy) wire;
      the substrate applies chaos (drop/corrupt/partition/delay) and feeds
      surviving transmissions back into :meth:`on_wire`;
    * ``send_ack(ack, env, now)`` — return an ACK/NACK across the wire
      (``env`` rides along purely for CRN keying of ack-drop draws); the
      substrate feeds surviving acks into :meth:`on_ack`;
    * ``set_timer(delay, fn)`` — arrange ``fn(fire_time)`` after ``delay``
      substrate seconds (virtual heap event or wall-clock timer);
    * ``deliver(env, now)`` — hand a verified, first-seen envelope to the
      destination mailbox;
    * ``on_link_fail(src, dst, env, now)`` — retry budget exhausted.

    Retransmissions are byte-identical to the original envelope (same eseq,
    same epoch, same checksum): receiver-side dedup is what makes redundant
    arrivals harmless, so the sender never needs to know which attempt won.
    Timers are lazily cancelled — a stale RTO firing for an attempt that
    was already superseded (or acked) is a no-op.
    """

    def __init__(
        self,
        rcfg: ReliableConfig,
        *,
        transmit: Callable[[Envelope, int, float], None],
        send_ack: Callable[[Ack, Envelope, float], None],
        set_timer: Callable[[float, Callable[[float], None]], None],
        deliver: Callable[[Envelope, float], None],
        on_link_fail: Callable[[int, int, Envelope, float], None] | None = None,
        recorder=None,
        on_send: Callable[[Envelope, float], None] | None = None,
        seed: int = 0,
    ):
        self.rcfg = rcfg
        self._transmit = transmit
        self._send_ack = send_ack
        self._set_timer = set_timer
        self._deliver = deliver
        self._on_link_fail = on_link_fail
        self.recorder = recorder
        self.on_send = on_send
        self.seed = seed
        self._lock = threading.RLock()
        #: next eseq per (src, dst) edge
        self._next: dict[tuple[int, int], int] = {}
        #: (src, dst, eseq) -> unacknowledged envelope
        self._inflight: dict[tuple[int, int, int], _Inflight] = {}
        #: (src, dst) -> eseqs already delivered (survives stage respawn:
        #: the channel is run-scoped, so a pre-recovery duplicate arriving
        #: after the respawn still dedups here before the epoch fence)
        self._seen: dict[tuple[int, int], set[int]] = {}
        self.sent = 0
        self.retransmits = 0
        self.dedup_drops = 0
        self.corrupt_detected = 0
        self.link_failures = 0

    # ---- sender side -------------------------------------------------------
    def send(self, env: Envelope, now: float = 0.0) -> None:
        with self._lock:
            edge = (env.src_stage, env.dst_stage)
            eseq = self._next.get(edge, 0)
            self._next[edge] = eseq + 1
            env = dataclasses.replace(env, eseq=eseq)
            env = dataclasses.replace(env, checksum=envelope_checksum(env))
            self._inflight[edge + (eseq,)] = _Inflight(env)
            self.sent += 1
        if self.on_send is not None:
            self.on_send(env, now)
        self._attempt(edge + (eseq,), 0, now)

    def _rto_delay(self, env: Envelope, attempt: int) -> float:
        t = env.task
        rng = np.random.default_rng(
            [self.seed & 0x7FFFFFFF, zlib.crc32(b"rrfp-rto"),
             int(t.kind), t.stage, t.mb, t.chunk, env.rank, attempt,
             env.src_stage & 0x7FFFFFFF])
        base = self.rcfg.rto * self.rcfg.backoff ** attempt
        return base * (1.0 + self.rcfg.jitter * float(rng.random()))

    def _attempt(self, key: tuple[int, int, int], attempt: int,
                 now: float) -> None:
        escalate: Envelope | None = None
        with self._lock:
            entry = self._inflight.get(key)
            if entry is None or attempt <= entry.attempt:
                return  # acked, escalated, or a stale timer firing
            env = entry.env
            if attempt > self.rcfg.max_retries:
                # the edge is unhealable within budget: clear every inflight
                # message on it (recovery will replay them from the send log
                # — one escalation, not a stampede) and hand the fault up
                src, dst, eseq = key
                for k in [k for k in self._inflight if k[:2] == (src, dst)]:
                    del self._inflight[k]
                self.link_failures += 1
                if self.recorder is not None:
                    self.recorder.record(
                        tr.LINK_FAIL, src, env.task, rank=env.rank, t=now,
                        dst=dst, eseq=eseq, attempts=attempt)
                escalate = env
            else:
                entry.attempt = attempt
                if attempt > 0:
                    self.retransmits += 1
                    if self.recorder is not None:
                        self.recorder.record(
                            tr.RETRANSMIT, env.src_stage, env.task,
                            rank=env.rank, t=now, dst=env.dst_stage,
                            eseq=env.eseq, attempt=attempt)
        if escalate is not None:
            if self._on_link_fail is not None:
                self._on_link_fail(key[0], key[1], escalate, now)
            return
        self._transmit(env, attempt, now)
        self._set_timer(
            self._rto_delay(env, attempt),
            lambda fire_now, k=key, a=attempt: self._attempt(
                k, a + 1, fire_now))

    def on_ack(self, ack: Ack, now: float = 0.0) -> None:
        key = (ack.src, ack.dst, ack.eseq)
        with self._lock:
            entry = self._inflight.get(key)
            if entry is None:
                return  # duplicate ack for an already-settled eseq
            if not ack.nack:
                del self._inflight[key]
                return
            nxt = entry.attempt + 1
        self._attempt(key, nxt, now)

    # ---- receiver side -----------------------------------------------------
    def on_wire(self, env: Envelope, attempt: int, now: float = 0.0) -> None:
        """One transmission survived the wire; verify, dedup, ack, deliver."""
        edge = (env.src_stage, env.dst_stage)
        ack: Ack | None = None
        admit = False
        with self._lock:
            if envelope_checksum(env) != env.checksum:
                self.corrupt_detected += 1
                if self.recorder is not None:
                    self.recorder.record(
                        tr.CORRUPT, env.dst_stage, env.task, rank=env.rank,
                        t=now, src=env.src_stage, eseq=env.eseq,
                        attempt=attempt)
                ack = Ack(*edge, env.eseq, env.rank, attempt, nack=True)
            else:
                seen = self._seen.setdefault(edge, set())
                if env.eseq in seen:
                    self.dedup_drops += 1
                    if self.recorder is not None:
                        self.recorder.record(
                            tr.RDUP, env.dst_stage, env.task, rank=env.rank,
                            t=now, src=env.src_stage, eseq=env.eseq,
                            attempt=attempt)
                else:
                    seen.add(env.eseq)
                    admit = True
                ack = Ack(*edge, env.eseq, env.rank, attempt)
        # wire I/O outside the protocol lock: deliver may take the mailbox
        # condition and ack may re-enter on_ack synchronously
        self._send_ack(ack, env, now)
        if admit:
            self._deliver(env, now)

    # ---- introspection -----------------------------------------------------
    def inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

    def stats(self) -> dict:
        with self._lock:
            return {
                "sent": self.sent,
                "retransmits": self.retransmits,
                "dedup_drops": self.dedup_drops,
                "corrupt_detected": self.corrupt_detected,
                "link_failures": self.link_failures,
            }


class ReliableThreadTransport:
    """Wall-clock wire under a :class:`ReliableChannel` (thread substrate).

    Applies the chaos lossy-network model per transmission attempt — drop,
    corrupt (checksum flip), partition, plus the usual delay/duplication —
    and runs RTO timers on daemon :class:`threading.Timer` threads.  The
    ``mailboxes`` dict is the driver's *live* map: a respawned stage swaps
    its fresh mailbox in, and in-flight retransmissions land there (to be
    epoch-fenced if stale).
    """

    def __init__(
        self,
        mailboxes: dict[int, Mailbox],
        rcfg: ReliableConfig,
        chaos=None,
        seed: int = 0,
        clock: Callable[[], float] | None = None,
        recorder=None,
        on_send: Callable[[Envelope, float], None] | None = None,
        on_link_fail: Callable[[int, int, Envelope, float], None] | None = None,
    ):
        self.mailboxes = mailboxes
        self.chaos = chaos
        self.clock = clock or (lambda: 0.0)
        self.recorder = recorder
        self._timers: list[threading.Timer] = []
        self._tlock = threading.Lock()
        self.channel = ReliableChannel(
            rcfg,
            transmit=self._wire_transmit,
            send_ack=self._wire_ack,
            set_timer=self._set_timer,
            deliver=self._wire_deliver,
            on_link_fail=on_link_fail,
            recorder=recorder,
            on_send=on_send,
            seed=seed,
        )

    @property
    def sent(self) -> int:
        return self.channel.sent

    def stats(self) -> dict:
        return self.channel.stats()

    def send(self, env: Envelope, now: float = 0.0) -> None:
        self.channel.send(env, now=self.clock())

    # ---- wire primitives ---------------------------------------------------
    def _wire_transmit(self, env: Envelope, attempt: int,
                       now: float) -> None:
        copies = self.chaos.copies(env) if self.chaos is not None else 1
        for copy in range(copies):
            t_wire = self.clock()
            if self.chaos is not None and self.chaos.dropped(
                    env, t_wire, attempt, copy):
                if self.recorder is not None:
                    self.recorder.record(
                        tr.DROP, env.src_stage, env.task, rank=env.rank,
                        t=t_wire, dst=env.dst_stage, eseq=env.eseq,
                        attempt=attempt, copy=copy)
                continue
            arriving = env
            if self.chaos is not None and self.chaos.corrupted(env, attempt):
                arriving = dataclasses.replace(
                    env, checksum=env.checksum ^ (attempt + 1))
            delay = (self.chaos.comm_delay(env, copy)
                     if self.chaos is not None else 0.0)
            if delay <= 0:
                self.channel.on_wire(arriving, attempt, self.clock())
            else:
                self._set_timer(
                    delay,
                    lambda fire_now, e=arriving, a=attempt:
                        self.channel.on_wire(e, a, fire_now))

    def _wire_ack(self, ack: Ack, env: Envelope, now: float) -> None:
        if self.chaos is not None and self.chaos.ack_dropped(
                env, self.clock(), ack.attempt):
            return  # lost ack: the sender's RTO retransmits, receiver dedups
        self.channel.on_ack(ack, self.clock())

    def _wire_deliver(self, env: Envelope, now: float) -> None:
        self.mailboxes[env.dst_stage].deliver(env, now=self.clock())

    def _set_timer(self, delay: float,
                   fn: Callable[[float], None]) -> None:
        timer = threading.Timer(
            max(delay, 1e-6), lambda: fn(self.clock()))
        timer.daemon = True
        with self._tlock:
            self._timers = [t for t in self._timers if t.is_alive()]
            self._timers.append(timer)
        timer.start()

    # ---- lifecycle ---------------------------------------------------------
    def drain(self, timeout: float = 30.0) -> bool:
        """Block until nothing is unacknowledged (or timeout)."""
        import time as _time

        deadline = _time.monotonic() + timeout
        while self.channel.inflight() > 0:
            if _time.monotonic() >= deadline:
                return False
            _time.sleep(1e-3)
        return True

    def close(self) -> None:
        with self._tlock:
            for t in self._timers:
                t.cancel()
            self._timers.clear()
