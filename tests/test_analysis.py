"""Analysis tooling tests: roofline terms, memory model, cell planning."""
import numpy as np
import pytest

from repro.analysis.memory_model import cell_memory
from repro.analysis.roofline import (
    ProductionMeshShape,
    collective_bytes,
    roofline_cell,
)
from repro.configs import registry
from repro.launch.cells import all_cells, cell_is_runnable, plan_cell
from repro.models.common import SHAPES


class TestCells:
    def test_cell_matrix_size(self):
        """10 archs × 4 shapes − 7 long_500k exclusions = 33 cells."""
        cells = all_cells()
        assert len(cells) == 33
        longs = [a for a, s in cells if s == "long_500k"]
        assert sorted(longs) == ["gemma3-4b", "xlstm-350m", "zamba2-1.2b"]

    def test_long_500k_exclusion_reasoned(self):
        ok, why = cell_is_runnable("granite-34b", "long_500k")
        assert not ok and "full-attention" in why
        ok, _ = cell_is_runnable("zamba2-1.2b", "long_500k")
        assert ok

    @pytest.mark.parametrize("shape", list(SHAPES))
    def test_plan_partitions_batch(self, shape):
        mesh = ProductionMeshShape()
        plan = plan_cell("deepseek-7b", shape, mesh)
        cell = SHAPES[shape]
        if plan.step == "train":
            # all batch rows covered: dp * M * mb_rows == global batch
            assert plan.dp_total * plan.num_microbatches * plan.mb_rows \
                == cell.global_batch
        assert plan.seq_len + plan.enc_len in (cell.seq_len, cell.seq_len)

    def test_multi_pod_plan_halves_rows(self):
        p1 = plan_cell("deepseek-7b", "train_4k", ProductionMeshShape())
        p2 = plan_cell("deepseek-7b", "train_4k", ProductionMeshShape(True))
        assert p2.dp_total == 2 * p1.dp_total
        assert p2.num_microbatches * p2.mb_rows \
            == p1.num_microbatches * p1.mb_rows // 2

    def test_seamless_splits_seq(self):
        plan = plan_cell("seamless-m4t-large-v2", "train_4k",
                         ProductionMeshShape())
        assert plan.seq_len == 2048 and plan.enc_len == 2048

    def test_long500k_uses_sp(self):
        plan = plan_cell("zamba2-1.2b", "long_500k", ProductionMeshShape())
        assert plan.sp_mode and plan.step == "decode"


class TestMemoryModel:
    @pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-moe-16b",
                                      "zamba2-1.2b"])
    def test_train_breakdown_positive(self, arch):
        plan = plan_cell(arch, "train_4k", ProductionMeshShape())
        mem = cell_memory(plan)
        for k, v in mem.as_dict().items():
            assert v >= 0, k
        assert mem.params > 0 and mem.total > mem.params

    def test_decode_has_caches_not_grads(self):
        plan = plan_cell("deepseek-7b", "decode_32k", ProductionMeshShape())
        mem = cell_memory(plan)
        assert mem.caches > 0 and mem.grads == 0 and mem.opt_state == 0

    def test_sp_mode_shrinks_kv(self):
        p_full = plan_cell("gemma3-4b", "decode_32k", ProductionMeshShape())
        p_sp = plan_cell("gemma3-4b", "long_500k", ProductionMeshShape())
        m_sp = cell_memory(p_sp)
        # 500k cache sharded over 16 shards stays small
        assert m_sp.caches < 16e9

    def test_moe_expert_sharding_counted(self):
        plan = plan_cell("deepseek-moe-16b", "train_4k", ProductionMeshShape())
        mem = cell_memory(plan)
        # 16.4B params would be 2GB+/stage if replicated; EP shards experts
        assert mem.params < 1.5e9


class TestCollectiveModel:
    def test_moe_adds_a2a_bytes(self):
        from repro.pipeline import schedules
        from repro.core.taskgraph import PipelineSpec

        mesh = ProductionMeshShape()
        p_moe = plan_cell("deepseek-moe-16b", "train_4k", mesh)
        p_dense = plan_cell("deepseek-7b", "train_4k", mesh)
        t = schedules.one_f_one_b(PipelineSpec(16, 16))
        c_moe = collective_bytes(p_moe, t)
        c_dense = collective_bytes(p_dense, t)
        assert c_moe["moe"] > 0 and c_dense["moe"] == 0

    def test_sp_decode_adds_psum_bytes(self):
        p = plan_cell("zamba2-1.2b", "long_500k", ProductionMeshShape())
        c = collective_bytes(p, None)
        assert c["sp"] > 0


@pytest.mark.slow
class TestRooflineEndToEnd:
    def test_roofline_cell_smoke(self):
        r = roofline_cell("xlstm-350m", "train_4k")
        assert r.compute_s > 0 and r.memory_s > 0 and r.collective_s > 0
        assert r.dominant in ("compute", "memory", "collective")
        assert 0 < r.useful_ratio < 3
        assert 0 < r.projected_mfu < 1
