"""Record/replay determinism: a recorded trace re-executes exactly.

Three replay surfaces:

* sim substrate (time-exact): the replayed run's trace is bit-for-bit the
  recorded one, surviving a save/load roundtrip through the JSON-lines
  format;
* thread substrate (order-exact): replaying pins the per-stage dispatch
  order, reproducing an *eager* (order-sensitive) float32 reduction's loss
  and gradient bits;
* DES engine: ``EngineConfig.replay_trace`` re-executes the recorded
  arrival order as a pre-committed schedule.
"""
import dataclasses

import numpy as np
import pytest

from harness import NumpyStageProgram, make_scenario, sim_costs

from repro.core.engine import Engine, EngineConfig
from repro.runtime.rrfp import ActorConfig, ActorDriver, Trace

REPLAY_SEEDS = [7, 19, 42]


@pytest.mark.parametrize("seed", REPLAY_SEEDS)
def test_sim_replay_survives_file_roundtrip(tmp_path, seed):
    sc = make_scenario(seed)
    driver = ActorDriver(sc.spec, sim_costs(sc.spec, seed), sc.config)
    result = driver.run()
    path = tmp_path / "trace.jsonl"
    driver.trace.save(str(path))
    loaded = Trace.load(str(path))
    assert loaded.signature() == driver.trace.signature()
    assert loaded.meta["mode"] == sc.config.mode

    rdriver = ActorDriver(
        sc.spec, None, ActorConfig(record_trace=True, replay=loaded))
    replayed = rdriver.run()
    assert replayed.makespan == result.makespan
    assert rdriver.trace.signature() == driver.trace.signature()


@pytest.mark.parametrize("seed", REPLAY_SEEDS)
def test_threaded_replay_reproduces_eager_loss_bits(seed):
    """Order-exact replay pins an order-*sensitive* reduction's bits."""
    sc = make_scenario(seed, substrate="thread")
    spec = sc.spec
    S = spec.num_stages

    first = [NumpyStageProgram(s, spec, seed, deterministic=False)
             for s in range(S)]
    driver = ActorDriver(spec, None, sc.config)
    driver.run_threaded(list(first))
    trace = driver.trace

    second = [NumpyStageProgram(s, spec, seed, deterministic=False)
              for s in range(S)]
    rdriver = ActorDriver(
        spec, None,
        ActorConfig(record_trace=True, replay=trace,
                    deadlock_timeout=sc.config.deadlock_timeout))
    rdriver.run_threaded(list(second))

    assert (rdriver.trace.dispatch_orders(S)
            == trace.dispatch_orders(S))
    for a, b in zip(first, second):
        assert a.loss.tobytes() == b.loss.tobytes()
        assert a.d_w.tobytes() == b.d_w.tobytes()


@pytest.mark.parametrize("seed", REPLAY_SEEDS)
def test_engine_replays_recorded_arrival_order(seed):
    sc = make_scenario(seed)
    costs = sim_costs(sc.spec, seed)
    driver = ActorDriver(sc.spec, costs, sc.config)
    driver.run()
    trace = driver.trace

    engine = Engine(sc.spec, costs, EngineConfig(replay_trace=trace))
    result = engine.run()
    assert result.stage_orders() == trace.dispatch_orders(sc.spec.num_stages)


def test_replay_adopts_recorded_configuration():
    """Replay must not depend on the caller re-supplying mode/hint/caps."""
    sc = make_scenario(11)
    driver = ActorDriver(sc.spec, sim_costs(sc.spec, 11), sc.config)
    result = driver.run()
    # deliberately wrong defaults in the replay config
    rdriver = ActorDriver(
        sc.spec, None,
        ActorConfig(mode="precommitted", fixed_order="gpipe",
                    record_trace=True, replay=driver.trace))
    replayed = rdriver.run()
    assert replayed.makespan == result.makespan
    assert rdriver.trace.signature() == driver.trace.signature()


def test_replay_disables_chaos_resampling():
    """A replayed run must not re-inject faults on top of recorded ones."""
    sc = make_scenario(23)
    driver = ActorDriver(sc.spec, sim_costs(sc.spec, 23), sc.config)
    result = driver.run()
    rdriver = ActorDriver(
        sc.spec, None,
        dataclasses.replace(sc.config, replay=driver.trace))
    assert rdriver.run().makespan == result.makespan
