"""DeepSeekMoE-16B — fine-grained 64 routed experts top-6 + 2 shared, first
layer dense (d_ff 10944).  [arXiv:2401.06066; hf]  Expert layout: true EP
(4 experts/device over the data axis), DESIGN §3."""
import jax.numpy as jnp
from repro.models.common import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,              # moe_intermediate_size
    vocab_size=102400,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2,
                  capacity_factor=1.0, dense_d_ff=10944),   # §Perf: cf 1.25->1.0
    dtype=jnp.bfloat16,
)
