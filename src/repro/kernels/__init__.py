"""Pallas TPU kernels for model hot spots + jit'd dispatch wrappers.

Each kernel file pairs a ``pl.pallas_call`` + BlockSpec implementation with a
pure-jnp oracle in ``ref.py``; ``ops.py`` is the public API used by the model
zoo and switches between the XLA path (any backend, differentiable) and the
Pallas path (TPU target; validated on CPU with interpret=True).
"""
