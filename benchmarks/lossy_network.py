"""Lossy-network benchmark: goodput, MTTR and exactly-once under drops,
partitions and concurrent faults (emits ``BENCH_lossy.json``).

Three cell families, all on the sim substrate (virtual clock -> deterministic,
CRN-seeded), all with the reliable-delivery layer on:

* **goodput vs drop rate** — p ∈ {0, 0.01, 0.05, 0.2} i.i.d. per-transmission
  drop (plus 1% detectable corruption at p > 0): completed tasks per virtual
  second, normalized to the p=0 cell, alongside the retransmission and dedup
  counters that explain the slope;
* **MTTR under partition** — a bidirectional link blackout longer than the
  retry budget: the transport escalates the unhealable edge to a link-failure
  event and the recovery coordinator respawns the unreachable stage (the
  partition is the *detector* here — no heartbeat wait);
* **MTTR under concurrent double-kill** — two overlapping stage deaths inside
  one iteration (cascading recovery windows, total epoch fencing across
  both).  This cell also dumps its recovered trace and a Perfetto timeline
  under ``_artifacts/`` for the CI lossy smoke job to upload.

Every cell is **self-asserting**: the row carries ``exactly_once_ok`` (full
conformance including ``check_reliable_delivery``) and ``parity_ok``
(bitwise loss/grad equality against the same seed's unfailed run through
deterministic numpy stage programs), and the bench raises if either is ever
False — the JSON is a record of invariants that *held*, not a scoreboard.

    PYTHONPATH=src python -m benchmarks.run --backend actor --lossy

Set ``REPRO_SMOKE=1`` to shrink the sweep for CI smoke runs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import sys

import numpy as np

from repro.core import CostModel, PipelineSpec
from repro.runtime.rrfp import (
    ActorConfig,
    ActorDriver,
    ChaosConfig,
    ReliableConfig,
)
from repro.runtime.rrfp.conformance import holds as invariants_hold

# the parity harness lives with the conformance suite; the bench reuses it
# rather than duplicating the float32 stage programs
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                       / "tests" / "conformance"))
from harness import execute_complete_order  # noqa: E402

S, M = 4, 16
DROP_RATES = (0.0, 0.01, 0.05, 0.2)
RELIABLE = ReliableConfig(rto=0.5)
ARTIFACT_DIR = pathlib.Path("_artifacts")


def _workload() -> tuple[PipelineSpec, CostModel]:
    spec = PipelineSpec(S, M)
    costs = CostModel.uniform(S, f=1.0, b=2.0, comm_base=1e-3, seed=0)
    return spec, costs


def _parity_ok(trace, calm_trace, spec: PipelineSpec, seed: int) -> bool:
    got = execute_complete_order(trace, spec, seed)
    want = execute_complete_order(calm_trace, spec, seed)
    return all(
        want[s].loss == got[s].loss and np.array_equal(want[s].d_w,
                                                       got[s].d_w)
        for s in range(spec.num_stages))


def _run_cell(spec, costs, cfg, calm_trace, seed: int) -> tuple[dict, object]:
    driver = ActorDriver(spec, costs, cfg)
    result = driver.run()
    trace = driver.trace
    ok = invariants_hold(trace, spec, cfg)
    parity = _parity_ok(trace, calm_trace, spec, seed)
    stats = trace.meta.get("reliable_stats", {})
    row = {
        "makespan_s": result.makespan,
        "goodput_tasks_per_s": spec.total_tasks() / result.makespan,
        "sent": stats.get("sent", 0),
        "retransmits": stats.get("retransmits", 0),
        "dedup_drops": stats.get("dedup_drops", 0),
        "corrupt_detected": stats.get("corrupt_detected", 0),
        "link_failures": stats.get("link_failures", 0),
        "exactly_once_ok": ok,
        "parity_ok": parity,
    }
    return row, trace


def run_lossy_bench() -> dict:
    smoke = bool(os.environ.get("REPRO_SMOKE"))
    drop_rates = (0.0, 0.05) if smoke else DROP_RATES
    spec, costs = _workload()
    seed = 0
    base_cfg = ActorConfig(record_trace=True, seed=seed, reliable=RELIABLE)
    calm = ActorDriver(spec, costs,
                       dataclasses.replace(base_cfg, reliable=None))
    calm.run()
    rows = []

    # ---- goodput vs drop rate ---------------------------------------------
    base_goodput = None
    for p in drop_rates:
        chaos = (ChaosConfig(seed=101, drop_prob=p, corrupt_prob=0.01)
                 if p > 0 else None)
        cfg = dataclasses.replace(base_cfg, chaos=chaos)
        row, _ = _run_cell(spec, costs, cfg, calm.trace, seed)
        if base_goodput is None:
            base_goodput = row["goodput_tasks_per_s"]
        row.update({
            "cell": f"goodput/drop={p}",
            "drop_prob": p,
            "relative_goodput": row["goodput_tasks_per_s"] / base_goodput,
        })
        rows.append(row)

    # ---- MTTR under a partition (link-failure escalation, then heal) ------
    chaos = ChaosConfig(seed=202, partitions=((1, 2, 5.0, 10.0),))
    cfg = dataclasses.replace(
        base_cfg, chaos=chaos,
        reliable=ReliableConfig(rto=0.2, max_retries=4), recover=True)
    row, trace = _run_cell(spec, costs, cfg, calm.trace, seed)
    wins = trace.recovery_windows()
    row.update({
        "cell": "mttr/partition",
        "recoveries": len(wins),
        "fail_kinds": sorted({w["fail_kind"] for w in wins}),
        "mttr_s": float(np.mean([w["t_end"] - w["t_fail"] for w in wins]))
        if wins else 0.0,
    })
    assert row["link_failures"] >= 1, "partition cell never escalated"
    rows.append(row)

    # ---- MTTR under concurrent double-kill (+ drops) ----------------------
    chaos = ChaosConfig(seed=303, drop_prob=0.05,
                        fail_stages=((1, "kill", 5), (2, "kill", 7)))
    cfg = dataclasses.replace(base_cfg, chaos=chaos, recover=True)
    row, trace = _run_cell(spec, costs, cfg, calm.trace, seed)
    wins = trace.recovery_windows()
    row.update({
        "cell": "mttr/double_kill",
        "recoveries": len(wins),
        "fail_kinds": sorted({w["fail_kind"] for w in wins}),
        "mttr_s": float(np.mean([w["t_end"] - w["t_fail"] for w in wins]))
        if wins else 0.0,
    })
    assert len(wins) >= 2, "double-kill cell produced < 2 recovery windows"
    rows.append(row)
    # recovered-trace artifacts for the CI lossy smoke job (gitignored dir)
    ARTIFACT_DIR.mkdir(exist_ok=True)
    trace.save(str(ARTIFACT_DIR / "lossy_doublekill_trace.jsonl"))
    try:
        from repro.obs.export import export_perfetto

        export_perfetto(trace,
                        str(ARTIFACT_DIR / "lossy_doublekill.perfetto.json"))
    except Exception as exc:  # pragma: no cover - visualization best-effort
        print(f"# perfetto export skipped: {exc}", file=sys.stderr)

    # the bench is a gate, not just a report
    bad = [r["cell"] for r in rows
           if not (r["exactly_once_ok"] and r["parity_ok"])]
    assert not bad, f"invariant columns failed on cells: {bad}"
    return {
        "spec": {"stages": S, "microbatches": M,
                 "drop_rates": list(drop_rates),
                 "reliable": dataclasses.asdict(RELIABLE),
                 "smoke": smoke},
        "rows": rows,
    }


def emit_json(path: str = "BENCH_lossy.json") -> dict:
    report = run_lossy_bench()
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def lossy_rows(json_path: str = "BENCH_lossy.json") -> list[tuple]:
    """CSV rows for ``benchmarks.run``."""
    report = emit_json(json_path)
    out = []
    for r in report["rows"]:
        if r["cell"].startswith("goodput"):
            derived = (f"rel_goodput={r['relative_goodput']:.3f},"
                       f"retx={r['retransmits']},dedup={r['dedup_drops']}")
        else:
            derived = (f"recoveries={r['recoveries']},"
                       f"mttr={r['mttr_s'] * 1e3:.1f}ms,"
                       f"linkfail={r['link_failures']}")
        derived += (f",exactly_once={r['exactly_once_ok']},"
                    f"parity={r['parity_ok']}")
        out.append((f"lossy/{r['cell']}", r["makespan_s"] * 1e6, derived))
    return out
