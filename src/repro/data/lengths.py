"""Shared per-sample modality-token length sampling.

Multimodal samples vary strongly in encoder-token count: multi-image
samples at dynamic resolution (Qwen2-VL) and variable-duration audio
(SeamlessM4T) produce far more — or fewer — patch/frame tokens than text
tokens, with large per-sample variance.  That one distribution drives
three consumers, which previously carried parallel implementations:

* ``benchmarks.workloads`` — per-microbatch compute skew of the DES cost
  models (vision stages scale with token count, LM stages barely);
* ``data.synthetic`` — per-microbatch encoder-token counts of the real
  multimodal batches fed to the jitted DAG pipeline;
* ``repro.multimodal`` — the shape-bucketing layer that pads those
  variable lengths to a bounded bucket set so jit recompiles stay bounded.

The skew is a **mean-one lognormal**: multiplying a mean token count (or a
mean stage cost) by it preserves the mean while spreading individual
samples heavy-tailed — the §2.1 workload-dynamicity model.
"""
from __future__ import annotations

import numpy as np

#: Fig. 2-calibrated per-sample spread of vision-encoder token counts
#: (dynamic-resolution multi-image mix).
VISION_SIGMA = 0.6
#: Residual text-side variation (sequence packing is nearly uniform).
TEXT_SIGMA = 0.1


def length_skew(num: int, sigma: float,
                rng: np.random.Generator) -> np.ndarray:
    """``num`` mean-one lognormal multipliers (sigma=0 -> all ones)."""
    if sigma <= 0:
        return np.ones(num)
    return rng.lognormal(mean=-0.5 * sigma**2, sigma=sigma, size=num)


def sample_token_lengths(
    num: int,
    mean_tokens: int,
    sigma: float = VISION_SIGMA,
    *,
    seed: int = 0,
    step: int = 0,
    lo: int = 1,
    hi: int | None = None,
) -> np.ndarray:
    """Per-microbatch encoder-token counts for one training step.

    Deterministic in (seed, step) — restart-safe like the rest of the
    synthetic data pipeline.  Clipped to [lo, hi].
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 0x1E45]))
    lens = np.round(mean_tokens * length_skew(num, sigma, rng)).astype(int)
    return np.clip(lens, lo, hi if hi is not None else None)


def bucket_for(length: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket >= length; lengths beyond the largest bucket clamp
    to it (the batch builder truncates, keeping compile counts bounded)."""
    for b in sorted(buckets):
        if length <= b:
            return int(b)
    return int(max(buckets))
