"""Pallas TPU flash attention (forward), MXU-aligned BlockSpec tiling.

Grid: (batch, q_heads, q_blocks, kv_blocks) with kv innermost so the
(m, l, acc) online-softmax state lives in VMEM scratch across kv steps.
GQA maps query head h to kv head h // (hq // hkv) in the k/v index maps.
Layout: [b, h, s, hd] (transposed from the model's [b, s, h, hd] by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, sk: int, block_q: int, block_k: int,
            num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # Skip fully-masked blocks (strictly above the causal diagonal / outside
    # the sliding window).
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window > 0:
        run = jnp.logical_and(run, k_start + block_k > q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bk]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < sk
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: [b, hq, sq, hd]; k, v: [b, hkv, sk, hd] -> [b, hq, sq, hd].

    Scale (hd**-0.5) must be pre-applied to q by the caller (ops.py does).
    """
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = -(-sq // block_q)
    nk = -(-sk // block_k)
    q_pad = nq * block_q - sq
    k_pad = nk * block_k - sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, k_pad), (0, 0)))

    kernel = functools.partial(
        _kernel, causal=causal, window=window, sk=sk,
        block_q=block_q, block_k=block_k, num_k_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, nq * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
