"""Batched serving demo: pipelined one-token decode steps with stage-local
KV caches via the serve executor.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python examples/serve_batch.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import build_server

s = build_server("deepseek-7b", data=2, stages=4, layers=8, batch=8,
                 cache_len=64)
cfg = s["cfg"]
tokens = jax.random.randint(jax.random.key(7), (8,), 0, cfg.vocab_size
                            ).astype(jnp.int32)
caches = s["caches"]
seqs = [np.asarray(tokens)]
t0 = time.time()
for pos in range(24):
    tokens, caches = s["serve_step"](s["sp"], s["io"], caches,
                                     {"tokens": tokens},
                                     jnp.asarray(pos, jnp.int32))
    seqs.append(np.asarray(tokens))
dt = time.time() - t0
out = np.stack(seqs, 1)
print(f"decoded 24 tokens x batch 8 in {dt:.2f}s "
      f"({8 * 24 / dt:.1f} tok/s on host devices)")
print("sample rows:")
for row in out[:3]:
    print("  ", row.tolist())
