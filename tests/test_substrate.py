"""Substrate tests: data pipeline, checkpoint store, straggler monitor,
elastic re-meshing, optimizer math, end-to-end training descent."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.store import CheckpointStore
from repro.configs import registry
from repro.core.costs import CostModel
from repro.core.taskgraph import PipelineSpec
from repro.data.synthetic import PrefetchIterator, synth_batch
from repro.models.build import build
from repro.optim.adamw import AdamWConfig, _adamw_update, lr_at
from repro.runtime.elastic import plan_remesh, relayout_stage_params
from repro.runtime.straggler import StragglerMonitor


class TestData:
    def test_deterministic(self):
        cfg = registry.reduced_config("deepseek-7b")
        a = synth_batch(cfg, 4, 32, seed=1, step=5)
        b = synth_batch(cfg, 4, 32, seed=1, step=5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = synth_batch(cfg, 4, 32, seed=1, step=6)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = registry.reduced_config("deepseek-7b")
        a = synth_batch(cfg, 2, 16, seed=0, step=0)
        # bigram structure => labels correlate with succ(tokens)
        assert a["labels"].shape == a["tokens"].shape

    def test_modalities(self):
        vlm = registry.reduced_config("qwen2-vl-2b")
        b = synth_batch(vlm, 2, 8)
        assert "embeds" in b and "mrope" in b
        enc = registry.reduced_config("seamless-m4t-large-v2")
        b = synth_batch(enc, 2, 8, enc_len=6)
        assert b["enc_embeds"].shape == (2, 6, enc.d_model)

    def test_prefetch_resumes_from_step(self):
        seen = []
        it = PrefetchIterator(lambda s: {"step": s}, start_step=7)
        for _ in range(3):
            step, batch = next(it)
            seen.append(step)
        it.close()
        assert seen == [7, 8, 9]


class TestCheckpoint:
    def test_roundtrip_and_latest(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        store.save(3, tree, meta={"arch": "x"})
        store.save(7, jax.tree.map(lambda x: x * 2, tree))
        assert store.latest_step() == 7
        got, meta = store.restore(7, tree)
        np.testing.assert_allclose(np.asarray(got["a"]),
                                   np.asarray(tree["a"]) * 2)

    def test_gc_keeps_last_k(self, tmp_path):
        store = CheckpointStore(str(tmp_path), keep=2)
        t = {"a": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            store.save(s, t)
        assert store.list_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save(1, {"a": jnp.zeros(128)}, asynchronous=True)
        store.wait()
        assert store.latest_step() == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.save(1, {"a": jnp.zeros(4)})
        with pytest.raises(ValueError):
            store.restore(1, {"a": jnp.zeros(5)})


class TestOptimizer:
    def test_lr_schedule(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        assert float(lr_at(cfg, jnp.asarray(0))) < 2e-4
        assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1e-3, rel=0.1)
        assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(1e-4, rel=0.1)

    def test_adamw_descends_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        p = jnp.asarray(5.0)
        m = v = jnp.asarray(0.0)
        for step in range(200):
            g = 2 * p
            p, m, v = _adamw_update(cfg, p, g, m, v, step, 0.1)
        assert abs(float(p)) < 0.2


class TestRuntime:
    def test_plan_remesh(self):
        p = plan_remesh(256)
        assert (p.data, p.model) == (16, 16)
        p = plan_remesh(240)           # one node of 16 lost
        assert p.devices <= 240 and p.model in (16, 8, 4, 2)
        with pytest.raises(ValueError):
            plan_remesh(1, min_model=2)

    def test_relayout_preserves_layers(self):
        cfg = registry.reduced_config("deepseek-7b", num_layers=6)
        m_old = build(cfg, num_stages=4)
        sp = m_old.init_stage_params(jax.random.key(0))
        sp_host = jax.tree.map(np.asarray, sp)
        m_new, sp_new = relayout_stage_params(m_old, 2, sp_host)
        assert m_new.num_stages == 2
        # layer 3 lived at old (2, 0); new layout (1, 0)
        old_leaf = jax.tree.leaves(sp_host)[0]
        new_leaf = jax.tree.leaves(sp_new)[0]
        from repro.models.common import global_layer_index
        old_gli = global_layer_index(m_old.counts)
        new_gli = global_layer_index(m_new.counts)
        for g in range(6):
            so, io_ = np.argwhere(old_gli == g)[0]
            sn, in_ = np.argwhere(new_gli == g)[0]
            np.testing.assert_array_equal(old_leaf[so, io_], new_leaf[sn, in_])

    def test_straggler_triggers_resynthesis(self):
        S = 4
        mon = StragglerMonitor(
            spec=PipelineSpec(S, 8), costs=CostModel.uniform(S),
            min_steps_between_replans=1, decay=0.0)
        flat = np.ones(S)
        assert mon.observe(flat, 2 * flat) is None  # balanced: no replan
        slow = flat.copy()
        slow[2] = 3.0  # stage 2 degrades
        table = mon.observe(slow, 2 * slow)
        assert table is not None
        table.validate()
        assert mon.replans == 1


def test_end_to_end_training_descends(tmp_path):
    """Full driver: loss must descend and checkpoints must be written."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "deepseek-7b",
         "--devices", "8", "--stages", "4", "--layers", "8", "--steps", "8",
         "--seq", "64", "--microbatches", "4", "--schedule", "rrfp",
         "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "4"],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
    losses = [float(l.split("loss")[1].split()[0])
              for l in r.stdout.splitlines() if "loss" in l]
    assert len(losses) == 8
    assert losses[-1] < losses[0], losses
    assert (tmp_path / "ck" / "LATEST").exists()


def test_serve_driver_runs():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "deepseek-7b",
         "--devices", "8", "--stages", "4", "--layers", "8", "--batch", "4",
         "--tokens", "4", "--cache-len", "32"],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "tok/s" in r.stdout
