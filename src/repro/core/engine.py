"""RRFP discrete-event runtime engine (§3–§5, Appendices A/C/D).

Faithfully models the paper's runtime at task granularity:

* **Message-driven asynchronous communication** (§4.1): task completion posts a
  message; its arrival (after a sampled, possibly heavy-tailed latency) updates
  the receiver's ready buffers.  Send/receive never occupy the compute thread.
* **Ready-set arbitration** (§5/App. A): when the compute thread is free it
  scans the hint order over the *current* ready buffers and dispatches the
  first ready entry (``HINT`` mode), or — for the fixed-order baselines —
  waits for the exact next entry of a pre-committed sequence (``PRECOMMITTED``
  mode).  One schedule, two consumption modes: the paper's core contrast.
* **Backpressure** (App. C): when D_i = n_f - n_b reaches the buffer limit the
  stage switches to backward-only drain (non-interleaved) or the deterministic
  per-microbatch completion order (interleaved).
* **Tensor-parallel coordination** (§4.2/App. D): with tp_degree K, message
  arrivals are sampled per TP rank and a task only becomes ready once *all*
  ranks hold it (the group cannot agree earlier); each collective-relevant
  dispatch additionally pays a scalar all-gather overhead.  Rank-divergence
  deferrals are counted whenever the per-rank arrival spread is nonzero.

The engine records the paper's RQ2 breakdown (compute / blocking / TP-coord)
and full per-task traces for the Theorem 6.1 bound checker and the Fig. 6
bottleneck statistics.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable

import numpy as np

from repro.core.costs import CostModel
from repro.core.hints import (
    FIXED_ORDERS,
    HintArbiter,
    HintKind,
    ReadySet,
    backpressure_drain,
)
from repro.core.taskgraph import Kind, PipelineSpec, Task


class DeadlockError(RuntimeError):
    pass


@dataclasses.dataclass
class StageStats:
    compute: float = 0.0
    blocking: float = 0.0
    tp_coord: float = 0.0
    deferrals: int = 0


@dataclasses.dataclass
class RunResult:
    makespan: float
    stage_stats: list[StageStats]
    #: realized durations: dur[(task)] and start/end times
    start: dict[Task, float]
    end: dict[Task, float]
    spec: PipelineSpec
    #: structured event trace (actor runtime with record_trace=True)
    trace: object | None = None
    #: the run's :class:`repro.obs.metrics.MetricsRegistry` (actor runtime
    #: with ``ActorConfig.metrics`` attached)
    metrics: object | None = None

    # ---- derived ----------------------------------------------------------
    def durations(self, kind: Kind) -> np.ndarray:
        """[stage, mb] realized durations (chunk-summed)."""
        S, M = self.spec.num_stages, self.spec.num_microbatches
        out = np.zeros((S, M))
        for t, e in self.end.items():
            if t.kind == kind:
                out[t.stage, t.mb] += e - self.start[t]
        return out

    def breakdown(self) -> dict[str, float]:
        n = len(self.stage_stats)
        return {
            "iter": self.makespan,
            "compute": sum(s.compute for s in self.stage_stats) / n,
            "blocking": sum(s.blocking for s in self.stage_stats) / n,
            "tp_coord": sum(s.tp_coord for s in self.stage_stats) / n,
        }

    def stage_orders(self) -> list[list[Task]]:
        """Per-stage realized execution order (for schedule synthesis).

        Cached after the first call: the result is immutable post-run and
        this sits on diagnostic/synthesis paths that may poll it
        repeatedly, so the full re-sort of ``start`` must not recur.
        """
        cached = self.__dict__.get("_stage_orders")
        if cached is not None:
            return cached
        S = self.spec.num_stages
        orders: list[list[Task]] = [[] for _ in range(S)]
        for t in sorted(self.start, key=lambda t: self.start[t]):
            orders[t.stage].append(t)
        self.__dict__["_stage_orders"] = orders
        return orders


@dataclasses.dataclass
class EngineConfig:
    mode: str = "hint"  # "hint" (RRFP) | "precommitted" (fixed-order baselines)
    hint: HintKind = HintKind.BF
    fixed_order: str = "1f1b"  # for precommitted mode: key into FIXED_ORDERS
    buffer_limit: int = 32  # App. C backpressure limit (paper default)
    tp_degree: int = 1
    tp_coord_base: float = 75e-6  # scalar all-gather cost, calibrated to Table 3
    seed: int = 0
    custom_orders: list[list[Task]] | None = None  # overrides fixed_order
    #: pre-committed mode only: sends rendezvous with the receiver's matching
    #: recv (Megatron-style paired p2p, §4.1); ``send_queue`` irecvs may be
    #: posted ahead.  RRFP's message-driven comm never blocks the sender.
    sync_sends: bool = True
    send_queue: int = 1
    #: replay a recorded actor-runtime trace: the realized per-stage dispatch
    #: orders are consumed as a pre-committed schedule (order-exact replay;
    #: timing is re-sampled — use the actor driver's replay for time-exact).
    replay_trace: object | None = None
    #: verification/benchmark knob: arbitrate via the reference
    #: sort-then-rank path instead of the incremental ReadySet index.
    #: Decisions are identical by construction (the dispatch-overhead
    #: benchmark and the property suite check this); only the per-decision
    #: cost differs.
    reference_arbitration: bool = False


# --------------------------------------------------------------------------


class _Stage:
    __slots__ = (
        "idx", "ready", "arrived", "done", "busy_until", "idle_since",
        "n_f", "n_b", "arbiter", "order", "order_pos", "stats", "inj_state",
        "drain_focus", "outstanding", "send_blocked",
    )

    def __init__(self, idx: int, arbiter: HintArbiter, order: list[Task] | None):
        self.idx = idx
        self.ready = ReadySet()
        #: per-task arrived source stages (DAG fan-in needs every edge)
        self.arrived: dict[Task, set[int]] = {}
        self.done: set[Task] = set()
        self.busy_until = 0.0
        self.idle_since = 0.0
        self.n_f = 0
        self.n_b = 0
        self.arbiter = arbiter
        self.order = order
        self.order_pos = 0
        self.stats = StageStats()
        self.inj_state: dict = {}
        self.drain_focus = 0  # interleaved backpressure: focused microbatch
        self.outstanding = 0  # unmatched rendezvous sends (sync_sends mode)
        self.send_blocked = False


class Engine:
    """One training-iteration simulation."""

    def __init__(self, spec: PipelineSpec, costs: CostModel, config: EngineConfig):
        if costs.num_stages != spec.num_stages:
            raise ValueError("cost model / spec stage mismatch")
        if config.replay_trace is not None:
            # replay mode: the recorded dispatch orders ARE the schedule
            config = dataclasses.replace(
                config, mode="precommitted", sync_sends=False,
                custom_orders=config.replay_trace.dispatch_orders(
                    spec.num_stages))
        if (spec.split_backward and config.mode == "hint"
                and config.hint != HintKind.BFW):
            raise ValueError(
                f"hint mode on a split-backward spec requires HintKind.BFW "
                f"(got {config.hint}): only the BFW hint dispatches W tasks")
        self.spec = spec
        self.costs = costs
        self.config = config
        self.rng = costs.make_rng(config.seed)
        self._tp_coord_cost = (
            0.0
            if config.tp_degree <= 1
            else config.tp_coord_base * (1.0 + math.log2(config.tp_degree))
        )

    # ---- public -----------------------------------------------------------
    def run(self) -> RunResult:
        spec, cfg = self.spec, self.config
        stages = []
        for s in range(spec.num_stages):
            order = None
            if cfg.mode == "precommitted":
                if cfg.custom_orders is not None:
                    order = cfg.custom_orders[s]
                else:
                    order = FIXED_ORDERS[cfg.fixed_order](spec, s)
            stages.append(_Stage(s, HintArbiter(cfg.hint), order))
            stages[s].inj_state = self.costs.injection.make_state()

        start: dict[Task, float] = {}
        end: dict[Task, float] = {}
        events: list = []  # (time, seq, kind, payload)
        seq = 0

        def push(t: float, kind: str, payload) -> None:
            nonlocal seq
            heapq.heappush(events, (t, seq, kind, payload))
            seq += 1

        # Source stages' chunk-0 forward data is locally available at t=0
        # (stage 0 on a chain; every branch root on a DAG).
        for s0 in spec.source_stages():
            for j in range(spec.num_microbatches):
                stages[s0].ready.add(Task(Kind.F, s0, j, 0))

        total = spec.total_tasks()
        n_done = 0
        now = 0.0

        # ---- helpers -------------------------------------------------------
        def is_ready(st: _Stage, t: Task) -> bool:
            mps = spec.message_predecessors(t)
            if mps and len(st.arrived.get(t, ())) < len(mps):
                return False
            lp = spec.local_predecessor(t)
            if lp is not None and lp not in st.done:
                return False
            return True

        def maybe_enqueue_local(st: _Stage, t: Task) -> None:
            if t not in st.done and t not in st.ready and is_ready(st, t):
                st.ready.add(t)

        def backpressured(st: _Stage) -> bool:
            return (
                cfg.mode == "hint"
                and st.n_f - st.n_b >= cfg.buffer_limit
            )

        ref = cfg.reference_arbitration

        def select_backpressure(st: _Stage) -> Task | None:
            """App. C drain orders (shared impl in core.hints)."""
            task, st.drain_focus = backpressure_drain(
                spec, st.idx, sorted(st.ready) if ref else st.ready,
                st.done, st.drain_focus
            )
            return task

        def select(st: _Stage) -> Task | None:
            if cfg.mode == "precommitted":
                if st.order_pos >= len(st.order):
                    return None
                nxt = st.order[st.order_pos]
                return nxt if nxt in st.ready else None
            if backpressured(st):
                return select_backpressure(st)
            return st.arbiter.select(sorted(st.ready) if ref else st.ready)

        def dispatch(st: _Stage, t_now: float) -> None:
            """If the stage is idle, pick and start the next task."""
            if st.busy_until > t_now or st.send_blocked:
                return
            task = select(st)
            if task is None:
                return
            # TP coordination: per-dispatch scalar all-gather (F/B only).
            coord = self._tp_coord_cost if task.kind != Kind.W else 0.0
            dur = self.costs.sample_compute(task.kind, st.idx, task.mb, self.rng)
            if task.kind != Kind.W:
                dur += self.costs.injection.sample_delay(st.inj_state, dur, self.rng)
            st.stats.blocking += max(0.0, t_now - st.idle_since)
            st.stats.tp_coord += coord
            st.stats.compute += dur
            st.ready.discard(task)
            if cfg.mode == "precommitted":
                st.order_pos += 1
            begin = t_now + coord
            start[task] = begin
            st.busy_until = begin + dur
            push(st.busy_until, "complete", task)

        def arrival_time(t_now: float) -> float:
            """Message arrival; with TP, all K ranks must hold the message."""
            k = max(1, cfg.tp_degree)
            samples = [self.costs.sample_comm(self.rng) for _ in range(k)]
            return t_now + max(samples), max(samples) - min(samples)

        # rendezvous state (sync_sends / pre-committed): (succ task, sender
        # stage) -> completion time.  Keyed per edge: DAG fan-in receivers
        # rendezvous with each branch's send independently.
        pending: dict[tuple[Task, int], float] = {}
        sync = cfg.mode == "precommitted" and cfg.sync_sends

        def try_match(t_now: float) -> None:
            """Match pending sends whose receiver has posted the recv."""
            matched = []
            for (succ, sender_idx), _done_at in pending.items():
                recv = stages[succ.stage]
                # the receiver's recv window covers its next `send_queue`+1
                # order entries (irecvs posted one step ahead)
                window = []
                if recv.order is not None:
                    for k in range(recv.order_pos,
                                   min(recv.order_pos + 1 + cfg.send_queue,
                                       len(recv.order))):
                        window.append(recv.order[k])
                if succ in window or recv.order is None:
                    matched.append((succ, sender_idx))
            for succ, sender_idx in matched:
                del pending[(succ, sender_idx)]
                at, spread = arrival_time(t_now)
                if spread > 0:
                    stages[succ.stage].stats.deferrals += 1
                push(at, "message", (succ, sender_idx))
                snd = stages[sender_idx]
                snd.outstanding -= 1
                if snd.send_blocked and snd.outstanding <= cfg.send_queue:
                    snd.send_blocked = False
                    snd.idle_since = min(snd.idle_since, t_now)
                    dispatch(snd, max(t_now, snd.busy_until))

        # ---- main loop -----------------------------------------------------
        for s in range(spec.num_stages):
            dispatch(stages[s], 0.0)

        while events:
            now, _, ekind, payload = heapq.heappop(events)
            if ekind == "complete":
                task: Task = payload
                st = stages[task.stage]
                end[task] = now
                st.done.add(task)
                n_done += 1
                if task.kind == Kind.F:
                    st.n_f += 1
                elif task.kind == Kind.B:
                    st.n_b += 1
                # local successors
                if task.kind == Kind.F:
                    maybe_enqueue_local(st, Task(Kind.B, st.idx, task.mb, task.chunk))
                if task.kind == Kind.B and spec.split_backward:
                    maybe_enqueue_local(st, Task(Kind.W, st.idx, task.mb, task.chunk))
                # outgoing messages: async (RRFP sender threads) or
                # rendezvous (pre-committed paired p2p); one per out-edge
                for succ in spec.message_successors(task):
                    if sync:
                        pending[(succ, st.idx)] = now
                        st.outstanding += 1
                        if st.outstanding > cfg.send_queue:
                            st.send_blocked = True
                        try_match(now)
                    else:
                        at, spread = arrival_time(now)
                        if spread > 0:
                            stages[succ.stage].stats.deferrals += 1
                        push(at, "message", (succ, st.idx))
                st.idle_since = now
                dispatch(st, now)
                if sync:
                    # order pointers advanced: pending sends may now match
                    try_match(now)
            else:  # message arrival enabling one edge of `payload`
                tgt, src = payload
                st = stages[tgt.stage]
                st.arrived.setdefault(tgt, set()).add(src)
                if tgt not in st.done and is_ready(st, tgt):
                    st.ready.add(tgt)
                dispatch(st, now)
                if sync:
                    try_match(now)

        if n_done != total:
            missing = total - n_done
            raise DeadlockError(
                f"engine stalled with {missing} tasks unexecuted "
                f"(mode={cfg.mode}, limit={cfg.buffer_limit})"
            )
        makespan = max(end.values())
        # Blocking accounting: idle tail up to makespan counts as blocking.
        for st in stages:
            st.stats.blocking += max(0.0, makespan - st.busy_until)
        return RunResult(
            makespan=makespan,
            stage_stats=[st.stats for st in stages],
            start=start,
            end=end,
            spec=spec,
        )



# --------------------------------------------------------------------------


def run_iteration(
    spec: PipelineSpec,
    costs: CostModel,
    config: EngineConfig,
) -> RunResult:
    return Engine(spec, costs, config).run()


def average_makespan(
    spec: PipelineSpec,
    costs: CostModel,
    config: EngineConfig,
    iters: int = 10,
) -> tuple[float, float, list[RunResult]]:
    """Mean/std of makespan over ``iters`` independently-seeded iterations."""
    results = []
    for i in range(iters):
        cfg = dataclasses.replace(config, seed=config.seed + 1000 * i)
        results.append(Engine(spec, costs, cfg).run())
    xs = np.array([r.makespan for r in results])
    return float(xs.mean()), float(xs.std()), results
