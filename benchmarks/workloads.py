"""Paper workload calibration for the engine benchmarks (§7.1).

Per-stage F/B costs derive from parameter-count-based FLOP estimates of the
paper's model pairs, split across pipeline stages the way a layer-count
partitioner would (vision stages first — the source of the paper's stage
imbalance).  Jitter uses the Fig. 2-calibrated defaults; the RTX-4090 (~165
TFLOP/s fp16, ~40% eff) and batch sizes come from §7.
"""
from __future__ import annotations

import numpy as np

from repro.core.costs import CostModel, JitterModel, multimodal_stage_flops
from repro.data.lengths import TEXT_SIGMA, VISION_SIGMA, length_skew

GPU_FLOPS = 165e12 * 0.35
TOKENS = 2048  # text tokens per sample
#: vision-encoder tokens per sample: multi-image samples at dynamic
#: resolution produce far more patch tokens than text tokens (the
#: DIP/Cornstarch observation), with large per-sample variance.
VIT_TOKENS = 8192

#: forward FLOPs per microbatch ~ 2·N·tokens (per sample)
PARAMS = {
    "gpt3-large": 0.76e9,
    "qwen3-1.7b": 1.7e9,
    "qwen3-4b": 4e9,
    "llama3-8b": 8e9,
    "qwen3-32b": 32e9,
    "llama3-70b": 70e9,
    "vit-l": 0.3e9,
    "vit-h": 0.63e9,
    "vit-g": 1.0e9,
    "vit-big": 1.8e9,
    "vit-5b": 5.5e9,
    "internvit": 6e9,
    "vit-22b": 22e9,
}

#: layer counts: the paper's planner splits stages by LAYER COUNT, which is
#: exactly what creates the cost imbalance RRFP exploits (ViT layers are much
#: cheaper than LM layers).
LAYERS = {
    "gpt3-large": 24, "qwen3-1.7b": 28, "qwen3-4b": 36, "llama3-8b": 32,
    "qwen3-32b": 64, "llama3-70b": 80, "vit-l": 24, "vit-h": 32,
    "vit-g": 40, "vit-big": 48, "vit-5b": 54, "internvit": 45, "vit-22b": 48,
}

#: (d_model, vocab) for the LM-head cost carried by the *last* stage — the
#: source of the paper's last-stage dominance (Fig. 6).
HEAD_DIMS = {
    "gpt3-large": (1536, 50304),
    "qwen3-1.7b": (2048, 151936),
    "qwen3-4b": (2560, 151936),
    "llama3-8b": (4096, 128256),
    "qwen3-32b": (5120, 151936),
    "llama3-70b": (8192, 128256),
}


def _fwd_flops(params: float, micro_batch: int = 1) -> float:
    return 2.0 * params * TOKENS * micro_batch


def _head_flops(lm: str) -> float:
    d, v = HEAD_DIMS[lm]
    return 2.0 * d * v * TOKENS


def stage_costs(lm: str, vit: str | None, pp: int, tp: int = 1,
                seed: int = 0) -> CostModel:
    """CostModel for one paper workload at PP depth ``pp`` and TP ``tp``."""
    lm_f = _fwd_flops(PARAMS[lm]) / tp
    if vit is None:
        flops = np.full(pp, lm_f / pp)
    else:
        vit_f = 2.0 * PARAMS[vit] * VIT_TOKENS / tp
        # layer-count split puts the ViT on a number of leading stages
        # proportional to its DEPTH, not its cost -> imbalance (ViT layers
        # are far cheaper per layer than LM layers)
        vis_frac = LAYERS[vit] / (LAYERS[vit] + LAYERS[lm])
        flops = multimodal_stage_flops(vit_f, lm_f, pp, vis_frac)
    flops = flops.copy()
    n_vis = max(1, int(round(pp * vis_frac))) if vit is not None else 0
    flops[-1] += _head_flops(lm) / tp  # vocab head + loss live on last stage
    # Per-microbatch heterogeneity: multimodal samples vary strongly in
    # image content, and the variation is CORRELATED across the vision
    # stages that process the same microbatch (§2.1's workload dynamicity
    # on top of runtime variability).  The skew is the shared modality
    # length sampler (``repro.data.lengths``): vision-stage cost scales
    # with per-sample token count, LM-stage cost barely moves.
    skew = None
    if vit is not None:
        rng = np.random.default_rng(seed)
        per_mb_vis = length_skew(64, VISION_SIGMA, rng)
        per_mb_lm = length_skew(64, TEXT_SIGMA, rng)
        skew = np.ones((pp, 64))
        skew[:n_vis] = per_mb_vis[None, :]
        skew[n_vis:] = per_mb_lm[None, :]
    # Within-iteration comm spikes are milder than the cross-run Fig. 2
    # spread (which fig2_variability reproduces with the full model).
    return CostModel.from_stage_flops(
        flops, chip_flops=GPU_FLOPS, efficiency=1.0,
        comm_base=4e-3 / tp, mb_skew=skew, seed=seed,
        comm_jitter=JitterModel(sigma=0.35, spike_prob=0.03, spike_scale=20.0))


REPRESENTATIVE = {
    # workload: (lm, vit, global batch)
    "GPT3-Large": ("gpt3-large", None, 64),
    "Qwen3-1.7B+ViT-H": ("qwen3-1.7b", "vit-h", 192),
    "Qwen3-4B+ViT-Big": ("qwen3-4b", "vit-big", 192),
}

LARGE_SCALE = [
    # (gpus, workload, lm, vit, tp, pp, dp, batch)
    (32, "LLaMA3-8B+ViT-5B", "llama3-8b", "vit-5b", 1, 32, 1, 64),
    (32, "LLaMA3-8B+ViT-5B", "llama3-8b", "vit-5b", 2, 16, 1, 64),
    (32, "LLaMA3-8B+ViT-5B", "llama3-8b", "vit-5b", 2, 8, 2, 64),
    (64, "Qwen3-32B+InternViT", "qwen3-32b", "internvit", 1, 64, 1, 64),
    (64, "Qwen3-32B+InternViT", "qwen3-32b", "internvit", 2, 32, 1, 64),
    (64, "Qwen3-32B+InternViT", "qwen3-32b", "internvit", 2, 16, 2, 64),
    (128, "LLaMA3-70B+ViT-22B", "llama3-70b", "vit-22b", 2, 64, 1, 64),
    (128, "LLaMA3-70B+ViT-22B", "llama3-70b", "vit-22b", 2, 32, 2, 64),
]
