"""§4.2 TP all-ranks gate under adversarial message reordering/duplication.

The tensor-parallel admission barrier must be correct for *every* arrival
interleaving: a task enters the stage's ready buffers exactly when its last
rank's copy lands, exactly once, with duplicated envelopes (network-level
retransmits, chaos injection) fully idempotent — before, between, and after
admission.  These tests enumerate interleavings exhaustively where feasible
and drive full chaotic runs where not.
"""
import itertools

import pytest

from repro.core import CostModel, JitterModel, PipelineSpec
from repro.core.taskgraph import Kind, Task
from repro.runtime.rrfp import (
    ActorConfig,
    ChaosConfig,
    Envelope,
    Mailbox,
    TPGroup,
    envelopes_for,
    run_actor_iteration,
)


def det_costs(S, comm=1e-4):
    return CostModel.uniform(
        S, comm_base=comm,
        compute_jitter=JitterModel(), comm_jitter=JitterModel())


# ---------------------------------------------------------------------------
# exhaustive interleavings of two tasks' rank sets
# ---------------------------------------------------------------------------
class TestAdversarialReorder:
    @pytest.mark.parametrize("tp", [2, 3])
    def test_every_interleaving_admits_at_last_rank(self, tp):
        """All (2·tp choose tp) interleavings of two tasks' rank envelopes:
        each task admits exactly at its own last-rank arrival."""
        t_a, t_b = Task(Kind.F, 0, 0), Task(Kind.F, 0, 1)
        env_a = envelopes_for(t_a, src_stage=1, tp_degree=tp)
        env_b = envelopes_for(t_b, src_stage=1, tp_degree=tp)
        for pattern in itertools.permutations("a" * tp + "b" * tp, 2 * tp):
            g = TPGroup(stage=0, tp_degree=tp)
            seen = {"a": 0, "b": 0}
            admitted = []
            for i, which in enumerate(pattern):
                env = (env_a if which == "a" else env_b)[seen[which]]
                seen[which] += 1
                adm = g.offer(env, now=float(i))
                if adm is not None:
                    admitted.append((adm.task, seen[which]))
            # both admitted, each exactly at its tp-th envelope
            assert [n for _, n in admitted] == [tp, tp]
            assert sorted(t for t, _ in admitted) == sorted([t_a, t_b])
            assert g.pending() == {}

    @pytest.mark.parametrize("tp", [2, 4])
    def test_reversed_and_rotated_rank_orders(self, tp):
        """Rank arrival order (identity, reversed, every rotation) never
        changes the admission outcome, only the recorded spread."""
        t = Task(Kind.B, 2, 5)
        envs = envelopes_for(t, src_stage=3, tp_degree=tp)
        orders = [list(range(tp)), list(reversed(range(tp)))] + [
            list(range(r, tp)) + list(range(r)) for r in range(1, tp)]
        for order in orders:
            g = TPGroup(stage=2, tp_degree=tp)
            adms = [g.offer(envs[r], now=float(i))
                    for i, r in enumerate(order)]
            assert all(a is None for a in adms[:-1])
            assert adms[-1] is not None
            assert adms[-1].spread == float(tp - 1)

    def test_interleaved_tasks_admit_in_completion_order_not_send_order(self):
        """A task sent *later* but completed *earlier* (rank reordering)
        admits first — admission tracks completion of the rank set."""
        mb = Mailbox(stage=1, tp_degree=2)
        early, late = Task(Kind.F, 1, 0), Task(Kind.F, 1, 1)
        e0, e1 = envelopes_for(early, src_stage=0, tp_degree=2)
        l0, l1 = envelopes_for(late, src_stage=0, tp_degree=2)
        assert mb.deliver(e0, now=0.0) is None   # early: rank 0 only
        assert mb.deliver(l0, now=1.0) is None
        assert mb.deliver(l1, now=2.0) is not None  # late completes first
        assert mb.arrived_tasks() == [late]
        assert mb.deliver(e1, now=3.0) is not None
        assert mb.arrived_tasks() == [late, early]


# ---------------------------------------------------------------------------
# duplicated envelopes
# ---------------------------------------------------------------------------
class TestDuplication:
    def test_full_duplicate_set_does_not_readmit(self):
        """A complete duplicated rank set after admission must not re-buffer
        the task (pre-hardening this re-ran the admission protocol)."""
        mb = Mailbox(stage=0, tp_degree=2)
        t = Task(Kind.F, 0, 0)
        envs = envelopes_for(t, src_stage=1, tp_degree=2)
        for env in envs:
            mb.deliver(env, now=0.0)
        assert mb.arrived_tasks() == [t]
        for env in envs:  # retransmit the whole set
            assert mb.deliver(env, now=1.0) is None
        assert mb.arrived_tasks() == [t]  # still buffered exactly once
        assert mb.group.admitted == 1
        assert mb.group.duplicates == 2

    def test_duplicate_after_consume_does_not_resurrect_payload(self):
        """A retransmit landing after the actor consumed the task must not
        re-stash a payload nobody will ever pop (unbounded memory)."""
        mb = Mailbox(stage=0, tp_degree=1)
        t = Task(Kind.F, 0, 0)
        env = Envelope(task=t, src_stage=1, dst_stage=0, payload="act")
        mb.deliver(env, now=0.0)
        assert mb.consume(t) == "act"
        mb.deliver(env, now=1.0)  # late retransmit
        assert t not in mb.payloads
        assert mb.arrived_tasks() == []

    def test_duplicate_mid_set_keeps_first_arrival_time(self):
        g = TPGroup(stage=0, tp_degree=2)
        t = Task(Kind.F, 0, 0)
        e0, e1 = envelopes_for(t, src_stage=1, tp_degree=2)
        assert g.offer(e0, now=0.0) is None
        assert g.offer(e0, now=5.0) is None  # duplicate: first arrival wins
        adm = g.offer(e1, now=1.0)
        assert adm is not None and adm.spread == pytest.approx(1.0)
        assert g.duplicates == 1

    def test_chaotic_duplication_full_run_executes_exactly_once(self):
        """End-to-end: duplicate *every* envelope (TP=2) through a whole
        iteration; every task still executes exactly once and all
        dependencies hold."""
        spec = PipelineSpec(4, 6)
        chaos = ChaosConfig(seed=3, duplicate_prob=1.0, max_duplicates=2,
                            latency_base=1e-3, reorder_prob=0.5,
                            reorder_window=5e-3)
        r = run_actor_iteration(
            spec, det_costs(4), ActorConfig(mode="hint", tp_degree=2,
                                            chaos=chaos, record_trace=True))
        assert set(r.end) == set(spec.tasks())
        for t in spec.tasks():
            for p in spec.predecessors(t):
                assert r.start[t] >= r.end[p] - 1e-12
        # the trace shows the dup-suppression actually firing
        dups = [ev for ev in r.trace.events if ev.kind == "tp_dup"]
        assert dups, "chaos duplication produced no tp_dup events"
        dispatches = [ev for ev in r.trace.events if ev.kind == "dispatch"]
        assert len(dispatches) == spec.total_tasks()
