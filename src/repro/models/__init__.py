"""Model zoo: pure-JAX implementations of the ten assigned architectures."""
from repro.models.build import ArchModel, build
from repro.models.common import ArchConfig, MoEConfig, SHAPES, SSMConfig, ShapeCell
