"""Actor-runtime driver: builds the actors, pumps messages, records traces.

Two execution substrates behind one configuration:

* ``run()`` — :class:`~repro.runtime.rrfp.transport.SimTransport` on a
  virtual clock.  Arrivals and completions are heap events; actors make
  every dispatch decision reactively (no schedule-table tick).  Compute and
  communication samples are keyed per task (common random numbers), so hint
  vs. precommitted runs on the same seed experience the same realized
  variability — the paper's one-schedule-two-consumption-modes contrast
  isolated from sampling noise.

* ``run_threaded(work_fn)`` — thread-per-stage actors over the
  :class:`~repro.runtime.rrfp.transport.ThreadTransport`, executing real
  work callables (e.g. jitted stage functions from
  ``repro.pipeline.stagefn``) on the wall clock.

Both return the DES engine's :class:`~repro.core.engine.RunResult`, so
``benchmarks/``, the Theorem 6.1 bound checker and
``runtime.straggler`` consume actor traces unchanged.

Record / chaos / replay (the conformance machinery):

* ``ActorConfig.record_trace`` threads a
  :class:`~repro.runtime.rrfp.trace.TraceRecorder` through every mailbox,
  TP gate, transport and actor; after a run the full event log is on
  ``driver.trace`` (and ``RunResult.trace``).
* ``ActorConfig.chaos`` plugs a :class:`~repro.runtime.rrfp.chaos.ChaosEngine`
  into the delivery and compute paths of both substrates: per-edge latency,
  message reorder/duplication, stage stragglers and transient stalls, all
  CRN-keyed so the same scenario hits every consumption mode identically.
* ``ActorConfig.replay`` re-executes a recorded trace.  On the sim
  substrate replay is *time-exact*: a
  :class:`~repro.runtime.rrfp.trace.ReplayOracle` substitutes the recorded
  delivery times and task durations for every sample, so the event heap
  evolves identically and the replayed trace is bit-for-bit the recorded
  one.  On the thread substrate replay is *order-exact*: the recorded
  per-stage dispatch orders are consumed as a pre-committed schedule, which
  pins the floating-point reduction order and therefore the loss/grad bits.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import zlib
from typing import Any, Callable

import numpy as np

from repro.core.costs import CostModel
from repro.core.engine import DeadlockError, RunResult, StageStats
from repro.core.hints import FIXED_ORDERS, HintKind
from repro.core.taskgraph import Kind, PipelineSpec, Task

from repro.runtime.rrfp import trace as _tr
from repro.runtime.rrfp.actor import StageActor
from repro.runtime.rrfp.chaos import ChaosConfig, ChaosEngine, ChaosThreadTransport
from repro.runtime.rrfp.mailbox import Mailbox
from repro.runtime.rrfp.messages import Envelope, envelopes_for, reset_seq
from repro.runtime.rrfp.trace import ReplayOracle, Trace, TraceRecorder
from repro.runtime.rrfp.transport import SimTransport, ThreadTransport


@dataclasses.dataclass
class ActorConfig:
    """Runtime configuration (mirrors ``EngineConfig`` where they overlap)."""

    mode: str = "hint"  # "hint" (RRFP) | "precommitted" (fixed-order baselines)
    hint: HintKind = HintKind.BF
    fixed_order: str = "1f1b"  # precommitted mode: key into FIXED_ORDERS
    custom_orders: list[list[Task]] | None = None  # overrides fixed_order
    buffer_limit: int = 32  # App. C backpressure limit
    #: BFW: max outstanding un-executed W tasks per stage (each holds one
    #: stashed (x, g_in) activation pair); 0 = unbounded deferral
    w_defer_cap: int = 0
    tp_degree: int = 1
    tp_coord_base: float = 75e-6  # scalar all-gather cost (Table 3)
    seed: int = 0
    #: thread mode: seconds of mailbox starvation before DeadlockError
    deadlock_timeout: float = 30.0
    #: fault injection scenario (None = no chaos)
    chaos: ChaosConfig | None = None
    #: record a structured event trace (driver.trace / RunResult.trace)
    record_trace: bool = False
    #: re-execute a recorded trace (time-exact on sim, order-exact threaded)
    replay: Trace | None = None
    #: record full sorted ready-set snapshots on every dispatch instead of
    #: the cheap incremental diff encoding (``Trace.ready_sets()`` decodes
    #: both) — opt-in, for human-readable traces
    trace_full_ready: bool = False
    #: verification/benchmark knob: arbitrate via the reference
    #: sort-then-rank path instead of the incremental ReadySet index
    #: (decision-identical by construction; only per-decision cost differs)
    reference_arbitration: bool = False
    #: observability: a :class:`repro.obs.metrics.MetricsRegistry` whose
    #: per-stage shards the runtime feeds (None = zero-cost).  Reuse one
    #: registry across steps to accumulate and keep cost EWMAs warm.
    #: Metrics never alter scheduling decisions (CI's paired-trace check);
    #: with a recorder also attached they add info annotations (e.g.
    #: ``ewma`` on COMPLETE) that replay tolerates.
    metrics: Any | None = None


def _compute_rng(seed: int, task: Task) -> np.random.Generator:
    return np.random.default_rng(
        [seed & 0x7FFFFFFF, zlib.crc32(b"rrfp-compute"),
         int(task.kind), task.stage, task.mb, task.chunk])


class ActorDriver:
    """One training iteration through the actor runtime."""

    def __init__(self, spec: PipelineSpec, costs: CostModel | None,
                 config: ActorConfig):
        if costs is not None and costs.num_stages != spec.num_stages:
            raise ValueError("cost model / spec stage mismatch")
        if (spec.split_backward and config.mode == "hint"
                and config.replay is None
                and config.hint != HintKind.BFW):
            raise ValueError(
                f"hint mode on a split-backward spec requires HintKind.BFW "
                f"(got {config.hint}): only the BFW hint dispatches W tasks")
        self.spec = spec
        self.costs = costs
        self.config = config
        #: event log of the last run (when record_trace was set)
        self.trace: Trace | None = None

    # ------------------------------------------------------------------
    def _meta(self, cfg: ActorConfig, substrate: str) -> dict:
        spec = self.spec
        return {
            "substrate": substrate,
            "mode": cfg.mode,
            "hint": cfg.hint.value,
            "fixed_order": cfg.fixed_order,
            "buffer_limit": cfg.buffer_limit,
            "w_defer_cap": cfg.w_defer_cap,
            "tp_degree": cfg.tp_degree,
            "seed": cfg.seed,
            "num_stages": spec.num_stages,
            "num_microbatches": spec.num_microbatches,
            "num_chunks": spec.num_chunks,
            "split_backward": spec.split_backward,
            "graph": ([list(e) for e in spec.graph.edges]
                      if spec.graph is not None else None),
            "chaos": cfg.chaos.to_json() if cfg.chaos is not None else None,
            "trace_ready": "full" if cfg.trace_full_ready else "diff",
        }

    def _effective_config(self, substrate: str) -> ActorConfig:
        """Resolve replay: adopt the recorded run's scheduling parameters.

        Sim replays keep the recorded consumption mode (decisions re-derive
        identically from the replayed arrivals); thread replays consume the
        realized dispatch orders as a pre-committed schedule.
        """
        cfg = self.config
        if cfg.replay is None:
            return cfg
        meta = cfg.replay.meta
        cfg = dataclasses.replace(
            cfg,
            mode=meta.get("mode", cfg.mode),
            hint=HintKind(meta.get("hint", cfg.hint.value)),
            buffer_limit=meta.get("buffer_limit", cfg.buffer_limit),
            w_defer_cap=meta.get("w_defer_cap", cfg.w_defer_cap),
            tp_degree=meta.get("tp_degree", cfg.tp_degree),
            chaos=None,  # realized durations/arrivals already include chaos
        )
        if substrate == "thread" or cfg.mode == "precommitted":
            # order-exact replay: realized orders become the schedule
            cfg = dataclasses.replace(
                cfg, mode="precommitted",
                custom_orders=cfg.replay.dispatch_orders(self.spec.num_stages))
        return cfg

    def _build_actors(
        self, cfg: ActorConfig, recorder: TraceRecorder | None,
    ) -> tuple[list[Mailbox], list[StageActor]]:
        spec = self.spec
        mailboxes, actors = [], []
        for s in range(spec.num_stages):
            order = None
            if cfg.mode == "precommitted":
                if cfg.custom_orders is not None:
                    order = cfg.custom_orders[s]
                else:
                    order = FIXED_ORDERS[cfg.fixed_order](spec, s)
            shard = (cfg.metrics.shard(s)
                     if cfg.metrics is not None else None)
            mb = Mailbox(s, cfg.tp_degree, recorder=recorder,
                         fan_in=spec.fan_in, metrics=shard)
            mailboxes.append(mb)
            actors.append(StageActor(
                s, spec, mb, mode=cfg.mode, hint=cfg.hint, order=order,
                buffer_limit=cfg.buffer_limit, w_defer_cap=cfg.w_defer_cap,
                reference_arbitration=cfg.reference_arbitration,
                trace_full_ready=cfg.trace_full_ready, metrics=shard))
        return mailboxes, actors

    def _seed_inputs(self, mailboxes: list[Mailbox]) -> None:
        """Source stages' chunk-0 forward inputs are locally available at
        t=0 (stage 0 on a chain; every branch root on a DAG)."""
        for s in self.spec.source_stages():
            for j in range(self.spec.num_microbatches):
                mailboxes[s].deliver_local(Task(Kind.F, s, j, 0))

    # ---- simulation substrate -----------------------------------------
    def run(self) -> RunResult:
        spec = self.spec
        reset_seq()  # envelope seqs are run-local: traces stay byte-stable
        cfg = self._effective_config("sim")
        oracle = ReplayOracle(cfg.replay) if cfg.replay is not None else None
        if self.costs is None and oracle is None:
            raise ValueError("simulation mode requires a CostModel")
        costs = self.costs
        recorder = (TraceRecorder(self._meta(cfg, "sim"))
                    if cfg.record_trace else None)
        chaos = (ChaosEngine(cfg.chaos)
                 if cfg.chaos is not None and cfg.chaos.active() else None)
        mailboxes, actors = self._build_actors(cfg, recorder)

        events: list = []  # (time, seq, kind, payload)
        seq = 0

        def push(t: float, ekind: str, payload) -> None:
            nonlocal seq
            heapq.heappush(events, (t, seq, ekind, payload))
            seq += 1

        def schedule_delivery(t: float, env: Envelope) -> None:
            """Transport hook; the chaos layer perturbs the arrival here."""
            if chaos is None:
                push(t, "deliver", env)
                return
            for copy in range(chaos.copies(env)):
                push(t + chaos.comm_delay(env, copy), "deliver", env)

        def record_send(env: Envelope, _lat: float) -> None:
            if recorder is not None:
                recorder.record(_tr.SEND, env.src_stage, env.task,
                                rank=env.rank, t=env.send_time, seq=env.seq)

        transport = SimTransport(
            costs, schedule=schedule_delivery, seed=cfg.seed,
            on_send=record_send) if oracle is None else None

        def send_messages(succ: Task, src: int, now: float) -> None:
            for env in envelopes_for(succ, src, cfg.tp_degree, send_time=now):
                if oracle is None:
                    transport.send(env, now=now)
                else:
                    record_send(env, 0.0)
                    for at in oracle.delivery_times(env.task, env.rank,
                                                    env.src_stage):
                        push(at, "deliver", env)

        inj_states = [
            costs.injection.make_state() if costs is not None else None
            for _ in range(spec.num_stages)]
        busy_until = [0.0] * spec.num_stages
        idle_since = [0.0] * spec.num_stages
        start: dict[Task, float] = {}
        end: dict[Task, float] = {}
        n_done = 0
        total = spec.total_tasks()

        self._seed_inputs(mailboxes)
        for a in actors:
            a.sync_mailbox()

        def task_duration(s: int, task: Task) -> float:
            if oracle is not None:
                return oracle.duration(task)
            rng = _compute_rng(cfg.seed, task)
            dur = costs.sample_compute(task.kind, s, task.mb, rng)
            if task.kind != Kind.W:
                dur += costs.injection.sample_delay(inj_states[s], dur, rng)
            if chaos is not None:
                # straggler slowdown + transient stall, folded into the
                # realized duration (and therefore into recorded traces)
                dur = dur * chaos.compute_scale(s) + chaos.stall(task)
            return dur

        def try_dispatch(s: int, now: float) -> None:
            actor = actors[s]
            if busy_until[s] > now:
                return
            task, sel_info = actor.select_traced()
            if task is None:
                return
            actor.begin(task, now=now, info=sel_info)
            coord = mailboxes[s].group.coordination_cost(task, cfg.tp_coord_base)
            dur = task_duration(s, task)
            actor.stats.blocking += max(0.0, now - idle_since[s])
            actor.stats.tp_coord += coord
            actor.stats.compute += dur
            begin = now + coord
            start[task] = begin
            busy_until[s] = begin + dur
            push(busy_until[s], "complete", task)

        for s in range(spec.num_stages):
            try_dispatch(s, 0.0)

        while events:
            now, _, ekind, payload = heapq.heappop(events)
            if ekind == "complete":
                task: Task = payload
                s = task.stage
                end[task] = now
                n_done += 1
                succs = actors[s].complete(task, now=now, dur=now - start[task])
                for succ in succs:
                    send_messages(succ, s, now)
                idle_since[s] = now
                try_dispatch(s, now)
            else:  # deliver
                env: Envelope = payload
                s = env.dst_stage
                adm = mailboxes[s].deliver(env, now=now)
                if adm is not None:
                    actors[s].sync_mailbox()
                    try_dispatch(s, now)

        if recorder is not None:
            self.trace = recorder.trace()
        if n_done != total:
            starved = {
                a.idx: a.waiting_on()[:4] for a in actors if not a.finished()
            }
            raise DeadlockError(
                f"actor runtime stalled with {total - n_done} tasks "
                f"unexecuted (mode={cfg.mode}); starved stages -> first "
                f"missing messages: {starved}")
        makespan = max(end.values())
        for s, a in enumerate(actors):
            a.stats.blocking += max(0.0, makespan - busy_until[s])
            a.stats.deferrals = mailboxes[s].group.deferrals
        if recorder is not None:
            recorder.meta["makespan"] = makespan
            self.trace = recorder.trace()
        return RunResult(
            makespan=makespan,
            stage_stats=[a.stats for a in actors],
            start=start,
            end=end,
            spec=spec,
            trace=self.trace,
            metrics=cfg.metrics,
        )

    # ---- thread-per-stage substrate ------------------------------------
    def run_threaded(
        self,
        work_fn: Callable[[Task, Any], Any] | list[Callable[[Task, Any], Any]],
    ) -> RunResult:
        """Drive real per-stage callables with thread actors (wall clock).

        ``work_fn(task, payload)`` (or one callable per stage) performs the
        actual computation and returns the payload for the outgoing message.
        """
        import time as _time

        spec = self.spec
        reset_seq()  # envelope seqs are run-local: traces stay byte-stable
        cfg = self._effective_config("thread")
        recorder = (TraceRecorder(self._meta(cfg, "thread"))
                    if cfg.record_trace else None)
        chaos = (ChaosEngine(cfg.chaos)
                 if cfg.chaos is not None and cfg.chaos.active() else None)
        mailboxes, actors = self._build_actors(cfg, recorder)
        t0 = _time.perf_counter()
        clock = lambda: _time.perf_counter() - t0  # noqa: E731

        def record_send(env: Envelope, now: float) -> None:
            if recorder is not None:
                recorder.record(_tr.SEND, env.src_stage, env.task,
                                rank=env.rank, t=now, seq=env.seq)

        mb_map = {m.stage: m for m in mailboxes}
        if chaos is not None:
            transport = ChaosThreadTransport(mb_map, chaos,
                                             on_send=record_send)
        else:
            transport = ThreadTransport(mb_map, on_send=record_send)
        work_fns = (work_fn if isinstance(work_fn, list)
                    else [work_fn] * spec.num_stages)
        if chaos is not None:
            def chaotic(fn):
                def wrapped(task, payload):
                    d = chaos.thread_delay(task)
                    if d > 0:
                        if recorder is not None:
                            recorder.record(_tr.STALL, task.stage, task,
                                            t=clock(), dur=d)
                        _time.sleep(d)
                    return fn(task, payload)
                return wrapped

            work_fns = [chaotic(fn) for fn in work_fns]
        abort = threading.Event()
        errors: list[BaseException] = []

        def runner(actor: StageActor):
            try:
                actor.run_thread(
                    work_fns[actor.idx], transport, clock,
                    tp_degree=cfg.tp_degree,
                    deadlock_timeout=cfg.deadlock_timeout,
                    abort=abort,
                )
            except BaseException as e:  # noqa: BLE001 - reraised on join
                errors.append(e)
                abort.set()
                # Event-driven wakeups have no poll period to fall back on:
                # sibling actors blocked on their mailbox condition must be
                # notified, or they sleep until their starvation deadline.
                for m in mailboxes:
                    m.stop()

        self._seed_inputs(mailboxes)
        threads = [
            threading.Thread(target=runner, args=(a,), name=f"stage-{a.idx}",
                             daemon=True)
            for a in actors
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if isinstance(transport, ChaosThreadTransport):
            # chaos duplicates may still be in flight; land them before
            # stopping so no timer outlives the run
            transport.drain(timeout=cfg.deadlock_timeout)
        for m in mailboxes:
            m.stop()
        if recorder is not None:
            self.trace = recorder.trace()
        if errors:
            raise errors[0]
        start = {tr.task: tr.start for a in actors for tr in a.traces}
        end = {tr.task: tr.end for a in actors for tr in a.traces}
        if len(end) != spec.total_tasks():
            raise DeadlockError(
                f"threaded run finished {len(end)}/{spec.total_tasks()} tasks")
        makespan = max(end.values())
        for a in actors:
            a.stats.blocking += max(
                0.0, makespan - max(tr.end for tr in a.traces))
            a.stats.deferrals = a.mailbox.group.deferrals
        if recorder is not None:
            recorder.meta["makespan"] = makespan
            self.trace = recorder.trace()
        return RunResult(
            makespan=makespan,
            stage_stats=[a.stats for a in actors],
            start=start,
            end=end,
            spec=spec,
            trace=self.trace,
            metrics=cfg.metrics,
        )


# --------------------------------------------------------------------------
def run_actor_iteration(
    spec: PipelineSpec, costs: CostModel, config: ActorConfig
) -> RunResult:
    return ActorDriver(spec, costs, config).run()


def average_makespan_actor(
    spec: PipelineSpec,
    costs: CostModel,
    config: ActorConfig,
    iters: int = 10,
) -> tuple[float, float, list[RunResult]]:
    """Mean/std of makespan over independently-seeded iterations (CRN per seed)."""
    results = []
    for i in range(iters):
        cfg = dataclasses.replace(config, seed=config.seed + 1000 * i)
        results.append(ActorDriver(spec, costs, cfg).run())
    xs = np.array([r.makespan for r in results])
    return float(xs.mean()), float(xs.std()), results
