"""Adaptive scheduling benchmark: static-hint decay vs adaptive hold under
drifting costs (emits ``BENCH_adaptive.json``).

The closed loop under test (``repro.runtime.adaptive``): measured per-stage
costs accumulate in the :class:`MetricsRegistry` EWMAs; at every iteration
boundary the :class:`AdaptiveScheduler` snapshots them, re-synthesizes a
candidate hint table, prices candidate-vs-active with the DES engine, and
hot-swaps when the drift detector's threshold+hysteresis fire.

Each cell runs K training iterations of the same pipeline on the sim
substrate with **jitter-free** base costs plus a deterministic drifting-cost
chaos profile (``drift_chaos``): a ``step`` regime change (a stage lands on
a time-shared device) or a slow ``ramp`` (thermal throttling).  Per-step
makespans are therefore deterministic — every adaptive-vs-static gap is
schedule quality, not sampling noise.  Three arms per cell:

* **static** — the table synthesized once from the base costs, never
  refreshed: the schedule the paper's offline synthesis would ship;
* **adaptive** — same initial table, plus the online re-synthesis loop;
* **precommitted** — fixed-order 1F1B/ZB baseline for context.

Invariants asserted on every run of this benchmark:

* on each **drifting** cell the adaptive arm's late-window mean makespan is
  strictly below the static arm's, and at least one swap fired;
* on the **stationary** cell the two arms' per-step makespans are
  *identical* and the detector never swaps (no flapping: the candidate
  re-derives the active table and the improvement ratio pins to 1.0).

Also writes ``BENCH_adaptive_trace.json`` next to the JSON report: a
recorded sim run with a mid-run ``HINT_SWAP`` (old table -> post-drift
table at a quiesce point), passed through the full conformance gauntlet —
CI uploads it and checks the swap events are present.

    PYTHONPATH=src python -m benchmarks.run --backend actor --adaptive

Set ``REPRO_SMOKE=1`` to shrink the sweep for CI smoke runs.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import CostModel, HintKind, PipelineSpec
from repro.core.costs import JitterModel
from repro.core.synthesis import synthesize
from repro.runtime.adaptive import AdaptiveConfig, AdaptiveScheduler
from repro.runtime.rrfp import ActorConfig, ActorDriver
from repro.runtime.rrfp.chaos import drift_chaos
from repro.runtime.rrfp.conformance import check_all


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_SMOKE"))


#: (name, num_stages, num_microbatches, per-stage base cost, comm_base,
#:  drift profile ("" = stationary), drift targets, drift period)
_B6 = (1.0, 1.2, 0.9, 1.3, 0.8, 1.1)
_B4 = (1.0, 1.3, 0.8, 1.1)
CELLS = (
    ("pp6_step", 6, 18, _B6, 0.4, "step", ((4, 2.0),), 6),
    ("pp4_ramp", 4, 16, _B4, 0.5, "ramp", ((2, 2.0),), 6),
    ("pp6_stationary", 6, 18, _B6, 0.4, "", (), 6),
)


def _workload(S: int, M: int, base, comm: float):
    """Split-backward pipeline with jitter-free heterogeneous costs.

    The BFW split is what gives re-synthesis room to win: W tasks are
    deferrable filler the new table can repack around the drifted stage's
    bubbles.  Jitter off so per-step makespans are deterministic."""
    spec = PipelineSpec(S, M, split_backward=True)
    b = np.asarray(base, dtype=float)
    costs = CostModel(
        f_cost=b, b_cost=b, w_cost=b, comm_base=comm,
        compute_jitter=JitterModel(), comm_jitter=JitterModel())
    return spec, costs


def _run_step(spec, costs, table, version, registry, chaos,
              record: bool = False):
    cfg = ActorConfig(
        mode="hint", hint=HintKind.BFW, hint_table=table,
        hint_table_version=version, chaos=chaos, metrics=registry,
        record_trace=record)
    return ActorDriver(spec, costs, cfg).run()


def _run_precommitted(spec, costs, chaos):
    cfg = ActorConfig(mode="precommitted", fixed_order="zb", chaos=chaos)
    return ActorDriver(spec, costs, cfg).run()


def _swap_trace_artifact(path: str) -> dict:
    """Record one sim run with a mid-run HINT_SWAP and conformance-check it.

    The sweep itself swaps at iteration boundaries (a fresh table per run),
    which never emits in-run HINT_SWAP events; this artifact exercises the
    other quiesce point — ``swap_at`` mid-makespan — so CI has a committed
    trace in which the swap protocol is visible and replayable."""
    name, S, M, base, comm, profile, targets, period = CELLS[0]
    spec, costs = _workload(S, M, base, comm)
    chaos = drift_chaos(profile, targets, period=period)
    chaos = dataclasses.replace(chaos, step=period + 2)  # post-drift regime
    drifted = dataclasses.replace(
        costs,
        f_cost=costs.f_cost * [chaos.drift_scale(s) for s in range(S)],
        b_cost=costs.b_cost * [chaos.drift_scale(s) for s in range(S)],
        w_cost=costs.w_cost * [chaos.drift_scale(s) for s in range(S)])
    old = synthesize(spec, costs, hint=HintKind.BFW).stage_orders
    new = synthesize(spec, drifted, hint=HintKind.BFW).stage_orders
    probe = _run_step(spec, costs, old, 0, None, chaos)
    cfg = ActorConfig(
        mode="hint", hint=HintKind.BFW, hint_table=old,
        hint_table_version=0, swap_table=new,
        swap_at=probe.makespan * 0.5, swap_after=M // 2,
        chaos=chaos, record_trace=True)
    res = ActorDriver(spec, costs, cfg).run()
    check_all(res.trace, spec, cfg)
    res.trace.save(path)
    n_swaps = sum(1 for ev in res.trace.events if ev.kind == "hint_swap")
    assert n_swaps == S, (n_swaps, S)
    return {"trace": os.path.basename(path), "hint_swap_events": n_swaps,
            "makespan": res.makespan}


def run_adaptive_bench() -> dict:
    smoke = _smoke()
    K = 8 if smoke else 12
    late_n = 3 if smoke else 4
    rows = []
    for name, S, M, base, comm, profile, targets, period in CELLS:
        if smoke:
            M, period = max(8, M // 2), 3
        spec, costs = _workload(S, M, base, comm)
        chaos0 = drift_chaos(profile, targets, period=period) \
            if profile else None
        acfg = AdaptiveConfig(resynth_every=1, swap_threshold=1.02,
                              hysteresis=2, hint=HintKind.BFW)

        def chaos_at(k: int):
            if chaos0 is None:
                return None
            return dataclasses.replace(chaos0, step=k)

        sched = AdaptiveScheduler(spec, costs, acfg)
        static_table = [list(o) for o in sched.table]
        mk_adaptive, mk_static, mk_pre = [], [], []
        for k in range(K):
            ch = chaos_at(k)
            mk_adaptive.append(_run_step(
                spec, costs, sched.table, sched.version,
                sched.registry, ch).makespan)
            sched.maybe_resynthesize(k)
            mk_static.append(_run_step(
                spec, costs, static_table, 0, None, ch).makespan)
            mk_pre.append(_run_precommitted(spec, costs, ch).makespan)

        late = slice(K - late_n, K)
        lm_static = float(np.mean(mk_static[late]))
        lm_adaptive = float(np.mean(mk_adaptive[late]))
        lm_pre = float(np.mean(mk_pre[late]))
        drifting = bool(profile)
        if drifting:
            assert sched.swaps, (
                f"{name}: drift detector never fired on a drifting cell")
            assert lm_adaptive < lm_static, (
                f"{name}: adaptive late mean {lm_adaptive} did not beat "
                f"static {lm_static}")
        else:
            assert sched.swaps == [], (
                f"{name}: spurious swaps {sched.swaps} on a stationary "
                f"cell (flapping)")
            assert mk_adaptive == mk_static, (
                f"{name}: stationary arms diverged")
        rows.append({
            "cell": name, "num_stages": S, "num_microbatches": M,
            "comm_base": comm, "drift_profile": profile,
            "drift_targets": [list(t) for t in targets],
            "drift_period": period, "steps": K,
            "makespans_static": mk_static,
            "makespans_adaptive": mk_adaptive,
            "makespans_precommitted": mk_pre,
            "late_mean_static": lm_static,
            "late_mean_adaptive": lm_adaptive,
            "late_mean_precommitted": lm_pre,
            "gain_pct": (lm_static / lm_adaptive - 1.0) * 100.0,
            "swaps": list(sched.swaps),
            "table_version": sched.version,
            "decisions": [d.to_json() for d in sched.decisions],
        })
    return {
        "meta": {
            "smoke": smoke, "steps": K, "late_window": late_n,
            "substrate": "sim", "jitter": "off (drift only)",
            "adaptive": {
                "resynth_every": 1, "swap_threshold": 1.02,
                "hysteresis": 2, "hint": "bfw"},
        },
        "rows": rows,
    }


def emit_json(path: str = "BENCH_adaptive.json") -> dict:
    report = run_adaptive_bench()
    trace_path = os.path.join(
        os.path.dirname(os.path.abspath(path)) or ".",
        os.path.splitext(os.path.basename(path))[0] + "_trace.json")
    report["meta"]["swap_trace"] = _swap_trace_artifact(trace_path)
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    return report


def adaptive_rows(json_path: str = "BENCH_adaptive.json") -> list[tuple]:
    """CSV rows for ``benchmarks.run``."""
    report = emit_json(json_path)
    out = []
    for r in report["rows"]:
        profile = r["drift_profile"] or "stationary"
        out.append((
            f"adaptive/{r['cell']}/{profile}",
            r["late_mean_adaptive"] * 1e6,
            f"static={r['late_mean_static']:.2f}s,"
            f"adaptive={r['late_mean_adaptive']:.2f}s,"
            f"gain={r['gain_pct']:.1f}%,"
            f"swaps={len(r['swaps'])}"))
    art = report["meta"]["swap_trace"]
    out.append((
        "adaptive/swap_trace", art["makespan"] * 1e6,
        f"hint_swap_events={art['hint_swap_events']},"
        f"conformance=ok"))
    return out


if __name__ == "__main__":
    for row in adaptive_rows():
        print(*row, sep=",")
