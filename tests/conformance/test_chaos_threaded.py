"""Randomized chaos scenarios on the thread substrate + bitwise parity.

Each seed runs real thread-per-stage actors under fault injection (delayed,
reordered and duplicated deliveries from timer threads; keyed stalls before
task execution) driving float32 numpy stage programs, then checks:

* deadlock-freedom (the run completes within the starvation timeout),
* every trace invariant from ``harness.check_all``,
* the w_defer memory bound actually held in the work layer
  (``w_high_water <= cap``), and
* **bitwise** loss and weight-gradient parity against the fixed-order
  reference executor — float32 addition is order-sensitive, so this only
  passes because chaotic execution + deterministic (stash-then-sorted-sum)
  reduction reproduces the reference's reduction order exactly.
"""
import numpy as np
import pytest

from harness import (
    NumpyStageProgram,
    artifact_on_failure,
    check_all,
    make_scenario,
    reference_execute,
)

from repro.runtime.rrfp import ActorDriver

THREAD_SEEDS_FAST = list(range(100, 132))
THREAD_SEEDS_SLOW = list(range(132, 196))


def _run_scenario(seed: int) -> None:
    sc = make_scenario(seed, substrate="thread")
    spec = sc.spec
    S = spec.num_stages

    reference = [NumpyStageProgram(s, spec, seed) for s in range(S)]
    reference_execute(spec, reference)
    for p in reference:
        p.finalize()

    chaotic = [NumpyStageProgram(s, spec, seed) for s in range(S)]
    driver = ActorDriver(spec, None, sc.config)
    with artifact_on_failure(lambda: driver.trace, f"thread_{sc.name()}"):
        result = driver.run_threaded(list(chaotic))
        trace = driver.trace
        assert len(result.end) == spec.total_tasks()
        check_all(trace, spec, sc.config)
        cap = sc.config.w_defer_cap
        for chaos_p, ref_p in zip(chaotic, reference):
            chaos_p.finalize()
            if (spec.split_backward and cap > 0
                    and sc.config.mode == "hint"):
                assert chaos_p.w_high_water <= cap, (
                    f"stage {chaos_p.stage} stashed {chaos_p.w_high_water} "
                    f"activation pairs > w_defer_cap={cap}")
            # bitwise: same bytes, not approximately-equal floats
            assert chaos_p.loss.tobytes() == ref_p.loss.tobytes(), (
                f"stage {chaos_p.stage} loss bits diverged: "
                f"{chaos_p.loss!r} != {ref_p.loss!r}")
            assert chaos_p.d_w.tobytes() == ref_p.d_w.tobytes(), (
                f"stage {chaos_p.stage} weight-grad bits diverged "
                f"(max abs diff "
                f"{np.max(np.abs(chaos_p.d_w - ref_p.d_w))})")


@pytest.mark.parametrize("seed", THREAD_SEEDS_FAST)
def test_threaded_chaos_scenario(seed):
    _run_scenario(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", THREAD_SEEDS_SLOW)
def test_threaded_chaos_scenario_full_matrix(seed):
    _run_scenario(seed)
