"""Heterogeneous multimodal pipelines on the readiness-driven runtime.

The paper's headline (up to 2.77×) result is multimodal: cheap,
variable-length vision-encoder stages misaligned with LM-decoder stages —
the regime where consuming the schedule as a non-binding hint pays most.
This package makes that regime executable end to end:

  model    -- branch+fusion DAG topology (encoder branch ∥ text frontend →
              fusion → LM chain) with real per-stage parameters built from
              ``models/layers.py``; bitwise padding-invariant encoder math
  stagefn  -- per-(stage, op) jitted callables with shape bucketing
              (compile cache bounded by bucket count) + the actor-runtime
              ``work_fn`` adapter handling DAG fan-in/fan-out payloads,
              BFW split backward and deterministic reduction
  costs    -- DES cost models of the same topologies for the simulation
              substrate and the multimodal benchmark

See ``docs/multimodal.md`` for the DAG task-graph semantics.
"""
from repro.multimodal.costs import multimodal_dag_costs
from repro.multimodal.model import (
    MULTIMODAL_ARCHS,
    MultimodalConfig,
    MultimodalModel,
    multimodal_config,
    multimodal_model,
)
from repro.multimodal.stagefn import (
    MultimodalStageFns,
    MultimodalStageProgram,
)

__all__ = [
    "MULTIMODAL_ARCHS",
    "MultimodalConfig",
    "MultimodalModel",
    "MultimodalStageFns",
    "MultimodalStageProgram",
    "multimodal_config",
    "multimodal_dag_costs",
    "multimodal_model",
]
