"""Elastic re-meshing: re-plan (data × model) for a degraded device set.

At 1000+ node scale, node loss is routine: the runtime checkpoints, picks the
largest feasible (data, model) grid for the surviving devices, re-lays-out
the stage dimension (layers redistribute across the new stage count), and
restores.  Stage re-layout works on host arrays so it composes with
CheckpointStore.restore on any mesh.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax

from repro.models.build import ArchModel, build
from repro.models.common import stage_layout, global_layer_index


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.data * self.model


def plan_remesh(alive_devices: int, prefer_model: int = 16,
                min_model: int = 2) -> MeshPlan:
    """Largest (data × model) grid fitting the surviving devices, preferring
    deep pipelines, then data width."""
    best = None
    m = prefer_model
    while m >= min_model:
        d = alive_devices // m
        if d >= 1:
            plan = MeshPlan(data=d, model=m)
            if best is None or plan.devices > best.devices:
                best = plan
        m //= 2
    if best is None:
        raise ValueError(f"cannot build a mesh from {alive_devices} devices")
    return best


def remap_stages(num_stages: int, dead) -> list[int]:
    """Host assignment after losing the device(s) hosting ``dead`` stage(s).

    The re-map recovery path (no spare device to respawn onto): every stage
    keeps its logical identity, each dead stage's actor is re-hosted on the
    nearest *surviving* neighbor's device, and the cohabitants time-share
    that device.  ``plan_remesh`` validates that the surviving device set
    still admits a mesh at all (the same feasibility rule full re-meshing
    uses); the minimal-movement fold keeps every other stage's state in
    place so only the dead stages restore from checkpoint.

    ``dead`` is a stage index or an iterable of them (concurrent faults —
    the cumulative dead set across overlapping recovery windows).  With
    several dead stages each folds onto its nearest survivor (ties break
    toward the lower index), so e.g. losing stages 1 and 2 of four folds
    1 -> 0 and 2 -> 3 rather than chaining onto a dead neighbor.

    Returns ``host_of``: stage index -> hosting device (device ids are the
    original stage indices; dead stages appear as nobody's host).
    """
    dead_set = {dead} if isinstance(dead, int) else set(dead)
    for d in dead_set:
        if not (0 <= d < num_stages):
            raise ValueError(f"dead stage {d} outside 0..{num_stages - 1}")
    alive = num_stages - len(dead_set)
    if alive < 1 or num_stages < 2:
        raise ValueError(
            f"cannot re-map {num_stages}-stage pipeline with "
            f"{len(dead_set)} dead stages")
    plan_remesh(alive, prefer_model=alive, min_model=1)
    survivors = [s for s in range(num_stages) if s not in dead_set]
    host_of = list(range(num_stages))
    for d in dead_set:
        host_of[d] = min(survivors, key=lambda s: (abs(s - d), s))
    return host_of


def relayout_stage_params(old_model: ArchModel, new_num_stages: int,
                          stage_params_host):
    """Re-distribute per-layer params [S_old, l_max_old, ...] onto a new
    stage count (host-side; feeds device_put under the new mesh)."""
    cfg = old_model.cfg
    new_model = build(cfg, num_stages=new_num_stages)
    old_gli = global_layer_index(old_model.counts)
    new_gli = global_layer_index(new_model.counts)
    # map: global layer -> (old stage, old slot)
    where_old = {}
    for s in range(old_model.num_stages):
        for i in range(old_model.l_max):
            g = old_gli[s, i]
            if g >= 0:
                where_old[g] = (s, i)

    def remap(leaf):
        leaf = np.asarray(leaf)
        out = np.zeros((new_model.num_stages, new_model.l_max) + leaf.shape[2:],
                       leaf.dtype)
        for s in range(new_model.num_stages):
            for i in range(new_model.l_max):
                g = new_gli[s, i]
                if g >= 0:
                    so, io_ = where_old[g]
                    out[s, i] = leaf[so, io_]
        return out

    return new_model, jax.tree.map(remap, stage_params_host)
