"""Recovery-conformance suite: stage death is a recoverable event.

Each seed derives a scenario (spec × consumption mode × chaos level) and
arms one fail-stop fault — a random non-source stage killed (or permanently
stalled) at a randomized dispatch index.  The run must complete under
``ActorConfig.recover``, and the recorded trace must satisfy
``check_recovery_exactly_once``: no microbatch lost or doubled across the
recovery boundary, repeats only as re-execution (one per incarnation), and
every fenced envelope genuinely stale.

On the sim substrate the suite additionally proves the paper-level claim
that recovery is *bitwise invisible*: executing the recovered run's realized
completion order through deterministic numpy stage programs yields the same
loss and weight-gradient bits as the unfailed run on the same seed.  On the
thread substrate the programs execute for real (payloads ride the
envelopes, a respawn rebuilds the dead stage's program from scratch) and
the finalized totals must again match the unfailed run exactly.

Fast seeds run on every PR; the full matrix rides the ``slow`` marker.
"""
import dataclasses

import numpy as np
import pytest

from harness import (
    NumpyStageProgram,
    artifact_on_failure,
    check_all,
    execute_complete_order,
    make_dag_scenario,
    make_scenario,
    sim_costs,
)

from repro.runtime.rrfp import (
    ActorConfig,
    ActorDriver,
    CHAOS_LEVELS,
    ChaosConfig,
    StageFailure,
)

SEEDS_FAST = list(range(0, 12))
SEEDS_SLOW = list(range(12, 48))
LEVELS = ("C0", "C1", "C2", "C3")


def _arm_fault(sc, seed: int):
    """Derive a randomized fail-stop fault for a scenario: a non-source
    stage, kill or permanent stall, at a randomized dispatch index, layered
    on a rotating chaos level (C0 control .. C3 heavy)."""
    rng = np.random.default_rng([0xFA11, seed])
    sources = set(sc.spec.source_stages())
    candidates = [s for s in range(sc.spec.num_stages) if s not in sources]
    fail_stage = int(rng.choice(candidates))
    fail_kind = str(rng.choice(["kill", "permanent_stall"]))
    fail_after = int(rng.integers(0, sc.spec.num_tasks_per_stage()))
    level = CHAOS_LEVELS[LEVELS[seed % len(LEVELS)]]
    chaos = dataclasses.replace(
        level, seed=seed, fail_stage=fail_stage, fail_kind=fail_kind,
        fail_after=fail_after)
    cfg = dataclasses.replace(
        sc.config, chaos=chaos, recover=True,
        recovery_mode="remap" if seed % 5 == 4 else "respawn")
    return cfg, (fail_stage, fail_kind, fail_after)


def _run_sim(sc, seed: int) -> None:
    cfg, fault = _arm_fault(sc, seed)
    costs = sim_costs(sc.spec, seed)
    driver = ActorDriver(sc.spec, costs, cfg)
    with artifact_on_failure(lambda: driver.trace,
                             f"recovery_sim_{sc.name()}"):
        result = driver.run()  # survives the fault: completes or raises
        trace = driver.trace
        assert trace.recovery_windows(), f"fault {fault} never fired"
        check_all(trace, sc.spec, cfg)  # recovery-aware exactly-once

        # bitwise parity: the recovered run's realized completion order
        # produces the unfailed run's exact loss/grad bits
        calm = ActorDriver(
            sc.spec, costs,
            dataclasses.replace(cfg, chaos=dataclasses.replace(
                cfg.chaos, fail_stage=-1), recover=False))
        calm.run()
        got = execute_complete_order(trace, sc.spec, seed)
        want = execute_complete_order(calm.trace, sc.spec, seed)
        for s in range(sc.spec.num_stages):
            assert want[s].loss == got[s].loss, f"stage {s} loss bits differ"
            assert np.array_equal(want[s].d_w, got[s].d_w), (
                f"stage {s} grad bits differ")
        assert len(result.end) == sc.spec.total_tasks()


@pytest.mark.parametrize("seed", SEEDS_FAST)
def test_sim_recovery_chain(seed):
    _run_sim(make_scenario(seed), seed)


@pytest.mark.parametrize("seed", SEEDS_FAST[:6])
def test_sim_recovery_dag(seed):
    _run_sim(make_dag_scenario(seed), seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", SEEDS_SLOW)
def test_sim_recovery_chain_full_matrix(seed):
    _run_sim(make_scenario(seed), seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", SEEDS_SLOW[:18])
def test_sim_recovery_dag_full_matrix(seed):
    _run_sim(make_dag_scenario(seed), seed)


# ---------------------------------------------------------------------------
# thread substrate: real re-execution through numpy stage programs
# ---------------------------------------------------------------------------
def _run_thread(sc, seed: int, recovery_mode: str = "respawn") -> None:
    spec = sc.spec
    cfg, fault = _arm_fault(sc, seed)
    # wall-clock scale: detect stalls fast, give recovery generous slack
    cfg = dataclasses.replace(cfg, hb_deadline=0.05, deadlock_timeout=20.0,
                              recovery_mode=recovery_mode)

    def build(with_fault: bool):
        progs = [NumpyStageProgram(s, spec, seed) for s in range(spec.num_stages)]

        def respawn(s: int):
            # in-memory state died with the stage: fresh program, full
            # re-execution (duplicated effects are dropped downstream)
            progs[s] = NumpyStageProgram(s, spec, seed)
            return lambda t, p: progs[s](t, p)

        c = cfg if with_fault else dataclasses.replace(
            cfg, chaos=dataclasses.replace(cfg.chaos, fail_stage=-1),
            recover=False, respawn=None)
        if with_fault:
            c = dataclasses.replace(c, respawn=respawn)
        drv = ActorDriver(spec, None, c)
        fns = [(lambda s: (lambda t, p: progs[s](t, p)))(s)
               for s in range(spec.num_stages)]
        return drv, fns, progs, c

    drv, fns, progs, c = build(True)
    with artifact_on_failure(lambda: drv.trace,
                             f"recovery_thread_{sc.name()}"):
        drv.run_threaded(fns)
        trace = drv.trace
        assert trace.recovery_windows(), f"fault {fault} never fired"
        check_all(trace, spec, c)
        calm_drv, calm_fns, calm_progs, _ = build(False)
        calm_drv.run_threaded(calm_fns)
        for p in progs:
            p.finalize()
        for p in calm_progs:
            p.finalize()
        for s in range(spec.num_stages):
            assert calm_progs[s].loss == progs[s].loss, (
                f"stage {s} loss bits differ across recovery")
            assert np.array_equal(calm_progs[s].d_w, progs[s].d_w), (
                f"stage {s} grad bits differ across recovery")


@pytest.mark.parametrize("seed", SEEDS_FAST[:6])
def test_thread_recovery_chain(seed):
    _run_thread(make_scenario(seed, substrate="thread"), seed)


@pytest.mark.parametrize("seed", SEEDS_FAST[:3])
def test_thread_recovery_dag(seed):
    _run_thread(make_dag_scenario(seed, substrate="thread"), seed)


@pytest.mark.parametrize("seed", SEEDS_FAST[:6])
def test_thread_recovery_remap(seed):
    """Elastic remap on the *thread* substrate: a randomized kill folds the
    dead stage onto a surviving neighbor (work_fns time-share the host via
    a shared lock) and the run still produces the unfailed run's exact
    loss/grad bits."""
    _run_thread(make_scenario(seed, substrate="thread"), seed,
                recovery_mode="remap")


@pytest.mark.slow
@pytest.mark.parametrize("seed", SEEDS_SLOW[:12])
def test_thread_recovery_full_matrix(seed):
    _run_thread(make_scenario(seed, substrate="thread"), seed)


# ---------------------------------------------------------------------------
# promotion, guards, and attribution
# ---------------------------------------------------------------------------
def test_fault_without_recover_is_promoted():
    """No recovery armed -> the fault fails fast (StageFailure), on both
    substrates, instead of hanging to the deadlock timeout."""
    from repro.core import PipelineSpec

    spec = PipelineSpec(3, 4)
    chaos = ChaosConfig(fail_stage=1, fail_after=2)
    with pytest.raises(StageFailure):
        ActorDriver(spec, sim_costs(spec, 0),
                    ActorConfig(chaos=chaos)).run()
    progs = [NumpyStageProgram(s, spec, 0) for s in range(3)]
    with pytest.raises(StageFailure):
        ActorDriver(spec, None, ActorConfig(
            chaos=chaos, hb_deadline=0.05, deadlock_timeout=10.0)
        ).run_threaded([(lambda s: (lambda t, p: progs[s](t, p)))(s)
                        for s in range(3)])


def test_recovered_trace_replay_is_rejected():
    """Time-exact replay of a recovered trace is explicitly unsupported."""
    from repro.core import PipelineSpec

    spec = PipelineSpec(3, 4)
    drv = ActorDriver(spec, sim_costs(spec, 0), ActorConfig(
        chaos=ChaosConfig(fail_stage=1, fail_after=2), recover=True,
        record_trace=True))
    drv.run()
    with pytest.raises(ValueError, match="recovered trace"):
        ActorDriver(spec, None, ActorConfig(
            record_trace=True, replay=drv.trace)).run()


def test_remap_folds_dead_stage_onto_neighbor():
    """recovery_mode="remap": the dead stage re-hosts on a surviving
    neighbor and the pair time-share the device — the run still completes
    exactly-once, and the time-sharing shows up as a longer makespan."""
    from repro.core import PipelineSpec

    spec = PipelineSpec(4, 8)
    costs = sim_costs(spec, 1)
    base = ActorConfig(chaos=ChaosConfig(fail_stage=2, fail_after=1),
                       recover=True, record_trace=True)
    respawn = ActorDriver(spec, costs, base).run()
    remap_cfg = dataclasses.replace(base, recovery_mode="remap")
    drv = ActorDriver(spec, costs, remap_cfg)
    remap = drv.run()
    check_all(drv.trace, spec, remap_cfg)
    assert drv.trace.recovery_windows()[0]["mode"] == "remap"
    assert remap.makespan > respawn.makespan  # co-hosting costs throughput


def test_killed_stage_gap_attributed_to_recovery():
    """Bubble decomposition: the outage is a ``recovery`` bubble, not
    ``dependency_wait``/``starvation``, and exact attribution survives."""
    from repro.core import PipelineSpec
    from repro.obs.bubbles import decompose

    spec = PipelineSpec(4, 8)
    costs = sim_costs(spec, 3)
    cfg = ActorConfig(chaos=ChaosConfig(fail_stage=1, fail_after=3),
                      recover=True, record_trace=True,
                      hb_deadline=0.5, restore_cost=0.25)
    drv = ActorDriver(spec, costs, cfg)
    drv.run()
    rep = decompose(drv.trace)
    assert rep.idle_fully_attributed()
    rec = rep.category_totals()["recovery"]
    w = drv.trace.recovery_windows()[0]
    outage = w["t_end"] - w["t_fail"]
    # at minimum the dead stage's own outage is attributed to recovery
    assert rec >= outage * 0.99
    # and the calm run has no recovery bubble at all
    calm_cfg = dataclasses.replace(
        cfg, chaos=None, recover=False)
    calm = ActorDriver(spec, costs, calm_cfg)
    calm.run()
    calm_rep = decompose(calm.trace)
    assert calm_rep.category_totals()["recovery"] == 0.0
    assert calm_rep.idle_fully_attributed()


def test_recovery_epoch_visible_in_trace():
    """The trace records the epoch transition: FAIL at the old epoch,
    RECOVERY_BEGIN carrying from/to, post-recovery events at the new."""
    from repro.core import PipelineSpec
    from repro.runtime.rrfp import trace as tr

    spec = PipelineSpec(3, 6)
    drv = ActorDriver(spec, sim_costs(spec, 7), ActorConfig(
        chaos=ChaosConfig(fail_stage=1, fail_kind="permanent_stall",
                          fail_after=4),
        recover=True, record_trace=True))
    drv.run()
    t = drv.trace
    assert t.max_epoch() == 1
    (w,) = t.recovery_windows()
    assert w["epoch_from"] == 0 and w["epoch_to"] == 1
    assert w["fail_kind"] == "permanent_stall"
    fails = t.select(tr.FAIL)
    assert len(fails) == 1 and fails[0].epoch == 0
    # the respawned incarnation's completions carry the new epoch
    late = [ev for ev in t.select(tr.COMPLETE)
            if ev.stage == 1 and ev.epoch == 1]
    assert late, "no post-recovery completions on the failed stage"
