"""Core RRFP engine behaviour: correctness, deadlock freedom, paper claims."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback (tests/_hyp_stub.py)
    from _hyp_stub import given, settings, strategies as st

from repro.core import (
    CostModel,
    EngineConfig,
    HintKind,
    JitterModel,
    Kind,
    PipelineSpec,
    Task,
    multimodal_stage_flops,
    run_iteration,
    synthesize,
)
from repro.core.bounds import (
    bottleneck_stats,
    check_theorem_6_1,
    corollary_terms,
    reference_makespan,
)
from repro.core.hints import (
    HintArbiter,
    gpipe_order,
    one_f_one_b_order,
    zero_bubble_order,
)


def det_costs(S, f=1.0, b=2.0, w=0.0, comm=1e-6, **kw):
    return CostModel.uniform(
        S, f=f, b=b, w=w, comm_base=comm,
        compute_jitter=JitterModel(), comm_jitter=JitterModel(), **kw,
    )


# ---------------------------------------------------------------------------
# Task graph
# ---------------------------------------------------------------------------
class TestTaskGraph:
    def test_dependency_structure(self):
        spec = PipelineSpec(4, 3)
        f21 = Task(Kind.F, 2, 1)
        assert spec.message_predecessor(f21) == Task(Kind.F, 1, 1)
        b21 = Task(Kind.B, 2, 1)
        assert spec.message_predecessor(b21) == Task(Kind.B, 3, 1)
        assert spec.local_predecessor(b21) == Task(Kind.F, 2, 1)
        # boundaries
        assert spec.message_predecessor(Task(Kind.F, 0, 0)) is None
        assert spec.message_predecessor(Task(Kind.B, 3, 0)) is None

    def test_interleaved_wrap(self):
        spec = PipelineSpec(4, 2, num_chunks=2)
        assert spec.message_predecessor(Task(Kind.F, 0, 1, 1)) == Task(Kind.F, 3, 1, 0)
        assert spec.message_predecessor(Task(Kind.B, 3, 1, 0)) == Task(Kind.B, 0, 1, 1)

    def test_counts(self):
        spec = PipelineSpec(4, 3, split_backward=True)
        assert spec.total_tasks() == 4 * 3 * 3
        assert len(list(spec.tasks())) == spec.total_tasks()


# ---------------------------------------------------------------------------
# Hint arbitration (Algorithm 1)
# ---------------------------------------------------------------------------
class TestHints:
    def test_bf_round_alternation(self):
        """After a B, the same round's F check runs; then B again."""
        arb = HintArbiter(HintKind.BF)
        b0, b1 = Task(Kind.B, 0, 0), Task(Kind.B, 0, 1)
        f0, f1 = Task(Kind.F, 0, 0), Task(Kind.F, 0, 1)
        assert arb.select([b0, b1, f0, f1]) == b0
        assert arb.select([b1, f0, f1]) == f0
        assert arb.select([b1, f1]) == b1
        assert arb.select([f1]) == f1

    def test_bf_never_blocks_on_unready(self):
        arb = HintArbiter(HintKind.BF)
        f0 = Task(Kind.F, 0, 0)
        assert arb.select([f0]) == f0  # no backward ready -> immediately forward

    def test_within_direction_priority(self):
        """Forward prefers lower chunk; backward prefers higher chunk."""
        arb = HintArbiter(HintKind.F_PRIORITY)
        fs = [Task(Kind.F, 0, 1, 1), Task(Kind.F, 0, 2, 0), Task(Kind.F, 0, 3, 0)]
        assert arb.select(fs) == Task(Kind.F, 0, 2, 0)
        arb2 = HintArbiter(HintKind.B_PRIORITY)
        bs = [Task(Kind.B, 0, 1, 0), Task(Kind.B, 0, 5, 1), Task(Kind.B, 0, 7, 1)]
        assert arb2.select(bs) == Task(Kind.B, 0, 5, 1)

    def test_bfw_uses_w_only_when_nothing_else(self):
        arb = HintArbiter(HintKind.BFW)
        w = Task(Kind.W, 0, 0)
        f = Task(Kind.F, 0, 0)
        assert arb.select([w, f]) == f
        assert arb.select([w]) == w
        assert arb.select([]) is None

    def test_fixed_orders_are_permutations(self):
        spec = PipelineSpec(4, 6)
        for s in range(4):
            o = one_f_one_b_order(spec, s)
            assert sorted(o) == sorted(
                [Task(Kind.F, s, j) for j in range(6)]
                + [Task(Kind.B, s, j) for j in range(6)]
            )
        specw = PipelineSpec(4, 6, split_backward=True)
        for s in range(4):
            o = zero_bubble_order(specw, s)
            assert len(o) == 18 and len(set(o)) == 18
        o = gpipe_order(spec, 2)
        assert all(t.kind == Kind.F for t in o[:6])

    def test_1f1b_order_respects_local_deps(self):
        spec = PipelineSpec(8, 16)
        for s in range(8):
            seen_f = set()
            for t in one_f_one_b_order(spec, s):
                if t.kind == Kind.F:
                    seen_f.add(t.mb)
                else:
                    assert t.mb in seen_f


# ---------------------------------------------------------------------------
# Engine end-to-end
# ---------------------------------------------------------------------------
class TestEngine:
    def test_ideal_homogeneous_makespans(self):
        """Deterministic homogeneous pipeline hits the textbook makespans."""
        S, M = 8, 32
        spec = PipelineSpec(S, M)
        cm = det_costs(S)
        m_1f1b = run_iteration(
            spec, cm, EngineConfig(mode="precommitted", fixed_order="1f1b")
        ).makespan
        m_rrfp = run_iteration(spec, cm, EngineConfig(mode="hint")).makespan
        ideal = 3.0 * M + 3.0 * (S - 1)
        assert m_1f1b == pytest.approx(ideal, rel=0.01)
        assert m_rrfp <= m_1f1b * 1.01

    def test_zb_hits_zero_bubble_ideal(self):
        S, M = 8, 16
        spec = PipelineSpec(S, M, split_backward=True)
        cm = det_costs(S, f=1.0, b=1.0, w=1.0)
        m = run_iteration(
            spec, cm, EngineConfig(mode="precommitted", fixed_order="zb")
        ).makespan
        assert m == pytest.approx(3 * M + (S - 1), rel=0.01)

    def test_rrfp_beats_1f1b_under_imbalance_and_jitter(self):
        S, M = 8, 32
        spec = PipelineSpec(S, M)
        sf = multimodal_stage_flops(4e12, 2e12, S)
        cm = CostModel.from_stage_flops(sf, comm_base=2e-3, seed=3)
        m1 = run_iteration(
            spec, cm, EngineConfig(mode="precommitted", fixed_order="1f1b", seed=11)
        ).makespan
        m2 = run_iteration(spec, cm, EngineConfig(mode="hint", seed=11)).makespan
        assert m2 < m1  # the paper's headline direction

    def test_breakdown_blocking_reduction(self):
        """RQ2: RRFP reduces blocking; compute comparable."""
        S, M = 16, 32
        spec = PipelineSpec(S, M)
        sf = multimodal_stage_flops(6e12, 2e12, S)
        cm = CostModel.from_stage_flops(sf, comm_base=2e-3, seed=5)
        b1 = run_iteration(
            spec, cm, EngineConfig(mode="precommitted", fixed_order="1f1b", seed=1)
        ).breakdown()
        b2 = run_iteration(spec, cm, EngineConfig(mode="hint", seed=1)).breakdown()
        assert b2["blocking"] < b1["blocking"]
        assert b2["compute"] == pytest.approx(b1["compute"], rel=0.25)

    def test_tp_coordination_overhead_small_but_nonzero(self):
        S, M = 8, 32
        spec = PipelineSpec(S, M)
        sf = multimodal_stage_flops(4e12, 2e12, S)
        cm = CostModel.from_stage_flops(sf, seed=2)
        r = run_iteration(spec, cm, EngineConfig(mode="hint", tp_degree=2))
        bd = r.breakdown()
        assert bd["tp_coord"] > 0
        assert bd["tp_coord"] < 0.05 * bd["iter"]  # paper: <1%; allow slack
        r1 = run_iteration(spec, cm, EngineConfig(mode="hint", tp_degree=1))
        assert r1.breakdown()["tp_coord"] == 0.0

    def test_last_stage_follows_1f1b_pattern(self):
        """Under BF, the last stage alternates F,B exactly (App. C proof)."""
        S, M = 4, 8
        spec = PipelineSpec(S, M)
        r = run_iteration(spec, det_costs(S), EngineConfig(mode="hint"))
        last = [t for t in r.stage_orders()[S - 1]]
        kinds = [t.kind for t in last]
        assert kinds == [Kind.F, Kind.B] * M

    def test_all_tasks_execute_exactly_once(self):
        spec = PipelineSpec(6, 10, split_backward=True)
        cm = det_costs(6, w=0.5)
        r = run_iteration(spec, cm, EngineConfig(mode="hint", hint=HintKind.BFW))
        assert set(r.end) == set(spec.tasks())

    def test_dependencies_respected_in_trace(self):
        spec = PipelineSpec(6, 8)
        sf = multimodal_stage_flops(4e12, 2e12, 6)
        cm = CostModel.from_stage_flops(sf, comm_base=1e-3, seed=9)
        r = run_iteration(spec, cm, EngineConfig(mode="hint", seed=4))
        for t in spec.tasks():
            for p in spec.predecessors(t):
                assert r.start[t] >= r.end[p] - 1e-12, (t, p)

    def test_backpressure_limits_inflight(self):
        S, M, limit = 4, 32, 3
        spec = PipelineSpec(S, M)
        cm = det_costs(S, f=1.0, b=0.1)  # cheap B: F wants to run far ahead
        r = run_iteration(spec, cm, EngineConfig(mode="hint", buffer_limit=limit))
        # replay the trace, tracking D_0
        ev = sorted(
            [(r.end[t], t.kind, t.stage) for t in r.end]
        )
        d = 0
        for _, k, s in ev:
            if s == 0 and k == Kind.F:
                d += 1
            if s == 0 and k == Kind.B:
                d -= 1
            assert d <= limit + 1  # Thm C.1 (non-interleaved: <= limit)


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(
    S=st.integers(2, 8),
    M=st.integers(1, 24),
    limit=st.integers(1, 8),
    seed=st.integers(0, 10_000),
    hint=st.sampled_from(list(HintKind)),
)
def test_property_no_deadlock_and_bound(S, M, limit, seed, hint):
    """Thm C.3 (deadlock freedom for any positive limit) + Thm 6.1 on the trace."""
    split = hint == HintKind.BFW
    spec = PipelineSpec(S, M, split_backward=split)
    rng = np.random.default_rng(seed)
    cm = CostModel(
        f_cost=rng.uniform(0.5, 2.0, S),
        b_cost=rng.uniform(0.5, 3.0, S),
        w_cost=rng.uniform(0.1, 1.0, S),
        comm_base=float(rng.uniform(1e-4, 5e-2)),
        comm_jitter=JitterModel(sigma=0.35),  # spike-free: Thm 6.1 setting
        seed=seed,
    )
    r = run_iteration(
        spec, cm, EngineConfig(mode="hint", hint=hint, buffer_limit=limit, seed=seed)
    )
    assert set(r.end) == set(spec.tasks())
    # dependencies respected
    for t in r.end:
        for p in spec.predecessors(t):
            assert r.start[t] >= r.end[p] - 1e-12
    # Theorem 6.1 is proved for the BF hint in the §6 setting: no
    # backpressure distortion (limit >= S keeps D_i unconstrained for BF's
    # 1F1B-like flows) and communication ignored (slack covers latency)
    if hint == HintKind.BF and limit >= S:
        rep = check_theorem_6_1(r.durations(Kind.F), r.durations(Kind.B), r.makespan)
        slack = (S + M) * cm.comm_base * 50
        assert r.makespan <= rep.theorem_rhs + slack
        assert r.makespan >= rep.lower_bound - 1e-9


@settings(max_examples=20, deadline=None)
@given(
    S=st.integers(2, 6),
    M=st.integers(2, 16),
    C=st.integers(2, 3),
    limit=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
def test_property_interleaved_no_deadlock(S, M, C, limit, seed):
    """App. C interleaved backpressure: deadlock-free, D bounded by limit+C."""
    spec = PipelineSpec(S, M, num_chunks=C)
    rng = np.random.default_rng(seed)
    cm = CostModel(
        f_cost=rng.uniform(0.5, 2.0, S),
        b_cost=rng.uniform(0.2, 1.0, S),  # cheap-ish B encourages runahead
        w_cost=np.zeros(S),
        comm_base=1e-3,
        seed=seed,
    )
    r = run_iteration(spec, cm, EngineConfig(mode="hint", buffer_limit=limit, seed=seed))
    assert set(r.end) == set(spec.tasks())
    ev = sorted([(r.end[t], t.kind, t.stage) for t in r.end])
    d = 0
    for _, k, s in ev:
        if s == 0 and k == Kind.F:
            d += 1
        elif s == 0 and k == Kind.B:
            d -= 1
        assert d <= limit + C  # Cor. C.2


@settings(max_examples=20, deadline=None)
@given(S=st.integers(2, 6), M=st.integers(1, 12), seed=st.integers(0, 1000))
def test_property_precommitted_modes_complete(S, M, seed):
    spec = PipelineSpec(S, M)
    rng = np.random.default_rng(seed)
    cm = CostModel(
        f_cost=rng.uniform(0.5, 2.0, S),
        b_cost=rng.uniform(0.5, 3.0, S),
        w_cost=np.zeros(S),
        comm_base=1e-3,
        seed=seed,
    )
    for order in ("1f1b", "gpipe"):
        r = run_iteration(
            spec, cm, EngineConfig(mode="precommitted", fixed_order=order, seed=seed)
        )
        assert set(r.end) == set(spec.tasks())


# ---------------------------------------------------------------------------
# Bounds / analysis module
# ---------------------------------------------------------------------------
class TestBounds:
    def test_reference_makespan_uniform(self):
        dur = np.ones((4, 8))
        assert reference_makespan(dur, "forward") == pytest.approx(8 + 3)
        assert reference_makespan(dur, "backward") == pytest.approx(8 + 3)

    def test_corollary_terms_homogeneous(self):
        f = np.ones((4, 8))
        b = np.ones((4, 8)) * 2
        t = corollary_terms(f, b)
        assert t["p"] == 0.0 and t["cor_bound"] == pytest.approx(1.0)

    def test_bottleneck_stats(self):
        f = np.ones((4, 10))
        f[3] = 2.0  # last stage dominates
        s = bottleneck_stats(f)
        assert s["bottleneck_share"][3] == 1.0
        assert s["rel_p85_p90_p95"].shape == (3, 4)


# ---------------------------------------------------------------------------
# Synthesis
# ---------------------------------------------------------------------------
class TestSynthesis:
    def test_orders_are_valid_permutations(self):
        spec = PipelineSpec(4, 8)
        cm = det_costs(4)
        syn = synthesize(spec, cm)
        for s, order in enumerate(syn.stage_orders):
            assert sorted(order) == sorted(
                [Task(Kind.F, s, j) for j in range(8)]
                + [Task(Kind.B, s, j) for j in range(8)]
            )

    def test_predicted_speedup_geq_one_under_imbalance(self):
        spec = PipelineSpec(8, 32)
        sf = multimodal_stage_flops(6e12, 2e12, 8)
        cm = CostModel.from_stage_flops(sf, comm_base=1e-3)
        syn = synthesize(spec, cm)
        assert syn.predicted_speedup >= 0.99
