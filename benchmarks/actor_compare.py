"""Actor-runtime benchmark: hint vs. precommitted under jitter (host runtime).

Runs the same one-schedule-two-consumption-modes contrast as the DES tables,
but through ``repro.runtime.rrfp`` — message-driven actors, mailbox
admission, CRN-keyed latency sampling — and emits ``BENCH_actor_runtime.json``
so the perf trajectory of the host runtime accumulates across PRs.

    PYTHONPATH=src python -m benchmarks.run --backend actor

Set ``REPRO_SMOKE=1`` to shrink the sweep for CI smoke runs.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.core import (
    CostModel,
    EngineConfig,
    HintKind,
    INJECTION_LEVELS,
    PipelineSpec,
    multimodal_stage_flops,
    run_iteration,
)
from repro.runtime.rrfp import ActorConfig, average_makespan_actor, run_actor_iteration

S, M = 8, 32
ITERS = 4


def _base_costs(seed: int = 0) -> CostModel:
    return CostModel.from_stage_flops(
        multimodal_stage_flops(4e12, 2e12, S), comm_base=2e-3, seed=seed)


def run_actor_benchmark() -> dict:
    """Hint (BF) vs precommitted 1F1B makespans across injection levels."""
    spec = PipelineSpec(S, M)
    smoke = bool(os.environ.get("REPRO_SMOKE"))
    iters = 1 if smoke else ITERS
    levels = ["J0", "J2"] if smoke else list(INJECTION_LEVELS)
    rows = []
    for level in levels:
        inj = INJECTION_LEVELS[level]
        costs = dataclasses.replace(_base_costs(), injection=inj)
        pre, pre_std, _ = average_makespan_actor(
            spec, costs, ActorConfig(mode="precommitted", fixed_order="1f1b"),
            iters)
        hint, hint_std, _ = average_makespan_actor(
            spec, costs, ActorConfig(mode="hint", hint=HintKind.BF), iters)
        rows.append({
            "level": level,
            "precommitted_1f1b_s": pre,
            "precommitted_std": pre_std,
            "hint_bf_s": hint,
            "hint_std": hint_std,
            "speedup": pre / max(hint, 1e-12),
        })
    # DES cross-check at J0: same spec, same keying seed policy
    costs0 = _base_costs()
    des = run_iteration(spec, costs0, EngineConfig(mode="hint")).makespan
    act = run_actor_iteration(spec, costs0, ActorConfig(mode="hint")).makespan
    return {
        "spec": {"stages": S, "microbatches": M, "iters": iters},
        "rows": rows,
        "des_vs_actor_hint_J0": {"des_s": des, "actor_s": act},
    }


def emit_json(path: str = "BENCH_actor_runtime.json") -> dict:
    report = run_actor_benchmark()
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def actor_runtime_rows(
    json_path: str = "BENCH_actor_runtime.json",
) -> list[tuple[str, float, str]]:
    """CSV rows for ``benchmarks.run`` (and the ALL_TABLES registry)."""
    report = emit_json(json_path)
    out = []
    for r in report["rows"]:
        out.append((
            f"actor/{r['level']}/1f1b", r["precommitted_1f1b_s"] * 1e6,
            "speedup=1.00x"))
        out.append((
            f"actor/{r['level']}/hint-bf", r["hint_bf_s"] * 1e6,
            f"speedup={r['speedup']:.2f}x"))
    return out
