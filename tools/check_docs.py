"""Docs checker: every fenced shell command in docs/reproduce.md must run,
and every intra-repo markdown link must resolve.

    PYTHONPATH=src python tools/check_docs.py [--links-only]

* **Commands** — each ```bash fence in ``docs/reproduce.md`` is executed
  verbatim with ``bash -e`` from the repo root under ``REPRO_SMOKE=1`` (the
  benchmark modules shrink their sweeps when it is set), so the
  reproduction guide can never drift from the code.  Benchmark JSON
  artifacts at the repo root are snapshotted before and restored after, so
  a smoke run never clobbers the committed full-size numbers.
* **Links** — all relative ``[text](path)`` links in README.md and
  docs/*.md must point at files that exist.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
EXEC_DOCS = [REPO / "docs" / "reproduce.md"]
LINK_DOCS = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]

FENCE_RE = re.compile(r"```bash\n(.*?)```", re.DOTALL)
LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")


def check_links() -> list[str]:
    errors = []
    for doc in LINK_DOCS:
        for target in LINK_RE.findall(doc.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue  # pure in-page anchor
            if not (doc.parent / path).exists():
                errors.append(f"{doc.relative_to(REPO)}: broken link -> {target}")
    return errors


def run_commands() -> list[str]:
    env = dict(os.environ, REPRO_SMOKE="1")
    env["PYTHONPATH"] = (
        f"{REPO / 'src'}:{env['PYTHONPATH']}"
        if env.get("PYTHONPATH") else str(REPO / "src"))
    # a smoke run must not clobber the committed full-size benchmark JSONs
    snapshots = {p: p.read_bytes() for p in REPO.glob("BENCH_*.json")}
    errors = []
    try:
        for doc in EXEC_DOCS:
            blocks = FENCE_RE.findall(doc.read_text())
            if not blocks:
                errors.append(f"{doc.relative_to(REPO)}: no ```bash fences found")
            for i, block in enumerate(blocks):
                print(f"== {doc.relative_to(REPO)} block {i + 1}/{len(blocks)}:")
                print(block.rstrip())
                proc = subprocess.run(
                    ["bash", "-e"], input=block, text=True, cwd=REPO, env=env)
                if proc.returncode != 0:
                    errors.append(
                        f"{doc.relative_to(REPO)} block {i + 1} exited "
                        f"{proc.returncode}")
    finally:
        for p, data in snapshots.items():
            p.write_bytes(data)
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links-only", action="store_true",
                    help="skip command execution (fast local check)")
    args = ap.parse_args()
    errors = check_links()
    if errors:  # broken links fail fast before the slow command pass
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"links OK across {len(LINK_DOCS)} docs")
    if not args.links_only:
        errors = run_commands()
        if errors:
            print("\n".join(errors), file=sys.stderr)
            return 1
        print("all doc commands ran clean (smoke mode)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
