"""Shared model configuration and parameter utilities (pure JAX, no flax)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    #: d_ff of the dense FFN used by any ``dense`` layers in the pattern
    dense_d_ff: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture, exactly as assigned (see repro.configs.<id>)."""

    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False  # qwen2-vl 3-axis M-RoPE
    sliding_window: int = 0  # 0 = full attention
    local_global_period: int = 0  # gemma3: every Nth layer is global
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    #: layer-type string per layer; None -> all "attn"
    layer_pattern: tuple[str, ...] | None = None
    encoder_layers: int = 0  # enc-dec: first N layers are the encoder
    embed_input: bool = False  # vlm/audio: inputs are precomputed embeddings
    act: str = "swiglu"
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    #: hybrid (zamba2): period of the shared attention block (0 = none)
    shared_attn_period: int = 0
    sub_quadratic: bool = False  # eligible for the long_500k shape

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.layer_pattern is not None:
            return self.layer_pattern
        if self.local_global_period:
            p = self.local_global_period
            return tuple(
                "attn_global" if (i + 1) % p == 0 else "attn_local"
                for i in range(self.num_layers)
            )
        if self.encoder_layers:
            return tuple(
                "enc" if i < self.encoder_layers else "dec"
                for i in range(self.num_layers)
            )
        if self.family == "moe":
            assert self.moe is not None
            return tuple(
                "dense" if (i == 0 and self.moe.dense_d_ff) else "moe"
                for i in range(self.num_layers)
            )
        return tuple("attn" for _ in range(self.num_layers))

    def layer_types(self) -> tuple[str, ...]:
        """Distinct layer types, in switch-branch order."""
        seen: list[str] = []
        for t in self.pattern:
            if t not in seen:
                seen.append(t)
        return tuple(seen)

    # ------------------------------------------------------------------
    def padded_vocab(self, multiple: int = 16) -> int:
        return int(math.ceil(self.vocab_size / multiple) * multiple)

    # ---- parameter accounting (for 6·N·D roofline terms) --------------
    def layer_param_count(self, kind: str) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        attn = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
        if self.qkv_bias:
            attn += (nq + 2 * nkv) * hd
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        ffn = glu * d * self.d_ff
        norms = 2 * d
        if kind in ("attn", "attn_local", "attn_global", "enc"):
            return attn + ffn + norms
        if kind == "dec":  # + cross attention
            return 2 * attn + ffn + norms + d
        if kind == "dense":
            assert self.moe is not None
            return attn + glu * d * self.moe.dense_d_ff + norms
        if kind == "moe":
            assert self.moe is not None
            e = self.moe.num_experts + self.moe.num_shared
            return attn + e * glu * d * self.d_ff + d * self.moe.num_experts + norms
        if kind == "mamba":
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            nh = self.ssm.num_heads(d)
            # in_proj -> (z, x, B, C, dt), conv, out_proj, A/D/dt_bias, norm
            in_p = d * (2 * di + 2 * self.ssm.d_state + nh)
            conv = self.ssm.d_conv * (di + 2 * self.ssm.d_state)
            return in_p + conv + di * d + 3 * nh + di + d
        if kind == "mlstm":
            hd_x = d // self.num_heads
            return 4 * d * d + 3 * d + 2 * d  # q,k,v,o + gates + norms
        if kind == "slstm":
            return 4 * d * d + 4 * d + 2 * d
        raise ValueError(kind)

    def active_layer_param_count(self, kind: str) -> int:
        """Parameters touched per token (MoE: shared + top-k experts)."""
        if kind != "moe":
            return self.layer_param_count(kind)
        assert self.moe is not None
        d = self.d_model
        hd = self.resolved_head_dim
        attn = (
            d * (self.num_heads * hd)
            + 2 * d * (self.num_kv_heads * hd)
            + (self.num_heads * hd) * d
        )
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        e_active = self.moe.top_k + self.moe.num_shared
        return attn + e_active * glu * d * self.d_ff + d * self.moe.num_experts + 2 * d

    def param_count(self, include_embed: bool = True) -> int:
        n = sum(self.layer_param_count(k) for k in self.pattern)
        if self.shared_attn_period:
            n += self.layer_param_count("attn")  # one shared block
        n += self.d_model  # final norm
        if include_embed:
            n += 2 * self.padded_vocab() * self.d_model
        return n

    def active_param_count(self) -> int:
        """Parameters *touched per token* (compute accounting): the shared
        attention block counts once per invocation, not once per copy."""
        n = sum(self.active_layer_param_count(k) for k in self.pattern)
        if self.shared_attn_period:
            invocations = len(range(0, self.num_layers, self.shared_attn_period))
            n += invocations * self.layer_param_count("attn")
        n += self.d_model
        return n


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "train"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def keygen(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


def tree_size_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def stage_layout(num_layers: int, num_stages: int) -> tuple[np.ndarray, int]:
    """Distribute ``num_layers`` across stages.

    Returns (counts[num_stages], l_max).  Later stages may hold one fewer
    layer; disabled slots are skipped via per-layer enabled flags.
    """
    base = num_layers // num_stages
    extra = num_layers - base * num_stages
    counts = np.full(num_stages, base, dtype=np.int64)
    counts[:extra] += 1
    return counts, int(counts.max())


def global_layer_index(counts: np.ndarray) -> np.ndarray:
    """[num_stages, l_max] global layer id per slot (-1 = disabled)."""
    S, l_max = len(counts), int(counts.max())
    out = np.full((S, l_max), -1, dtype=np.int64)
    g = 0
    for s in range(S):
        for i in range(int(counts[s])):
            out[s, i] = g
            g += 1
    return out
