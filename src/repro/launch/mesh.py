"""Production mesh builders.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(data: int, model: int, pods: int = 1):
    """Arbitrary (pod ×) data × model mesh for tests / reduced runs."""
    if pods > 1:
        return jax.make_mesh(
            (pods, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
