"""Lossy-network conformance: exactly-once delivery + multi-fault recovery.

The acceptance scenario of the reliable-delivery layer: under drop=0.2 +
payload corruption + a timed bidirectional partition (with heal) + two
overlapping stage kills, training must still complete with exactly-once
delivery (``check_all`` green, including ``check_reliable_delivery``) and
**bitwise** loss/grad parity against the unfailed run — on both substrates.

Alongside the acceptance runs:

* CRN determinism — the same lossy config twice yields the identical event
  signature (record/replay of lossy runs reduces to this determinism);
* a property test driving :class:`ReliableChannel` directly through an
  adversarial wire (arbitrary drop / duplicate / reorder interleavings of
  transmissions, acks and RTO timers) — receiver-side dedup must keep
  delivery exactly-once and the protocol must still settle;
* strict ``parse_chaos`` coverage for the new drop / corrupt / partition /
  fail_stages syntax, including unknown-key fail-fast.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback (tests/_hyp_stub.py)
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from _hyp_stub import given, settings, strategies as st

from harness import (
    NumpyStageProgram,
    artifact_on_failure,
    check_all,
    execute_complete_order,
    sim_costs,
)

from repro.core import Kind, PipelineSpec, Task
from repro.runtime.rrfp import (
    ActorConfig,
    ActorDriver,
    ChaosConfig,
    Envelope,
    ReliableChannel,
    ReliableConfig,
    parse_chaos,
)
from repro.runtime.rrfp.conformance import check_reliable_delivery

SPEC = PipelineSpec(4, 8)


def _acceptance_chaos(seed: int, wall: bool = False) -> ChaosConfig:
    """drop=0.2 + corruption + one partition (with heal) + two overlapping
    stage kills.  ``wall=True`` compresses the partition window to thread-
    substrate wall-clock scale."""
    part = (1, 2, 0.05, 0.08) if wall else (1, 2, 3.0, 1.5)
    return ChaosConfig(
        seed=seed, drop_prob=0.2, corrupt_prob=0.05,
        latency_base=1e-4 if wall else 0.0,
        partitions=(part,),
        fail_stages=((1, "kill", 3), (2, "kill", 4)))


def _calm(cfg: ActorConfig) -> ActorConfig:
    return dataclasses.replace(cfg, chaos=None, reliable=None,
                               recover=False, respawn=None)


@pytest.mark.parametrize("seed", range(4))
def test_sim_lossy_multifault_acceptance(seed):
    costs = sim_costs(SPEC, seed)
    cfg = ActorConfig(
        record_trace=True, seed=seed, chaos=_acceptance_chaos(17 + seed),
        reliable=ReliableConfig(rto=0.5), recover=True)
    driver = ActorDriver(SPEC, costs, cfg)
    with artifact_on_failure(lambda: driver.trace, f"lossy_sim_{seed}"):
        driver.run()
        trace = driver.trace
        wins = trace.recovery_windows()
        assert len(wins) >= 2, f"expected overlapping faults, got {wins}"
        check_all(trace, SPEC, cfg)  # includes check_reliable_delivery
        stats = trace.meta["reliable_stats"]
        assert stats["retransmits"] > 0, "drop=0.2 never exercised the RTO"
        assert stats["link_failures"] == 0, (
            "partition outlived the retry budget in the healing scenario")
        # bitwise parity: the lossy, twice-failed run commits exactly the
        # unfailed run's loss/grad bits
        calm = ActorDriver(SPEC, costs, _calm(cfg))
        calm.run()
        got = execute_complete_order(trace, SPEC, seed)
        want = execute_complete_order(calm.trace, SPEC, seed)
        for s in range(SPEC.num_stages):
            assert want[s].loss == got[s].loss, f"stage {s} loss bits differ"
            assert np.array_equal(want[s].d_w, got[s].d_w), (
                f"stage {s} grad bits differ")


@pytest.mark.parametrize("seed", range(2))
def test_thread_lossy_multifault_acceptance(seed):
    spec = SPEC
    cfg = ActorConfig(
        record_trace=True, seed=seed,
        chaos=_acceptance_chaos(23 + seed, wall=True),
        reliable=ReliableConfig(rto=0.05), recover=True,
        hb_deadline=0.05, deadlock_timeout=20.0)

    def build(with_fault: bool):
        progs = [NumpyStageProgram(s, spec, seed)
                 for s in range(spec.num_stages)]

        def respawn(s: int):
            progs[s] = NumpyStageProgram(s, spec, seed)
            return lambda t, p: progs[s](t, p)

        c = dataclasses.replace(cfg, respawn=respawn) if with_fault \
            else _calm(cfg)
        drv = ActorDriver(spec, None, c)
        fns = [(lambda s: (lambda t, p: progs[s](t, p)))(s)
               for s in range(spec.num_stages)]
        return drv, fns, progs, c

    drv, fns, progs, c = build(True)
    with artifact_on_failure(lambda: drv.trace, f"lossy_thread_{seed}"):
        drv.run_threaded(fns)
        trace = drv.trace
        assert len(trace.recovery_windows()) >= 2
        check_all(trace, spec, c)
        calm_drv, calm_fns, calm_progs, _ = build(False)
        calm_drv.run_threaded(calm_fns)
        for p in progs:
            p.finalize()
        for p in calm_progs:
            p.finalize()
        for s in range(spec.num_stages):
            assert calm_progs[s].loss == progs[s].loss, (
                f"stage {s} loss bits differ under lossy multi-fault")
            assert np.array_equal(calm_progs[s].d_w, progs[s].d_w), (
                f"stage {s} grad bits differ under lossy multi-fault")


def test_lossy_run_is_crn_deterministic():
    """Same lossy config twice -> identical event signature: every drop,
    corruption, retransmission and partition blackout is a pure function of
    the chaos seed (record/replay exactness of lossy runs rests on this)."""
    costs = sim_costs(SPEC, 3)
    cfg = ActorConfig(
        record_trace=True, seed=3, chaos=_acceptance_chaos(31),
        reliable=ReliableConfig(rto=0.5), recover=True)
    a = ActorDriver(SPEC, costs, cfg)
    a.run()
    b = ActorDriver(SPEC, costs, cfg)
    b.run()
    assert a.trace.signature() == b.trace.signature()


def test_partition_escalates_to_link_failure_and_recovers():
    """A partition outliving the retry budget becomes a link-failure event
    the recovery coordinator heals like a stage fault (partition + death)."""
    costs = sim_costs(SPEC, 5)
    chaos = ChaosConfig(seed=41, partitions=((0, 1, 1.0, 200.0),),
                        fail_stages=((2, "kill", 3),))
    cfg = ActorConfig(
        record_trace=True, seed=5, chaos=chaos,
        reliable=ReliableConfig(rto=0.05, max_retries=3), recover=True)
    driver = ActorDriver(SPEC, costs, cfg)
    with artifact_on_failure(lambda: driver.trace, "lossy_partition_death"):
        driver.run()
        trace = driver.trace
        kinds = {w["fail_kind"] for w in trace.recovery_windows()}
        assert "link" in kinds, "partition never escalated"
        assert "kill" in kinds, "planned death missing"
        assert trace.meta["reliable_stats"]["link_failures"] >= 1
        check_all(trace, SPEC, cfg)


# ---------------------------------------------------------------------------
# protocol property: dedup is idempotent under arbitrary adversarial wires
# ---------------------------------------------------------------------------
class _AdversarialWire:
    """Manual wire around one ReliableChannel: every transmission, ack and
    RTO timer is parked here, and the test interleaves/duplicates/drops
    them in an arbitrary (drawn) order."""

    def __init__(self, n_msgs: int):
        self.transmissions: list[tuple[Envelope, int]] = []
        self.acks: list = []
        self.timers: list = []
        self.delivered: list[Envelope] = []
        self.channel = ReliableChannel(
            ReliableConfig(rto=1.0, max_retries=10 ** 6),
            transmit=lambda env, a, now: self.transmissions.append((env, a)),
            send_ack=lambda ack, env, now: self.acks.append(ack),
            set_timer=lambda d, fn: self.timers.append(fn),
            deliver=lambda env, now: self.delivered.append(env),
        )
        self.n_msgs = n_msgs
        for i in range(n_msgs):
            self.channel.send(Envelope(
                task=Task(Kind.F, 1, i, 0), src_stage=0, dst_stage=1,
                payload=i))

    def step(self, action: int, index: int) -> None:
        """One adversarial move.  Duplication falls out of delivering the
        same parked transmission twice (the wire never consumes it);
        reordering from index-targeted picks; drop from firing a timer
        instead of delivering (the retransmission re-parks)."""
        if action == 0 and self.transmissions:  # deliver (dup/reorder ok)
            env, att = self.transmissions[index % len(self.transmissions)]
            self.channel.on_wire(env, att, 0.0)
        elif action == 1 and self.timers:  # fire an RTO (drop-equivalent)
            fn = self.timers.pop(index % len(self.timers))
            fn(0.0)
        elif action == 2 and self.acks:  # land an ack (reordered ok)
            ack = self.acks.pop(index % len(self.acks))
            self.channel.on_ack(ack, 0.0)
        elif action == 3 and self.transmissions:  # corrupt then deliver
            env, att = self.transmissions[index % len(self.transmissions)]
            bad = dataclasses.replace(env, checksum=env.checksum ^ 0xBEEF)
            self.channel.on_wire(bad, att, 0.0)

    def settle(self) -> None:
        """Honest endgame: ferry everything until nothing is unacked."""
        for _ in range(10 ** 4):
            if self.channel.inflight() == 0:
                return
            while self.transmissions:
                env, att = self.transmissions.pop()
                self.channel.on_wire(env, att, 0.0)
            while self.acks:
                self.channel.on_ack(self.acks.pop(), 0.0)
            if self.channel.inflight() and self.timers:
                self.timers.pop()(0.0)
        raise AssertionError("protocol failed to settle")


@settings(max_examples=40, deadline=None)
@given(n_msgs=st.integers(2, 6), seed=st.integers(0, 10 ** 6))
def test_reliable_dedup_idempotent_under_adversarial_wire(n_msgs, seed):
    rng = np.random.default_rng(seed)
    wire = _AdversarialWire(n_msgs)
    for _ in range(int(rng.integers(5, 40))):
        wire.step(int(rng.integers(0, 4)), int(rng.integers(0, 100)))
        eseqs = [e.eseq for e in wire.delivered]
        assert len(eseqs) == len(set(eseqs)), (
            f"duplicate delivery mid-interleaving: {eseqs}")
    wire.settle()
    assert sorted(e.eseq for e in wire.delivered) == list(range(n_msgs)), (
        "exactly-once violated after settling")
    # payloads rode intact: delivery i carries payload i
    for env in wire.delivered:
        assert env.payload == env.eseq


# ---------------------------------------------------------------------------
# strict chaos grammar
# ---------------------------------------------------------------------------
def test_parse_chaos_lossy_syntax():
    c = parse_chaos(
        "drop_prob=0.05,corrupt_prob=0.01,partition=1:2:0.5:0.25,"
        "fail_stages=1:kill:3+2:kill:4,seed=7")
    assert c.drop_prob == 0.05 and c.corrupt_prob == 0.01
    assert c.partitions == ((1, 2, 0.5, 0.25),)
    assert c.fail_stages == ((1, "kill", 3), (2, "kill", 4))
    assert c.lossy() and c.active()


def test_parse_chaos_unknown_key_fails_fast():
    with pytest.raises(ValueError, match="unknown chaos key 'drop_porb'"):
        parse_chaos("drop_porb=0.05")
    with pytest.raises(ValueError, match="valid keys"):
        parse_chaos("latency_base=0.1,bogus=1")


def test_parse_chaos_bad_value_fails_fast():
    with pytest.raises(ValueError, match="bad chaos value"):
        parse_chaos("drop_prob=lots")
    with pytest.raises(ValueError, match="bad chaos value"):
        parse_chaos("partition=1:2:0.5")  # needs a:b:t0:dur
    with pytest.raises(ValueError):
        parse_chaos("fail_stages=1:frobnicate:3")  # unknown fail kind


def test_reliable_check_catches_seeded_duplicate():
    """check_reliable_delivery is not vacuous: planting a duplicate DELIVER
    record for an already-landed eseq must trip the dedup assertion."""
    costs = sim_costs(SPEC, 9)
    cfg = ActorConfig(record_trace=True, seed=9,
                      chaos=ChaosConfig(seed=9, drop_prob=0.1),
                      reliable=ReliableConfig(rto=0.5))
    driver = ActorDriver(SPEC, costs, cfg)
    driver.run()
    trace = driver.trace
    check_reliable_delivery(trace, SPEC)  # sane baseline
    ev = next(e for e in trace.events
              if e.kind == "deliver" and "eseq" in e.info)
    trace.events.append(ev)
    with pytest.raises(AssertionError, match="dedup violated"):
        check_reliable_delivery(trace, SPEC)
