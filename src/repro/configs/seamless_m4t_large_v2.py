"""SeamlessM4T-large-v2 — encoder-decoder multimodal (audio frontend stubbed:
input_specs provides precomputed frame embeddings).  [arXiv:2308.11596; hf]

24 encoder + 24 decoder layers (the assigned 24L spec applied to both halves
of the enc-dec stack, mirroring the HF config's symmetric layout)."""
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=48,          # 24 enc + 24 dec
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    act="gelu",
    embed_input=False,      # decoder tokens are embedded; enc frames stubbed
    dtype=jnp.bfloat16,
)
