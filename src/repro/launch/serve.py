"""Batched serving driver: pipelined decode with stage-local KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
        --devices 8 --stages 4 --batch 8 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_mesh
from repro.models.build import build
from repro.pipeline.decode import DecodeOptions, make_serve_fn
from repro.pipeline.sharding import partition_for


def build_server(arch: str, *, data: int, stages: int, layers: int | None,
                 batch: int, cache_len: int, reduced: bool = True):
    cfg = (registry.reduced_config(arch, num_layers=layers)
           if reduced else registry.get_arch(arch))
    model = build(cfg, num_stages=stages)
    mesh = make_mesh(data, stages)
    key = jax.random.key(0)
    sp = model.init_stage_params(key)
    io = model.init_io_params(jax.random.fold_in(key, 1))
    partition = partition_for(model, sp, io)
    rows_per_shard = batch // data
    opts = DecodeOptions(mb_rows=1, cache_len=cache_len)
    wrap, _, _ = make_serve_fn(model, mesh, opts, num_groups=rows_per_shard)
    serve_step = jax.jit(wrap(partition))
    one = model.init_layer_cache(batch, cache_len,
                                 enc_len=max(1, cache_len // 4))
    caches = jax.tree.map(
        lambda x: jnp.broadcast_to(
            x[None, None], (stages, model.l_max) + x.shape).copy(), one)
    return dict(cfg=cfg, model=model, mesh=mesh, serve_step=serve_step,
                sp=sp, io=io, caches=caches)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()
    data = args.devices // args.stages
    s = build_server(args.arch, data=data, stages=args.stages,
                     layers=args.layers, batch=args.batch,
                     cache_len=args.cache_len)
    cfg = s["cfg"]
    tokens = jax.random.randint(jax.random.key(7), (args.batch,), 0,
                                cfg.vocab_size).astype(jnp.int32)
    caches = s["caches"]
    seqs = [np.asarray(tokens)]
    t0 = time.time()
    for pos in range(args.tokens):
        batch = {"tokens": tokens}
        if cfg.embed_input:
            batch = {"embeds": jax.random.normal(
                jax.random.key(pos), (args.batch, 1, cfg.d_model)) * 0.02}
        tokens, caches = s["serve_step"](
            s["sp"], s["io"], caches, batch, jnp.asarray(pos, jnp.int32))
        seqs.append(np.asarray(tokens))
    dt = time.time() - t0
    out = np.stack(seqs, 1)
    print(f"decoded {args.tokens} tokens × batch {args.batch} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    for row in out[:4]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
