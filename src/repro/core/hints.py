"""Hint orders (§5, Appendix A) and fixed pre-committed execution orders.

A hint order ranks *currently ready* candidates; it never forces waiting.  The
same objects can also be consumed in ``PRECOMMITTED`` mode by the engine, which
is how the 1F1B / GPipe / ZeroBubble baselines are expressed: an explicit
per-stage task sequence that the stage must follow in order, waiting on any
not-yet-ready entry.  That "one schedule, two consumption modes" contrast is
the paper's central claim.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Iterable, Iterator, Sequence

from repro.core.taskgraph import Kind, PipelineSpec, Task


class HintKind(enum.Enum):
    BF = "bf"              # default: backward, then forward, each round
    FB = "fb"              # forward, then backward, each round
    B_PRIORITY = "b_priority"  # backward whenever any backward is ready
    F_PRIORITY = "f_priority"  # forward whenever any forward is ready
    BFW = "bfw"            # BF + weight-update tasks fill empty rounds


def _within_direction_key(t: Task):
    """Appendix A within-direction priority.

    Forward prefers the *smaller* model-chunk index, backward the *larger*;
    ties break on the smaller microbatch index.  (W inherits backward's rule.)
    """
    if t.kind == Kind.F:
        return (t.chunk, t.mb)
    return (-t.chunk, t.mb)


def pick(ready: "Sequence[Task] | ReadySet", kind: Kind) -> Task | None:
    """NextByPriority(L_r, Pi) restricted to one direction.

    Accepts either a plain task sequence (the reference sort-then-rank
    path) or a :class:`ReadySet` (O(1) peek at the precomputed per-kind
    minimum).  Both resolve ties identically: the within-direction key is
    injective over distinct tasks of one stage, and the ReadySet heap
    falls back to the Task total order on the (cross-stage-only) ties the
    reference resolves via the callers' sorted presentation order.
    """
    if isinstance(ready, ReadySet):
        return ready.peek(kind)
    cands = [t for t in ready if t.kind == kind]
    if not cands:
        return None
    return min(cands, key=_within_direction_key)


def table_ranks(order: Sequence[Task]) -> dict[Task, int]:
    """A synthesized per-stage order as a rank table (task -> position).

    The adaptive runtime consumes re-synthesized schedules this way:
    the table *ranks* ready work, it never forces waiting on an unready
    entry — the same non-binding contract as the directional hints.
    """
    return {t: i for i, t in enumerate(order)}


def _table_key(ranks: dict[Task, int], t: Task) -> tuple:
    """Total order under a rank table: ranked tasks first (by rank), then
    unranked ones by the Appendix A within-direction key (injective per
    stage), so a stale table still dispatches everything deterministically."""
    r = ranks.get(t)
    if r is not None:
        return (0, r)
    return (1, int(t.kind)) + _within_direction_key(t)


class ReadySet:
    """Incremental ready-set index: lazy-deletion heap per task kind.

    The sort-then-rank dispatch path cost O(n log n) per decision:
    ``arbiter.select(sorted(ready))`` re-sorted and re-scanned the whole
    ready set on *every* arbitration attempt.  This index keeps one binary
    heap per kind, keyed by the precomputed Appendix A within-direction
    priority, so the hot path becomes O(log n) insert / amortized-O(1)
    peek — with the exact same tie-break total order (heap entries carry
    ``(key, task)``; the key is injective over distinct tasks of one
    stage, and `Task`'s own total order resolves anything beyond that,
    matching ``min`` over a sorted presentation).

    Removals are lazy: ``discard`` only drops the task from the live set;
    stale heap heads are popped at the next ``peek``.  Each task is pushed
    at most once per ``add``, and the runtime dispatches each task exactly
    once, so heap garbage is bounded by the number of dispatches.

    Set-like surface (``in``, ``len``, iteration, ``add``/``discard``)
    keeps every cold-path consumer (trace snapshots, drains, diagnostics)
    working unchanged.
    """

    __slots__ = ("_live", "_heaps", "_table", "_theap")

    def __init__(self, tasks: Iterable[Task] = (),
                 table: dict[Task, int] | None = None):
        self._live: set[Task] = set()
        self._heaps: dict[Kind, list[tuple[tuple[int, int], Task]]] = {
            k: [] for k in Kind}
        #: optional rank table (task -> priority); maintains one extra
        #: cross-kind heap so ``peek_table`` stays amortized O(1)
        self._table: dict[Task, int] | None = table
        self._theap: list[tuple[tuple, Task]] = []
        for t in tasks:
            self.add(t)

    # ---- mutation ---------------------------------------------------------
    def add(self, t: Task) -> None:
        if t in self._live:
            return
        self._live.add(t)
        heapq.heappush(self._heaps[t.kind], (_within_direction_key(t), t))
        if self._table is not None:
            heapq.heappush(self._theap, (_table_key(self._table, t), t))

    def discard(self, t: Task) -> None:
        # Lazy: the heap entry stays until it surfaces at a peek.
        self._live.discard(t)

    def set_table(self, ranks: dict[Task, int] | None) -> None:
        """Install (or drop) a rank table — the hot-swap point.

        Rebuilds the cross-kind heap from the live set: O(n) for n ready
        tasks, paid once per swap (iteration boundaries), never on the
        dispatch hot path."""
        self._table = ranks
        if ranks is None:
            self._theap = []
            return
        self._theap = [(_table_key(ranks, t), t) for t in self._live]
        heapq.heapify(self._theap)

    # ---- queries ----------------------------------------------------------
    def peek(self, kind: Kind) -> Task | None:
        """The within-direction minimum ready task of ``kind`` (or None)."""
        heap = self._heaps[kind]
        while heap and heap[0][1] not in self._live:
            heapq.heappop(heap)
        return heap[0][1] if heap else None

    def peek_table(self) -> Task | None:
        """The rank-table minimum over *all* ready tasks (or None)."""
        heap = self._theap
        while heap and heap[0][1] not in self._live:
            heapq.heappop(heap)
        return heap[0][1] if heap else None

    def __contains__(self, t: Task) -> bool:
        return t in self._live

    def __len__(self) -> int:
        return len(self._live)

    def __bool__(self) -> bool:
        return bool(self._live)

    def __iter__(self) -> Iterator[Task]:
        return iter(self._live)

    def __repr__(self) -> str:  # diagnostics only
        return f"ReadySet({sorted(self._live)!r})"


@dataclasses.dataclass
class HintArbiter:
    """Algorithm 1's arbitration: stateful round structure per stage.

    ``last_dir`` implements the round alternation of the BF/FB hints: after a
    B executes, the same round's F check runs next (and vice versa for FB).
    """

    hint: HintKind = HintKind.BF
    last_dir: Kind | None = None
    #: optional rank table (task -> priority).  When set, ``select``
    #: serves the minimum-rank ready task instead of the directional
    #: round structure — same non-binding contract, finer priorities.
    #: Swapped at runtime via :meth:`set_table` (adaptive re-synthesis).
    table: dict[Task, int] | None = None

    def try_order(self) -> tuple[Kind, ...]:
        """The kind preference the *next* ``select`` will scan, in order.

        Exposed so the runtime can record each dispatch's arbitration order
        in the event trace: the conformance checker replays it against the
        stage's remaining tasks to verify that the hint order is violated
        only when the hinted task is unready.
        """
        return self.order_given(self.last_dir)

    def order_given(self, prev: Kind | None) -> tuple[Kind, ...]:
        """``try_order`` as of a captured pre-``select`` ``last_dir`` —
        lets a caller reconstruct the order a dispatch actually scanned
        after the select has already advanced the round alternation."""
        if self.hint == HintKind.B_PRIORITY:
            order: tuple[Kind, ...] = (Kind.B, Kind.F)
        elif self.hint == HintKind.F_PRIORITY:
            order = (Kind.F, Kind.B)
        elif self.hint == HintKind.FB:
            order = (Kind.B, Kind.F) if prev == Kind.F else (Kind.F, Kind.B)
        elif self.hint in (HintKind.BF, HintKind.BFW):
            order = (Kind.F, Kind.B) if prev == Kind.B else (Kind.B, Kind.F)
        else:  # pragma: no cover
            raise ValueError(self.hint)
        if self.hint == HintKind.BFW:
            # Weight-update tasks fill rounds with no ready compute direction.
            order += (Kind.W,)
        return order

    def rank_given(self, kind: Kind, prev: Kind | None) -> int:
        """``order_given(prev).index(kind)`` without building the tuple —
        the hint-divergence slot on the metrics hot path (0 = the hinted
        direction was served)."""
        if kind == Kind.W:
            return 2  # only BFW appends W, always last
        if self.hint == HintKind.B_PRIORITY:
            first = Kind.B
        elif self.hint == HintKind.F_PRIORITY:
            first = Kind.F
        elif self.hint == HintKind.FB:
            first = Kind.B if prev == Kind.F else Kind.F
        else:
            first = Kind.F if prev == Kind.B else Kind.B
        return 0 if kind == first else 1

    def select(self, ready: Sequence[Task] | ReadySet) -> Task | None:
        """Return the dispatched task for the current ready set (or None).

        With a :class:`ReadySet` each direction probe is an O(1) heap peek
        (the production hot path); with a plain sequence it is the
        reference linear scan.  Decisions are identical either way.
        """
        if self.table is not None:
            if isinstance(ready, ReadySet):
                return ready.peek_table()
            if not ready:
                return None
            ranks = self.table
            return min(ready, key=lambda t: _table_key(ranks, t))
        for k in self.try_order():
            t = pick(ready, k)
            if t is not None:
                # A W dispatch fills an empty round without consuming it:
                # round alternation tracks compute directions only.
                if k != Kind.W and self.hint in (
                        HintKind.BF, HintKind.FB, HintKind.BFW):
                    self.last_dir = t.kind
                return t
        return None

    def reset(self) -> None:
        self.last_dir = None

    def set_table(self, ranks: dict[Task, int] | None) -> None:
        """Hot-swap the rank table (None reverts to the directional hint)."""
        self.table = ranks


def backpressure_drain(
    spec: PipelineSpec,
    stage: int,
    ready: Sequence[Task] | ReadySet,
    done: set[Task],
    drain_focus: int,
) -> tuple[Task | None, int]:
    """Appendix C drain orders, shared by the DES engine and the actor runtime.

    Non-interleaved pipelines drain backward-only; interleaved pipelines
    follow the deterministic per-microbatch completion order
    F_0..F_{C-1}, B_{C-1}..B_0 focused on microbatches in index order.
    Returns (task-or-None, updated drain focus).  A :class:`ReadySet`
    serves the backward-only pick in O(1) and the interleaved membership
    probes in O(1); a plain sequence takes the reference linear path.
    """
    if spec.num_chunks == 1:
        return pick(ready, Kind.B), drain_focus
    C = spec.num_chunks
    ready_set = ready if isinstance(ready, ReadySet) else set(ready)
    j = drain_focus
    while j < spec.num_microbatches:
        seq_order = [Task(Kind.F, stage, j, c) for c in range(C)] + [
            Task(Kind.B, stage, j, c) for c in reversed(range(C))
        ]
        for t in seq_order:
            if t in done:
                continue
            return (t if t in ready_set else None), j
        j += 1
    return None, j


# --------------------------------------------------------------------------
# Fixed per-stage execution orders (pre-committed baselines + synthesis grid).
# --------------------------------------------------------------------------

def gpipe_order(spec: PipelineSpec, stage: int) -> list[Task]:
    """All forwards, then all backwards (GPipe; also the DeepSpeed-like mode)."""
    fs = [
        Task(Kind.F, stage, j, c)
        for c in range(spec.num_chunks)
        for j in range(spec.num_microbatches)
    ]
    bs = [
        Task(Kind.B, stage, j, c)
        for c in reversed(range(spec.num_chunks))
        for j in range(spec.num_microbatches)
    ]
    out = fs + bs
    if spec.split_backward:
        out += [
            Task(Kind.W, stage, j, c)
            for c in reversed(range(spec.num_chunks))
            for j in range(spec.num_microbatches)
        ]
    return out


def one_f_one_b_order(spec: PipelineSpec, stage: int) -> list[Task]:
    """Standard non-interleaved 1F1B (PipeDream-flush / Megatron default).

    Warmup: dist-to-sink forwards (S-1-s on a chain; the longest forward
    path to a loss stage on a DAG); steady state: alternate 1F/1B;
    cooldown: drain backwards.  Only defined for num_chunks == 1.
    """
    if spec.num_chunks != 1:
        raise NotImplementedError("interleaved 1F1B handled by synthesis")
    M = spec.num_microbatches
    warmup = min(spec.dist_to_sink(stage), M)
    order: list[Task] = [Task(Kind.F, stage, j) for j in range(warmup)]
    nf, nb = warmup, 0
    while nb < M:
        if nf < M:
            order.append(Task(Kind.F, stage, nf))
            nf += 1
        order.append(Task(Kind.B, stage, nb))
        nb += 1
    if spec.split_backward:
        raise NotImplementedError("use zero_bubble_order for split backward")
    return order


def zero_bubble_order(spec: PipelineSpec, stage: int) -> list[Task]:
    """ZB-H1-style fixed order: 1F1B over (F, B-dX) with W deferred.

    W for microbatch j is scheduled as late as the memory argument allows:
    early W fill the warmup-asymmetry bubbles, the rest drain in the cooldown.
    This is the representative fixed-order ZB baseline of §7 (not a full ILP
    ZB-V reimplementation).
    """
    if spec.num_chunks != 1:
        raise NotImplementedError
    if not spec.split_backward:
        raise ValueError("zero_bubble_order requires split_backward=True")
    M = spec.num_microbatches
    depth = spec.dist_to_sink(stage)
    warmup = min(depth, M)
    order: list[Task] = [Task(Kind.F, stage, j) for j in range(warmup)]
    nf, nb, nw = warmup, 0, 0
    while nb < M:
        if nf < M:
            order.append(Task(Kind.F, stage, nf))
            nf += 1
        order.append(Task(Kind.B, stage, nb))
        nb += 1
        # ZB: defer W unless we've run out of F's to issue (cooldown), in
        # which case W fills what would otherwise be a bubble slot.
        if nf >= M and nw < nb - depth:
            order.append(Task(Kind.W, stage, nw))
            nw += 1
    while nw < M:
        order.append(Task(Kind.W, stage, nw))
        nw += 1
    return order


def modality_balanced_order(
    spec: PipelineSpec, stage: int, stage_cost: Sequence[float]
) -> list[Task]:
    """Cornstarch-like baseline: cost-aware warmup depth, still pre-committed.

    Uses per-stage relative cost to shift the warmup depth (heavier stages get
    fewer in-flight microbatches), emulating a modality-aware planner that
    still commits to its order ahead of execution.  On a DAG the base depth
    is the stage's longest forward path to the loss stage, so encoder-branch
    stages (cheap, far from the sink) warm up deep while decoder stages stay
    shallow — the planner's view of the modality imbalance.

    Feasibility: with asynchronous sends, a set of per-stage 1F1B-style
    orders is deadlock-free iff every forward edge (s -> u) satisfies
    ``warmup(s) >= warmup(u) + 1`` (a stage must stay a microbatch ahead of
    each consumer before it starts waiting on backwards).  The cost-aware
    depths are therefore clamped by a reverse-topological pass; a stage
    pinned at ``M`` (GPipe-like, all forwards first) releases its
    predecessors from the constraint only if they are pinned at ``M`` too.
    """
    if spec.num_chunks != 1:
        raise NotImplementedError
    S, M = spec.num_stages, spec.num_microbatches

    def desired(s: int) -> int:
        rel = stage_cost[s] / max(max(stage_cost), 1e-12)
        return min(max(1, round(spec.dist_to_sink(s) * (1.5 - rel))), M, S)

    warmups: dict[int, int] = {}
    order_rev = (spec.graph.topological_order() if spec.graph is not None
                 else tuple(range(S)))
    for s in reversed(order_rev):
        need = max((warmups[u] + 1 for u in spec.stage_successors(s)),
                   default=0)
        warmups[s] = min(M, max(desired(s), need))
    warmup = warmups[stage]
    order: list[Task] = [Task(Kind.F, stage, j) for j in range(warmup)]
    nf, nb = warmup, 0
    while nb < M:
        if nf < M:
            order.append(Task(Kind.F, stage, nf))
            nf += 1
        order.append(Task(Kind.B, stage, nb))
        nb += 1
    if spec.split_backward:
        order += [Task(Kind.W, stage, j) for j in range(M)]
    return order


FIXED_ORDERS = {
    "gpipe": gpipe_order,
    "1f1b": one_f_one_b_order,
    "zb": zero_bubble_order,
}
