"""Deterministic synthetic data pipeline with host-side prefetch.

Batches are reproducible functions of (seed, step) — restart-safe: resuming
from a checkpoint at step k regenerates exactly the stream the crashed run
would have seen.  Token streams follow a Zipfian unigram mix with induced
bigram structure so the LM loss has signal to descend.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.models.common import ArchConfig


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synth_batch(cfg: ArchConfig, batch: int, seq: int, *, seed: int = 0,
                step: int = 0, enc_len: int = 0) -> dict:
    """One global batch for ``cfg``: tokens/labels (+ stub embeddings)."""
    rng = _rng(seed, step)
    v = cfg.vocab_size
    # zipf unigram with a deterministic bigram successor table: learnable
    base = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64) % v
    succ = (np.arange(v) * 31 + 7) % v
    follow = rng.random((batch, seq + 1)) < 0.5
    toks = base.copy()
    toks[:, 1:] = np.where(follow[:, 1:], succ[toks[:, :-1]], base[:, 1:])
    out = {
        "tokens": toks[:, :seq].astype(np.int32),
        "labels": toks[:, 1 : seq + 1].astype(np.int32),
    }
    if cfg.embed_input:
        out["embeds"] = (rng.standard_normal((batch, seq, cfg.d_model)) * 0.02
                         ).astype(np.float32)
    if cfg.mrope:
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32), (3, batch, seq))
        out["mrope"] = pos.copy()
    if cfg.encoder_layers:
        out["enc_embeds"] = (
            rng.standard_normal((batch, enc_len or seq, cfg.d_model)) * 0.02
        ).astype(np.float32)
    return out


class PrefetchIterator:
    """Host-side prefetch: a producer thread keeps ``depth`` batches ready so
    input generation overlaps device compute (the data-pipeline half of
    compute/IO overlap at scale)."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
