"""Critical-path engine conformance: the longest path *is* the makespan.

Property-checks ``repro.obs.critpath`` against randomized recorded runs
(the same scenario generators the chaos/recovery conformance suites use):

* the execution graph's longest path reconstructs the recorded sim
  makespan **bit-exactly** — chain and DAG topologies, C0..C3 chaos,
  with and without armed fail-stop faults (respawn and remap), and across
  mid-run HINT_SWAP table swaps;
* per-node slack is >= 0 everywhere and exactly 0 along the critical path;
* the category decomposition (compute / comm / gate / dispatch / recovery)
  sums *exactly* to the makespan — 100% accounted, no residue;
* the what-if recurrence at factor 1.0 regenerates the recorded makespan,
  and recovery windows are pinned: no virtual speedup shrinks MTTR.
"""
import dataclasses

import pytest

from harness import make_dag_scenario, make_scenario, sim_costs
from test_adaptive_swap import _swap_scenario
from test_recovery import _arm_fault

from repro.obs.critpath import CP_CATEGORIES, ExecGraph
from repro.obs.whatif import Speedup, predict, predict_ends
from repro.runtime.rrfp import ActorDriver

SEEDS_FAST = list(range(0, 8))
SEEDS_SLOW = list(range(8, 24))


def _run(spec, cfg, seed):
    cfg = dataclasses.replace(cfg, record_trace=True)
    res = ActorDriver(spec, sim_costs(spec, seed), cfg).run()
    return res.trace


def _check_exact(trace, spec):
    """The tentpole invariants, asserted on one recorded trace."""
    g = ExecGraph.build(trace, spec)
    mk = float(trace.meta["makespan"])
    assert g.makespan == mk, (g.makespan, mk)
    assert g.verify() < 1e-9
    slacks = g.slack()
    assert min(slacks.values()) >= 0.0
    for node, _ in g.critical_path():
        assert slacks[node.key] == 0.0
    rep = g.decompose()
    assert sum(rep.categories[c] for c in CP_CATEGORIES) == mk
    assert all(v >= 0.0 for v in rep.categories.values())
    # compute splits are internally consistent (to float tolerance)
    for split in (rep.compute_by_op, rep.compute_by_stage):
        assert sum(split.values()) == pytest.approx(
            rep.categories["compute"], rel=1e-9, abs=1e-12)
    return g


@pytest.mark.parametrize("seed", SEEDS_FAST)
@pytest.mark.parametrize("make", [make_scenario, make_dag_scenario],
                         ids=["chain", "dag"])
def test_critical_path_reconstructs_makespan(make, seed):
    sc = make(seed)
    _check_exact(_run(sc.spec, sc.config, seed), sc.spec)


@pytest.mark.slow
@pytest.mark.parametrize("seed", SEEDS_SLOW)
@pytest.mark.parametrize("make", [make_scenario, make_dag_scenario],
                         ids=["chain", "dag"])
def test_critical_path_reconstructs_makespan_slow(make, seed):
    sc = make(seed)
    _check_exact(_run(sc.spec, sc.config, seed), sc.spec)


@pytest.mark.parametrize("seed", SEEDS_FAST)
@pytest.mark.parametrize("make", [make_scenario, make_dag_scenario],
                         ids=["chain", "dag"])
def test_critical_path_exact_across_recovery(make, seed):
    """Armed fail-stop fault (kill / permanent stall, respawn / remap):
    the reconstruction stays bit-exact and the recovery category shows."""
    sc = make(seed)
    cfg, _ = _arm_fault(sc, seed)
    trace = _run(sc.spec, cfg, seed)
    g = _check_exact(trace, sc.spec)
    if trace.recovery_windows():
        assert g.num_recovery_windows >= 1
        # MTTR is charged exactly when an outage bounds the makespan
        on_path = any(n.op == "recovery" for n, _ in g.critical_path())
        assert (g.decompose().categories["recovery"] > 0.0) == on_path


@pytest.mark.parametrize("seed", [9, 17])
def test_critical_path_exact_across_hint_swap(seed):
    """Mid-run HINT_SWAP table swaps do not break the reconstruction."""
    spec, costs, cfg = _swap_scenario(seed)
    cfg = dataclasses.replace(cfg, record_trace=True)
    trace = ActorDriver(spec, costs, cfg).run().trace
    from repro.runtime.rrfp import trace as _tr
    assert any(ev.kind == _tr.HINT_SWAP for ev in trace.events)
    _check_exact(trace, spec)


@pytest.mark.parametrize("seed", SEEDS_FAST[:4])
def test_whatif_identity_at_factor_one(seed):
    """factor == 1.0 leaves every predicted completion at its recording."""
    sc = make_scenario(seed)
    g = ExecGraph.build(_run(sc.spec, sc.config, seed), sc.spec)
    assert predict(g, [Speedup(factor=1.0)]) == pytest.approx(
        g.makespan, rel=1e-9)
    assert predict(g, [Speedup(factor=1.0, comm=True)]) == pytest.approx(
        g.makespan, rel=1e-9)


@pytest.mark.parametrize("seed", SEEDS_FAST[:4])
def test_whatif_speedup_never_hurts(seed):
    """A virtual speedup (factor < 1) can only shrink the prediction; a
    virtual slowdown can only grow it."""
    sc = make_dag_scenario(seed)
    g = ExecGraph.build(_run(sc.spec, sc.config, seed), sc.spec)
    eps = 1e-9 * g.makespan
    for s in (Speedup(factor=0.5), Speedup(factor=0.5, comm=True),
              Speedup(factor=0.5, op="F")):
        assert predict(g, [s]) <= g.makespan + eps
    for s in (Speedup(factor=2.0), Speedup(factor=2.0, comm=True)):
        assert predict(g, [s]) >= g.makespan - eps


@pytest.mark.parametrize("seed", SEEDS_FAST)
def test_whatif_recovery_windows_pinned(seed):
    """MTTR is attributed, never 'sped up': recovery nodes keep their
    recorded completion under any virtual speedup."""
    sc = make_scenario(seed)
    cfg, _ = _arm_fault(sc, seed)
    trace = _run(sc.spec, cfg, seed)
    if not trace.recovery_windows():
        pytest.skip("fault did not produce a completed recovery window")
    g = ExecGraph.build(trace, sc.spec)
    rec_keys = [k for k, n in g.nodes.items() if n.op == "recovery"]
    assert rec_keys
    ends = predict_ends(g, [Speedup(factor=0.25),
                            Speedup(factor=0.25, comm=True)])
    for k in rec_keys:
        assert ends[k] == g.nodes[k].end_t
