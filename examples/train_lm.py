"""End-to-end driver: train a ~100M-parameter LM with the full stack —
RRFP-synthesized schedule, ZeRO-1 AdamW, checkpoint/restart, straggler
monitor.  (CPU-sized by default: --d-model 256 gives a ~25M model that runs
a few hundred steps in minutes; --full gives the 100M configuration.)

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python examples/train_lm.py --steps 200
"""
import argparse
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.costs import CostModel
from repro.core.taskgraph import PipelineSpec
from repro.data.synthetic import PrefetchIterator, synth_batch
from repro.runtime.straggler import StragglerMonitor

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--full", action="store_true", help="~100M params")
ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
args = ap.parse_args()

d = 768 if args.full else args.d_model
layers = 12 if args.full else args.layers

# a custom ~100M-class llama-style config on the deepseek-7b family
base = registry.reduced_config("deepseek-7b", num_layers=layers)
cfg = dataclasses.replace(
    base, d_model=d, num_heads=max(4, d // 64), num_kv_heads=max(4, d // 64),
    head_dim=0, d_ff=4 * d, vocab_size=32768 if args.full else 4096,
    name=f"lm-{d}d{layers}L")

from repro.models.build import build
from repro.pipeline.executor import ExecOptions, make_train_fn
from repro.pipeline.sharding import partition_for
from repro.optim.adamw import AdamWConfig, make_optimizer
from repro.pipeline import schedules
from repro.launch.mesh import make_mesh
import jax

model = build(cfg, num_stages=4)
mesh = make_mesh(2, 4)
key = jax.random.key(0)
sp = model.init_stage_params(key)
io = model.init_io_params(jax.random.fold_in(key, 1))
part = partition_for(model, sp, io)
spec = PipelineSpec(4, 8)
table = schedules.rrfp(spec)
gt = 2 * 8 * 1 * args.seq
opts = ExecOptions(mb_rows=1, seq_len=args.seq, loss_scale=1.0 / gt)
fn, _ = make_train_fn(model, table, mesh, opts, part)
oinit, oupd = make_optimizer(model, mesh, part,
                             AdamWConfig(lr=6e-4, warmup_steps=40,
                                         total_steps=args.steps))
opt = jax.jit(oinit)(sp, io)

@jax.jit
def train_step(sp, io, opt, batch, step):
    m, gs, eg = fn(sp, io, batch)
    sp, io, opt, st = oupd(sp, io, opt, gs, eg, step)
    return sp, io, opt, {**m, **st}

monitor = StragglerMonitor(spec=spec, costs=CostModel.uniform(4))
print(f"params: {cfg.param_count():,}")
it = PrefetchIterator(lambda s: synth_batch(cfg, 16, args.seq, step=s))
losses = []
t0 = time.time()
try:
    for _ in range(args.steps):
        step, batch = next(it)
        sp, io, opt, m = train_step(sp, io, opt, batch,
                                    jnp.asarray(step, jnp.int32))
        losses.append(float(m["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(m['gnorm']):.3f}  "
                  f"{(time.time()-t0)/max(step,1)*1e3:6.1f} ms/step")
finally:
    it.close()
print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
assert losses[-1] < losses[0]
