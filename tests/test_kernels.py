"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes/dtypes per the rubric; hypothesis property tests cover the
online-softmax and chunked-scan invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback (tests/_hyp_stub.py)
    from _hyp_stub import given, settings, strategies as st

from repro.kernels import ops, ref


def rand(rng, *shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,hq,hkv,hd,window",
    [
        (1, 128, 4, 4, 64, 0),      # MHA, exact block multiple
        (2, 200, 8, 2, 64, 0),      # GQA, ragged seq
        (1, 384, 8, 1, 128, 0),     # MQA (granite-style kv=1)
        (2, 160, 4, 4, 64, 64),     # sliding window (gemma3-style)
        (1, 96, 4, 2, 32, 0),       # smaller than one block
    ],
)
def test_flash_attention_matches_oracle(b, sq, hq, hkv, hd, window, dtype):
    rng = np.random.default_rng(hash((b, sq, hq, window)) % 2**32)
    q = rand(rng, b, sq, hq, hd, dtype=dtype)
    k = rand(rng, b, sq, hkv, hd, dtype=dtype)
    v = rand(rng, b, sq, hkv, hd, dtype=dtype)
    pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    want = ref.attention_ref(q, k, v, pos, True, window)
    got = ops.flash_attention(q, k, v, pos, causal=True, window=window,
                              backend="interpret")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_xla_blocked_attention_matches_oracle():
    rng = np.random.default_rng(0)
    q = rand(rng, 2, 200, 8, 64)
    k = rand(rng, 2, 200, 2, 64)
    v = rand(rng, 2, 200, 2, 64)
    pos = jnp.broadcast_to(jnp.arange(200)[None], (2, 200))
    for window in (0, 64):
        want = ref.attention_ref(q, k, v, pos, True, window)
        got = ops.flash_attention(q, k, v, pos, causal=True, window=window,
                                  backend="xla")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_attention_grad_matches_oracle_grad():
    rng = np.random.default_rng(1)
    q = rand(rng, 1, 64, 4, 32)
    k = rand(rng, 1, 64, 2, 32)
    v = rand(rng, 1, 64, 2, 32)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (1, 64))
    g_ref = jax.grad(lambda q: ref.attention_ref(q, k, v, pos, True, 0).sum())(q)
    g_xla = jax.grad(lambda q: ops.flash_attention(q, k, v, pos).sum())(q)
    np.testing.assert_allclose(np.asarray(g_xla), np.asarray(g_ref), atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    sq=st.integers(16, 300),
    hq=st.sampled_from([1, 2, 4, 8]),
    g=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([32, 64]),
    seed=st.integers(0, 99),
)
def test_property_flash_attention(sq, hq, g, hd, seed):
    hkv = max(1, hq // g)
    hq = hkv * g
    rng = np.random.default_rng(seed)
    q = rand(rng, 1, sq, hq, hd)
    k = rand(rng, 1, sq, hkv, hd)
    v = rand(rng, 1, sq, hkv, hd)
    pos = jnp.arange(sq)[None]
    want = ref.attention_ref(q, k, v, pos, True, 0)
    got = ops.flash_attention(q, k, v, pos, backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,S,hq,hkv,hd,length,window",
    [
        (2, 300, 8, 2, 64, 157, 0),
        (1, 1024, 4, 1, 128, 1024, 0),
        (2, 512, 4, 4, 64, 300, 128),  # windowed decode
        (1, 64, 2, 2, 32, 1, 0),       # first token
    ],
)
def test_flash_decode_matches_oracle(b, S, hq, hkv, hd, length, window, dtype):
    rng = np.random.default_rng(hash((b, S, length)) % 2**32)
    q = rand(rng, b, 1, hq, hd, dtype=dtype)
    kc = rand(rng, b, S, hkv, hd, dtype=dtype)
    vc = rand(rng, b, S, hkv, hd, dtype=dtype)
    want = ref.decode_ref(q, kc, vc, jnp.full((b,), length, jnp.int32), window)
    got = ops.decode_attention(q, kc, vc, length, window=window,
                               backend="interpret")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_decode_length_is_dynamic():
    """Same compiled kernel must serve any position (scalar prefetch)."""
    rng = np.random.default_rng(3)
    q = rand(rng, 1, 1, 4, 32)
    kc = rand(rng, 1, 256, 2, 32)
    vc = rand(rng, 1, 256, 2, 32)
    for length in (1, 100, 256):
        want = ref.decode_ref(q, kc, vc, jnp.full((1,), length, jnp.int32))
        got = ops.decode_attention(q, kc, vc, length, backend="interpret")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,nh,hd,ds,chunk",
    [
        (2, 256, 4, 32, 16, 64),
        (1, 128, 8, 64, 64, 128),   # zamba2-like state size
        (1, 192, 2, 16, 8, 64),     # non-power-of-two length
        (2, 100, 2, 16, 8, 64),     # needs padding
    ],
)
def test_ssd_matches_sequential_oracle(b, s, nh, hd, ds, chunk, dtype):
    rng = np.random.default_rng(hash((b, s, nh)) % 2**32)
    x = rand(rng, b, s, nh, hd, dtype=dtype)
    dt = jnp.abs(rand(rng, b, s, nh)) * 0.1
    A = -jnp.abs(rand(rng, nh))
    B = rand(rng, b, s, ds)
    C = rand(rng, b, s, ds)
    D = rand(rng, nh)
    want = ref.ssd_ref(x, dt, A, B, C, D)
    got_p = ops.ssd(x, dt, A, B, C, D, chunk=chunk, backend="interpret")
    got_x = ops.ssd(x, dt, A, B, C, D, chunk=chunk, backend="xla")
    # bf16: the XLA path contracts in bf16 (fp32 accumulation) per the
    # §Perf zamba2 iteration — rtol covers bf16 mantissa rounding on values
    # whose magnitude grows with the accumulation length
    atol, rtol = (5e-4, 1e-5) if dtype == jnp.float32 else (6e-2, 3e-2)
    np.testing.assert_allclose(
        np.asarray(got_p, np.float32), np.asarray(want, np.float32),
        atol=atol, rtol=rtol)
    np.testing.assert_allclose(
        np.asarray(got_x, np.float32), np.asarray(want, np.float32),
        atol=atol, rtol=rtol)


def test_ssd_decode_step_consistent_with_scan():
    rng = np.random.default_rng(5)
    b, s, nh, hd, ds = 2, 16, 2, 16, 8
    x = rand(rng, b, s, nh, hd)
    dt = jnp.abs(rand(rng, b, s, nh)) * 0.1
    A = -jnp.abs(rand(rng, nh))
    B = rand(rng, b, s, ds)
    C = rand(rng, b, s, ds)
    D = rand(rng, nh)
    want = ref.ssd_ref(x, dt, A, B, C, D)
    state = jnp.zeros((b, nh, hd, ds))
    for t in range(s):
        y_t, state = ops.ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t],
                                         C[:, t], D)
        np.testing.assert_allclose(
            np.asarray(y_t), np.asarray(want[:, t]), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(8, 200),
    nh=st.sampled_from([1, 2, 4]),
    chunk=st.sampled_from([32, 64]),
    seed=st.integers(0, 99),
)
def test_property_ssd_chunk_invariance(s, nh, chunk, seed):
    """Chunk size must not change the result (state-passing correctness)."""
    rng = np.random.default_rng(seed)
    x = rand(rng, 1, s, nh, 16)
    dt = jnp.abs(rand(rng, 1, s, nh)) * 0.1
    A = -jnp.abs(rand(rng, nh))
    B = rand(rng, 1, s, 8)
    C = rand(rng, 1, s, 8)
    D = rand(rng, nh)
    a = ops.ssd(x, dt, A, B, C, D, chunk=chunk, backend="xla")
    b_ = ops.ssd(x, dt, A, B, C, D, chunk=16, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-4)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 100, 128), (1, 256), (3, 7, 512)])
def test_rmsnorm_matches_oracle(shape, dtype):
    rng = np.random.default_rng(0)
    x = rand(rng, *shape, dtype=dtype)
    sc = rand(rng, shape[-1])
    want = ref.rmsnorm_ref(x, sc)
    got = ops.rmsnorm(x, sc, backend="interpret")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])
