"""Readiness-driven schedule synthesis: the RRFP -> XLA bridge (DESIGN §2).

On a TPU pod the per-tick behavior of every stage must be known at compile
time, so the runtime cannot skip-and-retry at task granularity.  Instead we
run the faithful RRFP engine over the *expected* cost model (optionally
EMA-updated from measured step times — the paper's e_t estimator) and extract
each stage's realized execution order.  That order is exactly what a
readiness-first runtime would have dispatched; we then list-schedule it onto
the executor's tick grid (one ring-permute hop per tick) to obtain a static
``stage_orders`` table the compiled executor consumes as data — changing the
table does not recompile.

``synthesize`` returns per-stage task sequences; ``repro.pipeline.spec``
converts them into a validated ScheduleTable.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costs import CostModel
from repro.core.engine import Engine, EngineConfig
from repro.core.hints import HintKind
from repro.core.taskgraph import PipelineSpec, Task


@dataclasses.dataclass
class SynthesisResult:
    stage_orders: list[list[Task]]
    sim_makespan: float
    #: simulated makespan of pre-committed 1F1B on the same costs (baseline)
    baseline_makespan: float

    @property
    def predicted_speedup(self) -> float:
        return self.baseline_makespan / max(self.sim_makespan, 1e-12)


def synthesize(
    spec: PipelineSpec,
    costs: CostModel,
    hint: HintKind = HintKind.BF,
    buffer_limit: int = 32,
    use_expected_costs: bool = True,
) -> SynthesisResult:
    """Run the RRFP engine and extract per-stage orders for the executor."""
    cm = costs.expected() if use_expected_costs else costs
    rrfp = Engine(
        spec, cm, EngineConfig(mode="hint", hint=hint, buffer_limit=buffer_limit)
    ).run()
    base = Engine(
        spec,
        cm,
        EngineConfig(
            mode="precommitted",
            # zero-bubble is the natural fixed-order baseline once the
            # backward is split; 1F1B is undefined for BFW specs
            fixed_order="zb" if spec.split_backward else "1f1b",
        ),
    ).run()
    return SynthesisResult(
        stage_orders=rrfp.stage_orders(),
        sim_makespan=rrfp.makespan,
        baseline_makespan=base.makespan,
    )


def price_orders(
    spec: PipelineSpec,
    orders: list[list[Task]],
    costs: CostModel,
    use_expected_costs: bool = True,
) -> float:
    """Predicted makespan of a candidate stage-order table under ``costs``.

    Runs the DES engine in pre-committed mode over the candidate orders —
    the same pricing model ``synthesize`` uses for its 1F1B baseline, so a
    re-synthesized table and the currently-active one are compared on
    equal footing.  The adaptive runtime's drift detector calls this with
    the *measured* (jitter-free EWMA snapshot) cost model: a swap happens
    only when the candidate's predicted makespan beats the active
    table's by the configured threshold (docs/adaptive.md).
    """
    cm = costs.expected() if use_expected_costs else costs
    # Async sends: the adaptive runtime executes tables on the actor
    # substrate (mailbox sends, no rendezvous).  Sync rendezvous would also
    # deadlock here — an RRFP-synthesized order can run sends arbitrarily
    # far ahead of the receiver's 2-deep recv window.
    r = Engine(
        spec, cm,
        EngineConfig(mode="precommitted", custom_orders=orders,
                     sync_sends=False),
    ).run()
    return r.makespan


def ema_update_costs(
    costs: CostModel,
    measured_f: np.ndarray,
    measured_b: np.ndarray,
    decay: float = 0.9,
) -> CostModel:
    """Online cost refresh: e_t = decay*e_{t-1} + (1-decay)*c_t (RQ4's EMA).

    Feeds straggler-aware re-synthesis: ``runtime.straggler`` calls this with
    per-stage step timings, then ``synthesize`` re-plans without recompiling.
    """
    return dataclasses.replace(
        costs,
        f_cost=decay * costs.f_cost + (1 - decay) * np.asarray(measured_f),
        b_cost=decay * costs.b_cost + (1 - decay) * np.asarray(measured_b),
    )
