"""Standalone per-stage jitted callables (factored out of the executor).

``pipeline.executor`` compiles the *whole* schedule into one SPMD program —
every stage steps in lockstep through a tick grid.  The actor runtime needs
the opposite factoring: one independently-callable, jitted function per
(stage, op) that a host thread can dispatch the moment the stage's message
arrives.  This module provides that factoring for single-process meshes
(CPU or multi-device single-host), sharing the executor's loss
(:func:`chunked_ce_sum`) and its remat-based backward recipe: B re-runs the
stage forward under ``jax.grad`` of a scalarized objective (CE at the last
stage, <y, g_in> elsewhere).

``ActorStageProgram`` adapts the callables to the actor runtime's
``work_fn(task, payload)`` protocol: it holds the stage's residual store
(per-microbatch forward inputs) and gradient accumulators, consumes arrived
activations/gradients as message payloads, and emits the outgoing payload.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.taskgraph import Kind, Task
from repro.models.build import ArchModel
from repro.models.layers import rmsnorm


@dataclasses.dataclass(frozen=True)
class StageFnOptions:
    mb_rows: int             # microbatch rows
    seq_len: int             # tokens per row
    ce_chunk: int = 0        # 0 -> auto from vocab size
    loss_scale: float = 1.0  # applied to the backward seed


def default_ce_chunk(cfg, requested: int = 0) -> int:
    if requested:
        return requested
    v = cfg.padded_vocab()
    return max(64, min(2048, (1 << 24) // v * 4))


# ---------------------------------------------------------------------------
# loss (shared with the executor)
# ---------------------------------------------------------------------------
def chunked_ce_sum(model: ArchModel, io, y, labels, chunk: int):
    """Sum of token cross-entropies, scanned over token chunks (bounded
    logits working set; checkpointed so backward re-materializes per chunk)."""
    cfg = model.cfg
    h = rmsnorm(y, io["final_ln"], cfg.norm_eps)
    d = h.shape[-1]
    h2 = h.reshape(-1, d)
    l2 = labels.reshape(-1)
    n = h2.shape[0]
    pad = (-n) % chunk
    if pad:
        h2 = jnp.pad(h2, ((0, pad), (0, 0)))
        l2 = jnp.pad(l2, (0, pad), constant_values=-1)
    h3 = h2.reshape(-1, chunk, d)
    l3 = l2.reshape(-1, chunk)
    head = io["head"]

    @jax.checkpoint
    def body(carry, inp):
        h_c, l_c = inp
        logits = (h_c @ head.T).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        pick = jnp.take_along_axis(
            logits, jnp.maximum(l_c, 0)[:, None], axis=1)[:, 0]
        w = (l_c >= 0).astype(jnp.float32)
        return carry + jnp.sum((lse - pick) * w), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h3, l3))
    return total


# ---------------------------------------------------------------------------
# per-stage callables
# ---------------------------------------------------------------------------
class StageFns:
    """Jitted forward/backward per stage of a single-process pipeline.

    ``forward(s)(sp_s, io, x, bm) -> (y, loss_sum)`` — loss_sum nonzero only
    at the last stage.  ``backward(s)(sp_s, io, x, g_in, bm) ->
    (dx, d_stage, d_io)`` — g_in ignored at the last stage (the loss is the
    objective there).

    Under BFW decomposition the fused backward splits into two jitted
    callables over the *same* scalarized objective:

    * ``backward_dx(s)(sp_s, io, x, g_in, bm) -> dx`` — the dX-only B task
      (``argnums=(2,)``), on the critical inter-stage path;
    * ``weight_grad(s)(sp_s, io, x, g_in, bm) -> (d_stage, d_io)`` — the
      deferrable per-microbatch W task (``argnums=(0, 1)``), stage-local.
    """

    def __init__(self, model: ArchModel, opts: StageFnOptions):
        self.model = model
        self.opts = opts
        cfg = model.cfg
        self.ce_chunk = default_ce_chunk(cfg, opts.ce_chunk)
        self._fwd: dict[int, Any] = {}
        self._bwd: dict[int, Any] = {}
        self._bwd_dx: dict[int, Any] = {}
        self._wgrad: dict[int, Any] = {}

    # ---- helpers -------------------------------------------------------
    def _aux(self, bm: dict) -> dict:
        seq = self.opts.seq_len
        a: dict[str, Any] = {
            "positions": jnp.broadcast_to(
                jnp.arange(seq, dtype=jnp.int32)[None],
                (self.opts.mb_rows, seq)),
            "data_size": 1,
            "moe_layout": "none",  # single process: experts computed locally
        }
        if "mrope" in bm:
            a["mrope"] = bm["mrope"]
        return a

    def _embed(self, io, bm: dict):
        cfg = self.model.cfg
        if cfg.embed_input:
            return bm["embeds"].astype(cfg.dtype)
        return io["embed"][bm["tokens"]]

    def _objective(self, stage: int, sp_s, io, x, g_in, bm):
        model, cfg = self.model, self.model.cfg
        x0 = self._embed(io, bm).astype(cfg.dtype) if stage == 0 else x
        y = model.stage_forward(sp_s, io, x0, self._aux(bm), model.rows(stage))
        if stage == model.num_stages - 1:
            return chunked_ce_sum(
                model, io, y, bm["labels"], self.ce_chunk) * self.opts.loss_scale
        return jnp.sum(y.astype(jnp.float32) * g_in.astype(jnp.float32))

    # ---- public --------------------------------------------------------
    def forward(self, stage: int):
        if stage not in self._fwd:
            model, cfg = self.model, self.model.cfg
            last = stage == model.num_stages - 1

            def f(sp_s, io, x, bm):
                x0 = (self._embed(io, bm).astype(cfg.dtype)
                      if stage == 0 else x)
                y = model.stage_forward(
                    sp_s, io, x0, self._aux(bm), model.rows(stage))
                loss = (chunked_ce_sum(model, io, y, bm["labels"],
                                       self.ce_chunk)
                        if last else jnp.zeros((), jnp.float32))
                return y, loss

            self._fwd[stage] = jax.jit(f)
        return self._fwd[stage]

    def backward(self, stage: int):
        if stage not in self._bwd:
            def b(sp_s, io, x, g_in, bm):
                dsp, dio, dx = jax.grad(
                    lambda sp_, io_, x_: self._objective(
                        stage, sp_, io_, x_, g_in, bm),
                    argnums=(0, 1, 2))(sp_s, io, x)
                return dx, dsp, dio

            self._bwd[stage] = jax.jit(b)
        return self._bwd[stage]

    def backward_dx(self, stage: int):
        """dX-only backward (the B task of the BFW decomposition)."""
        if stage not in self._bwd_dx:
            def b_dx(sp_s, io, x, g_in, bm):
                (dx,) = jax.grad(
                    lambda x_: self._objective(
                        stage, sp_s, io, x_, g_in, bm),
                    argnums=(0,))(x)
                return dx

            self._bwd_dx[stage] = jax.jit(b_dx)
        return self._bwd_dx[stage]

    def weight_grad(self, stage: int):
        """Per-microbatch weight gradient (the deferrable W task)."""
        if stage not in self._wgrad:
            def w(sp_s, io, x, g_in, bm):
                dsp, dio = jax.grad(
                    lambda sp_, io_: self._objective(
                        stage, sp_, io_, x, g_in, bm),
                    argnums=(0, 1))(sp_s, io)
                return dsp, dio

            self._wgrad[stage] = jax.jit(w)
        return self._wgrad[stage]


def microbatch(batch: dict, mb: int, mb_rows: int) -> dict:
    """Host-side microbatch slice of a [M*mb_rows, ...] batch dict."""
    lo, hi = mb * mb_rows, (mb + 1) * mb_rows
    out = {}
    for k, v in batch.items():
        if k == "mrope":
            out[k] = v[:, lo:hi]
        else:
            out[k] = v[lo:hi]
    return out


# ---------------------------------------------------------------------------
# actor-runtime adapter
# ---------------------------------------------------------------------------
class ActorStageProgram:
    """``work_fn(task, payload)`` for one stage actor driving real callables.

    F: consume the upstream activation payload (None at stage 0), run the
    jitted forward, stash the stage input for remat-backward, emit y.
    B (fused): consume the downstream gradient payload (None at the last
    stage), re-run forward under grad, accumulate parameter grads, emit dx.

    With ``split_backward=True`` (the BFW decomposition):

    B: run the dX-only backward, stash the (x, g_in) pair for the W task,
    emit dx.  Stage 0 skips the dX computation entirely — no stage consumes
    its input gradient.
    W: consume the stashed pair, run the weight-grad callable, accumulate
    ``d_stage``/``d_io``.  W emits no payload: its result is stage-local
    (``PipelineSpec.message_successor`` is None for W, so no envelope is
    ever sent and no TP admission gate applies).

    The running loss is accumulated as a device array — reading
    ``loss_sum`` materializes it (one sync), so the F hot path never blocks
    on the device.

    With ``deterministic_reduction=True`` the per-microbatch loss and grad
    contributions are *stashed* instead of folded in eagerly, and
    :meth:`finalize` sums them in microbatch order.  Floating-point addition
    is not associative, so the default eager accumulation is bit-sensitive
    to the runtime's dispatch order; the deterministic mode makes the final
    loss and gradients bitwise identical across any execution order of the
    same task set — the property the conformance suite checks between
    chaotic actor runs and the fixed-order reference executor.
    """

    def __init__(self, fns: StageFns, stage: int, sp_s, io, batch: dict,
                 *, split_backward: bool = False,
                 deterministic_reduction: bool = False):
        self.fns = fns
        self.stage = stage
        self.sp_s = sp_s
        self.io = io
        self.batch = batch
        self.split_backward = split_backward
        self.deterministic_reduction = deterministic_reduction
        self.residual: dict[int, Any] = {}  # mb -> stage input
        #: BFW: mb -> (x, g_in) held from B-time until the W task fires
        self.w_pending: dict[int, tuple[Any, Any]] = {}
        self.w_high_water = 0  # max outstanding W stashes (memory bound)
        self.d_stage = jax.tree.map(jnp.zeros_like, sp_s)
        self.d_io = jax.tree.map(jnp.zeros_like, io)
        self.loss_acc = jnp.zeros((), jnp.float32)
        #: deterministic mode: mb -> stashed contributions, folded by finalize
        self._mb_loss: dict[int, Any] = {}
        self._mb_grads: dict[int, tuple[Any, Any]] = {}
        #: highest microbatch already folded — guards against mid-run folds
        self._loss_folded: int | None = None
        self._grads_folded: int | None = None
        self._g_dummy = None

    def _add_grads(self, mb: int, dsp, dio) -> None:
        if self.deterministic_reduction:
            self._mb_grads[mb] = (dsp, dio)
            return
        self.d_stage = jax.tree.map(jnp.add, self.d_stage, dsp)
        self.d_io = jax.tree.map(jnp.add, self.d_io, dio)

    def finalize(self) -> "ActorStageProgram":
        """Fold stashed per-microbatch contributions in microbatch order.

        Idempotent; a no-op under eager accumulation.  Must run only after
        all of the stage's work has executed: a *partial* fold would fix the
        already-seen microbatches' position in the reduction order, making
        the final bits depend on when the read happened — so folding a
        microbatch below an already-folded one raises instead of silently
        breaking the bitwise order-independence guarantee.
        """
        def fold_guard(kind: str, folded: int | None, keys) -> int | None:
            if folded is not None and keys and min(keys) < folded:
                raise RuntimeError(
                    f"stage {self.stage}: deterministic {kind} fold of "
                    f"microbatch {min(keys)} after microbatch {folded} was "
                    f"already folded — finalize()/loss_sum was read mid-run")
            return max(keys, default=folded) if keys else folded

        self._loss_folded = fold_guard(
            "loss", self._loss_folded, list(self._mb_loss))
        for mb in sorted(self._mb_loss):
            self.loss_acc = self.loss_acc + self._mb_loss[mb]
        self._mb_loss.clear()
        self._grads_folded = fold_guard(
            "grad", self._grads_folded, list(self._mb_grads))
        for mb in sorted(self._mb_grads):
            dsp, dio = self._mb_grads[mb]
            self.d_stage = jax.tree.map(jnp.add, self.d_stage, dsp)
            self.d_io = jax.tree.map(jnp.add, self.d_io, dio)
        self._mb_grads.clear()
        return self

    @property
    def loss_sum(self) -> float:
        """Materialized loss total (forces one device sync per read)."""
        self.finalize()
        return float(self.loss_acc)

    def w_outstanding(self) -> int:
        """Un-executed W tasks currently holding activation memory."""
        return len(self.w_pending)

    def __call__(self, task: Task, payload: Any) -> Any:
        bm = microbatch(self.batch, task.mb, self.fns.opts.mb_rows)
        if task.kind == Kind.F:
            x = payload  # None at stage 0 (embedded inside the callable)
            y, loss = self.fns.forward(self.stage)(
                self.sp_s, self.io, x, bm)
            self.residual[task.mb] = x
            if self.deterministic_reduction:
                self._mb_loss[task.mb] = loss
            else:
                self.loss_acc = self.loss_acc + loss
            self._g_dummy = jnp.zeros_like(y)
            return y
        if task.kind == Kind.B:
            x = self.residual.pop(task.mb)
            g_in = payload if payload is not None else self._g_dummy
            if self.split_backward:
                self.w_pending[task.mb] = (x, g_in)
                self.w_high_water = max(self.w_high_water,
                                        len(self.w_pending))
                if self.stage == 0:
                    return None  # nobody consumes stage 0's input gradient
                return self.fns.backward_dx(self.stage)(
                    self.sp_s, self.io, x, g_in, bm)
            dx, dsp, dio = self.fns.backward(self.stage)(
                self.sp_s, self.io, x, g_in, bm)
            self._add_grads(task.mb, dsp, dio)
            return dx
        if task.kind == Kind.W:
            if not self.split_backward:
                raise ValueError(
                    f"{task!r} dispatched to a fused-backward stage program "
                    f"(construct ActorStageProgram with split_backward=True)")
            x, g_in = self.w_pending.pop(task.mb)
            dsp, dio = self.fns.weight_grad(self.stage)(
                self.sp_s, self.io, x, g_in, bm)
            self._add_grads(task.mb, dsp, dio)
            return None  # stage-local: no outgoing envelope
        raise ValueError(f"actor stage program cannot run {task!r}")
