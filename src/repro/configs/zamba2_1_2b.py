"""Zamba2-1.2B — Mamba2 backbone with a shared attention block invoked every
6 layers.  [arXiv:2411.15242; hf]  Runs long_500k (SSM decode state is O(1)
in sequence length)."""
import jax.numpy as jnp
from repro.models.common import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,              # shared block FFN
    vocab_size=32000,
    head_dim=64,
    layer_pattern=tuple("mamba" for _ in range(38)),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=64),
    shared_attn_period=6,
    dtype=jnp.bfloat16,
    sub_quadratic=True,
)
