"""Parameter partition policy: PartitionSpecs + reduction groups per leaf.

Layout (DESIGN §3):
* stage layer params — leading [S] dim on the ``model`` axis; MoE expert
  leaves additionally sharded over ``data`` (EP on the expert dim for
  deepseek-moe, TP on d_ff for grok); everything else data-replicated with
  ZeRO-1 optimizer-state sharding over (pod, data).
* io params (embed / head / final_ln / shared block) — replicated; their
  grads are psum'd over ``model`` (stage-masked contributions) and enter the
  same ZeRO-1 flat shard as the data-replicated stage grads.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.build import ArchModel


@dataclasses.dataclass
class ParamPartition:
    stage_specs: Any  # pytree of PartitionSpec matching stage params
    io_specs: Any
    #: pytree of bool matching stage params: True if leaf is sharded over
    #: data (EP/TP experts) and must NOT be DP-reduced.
    stage_data_sharded: Any


_MOE_EP_KEYS = ("wi", "wg", "wo")


def partition_for(model: ArchModel, stage_params, io_params) -> ParamPartition:
    layout = model.moe_layout

    def _is_routed_expert(path) -> bool:
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        # routed expert leaves live DIRECTLY under "moe" (shared experts are
        # nested one level deeper: moe/shared<i>/wi)
        return (len(names) >= 2 and names[-2] == "moe"
                and names[-1] in _MOE_EP_KEYS)

    def spec_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        extra = [None] * (leaf.ndim - 1)
        if _is_routed_expert(path):
            # leaf: [S, l_max, E, d, f]
            if layout == "ep":
                extra[1] = "data"  # shard the expert dim
            elif layout == "tp":
                # wi/wg: [.., E, d, f] shard f; wo: [.., E, f, d] shard f
                extra[3 if names[-1] in ("wi", "wg") else 2] = "data"
        return P("model", *extra)

    def data_sharded(path, leaf):
        return _is_routed_expert(path) and layout != "none"

    stage_specs = jax.tree_util.tree_map_with_path(spec_for, stage_params)
    flags = jax.tree_util.tree_map_with_path(data_sharded, stage_params)
    io_specs = jax.tree.map(lambda _: P(), io_params)
    return ParamPartition(stage_specs, io_specs, flags)


# ---------------------------------------------------------------------------
# flat ZeRO-1 shard helpers
# ---------------------------------------------------------------------------
def flatten_replicated(tree, flags, pad_to: int,
                       dtype=jnp.float32) -> jnp.ndarray:
    """Concat flattened data-replicated leaves into one padded vector."""
    leaves = [
        l.astype(dtype).reshape(-1)
        for l, f in zip(jax.tree.leaves(tree), jax.tree.leaves(flags))
        if not f
    ]
    vec = jnp.concatenate(leaves) if leaves else jnp.zeros((0,), dtype)
    pad = (-vec.size) % pad_to
    return jnp.pad(vec, (0, pad))


def unflatten_replicated(vec: jnp.ndarray, tree, flags):
    """Inverse of flatten_replicated: fill the replicated leaves from vec."""
    out = []
    off = 0
    for l, f in zip(jax.tree.leaves(tree), jax.tree.leaves(flags)):
        if f:
            out.append(l)
        else:
            n = l.size
            out.append(vec[off : off + n].reshape(l.shape).astype(l.dtype))
            off += n
    return jax.tree.unflatten(jax.tree.structure(tree), out)


def replicated_size(tree, flags) -> int:
    return sum(
        l.size
        for l, f in zip(jax.tree.leaves(tree), jax.tree.leaves(flags))
        if not f
    )
