"""Low-overhead runtime metrics: counters, gauges, log-bucketed histograms.

Design constraints (the dispatch hot path runs in single-digit microseconds,
see ``BENCH_dispatch.json``):

* **Per-stage shards, single writer.**  Each :class:`StageShard` is written
  by exactly one thread — the stage's actor thread (thread substrate) or the
  driver's event pump (sim substrate) — so every observation is a plain
  int/float update with **no lock, no allocation, no string formatting**.
  Aggregation happens at sync points (end of run / between steps) by the
  caller that owns the registry, never concurrently with the hot path.
* **Fixed bucket edges, deferred bucketing.**  Histograms use log-spaced
  edges computed once at construction; ``observe`` is a bare list append,
  and the bisect-per-observation fold runs lazily at the first read — sync
  points, never the hot path.
* **Pay for what you use.**  When no registry is attached
  (``ActorConfig.metrics is None``) the runtime's only added cost is an
  ``is None`` test per hook site.  The CI overhead gate
  (``benchmarks/dispatch_overhead.py``, ``METRICS_OVERHEAD_MAX``) enforces
  that metrics-ON stays within 10% of metrics-OFF per decision.

Metric catalogue (see ``docs/observability.md`` for semantics):

==========================  =============================================
``dispatches[kind]``        per-stage dispatch count per task kind
``dispatch_paths[path]``    arbitration path taken (hint / backpressure /
                            wcap / precommitted)
``divergence[slot]``        hint-divergence: index of the dispatched
                            task's *kind* in the arbiter's preference
                            order at dispatch time (0 = hinted direction
                            served; >0 = hinted direction was unready)
``ready_depth``             histogram of ready-set size at each decision
``durations[kind]``         histogram of realized task durations
``cost_ewma[kind]``         EWMA of realized durations (online cost table)
``queue_depth``             histogram of post-enqueue arrival-buffer depth
``enqueues/dequeues[kind]`` mailbox buffer traffic per kind
``comm_ewma``               EWMA of transport latency, sampled from the
                            envelope that completes each message set
``tp_admits/holds/dups``    TP all-ranks gate outcomes
``tp_spread``               histogram of per-rank arrival spread (the TP
                            hold time: last-rank minus first-rank arrival)
``fanin_holds``             DAG fan-in: edge admitted, other branch missing
``backpressure_drains``     dispatches taken on the App. C drain path
``wcap_dispatches``         dispatches forced by the W-deferral cap
``w_backlog_peak``          max observed deferred-W backlog
==========================  =============================================
"""
from __future__ import annotations

import math
from bisect import bisect_left
from typing import Iterable

from repro.core.taskgraph import Kind, Task

from repro.obs.cost_table import Ewma, OnlineCostTable

#: arbitration-path labels, fixed order for stable reports ("table" =
#: synthesized-rank table consumed as a non-binding hint, see docs/adaptive.md)
PATHS = ("hint", "table", "backpressure", "wcap", "precommitted")

#: default duration buckets: 1 µs .. 100 s, 8 buckets per decade
DURATION_EDGES = None  # computed below (module import time, once)

#: default depth buckets: 1 .. 4096, doubling
DEPTH_EDGES = None


def log_edges(lo: float, hi: float, n: int) -> tuple[float, ...]:
    """``n + 1`` log-spaced bucket edges covering [lo, hi] geometrically."""
    if not (lo > 0 and hi > lo and n >= 1):
        raise ValueError(f"bad edge spec lo={lo} hi={hi} n={n}")
    ratio = (hi / lo) ** (1.0 / n)
    edges = [lo * ratio**i for i in range(n + 1)]
    edges[-1] = hi  # kill accumulated float error at the top edge
    return tuple(edges)


DURATION_EDGES = log_edges(1e-6, 1e2, 8 * 8)
DEPTH_EDGES = tuple(float(2**i) for i in range(13))  # 1 .. 4096


class Histogram:
    """Fixed-edge histogram with deferred bucketing.

    ``observe`` is a bare list append — the raw observations queue in
    ``_pending`` and fold into buckets (one bisect each) lazily, the first
    time a reader asks for ``counts``/``count``/``total``/quantiles.  The
    hot path is written once per event by a single owner; readers are
    sync-point aggregation only, so the deferred fold is safe and keeps
    per-event cost at one append instead of a bisect + three updates.

    Bucket ``i`` counts observations ``x`` with ``edges[i-1] < x <=
    edges[i]`` (bucket 0 is the underflow ``x <= edges[0]``); one overflow
    bucket at the end counts ``x > edges[-1]``.  Exact sum and count ride
    along so means stay exact regardless of bucketing.
    """

    __slots__ = ("edges", "_counts", "_count", "_total", "_pending")

    def __init__(self, edges: Iterable[float] = DURATION_EDGES):
        if edges is DURATION_EDGES or edges is DEPTH_EDGES:
            # module defaults are pre-validated; shard construction sits on
            # the driver's build path, so skip the per-instance reconversion
            self.edges = edges
        else:
            self.edges = tuple(float(e) for e in edges)
            if list(self.edges) != sorted(set(self.edges)):
                raise ValueError("histogram edges must be strictly increasing")
        self._counts = [0] * (len(self.edges) + 1)
        self._count = 0
        self._total = 0.0
        self._pending: list[float] = []

    def observe(self, x: float) -> None:
        self._pending.append(x)

    def _fold(self) -> None:
        pending = self._pending
        if not pending:
            return
        counts, edges, total = self._counts, self.edges, self._total
        for x in pending:
            counts[bisect_left(edges, x)] += 1
            total += x  # incremental adds, same order as observed
        self._total = total
        self._count += len(pending)
        self._pending = []

    @property
    def counts(self) -> list[int]:
        self._fold()
        return self._counts

    @property
    def count(self) -> int:
        self._fold()
        return self._count

    @property
    def total(self) -> float:
        self._fold()
        return self._total

    def merge(self, other: "Histogram") -> None:
        if other.edges != self.edges:
            raise ValueError("cannot merge histograms with different edges")
        self._fold()
        for i, c in enumerate(other.counts):  # folds ``other`` too
            self._counts[i] += c
        self._count += other._count
        self._total += other._total

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Upper edge of the bucket containing the q-quantile (0 < q <= 1).

        A bucketed bound, not an exact order statistic; the overflow bucket
        reports ``inf``."""
        if not self.count:
            return 0.0
        target = math.ceil(q * self.count)
        run = 0
        for i, c in enumerate(self._counts):
            run += c
            if run >= target:
                return self.edges[i] if i < len(self.edges) else math.inf
        return math.inf

    def to_json(self) -> dict:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "count": self._count, "total": self._total}


class StageShard:
    """Single-writer metric shard for one stage (see module docstring)."""

    __slots__ = (
        "stage", "dispatches", "dispatch_paths", "divergence", "ready_depth",
        "durations", "cost_ewma", "queue_depth", "enqueues", "dequeues",
        "comm_ewma", "tp_admits", "tp_holds", "tp_dups", "tp_spread",
        "fanin_holds", "backpressure_drains", "wcap_dispatches",
        "w_backlog_peak", "busy",
    )

    def __init__(self, stage: int, alpha: float = 0.1):
        self.stage = stage
        # Kind is an IntEnum with values 0..2, so the per-kind structures
        # are flat lists indexed by the kind itself — no dict hashing on
        # the hot path.
        self.dispatches = [0] * len(Kind)
        self.dispatch_paths = {p: 0 for p in PATHS}
        # try_order() yields at most 3 kinds; slot 0 = hinted direction
        self.divergence = [0, 0, 0]
        self.ready_depth = Histogram(DEPTH_EDGES)
        self.durations = [Histogram(DURATION_EDGES) for _ in Kind]
        self.cost_ewma = [Ewma(alpha) for _ in Kind]
        self.queue_depth = Histogram(DEPTH_EDGES)
        self.enqueues = [0] * len(Kind)
        self.dequeues = [0] * len(Kind)
        self.comm_ewma = Ewma(alpha)
        self.tp_admits = 0
        self.tp_holds = 0
        self.tp_dups = 0
        self.tp_spread = Histogram(DURATION_EDGES)
        self.fanin_holds = 0
        self.backpressure_drains = 0
        self.wcap_dispatches = 0
        self.w_backlog_peak = 0
        self.busy = 0.0

    # ---- hooks (hot path; each a handful of plain updates) ---------------
    def on_dispatch(self, task: Task, ready_depth: int,
                    path: str, slot: int | None) -> None:
        self.dispatches[task.kind] += 1
        self.dispatch_paths[path] += 1
        self.ready_depth.observe(ready_depth)
        if slot is not None:
            self.divergence[slot] += 1
        elif path == "backpressure":
            self.backpressure_drains += 1
        elif path == "wcap":
            self.wcap_dispatches += 1

    def on_complete(self, task: Task, dur: float, w_backlog: int = 0) -> None:
        k = task.kind
        self.durations[k].observe(dur)
        self.cost_ewma[k].observe(dur)
        self.busy += dur
        if w_backlog > self.w_backlog_peak:
            self.w_backlog_peak = w_backlog

    def on_enqueue(self, kind: Kind, depth: int) -> None:
        self.enqueues[kind] += 1
        self.queue_depth.observe(depth)

    def on_admitted(self, kind: Kind, depth: int, latency: float) -> None:
        """Fused enqueue + transport-latency hook: one call per envelope
        that completes a task's message set (the mailbox's buffer path)."""
        self.enqueues[kind] += 1
        self.queue_depth.observe(depth)
        if latency >= 0.0:  # duplicate copies may replay at odd times
            self.comm_ewma.observe(latency)

    def on_dequeue(self, kind: Kind) -> None:
        self.dequeues[kind] += 1

    def on_tp_hold(self) -> None:
        self.tp_holds += 1

    def on_tp_admit(self, spread: float) -> None:
        self.tp_admits += 1
        self.tp_spread.observe(spread)

    def on_tp_dup(self) -> None:
        self.tp_dups += 1

    def on_fanin_hold(self) -> None:
        self.fanin_holds += 1

    # ---- aggregation (sync points only) -----------------------------------
    def hint_divergences(self) -> int:
        """Hint-path dispatches where the hinted direction was unready."""
        return sum(self.divergence[1:])

    def to_json(self) -> dict:
        return {
            "stage": self.stage,
            "dispatches": {k.name: self.dispatches[k] for k in Kind},
            "dispatch_paths": dict(self.dispatch_paths),
            "divergence": list(self.divergence),
            "ready_depth": self.ready_depth.to_json(),
            "durations": {k.name: self.durations[k].to_json() for k in Kind},
            "cost_ewma": {k.name: self.cost_ewma[k].value for k in Kind},
            "queue_depth": self.queue_depth.to_json(),
            "enqueues": {k.name: self.enqueues[k] for k in Kind},
            "dequeues": {k.name: self.dequeues[k] for k in Kind},
            "comm_ewma": self.comm_ewma.value,
            "tp": {"admits": self.tp_admits, "holds": self.tp_holds,
                   "dups": self.tp_dups,
                   "spread": self.tp_spread.to_json()},
            "fanin_holds": self.fanin_holds,
            "backpressure_drains": self.backpressure_drains,
            "wcap_dispatches": self.wcap_dispatches,
            "w_backlog_peak": self.w_backlog_peak,
            "busy": self.busy,
        }


class MetricsRegistry:
    """Owns the per-stage shards; aggregates at sync points.

    Pass one registry through :class:`~repro.runtime.rrfp.driver.ActorConfig`
    ``.metrics``; the driver hands each stage its shard.  Reusing the same
    registry across steps accumulates (and keeps the cost EWMAs warm across
    iterations — exactly what online cost tables want).
    """

    def __init__(self, num_stages: int = 0, alpha: float = 0.1):
        self.alpha = alpha
        #: keyed by *logical* stage — a respawned/remapped incarnation
        #: reuses its logical stage's shard, so co-hosted stages never
        #: merge their durations into one cell (cost-table correctness)
        self._shards: dict[int, StageShard] = {
            s: StageShard(s, alpha) for s in range(num_stages)}

    @property
    def num_stages(self) -> int:
        return max(self._shards) + 1 if self._shards else 0

    def shard(self, stage: int) -> StageShard:
        """The single-writer shard for logical ``stage`` (created on first
        use).  Sparse creation is fine: rows are keyed, not positional."""
        sh = self._shards.get(stage)
        if sh is None:
            sh = self._shards[stage] = StageShard(stage, self.alpha)
        return sh

    def shards(self) -> list[StageShard]:
        return [self._shards[s] for s in sorted(self._shards)]

    def on_recovery(self, stage: int, keep: int = 1) -> None:
        """RECOVERY_END boundary: the new incarnation may run at a
        different speed (cold caches, remapped device time-sharing) —
        collapse the stage's EWMAs to weak priors so post-recovery
        samples dominate instead of averaging across incarnations."""
        sh = self._shards.get(stage)
        if sh is None:
            return
        for e in sh.cost_ewma:
            e.downweight(keep)
        sh.comm_ewma.downweight(keep)

    # ---- sync-point aggregation -------------------------------------------
    def totals(self) -> dict:
        disp = {k.name: 0 for k in Kind}
        paths = {p: 0 for p in PATHS}
        div = [0, 0, 0]
        tp_admits = tp_holds = tp_dups = bp = wcap = fanin = 0
        for sh in self.shards():
            for k in Kind:
                disp[k.name] += sh.dispatches[k]
            for p in PATHS:
                paths[p] += sh.dispatch_paths[p]
            for i in range(3):
                div[i] += sh.divergence[i]
            tp_admits += sh.tp_admits
            tp_holds += sh.tp_holds
            tp_dups += sh.tp_dups
            bp += sh.backpressure_drains
            wcap += sh.wcap_dispatches
            fanin += sh.fanin_holds
        return {"dispatches": disp, "dispatch_paths": paths,
                "divergence": div, "tp_admits": tp_admits,
                "tp_holds": tp_holds, "tp_dups": tp_dups,
                "backpressure_drains": bp, "wcap_dispatches": wcap,
                "fanin_holds": fanin}

    def cost_table(self) -> OnlineCostTable:
        """Snapshot the live per-(stage, kind) EWMAs as an
        :class:`~repro.obs.cost_table.OnlineCostTable` (ROADMAP item 3's
        input for hint re-synthesis)."""
        table = OnlineCostTable(self.num_stages, alpha=self.alpha)
        for sh in self.shards():
            for k in Kind:
                e = sh.cost_ewma[k]
                if e.count:
                    table.seed(sh.stage, k, e.value, e.count)
            if sh.comm_ewma.count:
                table.seed_comm(sh.comm_ewma.value, sh.comm_ewma.count)
        return table

    def to_json(self) -> dict:
        return {"stages": [sh.to_json() for sh in self.shards()],
                "totals": self.totals()}

    def report(self) -> str:
        """End-of-run per-stage summary table (``--metrics-report``)."""
        hdr = (f"{'stage':>5} {'disp':>6} {'F/B/W':>11} {'diverge':>7} "
               f"{'ready(p50)':>10} {'q(p50)':>7} {'bp':>5} {'wcap':>5} "
               f"{'tp h/a':>9} {'ewma F':>9} {'ewma B':>9} {'ewma W':>9} "
               f"{'comm':>9}")
        lines = [hdr, "-" * len(hdr)]

        def fmt(v: float | None) -> str:
            return f"{v * 1e3:.3f}ms" if v is not None else "-"

        for sh in self.shards():
            disp = sum(sh.dispatches)
            fbw = "/".join(str(sh.dispatches[k]) for k in Kind)
            lines.append(
                f"{sh.stage:>5} {disp:>6} {fbw:>11} "
                f"{sh.hint_divergences():>7} "
                f"{sh.ready_depth.quantile(0.5):>10.0f} "
                f"{sh.queue_depth.quantile(0.5):>7.0f} "
                f"{sh.backpressure_drains:>5} {sh.wcap_dispatches:>5} "
                f"{sh.tp_holds:>4}/{sh.tp_admits:<4} "
                f"{fmt(sh.cost_ewma[Kind.F].value):>9} "
                f"{fmt(sh.cost_ewma[Kind.B].value):>9} "
                f"{fmt(sh.cost_ewma[Kind.W].value):>9} "
                f"{fmt(sh.comm_ewma.value):>9}")
        t = self.totals()
        lines.append("-" * len(hdr))
        lines.append(
            f"total dispatches={sum(t['dispatches'].values())} "
            f"paths={t['dispatch_paths']} hint_divergences={sum(t['divergence'][1:])} "
            f"tp holds/admits/dups={t['tp_holds']}/{t['tp_admits']}/{t['tp_dups']} "
            f"fanin_holds={t['fanin_holds']}")
        return "\n".join(lines)
