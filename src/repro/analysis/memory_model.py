"""Analytic per-device HBM model for every (arch × shape) cell.

The CPU dry-run's ``memory_analysis`` is polluted by XLA:CPU's bf16→f32
dot-operand upcasts (absent on TPU's MXU) — verified by buffer-assignment
dumps (EXPERIMENTS.md §Dry-run).  This model computes the TPU-faithful
per-device residency from the executor's actual buffer inventory:

  params (bf16, stage shard + replicated io)            [persistent]
  gradient accumulators (grad_dtype stage + io)         [persistent in step]
  optimizer state (fp32 m/v/master shards; expert m/v)  [persistent]
  pipeline buffers  K_{act,res,grad} × [mb, seq, d]     [persistent in step]
  remat residuals   l_max × layer-input (bf16)          [peak, B branch]
  attention-bwd transients  4 × [hkv·g, sq, block] f32  [peak]
  FFN transients    2 × [tokens, d_ff] bf16             [peak]
  CE chunk          [chunk, V] f32                      [peak, last stage]
  decode caches (serve cells)                           [persistent]
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.launch.cells import CellPlan
from repro.pipeline.spec import ScheduleTable

F32, BF16 = 4, 2


@dataclasses.dataclass
class MemoryBreakdown:
    params: float
    grads: float
    opt_state: float
    buffers: float
    peak_transient: float
    caches: float

    @property
    def total(self) -> float:
        return (self.params + self.grads + self.opt_state + self.buffers
                + self.peak_transient + self.caches)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total"] = self.total
        return d


def cell_memory(plan: CellPlan, table: ScheduleTable | None = None,
                hbm_budget: float = 16e9) -> MemoryBreakdown:
    cfg = plan.model.cfg
    model = plan.model
    S = model.num_stages
    d = cfg.d_model
    v = cfg.padded_vocab()
    data = 16  # production mesh data width
    n_stage_total = cfg.param_count(include_embed=False) - cfg.d_model
    n_io = 2 * v * d + d + (model.cfg.shared_attn_period and
                            cfg.layer_param_count("attn") or 0)
    n_stage = n_stage_total / S  # per stage-shard
    # expert leaves are additionally data-sharded (EP/TP)
    expert_frac = 0.0
    if cfg.moe is not None:
        e_params = sum(
            3 * d * cfg.d_ff * cfg.moe.num_experts
            for k in cfg.pattern if k == "moe") / len(cfg.pattern) * len(cfg.pattern) / S
        expert_frac = min(1.0, e_params / max(n_stage, 1))
    n_replicated = n_stage * (1 - expert_frac) + n_io
    n_sharded = n_stage * expert_frac / data

    params = (n_stage * (1 - expert_frac) + n_stage * expert_frac / data
              + n_io) * BF16

    if plan.step == "decode":
        cache_one = _cache_bytes(plan)
        bufs = (min(plan.num_microbatches, S) + 1) * plan.mb_rows * d * BF16
        return MemoryBreakdown(
            params=params, grads=0.0, opt_state=0.0, buffers=bufs,
            peak_transient=plan.mb_rows * d * 64 * BF16, caches=cache_one)

    grad_b = 2 if plan.arch in ("grok-1-314b", "granite-34b", "qwen1.5-32b") else 4
    grads = (n_stage * (1 - expert_frac) * grad_b
             + n_stage * expert_frac / data * 4  # expert grads fp32
             + n_io * BF16)  # io accumulators bf16
    # ZeRO-1: master+m+v fp32 on the data shard; expert m/v fp32 local
    opt = ((n_stage * (1 - expert_frac) + n_io) / data * 3 * F32
           + n_stage * expert_frac / data * 2 * F32)

    eff_seq = plan.seq_len + plan.enc_len
    occ = table.validate() if table is not None else {
        "act_span": min(S, plan.num_microbatches),
        "res_span": min(S, plan.num_microbatches),
        "grad_span": 2,
    }
    mb_bytes = plan.mb_rows * eff_seq * d * BF16
    bufs = (occ["act_span"] + occ["res_span"] + occ["grad_span"] + 2) * mb_bytes

    # B-branch peak: remat residuals + attention bwd + FFN transients + CE
    l_max = model.l_max
    resid = l_max * mb_bytes
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    block = 256
    attn_bwd = 4 * hq * eff_seq * block * plan.mb_rows * F32 \
        + 3 * plan.mb_rows * eff_seq * hq * hd * F32  # dq acc + q/do rows
    ffn = 2 * plan.mb_rows * eff_seq * max(cfg.d_ff, 2 * d) * BF16
    ce_chunk = max(64, min(2048, (1 << 24) // v * 4))
    ce = ce_chunk * v * F32
    peak = resid + attn_bwd + ffn + ce

    return MemoryBreakdown(params=params, grads=grads, opt_state=opt,
                           buffers=bufs, peak_transient=peak, caches=0.0)


def _cache_bytes(plan: CellPlan) -> float:
    cfg = plan.model.cfg
    model = plan.model
    b_loc = max(1, plan.cell.global_batch // plan.dp_total)
    kv = cfg.num_kv_heads * cfg.resolved_head_dim
    total = 0.0
    n_slots = int((model.type_ids >= 0).sum()) / model.num_stages
    seq = plan.cell.seq_len / (plan.dp_total if plan.sp_mode else 1)
    for kind in set(cfg.pattern):
        frac = sum(1 for k in cfg.pattern if k == kind) / len(cfg.pattern)
        n = n_slots * frac
        if kind in ("attn", "attn_local", "attn_global", "moe", "dense",
                    "dec", "enc"):
            w = cfg.sliding_window if kind == "attn_local" else 0
            eff = min(seq, w) if w else seq
            total += n * 2 * b_loc * eff * kv * BF16
            if kind == "dec":
                total += n * 2 * b_loc * (plan.enc_len / (plan.dp_total if plan.sp_mode else 1)) * kv * BF16
        elif kind == "mamba":
            ssm = cfg.ssm
            di = ssm.d_inner(cfg.d_model)
            total += n * b_loc * (
                (ssm.d_conv - 1) * (di + 2 * ssm.d_state) * BF16
                + ssm.num_heads(cfg.d_model) * ssm.head_dim * ssm.d_state * F32)
        elif kind == "mlstm":
            hd = cfg.d_model // cfg.num_heads
            total += n * b_loc * cfg.num_heads * (hd * hd + hd + 1) * F32
        elif kind == "slstm":
            total += n * b_loc * 4 * cfg.d_model * F32
    if cfg.shared_attn_period:
        # shared-attn KV rides every slot's cache union
        total += n_slots * 2 * b_loc * seq * kv * BF16
    return float(total)
