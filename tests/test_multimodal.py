"""Multimodal DAG pipeline: shape bucketing, bitwise parity, end-to-end.

The two bucketing guarantees of the subsystem (ISSUE satellites):

* **bounded recompiles** — the jit compile-cache size of every
  variable-length stage op stays <= the bucket count under randomized
  variable-length vision batches;
* **bitwise parity** — bucketed and unbucketed execution produce
  identical loss and gradient bits on a tiny model (the padding is
  arithmetically invisible, not just approximately so).

Plus: the real jitted DAG run on the actor runtime matches the
fixed-order reference executor bitwise under deterministic reduction, BFW
split backward matches the fused backward bitwise, and the registered
multimodal archs are reachable from the train CLI.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.core import HintKind
from repro.core.taskgraph import Kind, Task
from repro.data.lengths import bucket_for, length_skew, sample_token_lengths
from repro.data.synthetic import multimodal_batch
from repro.multimodal import (
    MultimodalStageFns,
    MultimodalStageProgram,
    multimodal_model,
)
from repro.multimodal.stagefn import MultimodalStageOptions
from repro.runtime.rrfp import ActorConfig, ActorDriver, ChaosConfig

M, ROWS, SEQ = 5, 2, 16
BUCKETS = (8, 16, 24)


@pytest.fixture(scope="module")
def tiny():
    model = multimodal_model(
        "qwen2-vl-2b", enc_stages=2, lm_stages=2, enc_layers_per_stage=1,
        lm_layers_per_stage=1, text_seq=SEQ, fusion_slots=4,
        mean_enc_tokens=14, buckets=BUCKETS)
    params = model.init_stage_params(jax.random.key(0))
    fns = MultimodalStageFns(model, MultimodalStageOptions(
        mb_rows=ROWS, loss_scale=1.0 / (M * ROWS * SEQ)))
    return model, params, fns


def run_step(model, params, fns, *, bucketing=True, split=False, cap=0,
             chaos=None, seed=0, step=0, mode="hint", deterministic=True):
    cfg = model.cfg
    batch = multimodal_batch(cfg, M, ROWS, seed=0, step=step,
                             bucketing=bucketing)
    programs = [
        MultimodalStageProgram(fns, s, params[s], batch,
                               split_backward=split,
                               deterministic_reduction=deterministic)
        for s in range(cfg.num_stages)
    ]
    spec = cfg.spec(M, split_backward=split)
    acfg = ActorConfig(
        mode=mode, hint=HintKind.BFW if split else HintKind.BF,
        fixed_order="zb" if split else "1f1b", w_defer_cap=cap,
        deadlock_timeout=120.0, chaos=chaos, seed=seed)
    ActorDriver(spec, None, acfg).run_threaded(list(programs))
    for p in programs:
        p.finalize()
    return programs


def loss_grad_bits(programs):
    loss = np.asarray(sum(p.loss_acc for p in programs)).tobytes()
    grads = b"".join(np.asarray(g).tobytes()
                     for p in programs for g in jax.tree.leaves(p.d_params))
    return loss, grads


# ---------------------------------------------------------------------------
# the shared length sampler
# ---------------------------------------------------------------------------
class TestLengthSampler:
    def test_mean_one_skew(self):
        rng = np.random.default_rng(0)
        s = length_skew(20000, 0.6, rng)
        assert abs(s.mean() - 1.0) < 0.05

    def test_deterministic_in_seed_step(self):
        a = sample_token_lengths(8, 24, seed=3, step=5)
        b = sample_token_lengths(8, 24, seed=3, step=5)
        c = sample_token_lengths(8, 24, seed=3, step=6)
        assert np.array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_bounds_and_buckets(self):
        lens = sample_token_lengths(64, 24, seed=0, lo=4, hi=24)
        assert lens.min() >= 4 and lens.max() <= 24
        assert bucket_for(5, BUCKETS) == 8
        assert bucket_for(8, BUCKETS) == 8
        assert bucket_for(9, BUCKETS) == 16
        assert bucket_for(99, BUCKETS) == 24  # clamps to the largest

    def test_workloads_share_the_sampler(self):
        """The DES workload skew is the same draw as the shared sampler."""
        from benchmarks.workloads import stage_costs

        cm = stage_costs("qwen3-1.7b", "vit-h", pp=8, seed=4)
        rng = np.random.default_rng(4)
        expect = length_skew(64, 0.6, rng)
        assert np.array_equal(cm.mb_skew[0], expect)


# ---------------------------------------------------------------------------
# bucketing: compile-cache bound
# ---------------------------------------------------------------------------
class TestShapeBucketing:
    def test_batch_shapes_are_bucketed(self, tiny):
        model, _, _ = tiny
        batch = multimodal_batch(model.cfg, 16, ROWS, seed=1, step=0)
        pads = {e.shape[1] for e in batch["enc_embeds"]}
        assert pads <= set(BUCKETS)
        for e, n in zip(batch["enc_embeds"], batch["enc_lens"]):
            assert e.shape[1] >= n
            assert not e[:, n:].any()  # exact-zero padding

    def test_compile_cache_bounded_by_bucket_count(self, tiny):
        """Randomized variable lengths over many steps: the jit cache of
        every variable-shape op stays <= len(buckets)."""
        model, params, fns = tiny
        cfg = model.cfg
        seen = set()
        for step in range(6):  # enough steps to visit every bucket
            batch = multimodal_batch(cfg, M, ROWS, seed=11, step=step)
            seen |= {e.shape[1] for e in batch["enc_embeds"]}
            programs = [
                MultimodalStageProgram(fns, s, params[s], batch)
                for s in range(cfg.num_stages)
            ]
            acfg = ActorConfig(mode="hint", hint=HintKind.BF,
                               deadlock_timeout=120.0)
            ActorDriver(cfg.spec(M), None, acfg).run_threaded(list(programs))
        assert len(seen) > 1, "scenario must exercise multiple buckets"
        for (op, stage), size in fns.compile_cache_sizes().items():
            assert size <= len(BUCKETS), (
                f"{op} at stage {stage}: {size} traces > "
                f"{len(BUCKETS)} buckets")

    def test_unbucketed_retraces_per_distinct_length(self, tiny):
        """Control: without bucketing the cache grows with distinct
        lengths (what bucketing is bounding)."""
        model, params, _ = tiny
        cfg = model.cfg
        fns = MultimodalStageFns(model, MultimodalStageOptions(
            mb_rows=ROWS, loss_scale=1.0 / (M * ROWS * SEQ)))
        lengths = set()
        for step in range(4):
            batch = multimodal_batch(cfg, M, ROWS, seed=11, step=step,
                                     bucketing=False)
            lengths |= {e.shape[1] for e in batch["enc_embeds"]}
            programs = [
                MultimodalStageProgram(fns, s, params[s], batch)
                for s in range(cfg.num_stages)
            ]
            acfg = ActorConfig(mode="hint", hint=HintKind.BF,
                               deadlock_timeout=120.0)
            ActorDriver(cfg.spec(M), None, acfg).run_threaded(list(programs))
        sizes = fns.compile_cache_sizes()
        assert sizes[("fwd", 0)] == len(lengths)


# ---------------------------------------------------------------------------
# bitwise parity
# ---------------------------------------------------------------------------
class TestBitwiseParity:
    def test_bucketed_equals_unbucketed(self, tiny):
        """Loss AND gradient bits identical with and without bucketing."""
        model, params, fns = tiny
        a = loss_grad_bits(run_step(model, params, fns, bucketing=True))
        b = loss_grad_bits(run_step(model, params, fns, bucketing=False))
        assert a[0] == b[0], "loss bits diverged under bucketing"
        assert a[1] == b[1], "gradient bits diverged under bucketing"

    def test_bucketed_equals_unbucketed_across_steps(self, tiny):
        model, params, fns = tiny
        for step in (1, 2):
            a = loss_grad_bits(run_step(model, params, fns, step=step))
            b = loss_grad_bits(run_step(model, params, fns, step=step,
                                        bucketing=False))
            assert a == b, f"parity broke at step {step}"

    def test_chaotic_run_matches_fixed_order_reference(self, tiny):
        """Deterministic reduction: a chaotic DAG actor run reproduces the
        precommitted fixed-order execution bit for bit."""
        model, params, fns = tiny
        chaos = ChaosConfig(seed=5, latency_base=1e-3, reorder_prob=0.5,
                            reorder_window=5e-3, duplicate_prob=0.3,
                            straggler=((1, 2.0),), stall_prob=0.1,
                            stall_scale=3e-3)
        a = loss_grad_bits(run_step(model, params, fns))
        b = loss_grad_bits(run_step(model, params, fns, chaos=chaos, seed=9))
        c = loss_grad_bits(run_step(model, params, fns, mode="precommitted"))
        assert a == b, "chaotic run diverged from clean run"
        assert a == c, "hint run diverged from fixed-order reference"

    def test_bfw_split_matches_fused_bitwise(self, tiny):
        """B(dX) + W(dW) == fused backward, bitwise, on the DAG — and the
        BFW hint run == the pre-committed ZB fixed-order reference."""
        model, params, fns = tiny
        a = loss_grad_bits(run_step(model, params, fns))
        d = loss_grad_bits(run_step(model, params, fns, split=True, cap=2))
        e = loss_grad_bits(run_step(model, params, fns, split=True,
                                    mode="precommitted"))
        assert a == d
        assert d == e

    def test_w_defer_cap_bounds_stash(self, tiny):
        model, params, fns = tiny
        progs = run_step(model, params, fns, split=True, cap=2)
        assert max(p.w_high_water for p in progs) <= 2
        assert all(p.w_outstanding() == 0 for p in progs)


# ---------------------------------------------------------------------------
# end-to-end: training decreases loss; both archs + CLI reachability
# ---------------------------------------------------------------------------
class TestEndToEnd:
    @pytest.mark.slow
    def test_loss_decreases_qwen(self):
        from repro.launch.train import train_multimodal

        class A:  # minimal args namespace
            arch = "qwen2-vl-2b"
            runtime = "actor"
            substrate = "thread"
            schedule = "rrfp"
            hint = "bfw"
            split_backward = True
            w_defer_cap = 2
            stages = 4
            microbatches = 4
            mb_rows = 1
            seq = 16
            steps = 6
            layers = None
            lr = 5e-3
            seed = 0
            chaos = None
            record_trace = None
            replay_trace = None
            deadlock_timeout = 300.0
            full_size = False

        losses = train_multimodal(A())
        assert losses[-1] < losses[0]

    def test_seamless_runs_one_step(self):
        model = multimodal_model(
            "seamless-m4t-large-v2", enc_stages=1, lm_stages=1,
            enc_layers_per_stage=1, lm_layers_per_stage=1, text_seq=8,
            fusion_slots=2, mean_enc_tokens=10, buckets=(8, 16))
        params = model.init_stage_params(jax.random.key(1))
        fns = MultimodalStageFns(model, MultimodalStageOptions(
            mb_rows=1, loss_scale=1.0 / 16))
        batch = multimodal_batch(model.cfg, 2, 1, seed=0, step=0)
        programs = [MultimodalStageProgram(fns, s, params[s], batch)
                    for s in range(model.cfg.num_stages)]
        acfg = ActorConfig(mode="hint", hint=HintKind.BF,
                           deadlock_timeout=120.0)
        res = ActorDriver(model.cfg.spec(2), None, acfg).run_threaded(
            list(programs))
        assert len(res.end) == model.cfg.spec(2).total_tasks()
        assert np.isfinite(float(sum(p.loss_acc for p in programs)))

    def test_archs_rejected_and_accepted(self):
        from repro.multimodal import multimodal_config

        with pytest.raises(ValueError, match="not a multimodal arch"):
            multimodal_config("deepseek-7b")
        for arch in ("qwen2-vl-2b", "seamless-m4t-large-v2"):
            cfg = multimodal_config(arch)
            assert cfg.num_stages == cfg.enc_stages + 1 + cfg.lm_stages

    def test_fusion_fan_in_payload_routing(self, tiny):
        """The fusion stage's F sees one payload per incoming edge."""
        model, params, fns = tiny
        cfg = model.cfg
        batch = multimodal_batch(cfg, M, ROWS, seed=0, step=0)
        prog = MultimodalStageProgram(
            fns, cfg.fusion_stage, params[cfg.fusion_stage], batch)
        h_enc = jax.numpy.zeros((ROWS, BUCKETS[0], cfg.d_enc))
        h_txt = jax.numpy.zeros((ROWS, cfg.text_seq, cfg.d_model))
        y = prog(Task(Kind.F, cfg.fusion_stage, 0),
                 {cfg.enc_stages - 1: h_enc, cfg.text_stage: h_txt})
        assert y.shape == (ROWS, cfg.fused_seq, cfg.d_model)
        dx = prog(Task(Kind.B, cfg.fusion_stage, 0),
                  jax.numpy.zeros_like(y))
        assert set(dx) == {cfg.enc_stages - 1, cfg.text_stage}
        assert dx[cfg.enc_stages - 1].shape == h_enc.shape
        assert dx[cfg.text_stage].shape == h_txt.shape
