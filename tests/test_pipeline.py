"""Pipeline executor + schedule table + optimizer integration tests.

Forces 8 host devices (mesh 2×4) via a subprocess-safe env setup in
conftest-style: this module must run in its own process group when the rest
of the suite saw 1 device, so it uses the devices fixture below.
"""
import os
import sys
import subprocess

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback (tests/_hyp_stub.py)
    from _hyp_stub import given, settings, strategies as st

from repro.core.costs import CostModel
from repro.core.taskgraph import Kind, PipelineSpec, Task
from repro.pipeline import schedules
from repro.pipeline.spec import OP_F, ScheduleTable, from_stage_orders


# ---------------------------------------------------------------------------
# ScheduleTable (host-only logic: no devices needed)
# ---------------------------------------------------------------------------
class TestScheduleTable:
    def test_1f1b_matches_textbook(self):
        spec = PipelineSpec(4, 8)
        t = schedules.one_f_one_b(spec)
        occ = t.validate()
        # steady-state in-flight at stage 0 is the pipeline depth
        assert occ["res"] == 4
        assert occ["res_span"] >= occ["res"]
        # last stage alternates F,B with no idle between
        last = t.ops[3]
        busy = last[last != 0]
        assert list(busy[:6]) == [OP_F, 2, OP_F, 2, OP_F, 2] or len(busy) == 16

    def test_all_builders_valid(self):
        spec = PipelineSpec(8, 16)
        for name in ("gpipe", "1f1b", "rrfp"):
            t = schedules.BUILDERS[name](spec)
            occ = t.validate()
            assert occ["res"] <= 16
        specw = PipelineSpec(8, 16, split_backward=True)
        schedules.zero_bubble(specw).validate()

    def test_gpipe_has_more_residency_than_1f1b(self):
        spec = PipelineSpec(4, 12)
        g = schedules.gpipe(spec).validate()
        f = schedules.one_f_one_b(spec).validate()
        assert g["res"] == 12         # all microbatches in flight
        assert f["res"] == 4          # bounded by depth (the 1F1B point)

    def test_rrfp_table_from_heterogeneous_costs(self):
        """Synthesized tables stay valid under stage imbalance."""
        from repro.core.costs import multimodal_stage_flops

        spec = PipelineSpec(8, 16)
        cm = CostModel.from_stage_flops(
            multimodal_stage_flops(4e12, 2e12, 8))
        t = schedules.rrfp(spec, cm)
        occ = t.validate()
        # grid-bubble is schedule shape only (ticks are unit-cost here);
        # heterogeneous realized orders stretch the grid
        assert t.bubble_fraction() < 0.9

    def test_invalid_order_rejected(self):
        spec = PipelineSpec(2, 2)
        # B before its F on stage 0
        orders = [
            [Task(Kind.B, 0, 0), Task(Kind.F, 0, 0), Task(Kind.F, 0, 1),
             Task(Kind.B, 0, 1)],
            [Task(Kind.F, 1, 0), Task(Kind.B, 1, 0), Task(Kind.F, 1, 1),
             Task(Kind.B, 1, 1)],
        ]
        with pytest.raises(ValueError):
            from_stage_orders(spec, orders)

    def test_decode_table(self):
        t = schedules.decode_forward(PipelineSpec(4, 6))
        assert t.num_ticks == 9
        assert (t.ops == OP_F).sum() == 24

    @settings(max_examples=20, deadline=None)
    @given(S=st.integers(2, 8), M=st.integers(1, 20),
           name=st.sampled_from(["gpipe", "1f1b", "rrfp"]))
    def test_property_tables_validate(self, S, M, name):
        spec = PipelineSpec(S, M)
        t = schedules.BUILDERS[name](spec)
        occ = t.validate()
        assert occ["res_span"] <= M
        # every table is a complete permutation (validate() checks deps)
        assert (t.ops != 0).sum() == 2 * S * M


# ---------------------------------------------------------------------------
# Executor numerics (subprocess: needs 8 forced host devices)
# ---------------------------------------------------------------------------
_EXEC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs import registry
from repro.models.build import build
from repro.core.taskgraph import PipelineSpec
from repro.pipeline import schedules
from repro.pipeline.executor import ExecOptions, make_train_fn, chunked_ce_sum
from repro.pipeline.sharding import partition_for

ARCH = os.environ.get("TEST_ARCH", "deepseek-7b")
SCHEDULE = os.environ.get("TEST_SCHEDULE", "1f1b")
S, DATA = 4, 2
mesh = jax.make_mesh((DATA, S), ("data", "model"))
cfg = registry.reduced_config(ARCH, num_layers=8)
model = build(cfg, num_stages=S)
key = jax.random.key(0)
sp = model.init_stage_params(key)
io = model.init_io_params(jax.random.fold_in(key, 1))
M, mb_rows, seq = 4, 2, 16
B = DATA * M * mb_rows
batch = {
    "tokens": jax.random.randint(jax.random.key(2), (B, seq), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.key(3), (B, seq), 0, cfg.vocab_size),
}
aux = {"positions": jnp.broadcast_to(jnp.arange(seq)[None], (B, seq)),
       "data_size": 1, "moe_layout": "none"}
if cfg.embed_input:
    batch["embeds"] = jax.random.normal(jax.random.key(4), (B, seq, cfg.d_model)) * 0.02
if cfg.mrope:
    batch["mrope"] = jnp.broadcast_to(jnp.arange(seq)[None, None], (3, B, seq)).astype(jnp.int32)
spec = PipelineSpec(S, M)
table = schedules.BUILDERS[SCHEDULE](spec)
opts = ExecOptions(mb_rows=mb_rows, seq_len=seq, loss_scale=1.0/(B*seq))
part = partition_for(model, sp, io)
fn, _ = make_train_fn(model, table, mesh, opts, part)
metrics, grad_shard, eg = jax.jit(fn)(sp, io, batch)

def ref_loss(sp, io):
    x = model.embed(io, batch)
    for s in range(S):
        spl = jax.tree.map(lambda p: p[s], sp)
        x = model.stage_forward(spl, io, x, aux, model.rows(s))
    return chunked_ce_sum(model, io, x, batch["labels"], 64) / (B * seq)

ref = float(ref_loss(sp, io))
got = float(metrics["loss"])
assert abs(got - ref) < 2e-3 * max(1, abs(ref)), (got, ref)
print("LOSS_MATCH", got, ref)
"""


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen2-vl-2b", "gemma3-4b",
                                  "zamba2-1.2b", "xlstm-350m"])
def test_executor_matches_reference(arch):
    env = dict(os.environ, TEST_ARCH=arch, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _EXEC_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "LOSS_MATCH" in r.stdout, r.stdout + r.stderr


@pytest.mark.parametrize("schedule", ["gpipe", "rrfp"])
def test_executor_schedule_equivalence(schedule):
    """Different schedules must compute identical losses (order-invariance:
    the paper's training-correctness claim, App. E)."""
    env = dict(os.environ, TEST_SCHEDULE=schedule, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _EXEC_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "LOSS_MATCH" in r.stdout, r.stdout + r.stderr
