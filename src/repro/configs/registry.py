"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib

import jax.numpy as jnp

from repro.models.common import ArchConfig, MoEConfig, SSMConfig

_MODULES = {
    "granite-34b": "granite_34b",
    "gemma3-4b": "gemma3_4b",
    "qwen1.5-32b": "qwen1_5_32b",
    "deepseek-7b": "deepseek_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "grok-1-314b": "grok_1_314b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "zamba2-1.2b": "zamba2_1_2b",
    "xlstm-350m": "xlstm_350m",
    # the paper's own workloads (engine benchmarks)
    "paper-gpt3-large": "paper_gpt3_large",
}

ARCHS = tuple(_MODULES)


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def list_archs() -> tuple[str, ...]:
    return ARCHS


def reduced_config(name: str, num_layers: int | None = None) -> ArchConfig:
    """Same-family tiny config for CPU smoke tests.

    Keeps the structural features (GQA ratio, layer pattern kind, MoE
    routing, pipeline pattern) while shrinking width/depth/vocab.
    """
    cfg = get_arch(name)
    layers = num_layers or max(4, len(cfg.layer_types()) * 2)
    # preserve the q/kv ratio
    nq = max(2, min(cfg.num_heads, 4))
    ratio = max(1, cfg.num_heads // cfg.num_kv_heads)
    nkv = max(1, nq // min(ratio, nq))
    upd: dict = dict(
        num_layers=layers,
        d_model=64,
        num_heads=nq,
        num_kv_heads=nkv,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        dtype=jnp.float32,
        layer_pattern=None,
    )
    if cfg.local_global_period:
        upd["local_global_period"] = 2
        upd["sliding_window"] = 8
    if cfg.encoder_layers:
        upd["encoder_layers"] = layers // 2
    if cfg.moe is not None:
        upd["moe"] = MoEConfig(
            num_experts=max(4, min(cfg.moe.num_experts, 8)),
            top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1),
            capacity_factor=2.0,
            dense_d_ff=96 if cfg.moe.dense_d_ff else 0,
        )
        upd["d_ff"] = 32
    if cfg.ssm is not None:
        upd["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16)
    if cfg.shared_attn_period:
        upd["shared_attn_period"] = 2
    if cfg.layer_pattern is not None and cfg.family == "ssm":
        # xlstm: keep the 7:1 idea at reduced scale -> 3:1
        upd["layer_pattern"] = tuple(
            "slstm" if (i + 1) % 4 == 0 else "mlstm" for i in range(layers)
        )
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **upd)
