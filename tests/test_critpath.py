"""Critical-path engine + what-if profiler + explain report (unit level).

Deterministic small-pipeline checks of ``repro.obs.critpath`` /
``whatif`` / ``report`` semantics — the randomized chaos/recovery
property matrix lives in ``tests/conformance/test_critpath.py``:

* graph construction: exact makespan, 100%-accounted decomposition,
  slack semantics on a trace small enough to reason about;
* ``Speedup`` validation and ``apply_to_cost_model`` row scaling (the
  bridge the predicted-vs-realized benchmark gate rides on);
* ``explain()`` report assembly: bottleneck phrasing, what-if ranking,
  straggler flags, bubble cross-check — plus the CLI round trip;
* Perfetto export: the default output is byte-stable with the engine
  present, and ``critical_path=True`` adds a valid highlighted track.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import CostModel, HintKind, JitterModel, Kind, PipelineSpec
from repro.obs import (
    CP_CATEGORIES,
    ExecGraph,
    Speedup,
    apply_to_cost_model,
    candidate_speedups,
    explain,
    predict,
    to_perfetto,
    validate_chrome_trace,
)
from repro.obs.report import main as report_main
from repro.obs.whatif import rank
from repro.runtime.rrfp import ActorConfig, ActorDriver


def det_costs(S, f=1.0, b=2.0, w=0.0, comm=1e-3, **kw):
    return CostModel.uniform(
        S, f=f, b=b, w=w, comm_base=comm,
        compute_jitter=JitterModel(), comm_jitter=JitterModel(), **kw)


def run_recorded(spec, cm, **cfg_kw):
    cfg = ActorConfig(record_trace=True, **cfg_kw)
    driver = ActorDriver(spec, cm, cfg)
    driver.run()
    return driver.trace


@pytest.fixture(scope="module")
def chain():
    spec = PipelineSpec(4, 6)
    trace = run_recorded(spec, det_costs(4), mode="hint", hint=HintKind.BF)
    return spec, trace


# ---------------------------------------------------------------------------
# graph construction
# ---------------------------------------------------------------------------
def test_exec_graph_exact_makespan(chain):
    spec, trace = chain
    g = ExecGraph.build(trace, spec)
    assert g.makespan == float(trace.meta["makespan"])
    assert g.verify() < 1e-12
    assert len(g.nodes) == spec.total_tasks() + 1  # + the root


def test_decomposition_sums_exactly(chain):
    spec, trace = chain
    rep = ExecGraph.build(trace, spec).decompose()
    assert sum(rep.categories[c] for c in CP_CATEGORIES) == rep.makespan
    fr = rep.fractions()
    assert sum(fr.values()) == pytest.approx(1.0)
    # a deterministic no-fault chain is compute-bound
    assert rep.top_category() == "compute"
    assert rep.categories["recovery"] == 0.0
    assert rep.path_nodes > 0 and len(rep.path) == rep.path_nodes
    assert "compute" in rep.table()


def test_slack_zero_on_path_positive_off(chain):
    spec, trace = chain
    g = ExecGraph.build(trace, spec)
    slacks = g.slack()
    on_path = {n.key for n, _ in g.critical_path()}
    assert all(slacks[k] == 0.0 for k in on_path)
    assert min(slacks.values()) >= 0.0
    off = [slacks[k] for k in g.nodes if k not in on_path]
    assert off and max(off) > 0.0  # a 4x6 chain has genuinely idle nodes


# ---------------------------------------------------------------------------
# what-if: Speedup spec + prediction + cost-model bridge
# ---------------------------------------------------------------------------
def test_speedup_validation():
    with pytest.raises(ValueError):
        Speedup(factor=0.0)
    with pytest.raises(ValueError):
        Speedup(factor=-1.0, op="F")
    with pytest.raises(ValueError):
        Speedup(factor=0.5, comm=True, op="F")
    with pytest.raises(ValueError):
        Speedup(factor=0.5, comm=True, stage=1)
    with pytest.raises(ValueError):
        Speedup(factor=0.5, op="Q")
    assert Speedup(factor=0.5, op="dX", stage=2).describe() == \
        "dX @ stage 2 x0.5"
    assert Speedup(factor=0.5, comm=True).describe() == "comm x0.5"


def test_whatif_identity_and_composition(chain):
    spec, trace = chain
    g = ExecGraph.build(trace, spec)
    assert predict(g, []) == pytest.approx(g.makespan, rel=1e-12)
    assert predict(g, [Speedup(factor=1.0)]) == pytest.approx(
        g.makespan, rel=1e-12)
    # op speedups compose conjunctively with stage filters
    all_b = predict(g, [Speedup(factor=0.5, op="B")])
    one_b = predict(g, [Speedup(factor=0.5, op="B", stage=0)])
    assert all_b <= one_b <= g.makespan + 1e-12


def test_apply_to_cost_model_scales_rows():
    cm = det_costs(4, f=1.0, b=2.0, w=1.5)
    out = apply_to_cost_model(cm, [Speedup(factor=0.5, op="B"),
                                   Speedup(factor=0.25, stage=1, op="F"),
                                   Speedup(factor=2.0, comm=True)])
    assert np.allclose(out.b_cost, cm.b_cost * 0.5)
    assert out.f_cost[1] == pytest.approx(0.25 * cm.f_cost[1])
    assert np.allclose(out.f_cost[[0, 2, 3]], cm.f_cost[[0, 2, 3]])
    assert np.allclose(out.w_cost, cm.w_cost)
    assert out.comm_base == pytest.approx(2.0 * cm.comm_base)
    # the input model is untouched
    assert cm.b_cost[0] == 2.0
    # split-backward labels scale the same underlying rows
    out2 = apply_to_cost_model(cm, [Speedup(factor=0.5, op="dX")])
    assert np.allclose(out2.b_cost, cm.b_cost * 0.5)
    # stage-only speedups scale every compute row of that stage
    out3 = apply_to_cost_model(cm, [Speedup(factor=0.5, stage=2)])
    for row in ("f_cost", "b_cost", "w_cost"):
        assert getattr(out3, row)[2] == pytest.approx(
            0.5 * getattr(cm, row)[2])


def test_candidate_speedups_and_rank(chain):
    spec, trace = chain
    g = ExecGraph.build(trace, spec)
    cands = candidate_speedups(g, factor=0.75)
    assert sum(1 for s in cands if s.comm) == 1
    assert {s.stage for s in cands if s.stage is not None} == set(range(4))
    assert {s.op for s in cands if s.op is not None} == {"F", "B"}
    rows = rank(g, factor=0.75)
    assert len(rows) == len(cands)
    gains = [r["gain"] for r in rows]
    assert gains == sorted(gains, reverse=True)
    for r in rows:
        assert r["predicted_makespan"] == pytest.approx(
            g.makespan - r["gain"], rel=1e-12)
        assert 0.0 <= r["gain_frac"] <= 1.0
    # on a b=2f chain, speeding B up beats speeding comm up
    b_row = next(r for r in rows if r["op"] == "B")
    comm_row = next(r for r in rows if r["comm"])
    assert b_row["gain"] > comm_row["gain"]


# ---------------------------------------------------------------------------
# explain report + CLI
# ---------------------------------------------------------------------------
def test_explain_report_structure(chain):
    spec, trace = chain
    rep = explain(trace, spec)
    assert rep.makespan == float(trace.meta["makespan"])
    assert "compute" in rep.bottleneck
    assert rep.ranking and rep.ranking[0]["gain"] >= rep.ranking[-1]["gain"]
    doc = rep.to_json()
    json.dumps(doc)  # serializable end-to-end
    assert set(doc["critical_path"]["categories"]) == set(CP_CATEGORIES)
    txt = rep.format()
    assert "makespan explained" in txt and "what-if" in txt
    assert "bubble cross-check" in txt


def test_explain_flags_stragglers():
    spec = PipelineSpec(4, 6)
    cm = det_costs(4)
    slow = dataclasses.replace(
        cm, b_cost=cm.b_cost * np.array([1.0, 1.0, 3.0, 1.0]))
    rep = explain(run_recorded(spec, slow, mode="hint", hint=HintKind.BF),
                  spec)
    flagged = {(s["stage"], s["op"]) for s in rep.stragglers}
    assert (2, "B") in flagged
    s = next(s for s in rep.stragglers if s["stage"] == 2 and s["op"] == "B")
    assert s["ratio"] == pytest.approx(3.0, rel=0.05)
    assert "stage 2" in rep.format()


def test_explain_with_baseline_crosscheck(chain):
    spec, trace = chain
    # a starved baseline: same pipeline under 4x comm latency
    base = run_recorded(spec, det_costs(4, comm=4e-1), mode="hint",
                        hint=HintKind.BF)
    rep = explain(trace, spec, baseline=base)
    assert rep.crosscheck["baseline"] is True
    assert rep.crosscheck["speedup"] > 1.0
    assert "top_removed_bubble" in rep.crosscheck
    assert "vs baseline" in rep.format()


def test_report_cli_round_trip(tmp_path, capsys):
    spec = PipelineSpec(3, 4)
    trace = run_recorded(spec, det_costs(3), mode="hint", hint=HintKind.BF)
    p = tmp_path / "t.trace.jsonl"
    trace.save(str(p))
    assert report_main([str(p)]) == 0
    out = capsys.readouterr().out
    assert "makespan explained" in out and "binding bottleneck" in out
    pf = tmp_path / "t.perfetto.json"
    assert report_main([str(p), "--json", "--perfetto", str(pf)]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["makespan"] == pytest.approx(float(trace.meta["makespan"]))
    exported = json.load(open(pf))
    validate_chrome_trace(exported)
    assert any(e.get("cat") == "critical_path"
               for e in exported["traceEvents"])


# ---------------------------------------------------------------------------
# Perfetto export: byte-stable default, highlighted opt-in
# ---------------------------------------------------------------------------
def test_perfetto_default_output_unchanged(chain):
    spec, trace = chain
    plain = to_perfetto(trace)
    assert json.dumps(plain) == json.dumps(
        to_perfetto(trace, critical_path=False))
    for ev in plain["traceEvents"]:
        assert "slack_s" not in ev.get("args", {})
        assert ev.get("cat") != "critical_path"


def test_perfetto_critical_path_track(chain):
    spec, trace = chain
    doc = to_perfetto(trace, critical_path=True)
    validate_chrome_trace(doc)
    evs = doc["traceEvents"]
    cp = [e for e in evs if e.get("cat") == "critical_path"]
    g = ExecGraph.build(trace, spec)
    path = [n for n, _ in g.critical_path() if n.op != "root"]
    assert len(cp) == len(path)
    assert all(e["cname"] == "terrible" for e in cp)
    names = {e["name"] for e in evs if e["ph"] == "M"}
    assert "process_name" in names
    pid = max(e["pid"] for e in evs if "pid" in e)
    assert all(e["pid"] == pid for e in cp)  # own synthetic track
    # task slices carry slack annotations; on-path ones are flagged
    annotated = [e for e in evs if e.get("cat") == "task"
                 and "slack_s" in e.get("args", {})]
    assert annotated
    assert any(e["args"]["critical"] for e in annotated)
    assert all(e["args"]["slack_s"] >= 0.0 for e in annotated)
