"""Critical-path benchmark: explain the makespan, then PROVE the
explanation predicts.

Two gated parts, one committed artifact (``BENCH_critpath.json``):

**Part 1 — exact reconstruction.**  For every cell in a chain + DAG x
consumption-mode x chaos-level (C0..C3, with and without an armed
fail-stop fault and recovery) matrix, record a sim trace, lower it
through ``repro.obs.critpath.ExecGraph`` and check the longest path
reconstructs the recorded makespan **bit-exactly**, the category
decomposition sums exactly to the makespan, and slack is >= 0
everywhere.  CI fails if any cell is inexact.

**Part 2 — causal what-if validation.**  On the no-fault cells, apply
virtual speedups (each stage's compute, each op class, the comm latency
class) to the critical-path graph (Coz-style, zero re-execution) and
*also* realize each speedup in an actual DES rerun with the scaled cost
model (same CRN seed — multiplicative jitter scales proportionally).
Gate: the **median** |predicted - realized| / realized across all
experiments stays under ``MEDIAN_ERR_GATE`` (5%; a generous smoke
ceiling under ``REPRO_SMOKE=1`` keeps the CI signal about wiring, not
workload size).

    PYTHONPATH=src python -m benchmarks.run --backend actor --critpath
    REPRO_SMOKE=1 PYTHONPATH=src python -m benchmarks.critical_path

Emits ``BENCH_critpath.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics

from repro.core import CostModel, HintKind, PipelineSpec, StageGraph
from repro.obs.critpath import CP_CATEGORIES, ExecGraph
from repro.obs.whatif import Speedup, apply_to_cost_model, predict
from repro.runtime.rrfp import CHAOS_LEVELS, ActorConfig, ActorDriver

SEED = 7
LEVELS = ("C0", "C1", "C2", "C3")
#: full-size gate on the median predicted-vs-realized makespan error
MEDIAN_ERR_GATE = 0.05
#: smoke runs shrink microbatch counts; arbitration shifts weigh heavier,
#: so the smoke ceiling only guards against gross wiring regressions
MEDIAN_ERR_GATE_SMOKE = 0.20
WHATIF_FACTOR = 0.75


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_SMOKE"))


def _branch_dag(num_stages: int = 5) -> StageGraph:
    # encoder pair -> fusion -> LM chain (the multimodal shape, small)
    return StageGraph(num_stages, ((0, 2), (1, 2), (2, 3), (3, 4)))


def workloads(microbatches: int) -> dict[str, tuple[PipelineSpec, CostModel,
                                                    ActorConfig]]:
    """The benchmark's workload matrix: chain and DAG, fused and split."""
    chain = PipelineSpec(4, microbatches)
    chain_split = PipelineSpec(6, max(4, microbatches // 2),
                               split_backward=True)
    dag = PipelineSpec(5, microbatches, graph=_branch_dag())
    return {
        "chain-4s/hint-bf": (
            chain,
            CostModel.uniform(4, f=1.0, b=2.0, w=0.0, comm_base=1e-3,
                              seed=SEED),
            ActorConfig(mode="hint", hint=HintKind.BF)),
        "chain-6s-split/hint-bfw": (
            chain_split,
            CostModel.uniform(6, f=1.0, b=2.0, w=1.0, comm_base=1e-3,
                              seed=SEED),
            ActorConfig(mode="hint", hint=HintKind.BFW, w_defer_cap=2)),
        "dag-5s/hint-bf": (
            dag,
            CostModel.uniform(5, f=1.0, b=2.0, w=0.0, comm_base=1e-3,
                              seed=SEED),
            ActorConfig(mode="precommitted", fixed_order="1f1b")),
    }


def _trace(spec, cm, cfg):
    cfg = dataclasses.replace(cfg, record_trace=True, seed=SEED)
    return ActorDriver(spec, cm, cfg).run().trace


def _reconstruction_cell(name: str, spec, cm, cfg) -> dict:
    trace = _trace(spec, cm, cfg)
    g = ExecGraph.build(trace, spec)
    mk = float(trace.meta["makespan"])
    rep = g.decompose()
    cat_sum = sum(rep.categories[c] for c in CP_CATEGORIES)
    slacks = g.slack()
    return {
        "cell": name,
        "makespan": mk,
        "graph_makespan": g.makespan,
        "reconstruct_exact": g.makespan == mk,
        "decomposition_exact": cat_sum == mk,
        "min_slack": min(slacks.values()),
        "verify_rel_err": g.verify(),
        "recovery_windows": g.num_recovery_windows,
        "categories": rep.categories,
        "fractions": rep.fractions(),
        "top_category": rep.top_category(),
    }


def reconstruction_cells(microbatches: int) -> list[dict]:
    """Part 1: chain + DAG x chaos level x (no fault | armed fault)."""
    out = []
    for wname, (spec, cm, cfg) in workloads(microbatches).items():
        for level in LEVELS:
            chaos = dataclasses.replace(CHAOS_LEVELS[level], seed=SEED)
            c = dataclasses.replace(cfg, chaos=chaos)
            out.append(_reconstruction_cell(
                f"{wname}/{level}", spec, cm, c))
        # armed fail-stop fault + elastic recovery, respawn and remap
        for mode in ("respawn", "remap"):
            chaos = dataclasses.replace(
                CHAOS_LEVELS["C2"], seed=SEED, fail_stage=spec.num_stages - 1,
                fail_kind="kill",
                fail_after=max(1, spec.num_tasks_per_stage() // 3))
            c = dataclasses.replace(cfg, chaos=chaos, recover=True,
                                    recovery_mode=mode)
            out.append(_reconstruction_cell(
                f"{wname}/C2+fail-{mode}", spec, cm, c))
    return out


def _experiments(spec, graph) -> list[list[Speedup]]:
    """The validated what-if sweep for one workload: every stage's
    compute, the op classes present, and the comm latency class."""
    ops = sorted({n.op for n in graph.nodes.values() if n.task is not None})
    exps = [[Speedup(factor=WHATIF_FACTOR, stage=s)]
            for s in range(spec.num_stages)]
    exps += [[Speedup(factor=WHATIF_FACTOR, op=op)] for op in ops]
    exps.append([Speedup(factor=WHATIF_FACTOR, comm=True)])
    return exps


def whatif_cells(microbatches: int) -> list[dict]:
    """Part 2: predicted-vs-realized makespan per virtual speedup."""
    out = []
    for wname, (spec, cm, cfg) in workloads(microbatches).items():
        base = _trace(spec, cm, cfg)
        graph = ExecGraph.build(base, spec)
        for speedups in _experiments(spec, graph):
            predicted = predict(graph, speedups)
            realized = float(
                _trace(spec, apply_to_cost_model(cm, speedups),
                       cfg).meta["makespan"])
            out.append({
                "cell": wname,
                "speedup": " + ".join(s.describe() for s in speedups),
                "base_makespan": graph.makespan,
                "predicted_makespan": predicted,
                "realized_makespan": realized,
                "rel_error": abs(predicted - realized) / realized,
            })
    return out


def run_critpath_benchmark() -> dict:
    microbatches = 8 if _smoke() else 24
    rec = reconstruction_cells(microbatches)
    wi = whatif_cells(microbatches)
    errors = [c["rel_error"] for c in wi]
    gate = MEDIAN_ERR_GATE_SMOKE if _smoke() else MEDIAN_ERR_GATE
    return {
        "spec": {
            "seed": SEED, "microbatches": microbatches,
            "categories": list(CP_CATEGORIES), "levels": list(LEVELS),
            "whatif_factor": WHATIF_FACTOR,
            "median_err_gate": gate, "smoke": _smoke(),
        },
        "reconstruction": rec,
        "whatif": wi,
        "summary": {
            "cells": len(rec),
            "all_reconstruct_exact": all(c["reconstruct_exact"]
                                         for c in rec),
            "all_decompositions_exact": all(c["decomposition_exact"]
                                            for c in rec),
            "min_slack": min(c["min_slack"] for c in rec),
            "whatif_experiments": len(wi),
            "whatif_median_rel_error": statistics.median(errors),
            "whatif_max_rel_error": max(errors),
        },
    }


def emit_json(path: str = "BENCH_critpath.json") -> dict:
    report = run_critpath_benchmark()
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def critpath_rows(
    json_path: str = "BENCH_critpath.json",
) -> list[tuple[str, float, str]]:
    """CSV rows for ``benchmarks.run``; raises on a failed gate."""
    report = emit_json(json_path)
    out = []
    for c in report["reconstruction"]:
        out.append((
            f"critpath/{c['cell']}",
            c["makespan"] * 1e6,
            f"exact={c['reconstruct_exact']},top={c['top_category']},"
            f"recoveries={c['recovery_windows']}",
        ))
    for c in report["whatif"]:
        out.append((
            f"whatif/{c['cell']}/{c['speedup'].replace(' ', '')}",
            c["predicted_makespan"] * 1e6,
            f"realized={c['realized_makespan'] * 1e6:.1f}us,"
            f"err={c['rel_error']:.2%}",
        ))
    s = report["summary"]
    gate = report["spec"]["median_err_gate"]
    if not s["all_reconstruct_exact"]:
        bad = [c["cell"] for c in report["reconstruction"]
               if not c["reconstruct_exact"]]
        raise SystemExit(
            f"critical path failed to reconstruct the recorded makespan "
            f"bit-exactly on: {', '.join(bad)}")
    if not s["all_decompositions_exact"]:
        bad = [c["cell"] for c in report["reconstruction"]
               if not c["decomposition_exact"]]
        raise SystemExit(
            f"critical-path category decomposition does not sum exactly "
            f"to the makespan on: {', '.join(bad)}")
    if s["min_slack"] < 0:
        raise SystemExit(
            f"negative scheduling slack: {s['min_slack']!r}")
    if s["whatif_median_rel_error"] > gate:
        raise SystemExit(
            f"what-if median predicted-vs-realized error "
            f"{s['whatif_median_rel_error']:.2%} exceeds the "
            f"{gate:.0%} gate across {s['whatif_experiments']} experiments")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in critpath_rows():
        print(f"{name},{us:.1f},{derived}")
