"""Stage actors: readiness-driven dispatch at host level (§4, §5, App. A/C).

A :class:`StageActor` owns one pipeline stage's scheduling state: the set of
tasks whose messages have been admitted (``arrived``), the currently ready
set, the done set, the F/B balance counters for Appendix C backpressure, and
a :class:`~repro.core.hints.HintArbiter` for ready-set arbitration.  The
actor is *reactive*: it makes a dispatch decision only when poked by an
arrival or a completion — there is no schedule-table tick anywhere.

The same actor expresses both consumption modes of the paper's central
contrast:

* ``hint``        — Algorithm 1 over the current ready set, plus the App. C
                    backward-only / deterministic drain under backpressure;
* ``precommitted``— follow a fixed per-stage order, waiting on any entry
                    that is not yet ready (1F1B / GPipe / ZB baselines).

``run_thread`` is the thread-per-stage execution loop used by the
ThreadTransport: it blocks on the mailbox condition, dispatches real work
callables, and reports completions back through the transport.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Any, Callable

from repro.core.engine import DeadlockError, StageStats
from repro.core.hints import (
    HintArbiter,
    HintKind,
    ReadySet,
    backpressure_drain,
    pick,
    table_ranks,
)
from repro.core.taskgraph import Kind, PipelineSpec, Task

from repro.runtime.rrfp import trace as _tr
from repro.runtime.rrfp.mailbox import Mailbox
from repro.runtime.rrfp.messages import (
    EdgePayloads,
    envelopes_for,
    payload_for_edge,
)


@dataclasses.dataclass
class TaskTrace:
    """One dispatch record (start/end on the driver's clock)."""

    task: Task
    start: float
    end: float


class StageActor:
    """Scheduling brain + (optionally) execution thread for one stage."""

    def __init__(
        self,
        idx: int,
        spec: PipelineSpec,
        mailbox: Mailbox,
        *,
        mode: str = "hint",
        hint: HintKind = HintKind.BF,
        order: list[Task] | None = None,
        buffer_limit: int = 32,
        w_defer_cap: int = 0,
        reference_arbitration: bool = False,
        trace_full_ready: bool = False,
        metrics=None,
        table: list[Task] | None = None,
        table_version: int = 0,
    ):
        if mode not in ("hint", "precommitted"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "precommitted" and order is None:
            raise ValueError("precommitted mode needs a per-stage order")
        if table is not None and mode != "hint":
            raise ValueError("a rank table is a hint-mode consumption knob")
        self.idx = idx
        self.spec = spec
        self.mailbox = mailbox
        self.recorder = mailbox.recorder
        self.mode = mode
        self.arbiter = HintArbiter(hint)
        #: synthesized-schedule-as-data: when set, the arbiter serves the
        #: minimum-rank ready task under this table instead of the
        #: directional round structure (still non-binding; see
        #: docs/adaptive.md).  Hot-swapped mid-run via set_hint_table().
        self.table_version = table_version
        if table is not None:
            self.arbiter.table = table_ranks(table)
        self.order = order
        self.order_pos = 0
        #: thread-substrate swap trigger (driver-armed): adopt swap_table
        #: after this stage's swap_after-th completion — a per-stage
        #: quiesce point (no task in flight when it fires)
        self.swap_table: list[Task] | None = None
        self.swap_after: int | None = None
        self._n_complete = 0
        self.buffer_limit = buffer_limit
        self.w_defer_cap = w_defer_cap
        #: verification knob: arbitrate via the reference sort-then-rank
        #: path (decision-identical; only the per-decision cost differs)
        self.reference_arbitration = reference_arbitration
        #: record full sorted ready snapshots per dispatch instead of the
        #: cheap incremental diff (``radd``) encoding
        self.trace_full_ready = trace_full_ready
        #: per-stage single-writer metric shard
        #: (:class:`repro.obs.metrics.StageShard`), or None = zero-cost
        self.metrics = metrics
        self.arrived: set[Task] = set()
        self.ready = ReadySet(table=self.arbiter.table)
        self.done: set[Task] = set()
        #: ready-set additions since the last recorded dispatch (diff-mode
        #: trace snapshots; maintained only while a recorder is attached)
        self._ready_added: list[Task] = []
        #: lazily built waiting_on() index (diagnostics)
        self._awaiting: set[Task] | None = None
        self.n_f = 0
        self.n_b = 0
        self.n_w = 0
        self.drain_focus = 0
        self.stats = StageStats()
        self.traces: list[TaskTrace] = []
        self._total = spec.num_tasks_per_stage()
        #: execution heartbeat (thread substrate): ``time.monotonic()`` at
        #: which the currently-running ``work_fn`` started, or None when not
        #: executing.  The recovery coordinator's watchdog reads this to
        #: detect a permanently-stalled stage by heartbeat staleness.
        self.exec_since: float | None = None
        #: thread substrate: set (under the mailbox condition) by the
        #: recovery coordinator to kill a *live* incarnation — e.g. the
        #: victim of a link failure, which is healthy but unreachable.  The
        #: run loop re-checks it at both quiesce points (the wait loop and
        #: immediately before recording a completion), so a halted actor can
        #: never commit state after its successor incarnation exists.
        self.halted = False

    # ---- readiness bookkeeping (call under the mailbox lock) ---------------
    def _is_ready(self, t: Task) -> bool:
        # the mailbox buffers a task only when its full message set (all TP
        # ranks x all fan-in edges) has been admitted, so task-level arrival
        # tracking stays correct on DAG specs
        if self.spec.fan_in(t) > 0 and t not in self.arrived:
            return False
        lp = self.spec.local_predecessor(t)
        if lp is not None and lp not in self.done:
            return False
        return True

    def _maybe_enqueue(self, t: Task) -> None:
        if t not in self.done and t not in self.ready and self._is_ready(t):
            self.ready.add(t)
            if self.recorder is not None and not self.trace_full_ready:
                self._ready_added.append(t)

    def sync_mailbox(self) -> None:
        """Drain arrivals admitted since the last sync into the ready set.

        ``Mailbox.drain_arrivals`` hands over only the tasks buffered since
        the previous drain, so repeated syncs stop rescanning already-seen
        envelopes; ``self.arrived`` is the persistent memory that lets a
        task whose local predecessor lags be re-attempted at the
        predecessor's completion."""
        for t in self.mailbox.drain_arrivals():
            self.arrived.add(t)
            if self._awaiting is not None:
                self._awaiting.discard(t)
            self._maybe_enqueue(t)

    # ---- arbitration -------------------------------------------------------
    def backpressured(self) -> bool:
        return self.mode == "hint" and self.n_f - self.n_b >= self.buffer_limit

    def w_backlog(self) -> int:
        """Completed-B microbatches whose W has not executed yet.  Each holds
        a stashed (x, g_in) pair, so this is the stage's deferred-W
        activation-memory footprint."""
        return self.n_b - self.n_w

    def w_overcap(self) -> bool:
        """App. C-style memory backpressure on W deferral: at the cap the
        stage must retire a weight-gradient task before any further B."""
        return (self.mode == "hint" and self.spec.split_backward
                and self.w_defer_cap > 0
                and self.w_backlog() >= self.w_defer_cap)

    def set_hint_table(self, order: list[Task], now: float = 0.0,
                       version: int | None = None) -> None:
        """Hot-swap a re-synthesized rank table into the live arbiter.

        Schedules are data: the swap replaces a priority table (O(ready)
        heap rebuild), no recompilation, no draining of in-flight work
        beyond the caller's quiesce point — the sim driver fires it
        between heap events, the thread loop under the mailbox condition
        right after a completion.  Recorded as a HINT_SWAP trace event
        (with the full new order) so replay and the conformance
        table-faithfulness check reconstruct the active table exactly."""
        ranks = table_ranks(order)
        self.arbiter.set_table(ranks)
        self.ready.set_table(ranks)
        self.table_version = (self.table_version + 1 if version is None
                              else version)
        if self.recorder is not None:
            self.recorder.record(
                _tr.HINT_SWAP, self.idx, t=now, version=self.table_version,
                order=[_tr.task_key(t) for t in order])

    def select(self) -> Task | None:
        """Pick the next task to dispatch from the *currently* ready set."""
        return self.select_traced()[0]

    def select_traced(self) -> tuple[Task | None, dict | None]:
        """Like ``select``, plus the arbitration path taken — recorded into
        the dispatch event so the conformance checker can verify, offline,
        that each decision followed the hint (or deviated only because the
        hinted task was unready).  The info dict is only materialized when a
        recorder or a metric shard is attached: this runs on the dispatch
        hot path of every arbitration attempt.

        With metrics attached the hint path also stamps ``slot``: the index
        of the dispatched task's *kind* in the arbiter's preference order —
        the hint-divergence metric (0 = hinted direction served, >0 = the
        hinted direction was unready).  Within a direction the dispatched
        task is always the App. A minimum ready candidate (conformance's
        hint-faithfulness invariant), so kind-level rank is the whole
        divergence signal."""
        rec = self.recorder is not None
        obs = rec or self.metrics is not None
        ref = self.reference_arbitration
        # Failed attempts (task None) always return info None: nothing is
        # recorded or counted for a no-dispatch, and roughly half of all
        # arbitration attempts fail, so they must not pay the dict/tuple
        # materialization.
        if self.mode == "precommitted":
            if self.order_pos >= len(self.order):
                return None, None
            nxt = self.order[self.order_pos]
            if nxt not in self.ready:
                return None, None
            return nxt, ({"path": "precommitted"} if obs else None)
        if self.w_overcap():
            # Every completed B locally enables its W, so a ready W exists
            # whenever the backlog is nonzero; retiring it frees the stash.
            task = pick(sorted(self.ready) if ref else self.ready, Kind.W)
            if task is not None:
                return task, ({"path": "wcap", "backlog": self.w_backlog()}
                              if obs else None)
        if self.backpressured():
            task, self.drain_focus = backpressure_drain(
                self.spec, self.idx,
                sorted(self.ready) if ref else self.ready, self.done,
                self.drain_focus)
            if task is None:
                return None, None
            return task, ({"path": "backpressure"} if obs else None)
        # select() advances the round alternation, so capture last_dir
        # first: order/slot are reconstructed post-hoc only on a dispatch.
        prev_dir = self.arbiter.last_dir
        task = self.arbiter.select(sorted(self.ready) if ref else self.ready)
        if not obs or task is None:
            return task, None
        if self.arbiter.table is not None:
            # rank-table consumption: no directional round structure, so
            # no order/slot — faithfulness is checked against the table
            return task, {"path": "table", "tv": self.table_version}
        info: dict = {"path": "hint"}
        if rec:
            info["order"] = [
                int(k) for k in self.arbiter.order_given(prev_dir)]
        if self.metrics is not None:
            info["slot"] = self.arbiter.rank_given(task.kind, prev_dir)
        return task, info

    def begin(self, task: Task, now: float = 0.0,
              info: dict | None = None) -> Any:
        """Commit to a dispatch: consume the task's buffered message (if any)
        and return its payload."""
        if self.metrics is not None:
            # info is always materialized when a shard is attached
            self.metrics.on_dispatch(task, len(self.ready), info["path"],
                                     info.get("slot"))
        if self.recorder is not None:
            # Ready-set snapshot: the default "diff" encoding records only
            # the tasks *added* since this stage's previous dispatch (the
            # sole removal between dispatches is the dispatched task
            # itself), so recording stops paying O(n log n) per decision —
            # `Trace.ready_sets()` reconstructs the full snapshots offline.
            # `trace_full_ready` opts back into the verbose sorted form.
            if self.trace_full_ready:
                snap = {"ready": [_tr.task_key(t) for t in sorted(self.ready)]}
            else:
                snap = {"radd": [_tr.task_key(t) for t in self._ready_added]}
                self._ready_added = []
            self.recorder.record(
                _tr.DISPATCH, self.idx, task, t=now, **snap, **(info or {}))
        self.ready.discard(task)
        if self.mode == "precommitted":
            self.order_pos += 1
        payload = None
        if task in self.mailbox.buffers[task.kind]:
            payload = self.mailbox.consume(task, now=now)
        return payload

    def complete(self, task: Task, now: float = 0.0,
                 dur: float | None = None) -> tuple[Task, ...]:
        """Mark done, enable local successors; return the remote successors
        whose messages must now be sent (empty for stage-local results)."""
        self.done.add(task)
        if task.kind == Kind.F:
            self.n_f += 1
            self._maybe_enqueue(Task(Kind.B, self.idx, task.mb, task.chunk))
        elif task.kind == Kind.B:
            self.n_b += 1
            if self.spec.split_backward:
                self._maybe_enqueue(Task(Kind.W, self.idx, task.mb, task.chunk))
        elif task.kind == Kind.W:
            self.n_w += 1
        if self.metrics is not None and dur is not None:
            self.metrics.on_complete(
                task, dur,
                (self.n_b - self.n_w) if self.spec.split_backward else 0)
        if self.recorder is not None:
            info: dict[str, Any] = {"nf": self.n_f, "nb": self.n_b}
            if dur is not None:
                info["dur"] = dur
            if self.spec.split_backward:
                info["w_backlog"] = self.w_backlog()
            if self.metrics is not None and dur is not None:
                # annotate with the live cost-table state: extra info fields
                # that save/load and ReplayOracle must tolerate
                info["ewma"] = self.metrics.cost_ewma[task.kind].value
            self.recorder.record(_tr.COMPLETE, self.idx, task, t=now, **info)
        # W tasks are stage-local by construction: message_successors(W) is
        # empty, so no envelope is emitted and no TP admission gate applies.
        # DAG fan-out tasks feed one successor per outgoing edge.
        return self.spec.message_successors(task)

    def finished(self) -> bool:
        return len(self.done) == self._total

    def waiting_on(self) -> list[Task]:
        """Diagnostics: not-yet-done tasks whose message set is incomplete.

        The index is built once on first use (this-stage tasks that need a
        message and have not yet arrived) and then maintained incrementally
        by ``sync_mailbox``, so repeated diagnostic polls cost O(pending)
        instead of re-scanning every task in the spec."""
        if self._awaiting is None:
            self._awaiting = {
                t for t in self.spec.tasks()
                if t.stage == self.idx and t not in self.arrived
                and self.spec.fan_in(t) > 0}
        return sorted(self._awaiting - self.done)

    # ---- thread-per-stage execution loop (ThreadTransport) -----------------
    def run_thread(
        self,
        work_fn: Callable[[Task, Any], Any],
        transport,
        clock: Callable[[], float],
        *,
        tp_degree: int = 1,
        deadlock_timeout: float = 30.0,
        abort=None,
    ) -> None:
        """Execute this stage's tasks as they become ready.

        ``work_fn(task, payload) -> out_payload`` runs the real computation
        (e.g. a jitted stage callable); ``out_payload`` rides on the outgoing
        envelope.  Raises :class:`DeadlockError` if the mailbox starves for
        ``deadlock_timeout`` seconds while work remains.

        The wait is event-driven: the actor blocks on the mailbox condition
        until ``Mailbox.deliver``/``deliver_local``/``stop`` notifies it —
        zero busy-wait, wakeup latency bounded by the notify, not by a poll
        period.  The only timed wake is the starvation deadline (deadlock
        detection), so abort/stop signals must notify the condition to be
        seen promptly (``Mailbox.stop`` does; the driver stops every
        mailbox when a sibling stage errors).
        """
        idle_since = clock()
        while not self.finished():
            if abort is not None and abort.is_set():
                return
            with self.mailbox.cond:
                task = None
                while True:
                    if self.halted:
                        return
                    self.sync_mailbox()
                    task, sel_info = self.select_traced()
                    if task is not None or self.finished():
                        break
                    if self.mailbox.stopped or (
                            abort is not None and abort.is_set()):
                        return
                    remaining = deadlock_timeout - self.mailbox.starved_for()
                    if remaining <= 0:
                        if abort is not None:
                            abort.set()
                        raise DeadlockError(
                            f"stage {self.idx} starved >{deadlock_timeout}s "
                            f"with {self._total - len(self.done)} tasks left; "
                            f"waiting on messages for {self.waiting_on()[:4]}")
                    self.mailbox.wait_for_work(remaining)
                if task is None:  # finished() flipped
                    return
                payload = self.begin(task, now=clock(), info=sel_info)
            start = clock()
            self.stats.blocking += max(0.0, start - idle_since)
            self.exec_since = _time.monotonic()
            try:
                out_payload = work_fn(task, payload)
            finally:
                self.exec_since = None
            end = clock()
            self.stats.compute += end - start
            with self.mailbox.cond:
                if self.halted:
                    # killed mid-execution (link failure on a live stage):
                    # the successor incarnation re-executes this task, so
                    # committing it here would double-complete it
                    return
                succs = self.complete(task, now=end, dur=end - start)
                self._n_complete += 1
                if (self.swap_table is not None
                        and self._n_complete == self.swap_after):
                    # quiesce point: this stage holds no in-flight task
                    self.set_hint_table(self.swap_table, now=end)
                self.mailbox.touch()
            self.traces.append(TaskTrace(task, start, end))
            idle_since = end
            if isinstance(out_payload, EdgePayloads):
                # a missing edge entry would silently deliver payload=None
                # (downstream substitutes a zero gradient) — fail fast
                missing = [t.stage for t in succs
                           if t.stage not in out_payload]
                if missing:
                    raise ValueError(
                        f"stage {self.idx}: {task!r} returned EdgePayloads "
                        f"without entries for successor stage(s) {missing}")
            for succ in succs:
                for env in envelopes_for(
                        succ, self.idx, tp_degree, send_time=end,
                        payload=payload_for_edge(out_payload, succ.stage)):
                    transport.send(env, now=end)
