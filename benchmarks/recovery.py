"""Recovery benchmark: MTTR + post-recovery throughput under fail-stop
faults (emits ``BENCH_recovery.json``).

For each chaos level C0..C3 (the fail-stop fault is layered *on top of* the
level's latency/reorder/duplication/straggler noise), each workload (linear
chain and branch+fusion multimodal DAG) and each recovery mode (respawn =
standby host, remap = fold the dead stage onto a surviving neighbor), runs
seeded iterations on the sim substrate in which a mid-pipeline stage is
killed (or permanently stalled) partway through the iteration and the run
must finish under ``ActorConfig.recover``.  Reports:

* **MTTR** (mean time to repair: fault injection -> respawned stage
  dispatching again), decomposed nowhere — it is detection (heartbeat
  deadline) + restore cost by construction;
* **post-recovery throughput** relative to pre-failure throughput (tasks
  completed per second after RECOVERY_END vs before the fault) — respawn
  should recover the full rate, remap pays the co-hosting tax;
* **makespan overhead** vs the same scenario without the fault;
* the count of runs on which the *recovery-aware* conformance invariants
  held (``check_recovery_exactly_once`` et al. via ``conformance.holds``)
  — the exactly-once claim as a measured quantity.

    PYTHONPATH=src python -m benchmarks.run --recovery

Set ``REPRO_SMOKE=1`` to shrink the sweep for CI smoke runs.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import (
    CostModel,
    HintKind,
    PipelineSpec,
    StageGraph,
    multimodal_stage_flops,
)
from repro.runtime.rrfp import CHAOS_LEVELS, ActorConfig, ActorDriver
from repro.runtime.rrfp.conformance import holds as invariants_hold

S, M = 8, 32
ITERS = 4
FAIL_KINDS_CYCLE = ("kill", "permanent_stall")


def _chain_workload() -> tuple[PipelineSpec, CostModel]:
    spec = PipelineSpec(S, M)
    costs = CostModel.from_stage_flops(
        multimodal_stage_flops(4e12, 2e12, S), comm_base=2e-3, seed=0)
    return spec, costs


def _dag_workload() -> tuple[PipelineSpec, CostModel]:
    """Branch+fusion: 3-stage encoder ∥ text frontend -> fusion -> 2-stage
    LM chain (7 stages)."""
    enc, lm = 3, 2
    n = enc + 1 + lm + 1
    edges = [(s, s + 1) for s in range(enc - 1)]
    edges += [(enc - 1, enc + 1), (enc, enc + 1)]
    edges += [(s, s + 1) for s in range(enc + 1, n - 1)]
    graph = StageGraph(n, tuple(edges))
    spec = PipelineSpec(n, M, graph=graph)
    costs = CostModel.uniform(n, f=1.0, b=2.0, comm_base=2e-3, seed=0)
    return spec, costs


def _throughput(trace, lo: float, hi: float) -> float:
    """Completed tasks per second inside the wall-clock window [lo, hi)."""
    if hi <= lo:
        return 0.0
    n = sum(1 for ev in trace.events
            if ev.kind == "complete" and lo <= ev.t < hi)
    return n / (hi - lo)


def run_recovery_bench() -> dict:
    smoke = bool(os.environ.get("REPRO_SMOKE"))
    iters = 1 if smoke else ITERS
    levels = ["C0", "C2"] if smoke else list(CHAOS_LEVELS)
    workloads = {"chain": _chain_workload(), "multimodal_dag": _dag_workload()}
    modes = ("respawn",) if smoke else ("respawn", "remap")
    rows = []
    for level in levels:
        base_chaos = CHAOS_LEVELS[level]
        for wl_name, (spec, costs) in workloads.items():
            fail_stage = spec.num_stages // 2
            for rmode in modes:
                mttrs, overheads, post_ratio, ok = [], [], [], 0
                for i in range(iters):
                    chaos = dataclasses.replace(
                        base_chaos, seed=100 + i, fail_stage=fail_stage,
                        fail_kind=FAIL_KINDS_CYCLE[i % 2],
                        fail_after=3 + 5 * i)
                    cfg = ActorConfig(
                        mode="hint", hint=HintKind.BF, seed=1000 * i,
                        chaos=chaos, record_trace=True, recover=True,
                        recovery_mode=rmode)
                    driver = ActorDriver(spec, costs, cfg)
                    result = driver.run()
                    trace = driver.trace
                    if invariants_hold(trace, spec, cfg):
                        ok += 1
                    (w,) = trace.recovery_windows()
                    mttrs.append(w["t_end"] - w["t_fail"])
                    calm = ActorDriver(
                        spec, costs,
                        dataclasses.replace(
                            cfg, recover=False,
                            chaos=dataclasses.replace(chaos, fail_stage=-1)))
                    calm_res = calm.run()
                    overheads.append(result.makespan - calm_res.makespan)
                    pre = _throughput(trace, 0.0, w["t_fail"])
                    post = _throughput(trace, w["t_end"], result.makespan)
                    post_ratio.append(post / max(pre, 1e-12))
                rows.append({
                    "level": level,
                    "workload": wl_name,
                    "recovery_mode": rmode,
                    "fail_stage": fail_stage,
                    "runs": iters,
                    "exactly_once_ok": ok,
                    "mttr_s": float(np.mean(mttrs)),
                    "mttr_std": float(np.std(mttrs)),
                    "makespan_overhead_s": float(np.mean(overheads)),
                    "post_recovery_throughput_ratio":
                        float(np.mean(post_ratio)),
                })
    return {
        "spec": {
            "chain": {"stages": S, "microbatches": M},
            "multimodal_dag": {
                "stages": workloads["multimodal_dag"][0].num_stages,
                "microbatches": M},
            "iters": iters,
            "fail_kinds": list(FAIL_KINDS_CYCLE),
        },
        "rows": rows,
    }


def emit_json(path: str = "BENCH_recovery.json") -> dict:
    report = run_recovery_bench()
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def recovery_rows(json_path: str = "BENCH_recovery.json") -> list[tuple]:
    """CSV rows for ``benchmarks.run``."""
    report = emit_json(json_path)
    out = []
    for r in report["rows"]:
        out.append((
            f"recovery/{r['level']}/{r['workload']}/{r['recovery_mode']}",
            r["mttr_s"] * 1e6,
            f"exactly_once={r['exactly_once_ok']}/{r['runs']},"
            f"post_tput={r['post_recovery_throughput_ratio']:.2f}x,"
            f"overhead={r['makespan_overhead_s']*1e3:.1f}ms"))
    return out
