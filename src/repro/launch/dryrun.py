import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on
the production meshes and record memory/cost analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-34b \
        --shape train_4k [--multi-pod] [--schedule rrfp] [--out out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST precede every other import (jax locks the
device count at first init); this module is the only place the 512
placeholder devices exist — tests and benchmarks see the real device.
"""
import argparse
import json
import re
import time
import traceback
from collections import Counter

import jax

from repro.launch import cells as cells_lib
from repro.launch.mesh import make_production_mesh

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\b")


def dryrun_cell(arch: str, shape: str, *, multi_pod: bool = False,
                schedule: str = "1f1b", num_stages: int = 16,
                keep_hlo: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = cells_lib.plan_cell(arch, shape, mesh, num_stages=num_stages)
    fn, args, _ = cells_lib.build_cell(plan, mesh, schedule=schedule)
    t0 = time.time()
    lowered = jax.jit(fn).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = Counter(COLLECTIVE_RE.findall(hlo))
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "schedule": schedule,
        "step": plan.step,
        "microbatches": plan.num_microbatches,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost_raw": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "collectives": dict(colls),
        "hlo_bytes": len(hlo),
    }
    if keep_hlo:
        result["hlo"] = hlo
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--schedule", default="1f1b",
                    choices=["1f1b", "rrfp", "gpipe", "zb"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.all:
        targets = cells_lib.all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        ok, why = cells_lib.cell_is_runnable(args.arch, args.shape)
        if not ok:
            print(f"SKIP {args.arch} × {args.shape}: {why}")
            return
        targets = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    for arch, shape in targets:
        for mp in meshes:
            tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
            try:
                r = dryrun_cell(arch, shape, multi_pod=mp,
                                schedule=args.schedule)
                results.append(r)
                print(f"OK   {tag}: compile={r['compile_s']}s "
                      f"temp={r['memory']['temp_bytes']} "
                      f"colls={r['collectives']}")
            except Exception as e:  # noqa: BLE001 — report and continue
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "error": str(e)})
                print(f"FAIL {tag}: {e}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    failed = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(failed)}/{len(results)} cells passed")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
