"""Property-based tests: hint arbitration is a total order; mailbox/TP-gate
ordering is permutation-stable.

Uses ``hypothesis`` when installed, the deterministic ``tests/_hyp_stub.py``
fallback otherwise (same properties, fixed example budget).

The central properties:

* for any ready set, repeatedly extracting the arbiter's choice visits
  *every* task exactly once — the hint ranking is a total order over the
  ready set (no task is unrankable, no tie is unresolvable);
* the extraction sequence is invariant under permutations of the ready
  set's presentation order — arbitration depends on task identity only;
* mailbox buffers are FIFO per kind regardless of kind interleaving, and
  TP-group admission commits at the last-rank arrival independent of the
  rank arrival permutation;
* epoch fencing is *total*: under any interleaving, an envelope from a
  recovery epoch older than its mailbox's is always dropped (never admitted,
  never payload-stashed) and an envelope at or above it never is.
"""
import itertools

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback (tests/_hyp_stub.py)
    from _hyp_stub import given, settings, strategies as st

from repro.core.hints import HintArbiter, HintKind, pick
from repro.core.taskgraph import Kind, PipelineSpec, Task
from repro.runtime.rrfp import Envelope, Mailbox, TPGroup, envelopes_for


def _ready_set(seed: int, size: int, split: bool) -> list[Task]:
    """Deterministic pseudo-random ready set (distinct tasks, one stage)."""
    rng = np.random.default_rng([0x5EED, seed])
    kinds = [Kind.F, Kind.B] + ([Kind.W] if split else [])
    out = set()
    while len(out) < size:
        out.add(Task(kind=kinds[int(rng.integers(len(kinds)))],
                     stage=0,
                     mb=int(rng.integers(0, 8)),
                     chunk=int(rng.integers(0, 3))))
    return sorted(out)


def _extraction_order(hint: HintKind, ready: list[Task],
                      last_dir) -> list[Task]:
    """Drain the ready set through a fresh arbiter; the visit sequence is
    the arbitration ranking."""
    arb = HintArbiter(hint, last_dir=last_dir)
    pool = list(ready)
    seq = []
    while pool:
        t = arb.select(pool)
        assert t is not None, (
            f"hint {hint} cannot rank nonempty ready set {pool}")
        assert t in pool
        seq.append(t)
        pool.remove(t)
    return seq


HINTS_FUSED = [HintKind.BF, HintKind.FB, HintKind.B_PRIORITY,
               HintKind.F_PRIORITY]


# ---------------------------------------------------------------------------
# hint arbitration: total order, permutation-stable
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), size=st.integers(1, 12),
       hint_i=st.integers(0, len(HINTS_FUSED) - 1),
       last=st.integers(0, 2), perm_seed=st.integers(0, 10_000))
def test_arbitration_total_order_and_permutation_stable(
        seed, size, hint_i, last, perm_seed):
    hint = HINTS_FUSED[hint_i]
    last_dir = (None, Kind.F, Kind.B)[last]
    ready = _ready_set(seed, size, split=False)
    ranking = _extraction_order(hint, ready, last_dir)
    # total order: a permutation of the ready set, nothing skipped/duplicated
    assert sorted(ranking) == sorted(ready)
    # stability: any presentation order yields the identical ranking
    rng = np.random.default_rng([perm_seed, size])
    shuffled = list(ready)
    rng.shuffle(shuffled)
    assert _extraction_order(hint, shuffled, last_dir) == ranking


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), size=st.integers(1, 12),
       perm_seed=st.integers(0, 10_000))
def test_bfw_total_order_and_permutation_stable(seed, size, perm_seed):
    ready = _ready_set(seed, size, split=True)
    ranking = _extraction_order(HintKind.BFW, ready, None)
    assert sorted(ranking) == sorted(ready)
    rng = np.random.default_rng([perm_seed, 1 + size])
    shuffled = list(ready)
    rng.shuffle(shuffled)
    assert _extraction_order(HintKind.BFW, shuffled, None) == ranking


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), size=st.integers(1, 10))
def test_pick_selects_unique_minimum(seed, size):
    """`pick` resolves every direction to one unambiguous App. A minimum."""
    ready = _ready_set(seed, size, split=True)
    for kind in Kind:
        cands = [t for t in ready if t.kind == kind]
        chosen = pick(ready, kind)
        if not cands:
            assert chosen is None
            continue
        assert chosen in cands
        key = ((lambda t: (t.chunk, t.mb)) if kind == Kind.F
               else (lambda t: (-t.chunk, t.mb)))
        assert all(key(chosen) <= key(t) for t in cands)
        # ties are impossible: the key is injective over distinct tasks
        assert sum(1 for t in cands if key(t) == key(chosen)) == 1


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), size=st.integers(2, 10))
def test_bfw_w_only_fills_empty_rounds(seed, size):
    """BFW never dispatches W while a compute direction is ready."""
    ready = _ready_set(seed, size, split=True)
    arb = HintArbiter(HintKind.BFW)
    chosen = arb.select(ready)
    if any(t.kind in (Kind.F, Kind.B) for t in ready):
        assert chosen.kind != Kind.W


# ---------------------------------------------------------------------------
# mailbox: FIFO per kind, stable under interleaving
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 16))
def test_mailbox_fifo_per_kind(seed, n):
    rng = np.random.default_rng([0xB0F, seed])
    tasks = _ready_set(seed, n, split=True)
    order = list(tasks)
    rng.shuffle(order)
    mb = Mailbox(stage=0)
    for t in order:
        mb.deliver(Envelope(task=t, src_stage=1, dst_stage=0))
    # per-kind buffers preserve delivery order exactly
    for kind in Kind:
        assert mb.buffers[kind] == [t for t in order if t.kind == kind]
    # arrived_tasks is the per-kind concatenation in Kind order
    assert mb.arrived_tasks() == [t for kind in Kind
                                  for t in order if t.kind == kind]


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 12),
       k=st.integers(1, 8))
def test_mailbox_consume_is_exact(seed, n, k):
    """Consuming removes exactly the requested task, preserving the rest."""
    rng = np.random.default_rng([0xC0, seed])
    tasks = _ready_set(seed, n, split=False)
    mb = Mailbox(stage=0)
    for t in tasks:
        mb.deliver(Envelope(task=t, src_stage=1, dst_stage=0,
                            payload=("p", t)))
    victim = tasks[k % len(tasks)]
    assert mb.consume(victim) == ("p", victim)
    remaining = mb.arrived_tasks()
    assert victim not in remaining
    assert sorted(remaining) == sorted(t for t in tasks if t != victim)


# ---------------------------------------------------------------------------
# TP gate: admission at last rank, any permutation
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(tp=st.integers(2, 5), perm_seed=st.integers(0, 10_000))
def test_tp_admission_permutation_invariant(tp, perm_seed):
    task = Task(Kind.F, 0, 3)
    envs = envelopes_for(task, src_stage=1, tp_degree=tp)
    rng = np.random.default_rng([perm_seed, tp])
    order = list(range(tp))
    rng.shuffle(order)
    g = TPGroup(stage=0, tp_degree=tp)
    for i, rank_i in enumerate(order):
        adm = g.offer(envs[rank_i], now=float(i))
        if i < tp - 1:
            assert adm is None, "admitted before all ranks arrived"
        else:
            assert adm is not None and adm.task == task
            assert adm.spread == float(tp - 1)  # first at 0, last at tp-1


# ---------------------------------------------------------------------------
# epoch fencing: total under any interleaving
# ---------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 14),
       mb_epoch=st.integers(1, 3), tp=st.integers(1, 3),
       perm_seed=st.integers(0, 10_000))
def test_epoch_fencing_is_total(seed, n, mb_epoch, tp, perm_seed):
    """Fencing is total: under *any* interleaving of stale and live
    envelopes, every envelope whose epoch is older than the mailbox's is
    dropped before the TP admission gate, and every live one (same or newer
    epoch) admits normally — fencing never loses a live message and never
    leaks a stale one into a respawned incarnation's buffers."""
    rng = np.random.default_rng([0xFE2CE, seed])
    tasks = _ready_set(seed, n, split=True)
    # per-task epoch: some strictly below the mailbox's (stale stragglers
    # from a pre-failure incarnation), some at or above it
    epoch_of = {t: int(rng.integers(0, mb_epoch + 2)) for t in tasks}
    envs = [env for t in tasks
            for env in envelopes_for(t, src_stage=1, tp_degree=tp,
                                     epoch=epoch_of[t])]
    prng = np.random.default_rng([perm_seed, n, tp])
    prng.shuffle(envs)
    mb = Mailbox(stage=0, tp_degree=tp)
    mb.epoch = mb_epoch
    for env in envs:
        mb.deliver(env)
    live = {t for t in tasks if epoch_of[t] >= mb_epoch}
    stale_envs = sum(1 for env in envs if env.epoch < mb_epoch)
    # exactly the stale envelopes fenced: no live message dropped
    assert mb.fenced == stale_envs
    # exactly the live tasks admitted: no stale message leaked
    assert set(mb.arrived_tasks()) == live
    assert mb.group.admitted == len(live)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), tp=st.integers(1, 3),
       dup=st.integers(1, 3))
def test_stale_duplicates_fenced_after_live_admission(seed, tp, dup):
    """An old-epoch duplicate arriving *after* its task was admitted at the
    live epoch is still fenced — it can neither re-admit the task nor
    overwrite the admitted payload."""
    task = Task(Kind.F, 0, 2)
    mb = Mailbox(stage=0, tp_degree=tp)
    mb.epoch = 1
    for env in envelopes_for(task, src_stage=1, tp_degree=tp,
                             payload="live", epoch=1):
        mb.deliver(env)
    assert mb.arrived_tasks() == [task]
    for _ in range(dup):
        for env in envelopes_for(task, src_stage=1, tp_degree=tp,
                                 payload="stale", epoch=0):
            mb.deliver(env)
    assert mb.fenced == dup * tp
    assert mb.arrived_tasks() == [task]  # no re-admission
    assert mb.payloads[task][1] == "live"


@settings(max_examples=30, deadline=None)
@given(tp=st.integers(1, 4), dup=st.integers(1, 3))
def test_tp_gate_duplicate_envelopes_never_readmit(tp, dup):
    """Delivering every rank's envelope `dup`+1 times admits exactly once."""
    task = Task(Kind.B, 0, 1)
    envs = envelopes_for(task, src_stage=1, tp_degree=tp)
    g = TPGroup(stage=0, tp_degree=tp)
    admissions = 0
    for _round in range(dup + 1):
        for env in envs:
            if g.offer(env, now=1.0) is not None:
                admissions += 1
    assert admissions == 1
    assert g.admitted == 1
    assert g.duplicates == (dup + 1) * tp - tp
