"""xLSTM blocks (mLSTM matrix-memory + sLSTM scalar-memory) in pure JAX.

mLSTM trains with the stabilized parallel (quadratic) form and decodes with
the O(1) recurrent state (C [hd, hd], n [hd], m scalar per head) — so
long_500k decode is sequence-length-free.  sLSTM is inherently sequential
(recurrent weights) and trains with a lax.scan over time.

Layer pattern follows the paper's xLSTM[7:1] notation: 7 mLSTM per 1 sLSTM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, dense_init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm_layer(keys, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "ln": jnp.zeros((d,), cfg.dtype),
        "wq": dense_init(next(keys), (d, d), cfg.dtype),
        "wk": dense_init(next(keys), (d, d), cfg.dtype),
        "wv": dense_init(next(keys), (d, d), cfg.dtype),
        "wi": dense_init(next(keys), (d, cfg.num_heads), cfg.dtype),  # input gate
        "wf": dense_init(next(keys), (d, cfg.num_heads), cfg.dtype),  # forget gate
        "bi": jnp.zeros((cfg.num_heads,), jnp.float32),
        "bf": jnp.full((cfg.num_heads,), 3.0, jnp.float32),  # open at init
        "gate_ln": jnp.zeros((d,), cfg.dtype),
        "wo": dense_init(next(keys), (d, d), cfg.dtype),
    }


def _mlstm_gates(p, h):
    """h: [b, s, d] -> (log_i, log_f): [b, s, nh] in fp32."""
    i_pre = (h @ p["wi"]).astype(jnp.float32) + p["bi"]
    f_pre = (h @ p["wf"]).astype(jnp.float32) + p["bf"]
    log_f = -jax.nn.softplus(-f_pre)  # log sigmoid(f): stable
    return i_pre, log_f


def mlstm_parallel(q, k, v, i_pre, log_f):
    """Stabilized parallel mLSTM.

    q,k,v: [b, s, nh, hd]; i_pre, log_f: [b, s, nh].
    D[t,j] = sum_{j<u<=t} log_f[u] + i_pre[j]  (j <= t), -inf otherwise;
    h_t = sum_j exp(D[t,j] - m_t) (q_t . k_j / sqrt(hd)) v_j
          / max(|sum_j exp(D-m) q.k|, exp(-m_t)).
    """
    b, s, nh, hd = q.shape
    qf = q.astype(jnp.float32) * hd**-0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    cum_f = jnp.cumsum(log_f, axis=1)  # [b, s, nh]
    # D[t,j] = cum_f[t] - cum_f[j] + i_pre[j]
    dmat = (
        cum_f[:, :, None, :] - cum_f[:, None, :, :] + i_pre[:, None, :, :]
    )  # [b, t, j, nh]
    tt = jnp.arange(s)
    mask = tt[:, None] >= tt[None, :]
    dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2)  # [b, t, nh] row stabilizer
    w = jnp.exp(dmat - m[:, :, None, :])  # [b, t, j, nh]
    scores = jnp.einsum("btnd,bjnd->btjn", qf, kf) * w
    num = jnp.einsum("btjn,bjnd->btnd", scores, vf)
    den = jnp.abs(scores.sum(axis=2))  # [b, t, nh]
    den = jnp.maximum(den, jnp.exp(-m))
    return (num / den[..., None]).astype(q.dtype)


def mlstm_chunked(q, k, v, i_pre, log_f, chunk: int = 128):
    """Chunkwise-stabilized mLSTM: intra-chunk quadratic + inter-chunk
    (C, n, m) state passing — O(s·chunk) memory, matches ``mlstm_parallel``.

    The stabilizer recurrence m_t = max(a_t + m_{t-1}, i_t) unrolls to
    m_t = max_j (A_t - A_j + i_j) over j <= t; across chunk boundaries the
    earlier-j part is folded into m_prev + A_t.
    """
    b, s, nh, hd = q.shape
    pad = (-s) % chunk
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        z3 = ((0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, z4)
        k = jnp.pad(k, z4)
        v = jnp.pad(v, z4)
        i_pre = jnp.pad(i_pre, z3, constant_values=NEG_INF_GATE)
        log_f = jnp.pad(log_f, z3)
    sp = q.shape[1]
    nc = sp // chunk
    qf = (q.astype(jnp.float32) * hd**-0.5).reshape(b, nc, chunk, nh, hd)
    kf = k.astype(jnp.float32).reshape(b, nc, chunk, nh, hd)
    vf = v.astype(jnp.float32).reshape(b, nc, chunk, nh, hd)
    ip = i_pre.reshape(b, nc, chunk, nh)
    lf = log_f.reshape(b, nc, chunk, nh)
    A = jnp.cumsum(lf, axis=2)  # inclusive within-chunk cum log-forget
    A_last = A[:, :, -1]  # [b, nc, nh]

    # ---- intra-chunk quantities -----------------------------------------
    # D[t,j] = A_t - A_j + i_j (j <= t)
    dmat = A[:, :, :, None, :] - A[:, :, None, :, :] + ip[:, :, None, :, :]
    tt = jnp.arange(chunk)
    tri = tt[:, None] >= tt[None, :]
    dmat = jnp.where(tri[None, None, :, :, None], dmat, -jnp.inf)
    m_intra = jnp.max(dmat, axis=3)  # [b, nc, Q, nh]
    # per-chunk boundary input magnitude: max_j (A_last - A_j + i_j)
    m_in = jnp.max(A_last[:, :, None, :] - A + ip, axis=2)  # [b, nc, nh]

    # ---- inter-chunk state scan ------------------------------------------
    def scan_fn(carry, inp):
        C, n, m = carry  # scaled by exp(-m)
        a_last, m_in_c, kc, vc, Ac, ipc = inp
        m_out = carry[2]
        # emit state entering this chunk
        emit = (C, n, m)
        m_new = jnp.maximum(a_last + m, m_in_c)  # [b, nh]
        w_old = jnp.exp(a_last + m - m_new)
        wj = jnp.exp(a_last[:, None] - Ac + ipc - m_new[:, None])  # [b,Q,nh]
        C_new = C * w_old[..., None, None] + jnp.einsum(
            "bjnd,bjne,bjn->bnde", vc, kc, wj)
        n_new = n * w_old[..., None] + jnp.einsum("bjne,bjn->bne", kc, wj)
        return (C_new, n_new, m_new), emit

    C0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, nh, hd), jnp.float32)
    m0 = jnp.full((b, nh), -jnp.inf, jnp.float32)
    xs = (jnp.moveaxis(A_last, 1, 0), jnp.moveaxis(m_in, 1, 0),
          jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0),
          jnp.moveaxis(A, 1, 0), jnp.moveaxis(ip, 1, 0))
    _, (C_in, n_in, m_prev) = jax.lax.scan(scan_fn, (C0, n0, m0), xs)
    C_in = jnp.moveaxis(C_in, 0, 1)  # [b, nc, nh, hd, hd]
    n_in = jnp.moveaxis(n_in, 0, 1)
    m_prev = jnp.moveaxis(m_prev, 0, 1)  # [b, nc, nh]

    # ---- combine ----------------------------------------------------------
    m_inter = m_prev[:, :, None, :] + A  # [b, nc, Q, nh]
    m_tot = jnp.maximum(m_intra, m_inter)
    m_tot = jnp.maximum(m_tot, -1e30)  # guard -inf - -inf
    w_intra = jnp.where(tri[None, None, :, :, None],
                        jnp.exp(dmat - m_tot[:, :, :, None, :]), 0.0)
    scores = jnp.einsum("bctnd,bcjnd->bctjn", qf, kf) * w_intra
    num = jnp.einsum("bctjn,bcjnd->bctnd", scores, vf)
    den = scores.sum(axis=3)  # [b, nc, Q, nh]
    w_int = jnp.exp(m_inter - m_tot)  # [b, nc, Q, nh]
    num = num + jnp.einsum(
        "bctne,bcnde,bctn->bctnd", qf, C_in, w_int)
    den = den + jnp.einsum("bctnd,bcnd->bctn", qf, n_in) * w_int
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))
    y = (num / den[..., None]).reshape(b, sp, nh, hd)
    return y[:, :s].astype(q.dtype)


NEG_INF_GATE = -1e30


def mlstm_layer(p, x, cfg: ArchConfig, chunk: int = 128):
    from repro.models.layers import rmsnorm

    b, s, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(b, s, nh, hd)
    k = (h @ p["wk"]).reshape(b, s, nh, hd)
    v = (h @ p["wv"]).reshape(b, s, nh, hd)
    i_pre, log_f = _mlstm_gates(p, h)
    if s <= 2 * chunk:
        y = mlstm_parallel(q, k, v, i_pre, log_f).reshape(b, s, d)
    else:
        y = mlstm_chunked(q, k, v, i_pre, log_f, chunk=chunk).reshape(b, s, d)
    y = rmsnorm(y, p["gate_ln"], cfg.norm_eps)
    return x + y @ p["wo"]


def init_mlstm_cache(batch: int, cfg: ArchConfig):
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -jnp.inf, jnp.float32),
    }


def mlstm_layer_decode(p, x, cache, cfg: ArchConfig):
    """Recurrent mLSTM step.  x: [b, 1, d]."""
    from repro.models.layers import rmsnorm

    b, _, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    h = rmsnorm(x, p["ln"], cfg.norm_eps)[:, 0]
    q = (h @ p["wq"]).reshape(b, nh, hd).astype(jnp.float32) * hd**-0.5
    k = (h @ p["wk"]).reshape(b, nh, hd).astype(jnp.float32)
    v = (h @ p["wv"]).reshape(b, nh, hd).astype(jnp.float32)
    i_pre = (h @ p["wi"]).astype(jnp.float32) + p["bi"]  # [b, nh]
    f_pre = (h @ p["wf"]).astype(jnp.float32) + p["bf"]
    log_f = -jax.nn.softplus(-f_pre)
    m_prev, C_prev, n_prev = cache["m"], cache["C"], cache["n"]
    m_new = jnp.maximum(log_f + m_prev, i_pre)
    f_sc = jnp.exp(log_f + m_prev - m_new)[..., None]
    i_sc = jnp.exp(i_pre - m_new)[..., None]
    C_new = f_sc[..., None] * C_prev + i_sc[..., None] * jnp.einsum(
        "bnd,bne->bnde", v, k
    )
    n_new = f_sc * n_prev + i_sc * k
    num = jnp.einsum("bnde,bne->bnd", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bnd,bnd->bn", n_new, q)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, d).astype(x.dtype)
    y = rmsnorm(y, p["gate_ln"], cfg.norm_eps)
    out = x + (y @ p["wo"])[:, None]
    return out, {"C": C_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm_layer(keys, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    return {
        "ln": jnp.zeros((d,), cfg.dtype),
        "wz": dense_init(next(keys), (d, d), cfg.dtype),
        "wi": dense_init(next(keys), (d, d), cfg.dtype),
        "wf": dense_init(next(keys), (d, d), cfg.dtype),
        "wo_gate": dense_init(next(keys), (d, d), cfg.dtype),
        # block-diagonal recurrent weights: [nh, hd, hd] per gate
        "rz": dense_init(next(keys), (nh, hd, hd), cfg.dtype, scale=0.02),
        "ri": dense_init(next(keys), (nh, hd, hd), cfg.dtype, scale=0.02),
        "rf": dense_init(next(keys), (nh, hd, hd), cfg.dtype, scale=0.02),
        "ro": dense_init(next(keys), (nh, hd, hd), cfg.dtype, scale=0.02),
        "bf": jnp.full((d,), 3.0, jnp.float32),
        "gate_ln": jnp.zeros((d,), cfg.dtype),
        "wo": dense_init(next(keys), (d, d), cfg.dtype),
    }


def init_slstm_cache(batch: int, cfg: ArchConfig):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_step(p, cfg, state, inp):
    """One recurrence step.  inp: pre-computed input projections [b, 4, d]."""
    nh = cfg.num_heads
    d = cfg.d_model
    hd = d // nh
    c, n, h, m = state
    hb = h.reshape(-1, nh, hd)
    rec = lambda r: jnp.einsum("bnd,nde->bne", hb, r.astype(jnp.float32)).reshape(-1, d)
    z_pre = inp[:, 0] + rec(p["rz"])
    i_pre = inp[:, 1] + rec(p["ri"])
    f_pre = inp[:, 2] + rec(p["rf"]) + p["bf"]
    o_pre = inp[:, 3] + rec(p["ro"])
    z = jnp.tanh(z_pre)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(log_f + m - m_new)
    c_new = f_sc * c + i_sc * z
    n_new = f_sc * n + i_sc
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_layer(p, x, cfg: ArchConfig):
    from repro.models.layers import rmsnorm

    b, s, d = x.shape
    h0 = rmsnorm(x, p["ln"], cfg.norm_eps)
    inp = jnp.stack(
        [h0 @ p["wz"], h0 @ p["wi"], h0 @ p["wf"], h0 @ p["wo_gate"]], axis=2
    ).astype(jnp.float32)  # [b, s, 4, d]
    state = (
        jnp.zeros((b, d), jnp.float32),
        jnp.ones((b, d), jnp.float32),
        jnp.zeros((b, d), jnp.float32),
        jnp.zeros((b, d), jnp.float32),
    )
    step = lambda st, i: _slstm_step(p, cfg, st, i)
    _, hs = jax.lax.scan(step, state, jnp.moveaxis(inp, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # [b, s, d]
    y = rmsnorm(y, p["gate_ln"], cfg.norm_eps)
    return x + y @ p["wo"]


def slstm_layer_decode(p, x, cache, cfg: ArchConfig):
    from repro.models.layers import rmsnorm

    h0 = rmsnorm(x, p["ln"], cfg.norm_eps)[:, 0]
    inp = jnp.stack(
        [h0 @ p["wz"], h0 @ p["wi"], h0 @ p["wf"], h0 @ p["wo_gate"]], axis=1
    ).astype(jnp.float32)  # [b, 4, d]
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, h, m), y = _slstm_step(p, cfg, state, inp)
    y = rmsnorm(y.astype(x.dtype), p["gate_ln"], cfg.norm_eps)
    out = x + (y @ p["wo"])[:, None]
    return out, {"c": c, "n": n, "h": h, "m": m}
