"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-grad / decode step on CPU, asserting shapes + finiteness (deliverable f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.build import build

ASSIGNED = [a for a in registry.ARCHS if not a.startswith("paper-")]


def make_inputs(cfg, b=2, s=32):
    batch = {
        "tokens": jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.key(4), (b, s), 0, cfg.vocab_size),
    }
    aux = {
        "positions": jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
        "data_size": 1,
        "moe_layout": "none",
    }
    if cfg.embed_input:
        batch["embeds"] = (
            jax.random.normal(jax.random.key(3), (b, s, cfg.d_model)) * 0.02
        )
    if cfg.mrope:
        aux["mrope"] = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
    if cfg.encoder_layers:
        aux["dec_len"] = s // 2
    return batch, aux


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ASSIGNED:
        cfg = registry.reduced_config(name, num_layers=6)
        m = build(cfg, num_stages=4)
        key = jax.random.key(0)
        out[name] = (
            m,
            m.init_stage_params(key),
            m.init_io_params(jax.random.fold_in(key, 1)),
        )
    return out


@pytest.mark.parametrize("name", ASSIGNED)
def test_forward_shapes_and_finite(built, name):
    m, sp, io = built[name]
    cfg = m.cfg
    batch, aux = make_inputs(cfg)
    logits = m.reference_forward(sp, io, batch, aux)
    assert logits.shape == (2, 32, cfg.padded_vocab())
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_grad_step(built, name):
    """One loss+grad step: grads finite, loss finite (smoke 'train step')."""
    m, sp, io = built[name]
    cfg = m.cfg
    batch, aux = make_inputs(cfg)

    def loss_fn(sp, io):
        logits = m.reference_forward(sp, io, batch, aux).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, batch["labels"][..., None], axis=-1
        )[..., 0]
        return (lse - picked).mean()

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(sp, io)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in leaves)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in leaves)


@pytest.mark.parametrize("name", ASSIGNED)
def test_decode_step(built, name):
    """One-token decode against a warm cache (smoke 'serve step')."""
    m, sp, io = built[name]
    cfg = m.cfg
    b, cache_len = 2, 16
    x = jax.random.normal(jax.random.key(5), (b, 1, cfg.d_model)).astype(cfg.dtype) * 0.1
    aux = {"data_size": 1, "moe_layout": "none"}
    caches = [m.init_layer_cache(b, cache_len, enc_len=8) for _ in range(m.l_max)]
    stage_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    pos = jnp.asarray(3, jnp.int32)
    # use the last stage: encoder-only stages (seamless) are inert at decode
    last = m.num_stages - 1
    sp0 = jax.tree.map(lambda p: p[last], sp)
    y, new_cache = m.stage_decode(sp0, io, x, stage_cache, pos, aux, m.rows(last))
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())
    # cache must actually change for enabled slots
    changed = jax.tree.map(
        lambda a, b_: float(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)).max()),
        stage_cache, new_cache)
    assert max(jax.tree.leaves(changed)) > 0


@pytest.mark.parametrize("name", ASSIGNED)
def test_exact_assigned_config_is_registered(name):
    cfg = registry.get_arch(name)
    spec = {
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "seamless-m4t-large-v2": (48, 1024, 16, 16, 8192, 256206),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }[name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    # seamless: the 24L assignment is applied per enc/dec half
    if name == "seamless-m4t-large-v2":
        assert cfg.encoder_layers == 24 and cfg.num_layers == 48
        got = (48,) + got[1:]
    assert got == spec


def test_moe_configs():
    g = registry.get_arch("grok-1-314b")
    assert (g.moe.num_experts, g.moe.top_k) == (8, 2)
    d = registry.get_arch("deepseek-moe-16b")
    assert (d.moe.num_experts, d.moe.top_k, d.moe.num_shared) == (64, 6, 2)


def test_param_counts_near_nameplate():
    expect = {
        "granite-34b": 34e9, "gemma3-4b": 4.3e9, "deepseek-7b": 7e9,
        "grok-1-314b": 314e9, "deepseek-moe-16b": 16.4e9, "zamba2-1.2b": 1.2e9,
    }
    for name, n in expect.items():
        got = registry.get_arch(name).param_count()
        assert abs(got - n) / n < 0.15, (name, got, n)


def test_stage_layout_uneven_division():
    """88 layers over 16 stages: enabled flags mask the padding slots."""
    m = build(registry.get_arch("granite-34b"), num_stages=16)
    assert m.counts.sum() == 88
    assert m.l_max == 6
    assert ((m.type_ids >= 0).sum()) == 88
