"""Production mesh builders.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state.
"""
from __future__ import annotations

from repro.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(data: int, model: int, pods: int = 1):
    """Arbitrary (pod ×) data × model mesh for tests / reduced runs."""
    if pods > 1:
        return _make_mesh((pods, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))
