"""Transformer building blocks in pure JAX.

Attention is implemented flash-style even on the XLA path: an online-softmax
scan over KV blocks (``blocked_attention``) so that 32k-token prefill never
materializes an S×S score matrix.  The Pallas kernels in ``repro.kernels``
implement the same contract for the TPU hot path; ``repro.kernels.ops``
dispatches between them.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig, dense_init


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [b, s, h, hd]; positions: [b, s] (int)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [b, s, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 10_000.0, sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE: positions3 [3, b, s] (t/h/w axes).

    The rotary dimension is split into three sections, each rotated by its
    own position stream.  ``sections`` are half-dim sizes summing to hd/2.
    """
    hd = x.shape[-1]
    secs = np.asarray(sections, dtype=np.int64)
    if secs.sum() * 2 != hd:  # reduced configs: rescale proportionally
        secs = np.maximum(1, (secs * (hd // 2) / secs.sum()).astype(np.int64))
        secs[-1] = hd // 2 - secs[:-1].sum()
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)  # [hd/2]
    parts = np.concatenate([[0], np.cumsum(secs)])
    ang_parts = []
    for i in range(3):
        f = freqs[parts[i] : parts[i + 1]]
        ang_parts.append(positions3[i][..., None].astype(jnp.float32) * f)
    ang = jnp.concatenate(ang_parts, axis=-1)  # [b, s, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked (flash-style) attention — XLA path
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def _block_mask(q_pos, k_pos, causal: bool, window: int):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def blocked_attention(q, k, v, positions, causal=True, window=0, block=512):
    """Online-softmax attention over KV blocks (flash-style, XLA path).

    q: [b, sq, hq, hd]; k, v: [b, sk, hkv, hd]; positions: [b, sq] absolute
    query positions (for decode, the current position).  GQA: hq % hkv == 0.
    Custom VJP: forward saves only (q, k, v, out, lse); backward streams over
    KV blocks recomputing p from the saved log-sum-exp — O(s·d) residency
    instead of the O(s²) scan residuals naive autodiff would save.
    """
    out, _ = _blocked_attention_fwd_impl(q, k, v, positions, causal, window,
                                         block)
    return out


def _blocked_attention_fwd_impl(q, k, v, positions, causal, window, block):
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = hd ** -0.5
    qf = (q * scale).astype(q.dtype).reshape(b, sq, hkv, g, hd)
    qf = jnp.einsum("bqkgd->bkgqd", qf)
    nblk = -(-sk // block)
    pad = nblk * block - sk
    kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kf = kf.reshape(b, nblk, block, hkv, hd)
    vf = vf.reshape(b, nblk, block, hkv, hd)
    q_pos = positions  # [b, sq]

    def body(carry, blk):
        m_i, l_i, acc = carry
        k_b, v_b, kpos_b = blk  # [b, block, hkv, hd], [block]
        s = jnp.einsum("bkgqd,bjkd->bkgqj", qf, k_b,
                       preferred_element_type=jnp.float32)
        mask = _stream_mask(q_pos, kpos_b, causal, window, sk)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqj,bjkd->bkgqd", p.astype(v_b.dtype), v_b,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    kf_t = jnp.moveaxis(kf, 1, 0)
    vf_t = jnp.moveaxis(vf, 1, 0)
    kpos = jnp.arange(nblk * block).reshape(nblk, block)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kf_t, vf_t, kpos))
    l_safe = jnp.maximum(l_f, 1e-30)
    out = acc / l_safe[..., None]
    lse = m_f + jnp.log(l_safe)  # [b, hkv, g, sq]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, hd)
    return out.astype(q.dtype), lse


def _stream_mask(q_pos, kpos_b, causal, window, sk):
    b, sq = q_pos.shape
    block = kpos_b.shape[0]
    mask = (q_pos[:, :, None] >= kpos_b[None, None, :]) if causal else (
        jnp.ones((b, sq, block), jnp.bool_))
    if window > 0:
        mask &= q_pos[:, :, None] - kpos_b[None, None, :] < window
    mask &= (kpos_b < sk)[None, None, :]
    return mask


def _blocked_attention_fwd(q, k, v, positions, causal, window, block):
    out, lse = _blocked_attention_fwd_impl(q, k, v, positions, causal, window,
                                           block)
    return out, (q, k, v, positions, out, lse)


def _blocked_attention_bwd(causal, window, block, res, dout):
    q, k, v, positions, out, lse = res
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = hd ** -0.5
    qf = jnp.einsum(
        "bqkgd->bkgqd", (q * scale).astype(q.dtype).reshape(b, sq, hkv, g, hd))
    do = jnp.einsum("bqkgd->bkgqd", dout.reshape(b, sq, hkv, g, hd))
    of = jnp.einsum("bqkgd->bkgqd", out.reshape(b, sq, hkv, g, hd))
    delta = jnp.sum(do.astype(jnp.float32) * of.astype(jnp.float32),
                    axis=-1)  # [b, hkv, g, sq]
    nblk = -(-sk // block)
    pad = nblk * block - sk
    kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kf = jnp.moveaxis(kf.reshape(b, nblk, block, hkv, hd), 1, 0)
    vf = jnp.moveaxis(vf.reshape(b, nblk, block, hkv, hd), 1, 0)
    kpos = jnp.arange(nblk * block).reshape(nblk, block)
    q_pos = positions

    def body(dq_acc, blk):
        k_b, v_b, kpos_b = blk
        s = jnp.einsum("bkgqd,bjkd->bkgqj", qf, k_b,
                       preferred_element_type=jnp.float32)
        mask = _stream_mask(q_pos, kpos_b, causal, window, sk)
        p = jnp.where(mask[:, None, None], jnp.exp(s - lse[..., None]), 0.0)
        p_c = p.astype(k_b.dtype)
        dv_b = jnp.einsum("bkgqj,bkgqd->bjkd", p_c, do,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bkgqd,bjkd->bkgqj", do, v_b,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - delta[..., None])).astype(k_b.dtype)
        dq_acc = dq_acc + jnp.einsum("bkgqj,bjkd->bkgqd", ds, k_b,
                                     preferred_element_type=jnp.float32)
        dk_b = jnp.einsum("bkgqj,bkgqd->bjkd", ds, qf,
                          preferred_element_type=jnp.float32)
        return dq_acc, (dk_b, dv_b)

    dq0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(body, dq0, (kf, vf, kpos))
    dq = (jnp.moveaxis(dq, 3, 1).reshape(b, sq, hq, hd) * scale).astype(q.dtype)
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(b, nblk * block, hkv, hd)
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(b, nblk * block, hkv, hd)
    dk = dk[:, :sk].astype(k.dtype)
    dv = dv[:, :sk].astype(v.dtype)
    return dq, dk, dv, None


blocked_attention.defvjp(_blocked_attention_fwd, _blocked_attention_bwd)


def decode_attention(q, k_cache, v_cache, lengths, window: int = 0):
    """Single-position attention against a cache.

    q: [b, 1, hq, hd]; caches: [b, S, hkv, hd]; lengths: [b] valid lengths.
    """
    b, _, hq, hd = q.shape
    _, S, hkv, _ = k_cache.shape
    g = hq // hkv
    qf = (q.astype(jnp.float32) * hd**-0.5).reshape(b, hkv, g, hd)
    s = jnp.einsum("bkgd,bjkd->bkgj", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)[None, :]
    mask = pos < lengths[:, None]
    if window > 0:
        mask &= pos >= (lengths[:, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (GQA, optional bias / sliding window / M-RoPE)
# ---------------------------------------------------------------------------
def init_attention(keys, cfg: ArchConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    p = {
        "wq": dense_init(next(keys), (d, nq * hd), cfg.dtype),
        "wk": dense_init(next(keys), (d, nkv * hd), cfg.dtype),
        "wv": dense_init(next(keys), (d, nkv * hd), cfg.dtype),
        "wo": dense_init(next(keys), (nq * hd, d), cfg.dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((nq * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((nkv * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((nkv * hd,), cfg.dtype)
    return p


def attention_qkv(p, x, kv_src, cfg: ArchConfig):
    b, s, _ = x.shape
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = kv_src @ p["wk"]
    v = kv_src @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nq, hd)
    k = k.reshape(b, kv_src.shape[1], nkv, hd)
    v = v.reshape(b, kv_src.shape[1], nkv, hd)
    return q, k, v


def attention_block(p, x, positions, cfg: ArchConfig, *, causal=True, window=0,
                    mrope_pos=None, kv_src=None, rope: bool = True):
    """Self- (or cross-) attention sub-block, pre-norm residual handled by caller."""
    kv_src = x if kv_src is None else kv_src
    q, k, v = attention_qkv(p, x, kv_src, cfg)
    if rope:
        if cfg.mrope and mrope_pos is not None:
            q = apply_mrope(q, mrope_pos, cfg.rope_theta)
            k = apply_mrope(k, mrope_pos, cfg.rope_theta)
        else:
            kv_positions = positions if kv_src is x else jnp.broadcast_to(
                jnp.arange(kv_src.shape[1])[None], kv_src.shape[:2])
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, kv_positions, cfg.rope_theta)
    from repro.kernels import ops  # late import; dispatches XLA vs Pallas
    o = ops.flash_attention(q, k, v, positions, causal=causal, window=window)
    b, s = x.shape[:2]
    return o.reshape(b, s, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------
def init_ffn(keys, cfg: ArchConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {
            "wi": dense_init(next(keys), (d, f), cfg.dtype),
            "wg": dense_init(next(keys), (d, f), cfg.dtype),
            "wo": dense_init(next(keys), (f, d), cfg.dtype),
        }
    return {
        "wi": dense_init(next(keys), (d, f), cfg.dtype),
        "wo": dense_init(next(keys), (f, d), cfg.dtype),
    }


def ffn_block(p, x, act: str):
    h = x @ p["wi"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Standard decoder layer (attn + ffn, pre-norm)
# ---------------------------------------------------------------------------
def init_decoder_layer(keys, cfg: ArchConfig, d_ff: int | None = None) -> dict:
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": init_attention(keys, cfg),
        "ln2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ffn": init_ffn(keys, cfg, d_ff),
    }


def decoder_layer(p, x, positions, cfg: ArchConfig, *, causal=True, window=0,
                  mrope_pos=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + attention_block(p["attn"], h, positions, cfg, causal=causal,
                            window=window, mrope_pos=mrope_pos)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + ffn_block(p["ffn"], h, cfg.act)


# ---------------------------------------------------------------------------
# KV-cache decode variants
# ---------------------------------------------------------------------------
def decode_attention_block(p, x, cache, pos, cfg: ArchConfig, window=0,
                           axis_name: str | None = None):
    """One-token attention with cache update.

    cache: dict(k=[b,S,hkv,hd], v=[b,S,hkv,hd]); pos: [] scalar current index.
    If ``axis_name`` is set the cache's S dim is sharded over that axis
    (sequence parallelism for long_500k) and softmax is combined with psum.
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = attention_qkv(p, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)

    if axis_name is None:
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, pos, 0, 0))
        lengths = jnp.full((b,), pos + 1, jnp.int32)
        o = decode_attention(q, k_cache, v_cache, lengths, window=window)
    else:
        # Sequence-parallel cache: shard_size rows per device.
        shard = cache["k"].shape[1]
        idx = jax.lax.axis_index(axis_name)
        local_pos = pos - idx * shard
        in_range = (local_pos >= 0) & (local_pos < shard)
        upd_pos = jnp.clip(local_pos, 0, shard - 1)
        k_upd = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, upd_pos, 0, 0))
        v_upd = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, upd_pos, 0, 0))
        k_cache = jnp.where(in_range, k_upd, cache["k"])
        v_cache = jnp.where(in_range, v_upd, cache["v"])
        # distributed flash-decode: local partial softmax + psum combine
        hkv = k_cache.shape[2]
        hd = k_cache.shape[3]
        g = q.shape[2] // hkv
        qf = (q.astype(jnp.float32) * hd**-0.5).reshape(b, hkv, g, hd)
        s = jnp.einsum("bkgd,bjkd->bkgj", qf, k_cache.astype(jnp.float32))
        kpos = idx * shard + jnp.arange(shard)
        mask = kpos[None, :] <= pos
        if window > 0:
            mask &= kpos[None, :] > pos - window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_loc = s.max(-1)
        m_glob = jax.lax.pmax(m_loc, axis_name)
        p_ = jnp.exp(s - m_glob[..., None])
        num = jnp.einsum("bkgj,bjkd->bkgd", p_, v_cache.astype(jnp.float32))
        den = p_.sum(-1)
        num = jax.lax.psum(num, axis_name)
        den = jax.lax.psum(den, axis_name)
        o = (num / jnp.maximum(den[..., None], 1e-30)).reshape(b, 1, -1)
        o = o.astype(x.dtype)
    o = o.reshape(b, 1, -1) @ p["wo"]
    return o, {"k": k_cache, "v": v_cache}


def decoder_layer_decode(p, x, cache, pos, cfg: ArchConfig, window=0,
                         axis_name=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, cache = decode_attention_block(p["attn"], h, cache, pos, cfg,
                                      window=window, axis_name=axis_name)
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + ffn_block(p["ffn"], h, cfg.act), cache
