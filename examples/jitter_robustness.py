"""RQ4 demo: inject the paper's EMA-based compute jitter (J0-J3) and compare
how pre-committed 1F1B vs RRFP degrade (Table 6).

    PYTHONPATH=src python examples/jitter_robustness.py
"""
import dataclasses

from repro.core import (
    CostModel, EngineConfig, INJECTION_LEVELS, PipelineSpec,
    average_makespan, multimodal_stage_flops,
)

S, M = 8, 48
spec = PipelineSpec(S, M)
base = CostModel.from_stage_flops(
    multimodal_stage_flops(6e12, 2.5e12, S), comm_base=2e-3)

print(f"{'level':>6} {'1F1B (s)':>10} {'slow%':>7} {'RRFP (s)':>10} {'slow%':>7}")
bases = {}
for level, inj in INJECTION_LEVELS.items():
    costs = dataclasses.replace(base, injection=inj)
    row = [level]
    for meth, cfg in (("1f1b", EngineConfig(mode="precommitted",
                                            fixed_order="1f1b")),
                      ("rrfp", EngineConfig(mode="hint"))):
        mean, _, _ = average_makespan(spec, costs, cfg, iters=3)
        bases.setdefault(meth, mean)
        row += [mean, 100 * (mean / bases[meth] - 1)]
    print(f"{row[0]:>6} {row[1]:>10.3f} {row[2]:>+6.2f}% {row[3]:>10.3f} "
          f"{row[4]:>+6.2f}%")
print("\nRRFP degrades more slowly with jitter level — the paper's RQ4 claim.")
