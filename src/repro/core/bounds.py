"""Analytical characterization of the BF hint (§6, Appendix B).

Computes, from realized per-task durations:

* the forward-only / backward-only reference makespans F̃ and B̃ (pipelined
  recurrences respecting inter-stage dependencies),
* the Theorem 6.1 upper bound
  ``C <= F + B + sum_{j>=1}(Fmax^j - Flast^j) + sum_{j<=M-2}(Bmax^j - Blast^j)``,
* the universal lower bound ``L = sum_j (Flast^j + Blast^j)`` (any schedule
  must execute all last-stage work),
* the Fig. 6 bottleneck statistics (which stage is the per-microbatch
  bottleneck, and relative latencies vs the last stage).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def reference_makespan(dur: np.ndarray, direction: str) -> float:
    """Makespan of a single-direction pipeline with durations ``dur[s, j]``.

    ``forward``: all microbatches initially available at stage 0;
    ``backward``: all initially available at stage N-1.  Inter-stage
    dependencies respected; each stage serial.
    """
    S, M = dur.shape
    if direction == "backward":
        dur = dur[::-1]  # stage N-1 becomes row 0; recurrence is identical
    e = np.zeros((S, M))
    for i in range(S):
        for j in range(M):
            up = e[i - 1, j] if i > 0 else 0.0
            left = e[i, j - 1] if j > 0 else 0.0
            e[i, j] = max(up, left) + dur[i, j]
    return float(e[-1, -1])


@dataclasses.dataclass
class BoundReport:
    makespan: float
    theorem_rhs: float
    lower_bound: float
    f_ref: float
    b_ref: float
    imbalance_f: float
    imbalance_b: float

    @property
    def holds(self) -> bool:
        return self.makespan <= self.theorem_rhs + 1e-9

    @property
    def ratio_to_lb(self) -> float:
        return self.makespan / max(self.lower_bound, 1e-12)


def check_theorem_6_1(f_dur: np.ndarray, b_dur: np.ndarray, makespan: float) -> BoundReport:
    """Evaluate Theorem 6.1 for one realized iteration.

    ``f_dur`` / ``b_dur`` are [stage, microbatch] realized durations
    (chunk-summed; the analysis setting is non-interleaved).
    """
    S, M = f_dur.shape
    f_ref = reference_makespan(f_dur, "forward")
    b_ref = reference_makespan(b_dur, "backward")
    f_max = f_dur.max(axis=0)
    b_max = b_dur.max(axis=0)
    f_last = f_dur[S - 1]
    b_last = b_dur[S - 1]
    imb_f = float(np.sum(f_max[1:] - f_last[1:]))
    imb_b = float(np.sum(b_max[: M - 1] - b_last[: M - 1]))
    rhs = f_ref + b_ref + imb_f + imb_b
    lb = float(np.sum(f_last + b_last))
    return BoundReport(
        makespan=makespan,
        theorem_rhs=rhs,
        lower_bound=lb,
        f_ref=f_ref,
        b_ref=b_ref,
        imbalance_f=imb_f,
        imbalance_b=imb_b,
    )


def corollary_terms(f_dur: np.ndarray, b_dur: np.ndarray) -> dict[str, float]:
    """Empirical p and rho of Corollary 6.2 from realized durations."""
    S, M = f_dur.shape
    not_last_f = f_dur.max(axis=0) > f_dur[S - 1] + 1e-12
    not_last_b = b_dur.max(axis=0) > b_dur[S - 1] + 1e-12
    p = float((not_last_f.sum() + not_last_b.sum()) / (2 * M))
    with np.errstate(divide="ignore", invalid="ignore"):
        rho_f = np.where(f_dur[S - 1] > 0, f_dur.max(axis=0) / f_dur[S - 1], 1.0)
        rho_b = np.where(b_dur[S - 1] > 0, b_dur.max(axis=0) / b_dur[S - 1], 1.0)
    rho = float(max(rho_f.max(), rho_b.max()))
    return {"p": p, "rho": rho, "cor_bound": 1 + 2 * p * (rho - 1)}


def bottleneck_stats(f_dur: np.ndarray) -> dict[str, np.ndarray]:
    """Fig. 6: per-stage bottleneck share and relative latency percentiles."""
    S, M = f_dur.shape
    argmax = f_dur.argmax(axis=0)
    share = np.bincount(argmax, minlength=S) / M
    rel = f_dur / np.maximum(f_dur[S - 1][None, :], 1e-12)
    pct = np.percentile(rel, [85, 90, 95], axis=1)  # [3, S]
    return {"bottleneck_share": share, "rel_p85_p90_p95": pct}
