"""Schedule-table-driven SPMD pipeline executor (DESIGN §2).

One compiled ``train_step`` executes ANY valid ScheduleTable (1F1B, GPipe,
ZB-lite, RRFP-synthesized): per tick each stage looks up its (op, microbatch)
entry and `lax.switch`es into F / B / W / idle.  Activations and gradients
move on ring collective-permutes (one hop per tick) into slotted on-device
buffers — the compiled analog of the paper's four per-stage message buffers;
buffer capacities come from the table validator (= the App. C limit).

Backward is remat-based: B re-runs the stage forward under ``jax.grad`` of a
scalarized objective (CE at the last stage, <y, g_in> elsewhere), so no
activation stack is kept beyond each microbatch's stage input.

Collective-order consistency across a stage row (the paper's §4.2 constraint)
holds by construction: the table is uniform across the ``data`` axis, so all
ranks of a "TP group" (here: a data row) enter identical branches — data-axis
collectives (MoE all_to_all / vocab CE) are safe inside branches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.build import ArchModel
from repro.pipeline.sharding import ParamPartition, partition_for
from repro.pipeline.spec import OP_B, OP_F, OP_IDLE, OP_W, ScheduleTable
from repro.pipeline.stagefn import chunked_ce_sum, default_ce_chunk


@dataclasses.dataclass(frozen=True)
class ExecOptions:
    mb_rows: int            # microbatch rows per data shard
    seq_len: int            # decoder/self-attn token length per row
    enc_len: int = 0        # encoder frames (enc-dec archs)
    grad_dtype: Any = jnp.float32   # stage-grad accumulators
    io_grad_dtype: Any = jnp.bfloat16  # embed/head accumulators (huge)
    flat_dtype: Any = jnp.bfloat16  # ZeRO-1 reduce-scatter payload
    ce_chunk: int = 0       # 0 -> auto from vocab size
    loss_scale: float = 1.0  # applied to the backward seed
    dp_axes: tuple = ("data",)
    multi_pod: bool = False

    @property
    def all_dp_axes(self) -> tuple:
        return (("pod",) + self.dp_axes) if self.multi_pod else self.dp_axes


def _eff_seq(model: ArchModel, opts: ExecOptions) -> int:
    return opts.seq_len + (opts.enc_len if model.cfg.encoder_layers else 0)


def _ce_chunk(model: ArchModel, opts: ExecOptions) -> int:
    return default_ce_chunk(model.cfg, opts.ce_chunk)


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------
def make_train_fn(
    model: ArchModel,
    table: ScheduleTable,
    mesh,
    opts: ExecOptions,
    partition: ParamPartition,
):
    """Returns fn(stage_params, io_params, batch) -> (metrics, grad_shard,
    expert_grads) as a shard_map over the production mesh.

    ``grad_shard`` is the ZeRO-1 reduce-scattered flat fp32 vector of all
    data-replicated grads (stage + io); ``expert_grads`` holds the
    data-sharded leaves (EP/TP experts), locally reduced by construction.
    """
    cfg = model.cfg
    S = model.num_stages
    occ = table.validate()
    K_act = max(1, occ["act_span"])
    K_res = max(1, occ["res_span"])
    K_grad = max(1, occ["grad_span"])
    M = table.spec.num_microbatches
    T = table.num_ticks
    eff_seq = _eff_seq(model, opts)
    d = cfg.d_model
    mb_rows = opts.mb_rows
    ce_chunk = _ce_chunk(model, opts)
    dp_axes = opts.all_dp_axes
    dp_total = int(np.prod([mesh.shape[a] for a in dp_axes]))
    fwd_perm = [(i, i + 1) for i in range(S - 1)]
    bwd_perm = [(i, i - 1) for i in range(1, S)]
    ops_arr = jnp.asarray(table.ops, jnp.int32)
    mbs_arr = jnp.asarray(table.mbs, jnp.int32)
    rows_all = {k: jnp.asarray(v) for k, v in model.all_rows().items()}
    data_size = mesh.shape["data"]

    def device_fn(stage_params, io, batch):
        stage = jax.lax.axis_index("model")
        sp = jax.tree.map(lambda x: x[0], stage_params)  # drop stage dim
        rows = {k: v[stage] for k, v in rows_all.items()}
        tokens = batch["tokens"]  # [B_loc, seq]
        labels = batch["labels"]
        aux: dict[str, Any] = {
            "positions": jnp.broadcast_to(
                jnp.arange(eff_seq, dtype=jnp.int32)[None], (mb_rows, eff_seq)),
            "data_size": data_size,
            "moe_layout": model.moe_layout,
        }
        if cfg.encoder_layers:
            aux["dec_len"] = opts.seq_len

        def batch_mb(mb):
            out = {
                "tokens": jax.lax.dynamic_slice(
                    tokens, (mb * mb_rows, 0), (mb_rows, opts.seq_len)),
                "labels": jax.lax.dynamic_slice(
                    labels, (mb * mb_rows, 0), (mb_rows, opts.seq_len)),
            }
            if "embeds" in batch:
                e = batch["embeds"]
                out["embeds"] = jax.lax.dynamic_slice(
                    e, (mb * mb_rows, 0, 0), (mb_rows,) + e.shape[1:])
            if "mrope" in batch:
                mr = batch["mrope"]
                out["mrope"] = jax.lax.dynamic_slice(
                    mr, (0, mb * mb_rows, 0), (3, mb_rows, mr.shape[2]))
            if "enc_embeds" in batch:
                e = batch["enc_embeds"]
                out["enc_embeds"] = jax.lax.dynamic_slice(
                    e, (mb * mb_rows, 0, 0), (mb_rows,) + e.shape[1:])
            return out

        def aux_mb(bm):
            a = dict(aux)
            if "mrope" in bm:
                a["mrope"] = bm["mrope"]
            return a

        def pipeline_embed(io_, bm):
            if cfg.embed_input:
                x = bm["embeds"].astype(cfg.dtype)
            else:
                x = io_["embed"][bm["tokens"]]
            if cfg.encoder_layers:
                x = jnp.concatenate(
                    [x, bm["enc_embeds"].astype(cfg.dtype)], axis=1)
            return x

        def loss_of(io_, y, bm):
            if cfg.encoder_layers:
                y = y[:, : opts.seq_len]
            return chunked_ce_sum(model, io_, y, bm["labels"], ce_chunk)

        dt = cfg.dtype
        zero_state = {
            "act_buf": jnp.zeros((K_act, mb_rows, eff_seq, d), dt),
            "grad_buf": jnp.zeros((K_grad, mb_rows, eff_seq, d), dt),
            "res_buf": jnp.zeros((K_res, mb_rows, eff_seq, d), dt),
            "send_act": (jnp.zeros((mb_rows, eff_seq, d), dt),
                         jnp.zeros((), jnp.int32), jnp.zeros((), jnp.bool_)),
            "send_grad": (jnp.zeros((mb_rows, eff_seq, d), dt),
                          jnp.zeros((), jnp.int32), jnp.zeros((), jnp.bool_)),
            "d_stage": jax.tree.map(
                lambda x: jnp.zeros(x.shape, opts.grad_dtype), sp),
            "d_io": jax.tree.map(
                lambda x: jnp.zeros(x.shape, opts.io_grad_dtype), io),
            "loss": jnp.zeros((), jnp.float32),
        }

        # ---- per-op branches ------------------------------------------
        def idle_fn(state, mb):
            return state

        def f_fn(state, mb):
            bm = batch_mb(mb)
            a = aux_mb(bm)
            x_in = jax.lax.cond(
                stage == 0,
                lambda: pipeline_embed(io, bm).astype(dt),
                lambda: jax.lax.dynamic_index_in_dim(
                    state["act_buf"], mb % K_act, 0, keepdims=False),
            )
            y = model.stage_forward(sp, io, x_in, a, rows)
            loss_inc = jax.lax.cond(
                stage == S - 1,
                lambda: loss_of(io, y, bm),
                lambda: jnp.zeros((), jnp.float32),
            )
            res_buf = jax.lax.dynamic_update_index_in_dim(
                state["res_buf"], x_in, mb % K_res, 0)
            return {
                **state,
                "res_buf": res_buf,
                "loss": state["loss"] + loss_inc,
                "send_act": (y, mb, stage < S - 1),
            }

        def scalar_objective(sp_, io_, x, g_in, bm, a):
            x0 = jax.lax.cond(
                stage == 0, lambda: pipeline_embed(io_, bm).astype(dt), lambda: x)
            y = model.stage_forward(sp_, io_, x0, a, rows)
            return jax.lax.cond(
                stage == S - 1,
                lambda: loss_of(io_, y, bm) * opts.loss_scale,
                lambda: jnp.sum(
                    y.astype(jnp.float32) * g_in.astype(jnp.float32)),
            )

        def b_fn(state, mb):
            bm = batch_mb(mb)
            a = aux_mb(bm)
            g_in = jax.lax.dynamic_index_in_dim(
                state["grad_buf"], mb % K_grad, 0, keepdims=False)
            x_in = jax.lax.dynamic_index_in_dim(
                state["res_buf"], mb % K_res, 0, keepdims=False)
            argnums = (2,) if table.spec.split_backward else (0, 1, 2)
            grads = jax.grad(scalar_objective, argnums=argnums)(
                sp, io, x_in, g_in, bm, a)
            if table.spec.split_backward:
                (dx,) = grads
                new = {}
            else:
                dsp, dio, dx = grads
                new = {
                    "d_stage": jax.tree.map(
                        lambda acc, g: acc + g.astype(opts.grad_dtype),
                        state["d_stage"], dsp),
                    "d_io": jax.tree.map(
                        lambda acc, g: acc + g.astype(opts.io_grad_dtype),
                        state["d_io"], dio),
                }
            return {
                **state, **new,
                "send_grad": (dx.astype(dt), mb, stage > 0),
            }

        def w_fn(state, mb):
            if not table.spec.split_backward:
                return state
            bm = batch_mb(mb)
            a = aux_mb(bm)
            g_in = jax.lax.dynamic_index_in_dim(
                state["grad_buf"], mb % K_grad, 0, keepdims=False)
            x_in = jax.lax.dynamic_index_in_dim(
                state["res_buf"], mb % K_res, 0, keepdims=False)
            dsp, dio = jax.grad(scalar_objective, argnums=(0, 1))(
                sp, io, x_in, g_in, bm, a)
            return {
                **state,
                "d_stage": jax.tree.map(
                    lambda acc, g: acc + g.astype(opts.grad_dtype),
                    state["d_stage"], dsp),
                "d_io": jax.tree.map(
                    lambda acc, g: acc + g.astype(opts.io_grad_dtype),
                    state["d_io"], dio),
            }

        def tick_body(t, state):
            # deliver messages sent at t-1 (one ring hop per direction)
            pa, pm, pv = state["send_act"]
            ra = jax.lax.ppermute(pa, "model", fwd_perm)
            rm = jax.lax.ppermute(pm, "model", fwd_perm)
            rv = jax.lax.ppermute(pv.astype(jnp.int32), "model", fwd_perm) > 0
            cur = jax.lax.dynamic_index_in_dim(
                state["act_buf"], rm % K_act, 0, keepdims=False)
            act_buf = jax.lax.dynamic_update_index_in_dim(
                state["act_buf"], jnp.where(rv, ra, cur), rm % K_act, 0)
            ga, gm, gv = state["send_grad"]
            rga = jax.lax.ppermute(ga, "model", bwd_perm)
            rgm = jax.lax.ppermute(gm, "model", bwd_perm)
            rgv = jax.lax.ppermute(gv.astype(jnp.int32), "model", bwd_perm) > 0
            curg = jax.lax.dynamic_index_in_dim(
                state["grad_buf"], rgm % K_grad, 0, keepdims=False)
            grad_buf = jax.lax.dynamic_update_index_in_dim(
                state["grad_buf"], jnp.where(rgv, rga, curg), rgm % K_grad, 0)
            state = {
                **state,
                "act_buf": act_buf,
                "grad_buf": grad_buf,
                "send_act": (pa, pm, jnp.zeros((), jnp.bool_)),
                "send_grad": (ga, gm, jnp.zeros((), jnp.bool_)),
            }
            op = ops_arr[stage, t]
            mb = mbs_arr[stage, t]
            return jax.lax.switch(op, [idle_fn, f_fn, b_fn, w_fn], state, mb)

        state = jax.lax.fori_loop(0, T, tick_body, zero_state)

        # ---- reductions -----------------------------------------------
        loss_sum = jax.lax.psum(state["loss"], ("model",) + dp_axes)

        def rs(leaf):
            """Per-leaf ZeRO-1 reduce-scatter over the DP axes."""
            v = leaf.astype(opts.flat_dtype).reshape(-1)
            v = jnp.pad(v, (0, (-v.size) % dp_total))
            return jax.lax.psum_scatter(
                v.reshape(dp_total, -1), dp_axes, scatter_dimension=0,
                tiled=False)[None]

        grad_shards = {}
        expert_grads = {}
        for (path, leaf), (_, flag) in zip(
                jax.tree_util.tree_leaves_with_path(state["d_stage"]),
                jax.tree_util.tree_leaves_with_path(
                    partition.stage_data_sharded)):
            k = jax.tree_util.keystr(path)
            if flag:
                # expert (data-sharded) grads stay local
                expert_grads[k] = leaf[None]
            else:
                grad_shards[k] = rs(leaf)
        for path, leaf in jax.tree_util.tree_leaves_with_path(state["d_io"]):
            # io grads: stage-masked contributions -> sum over model first
            g = jax.lax.psum(leaf, "model")
            grad_shards["io:" + jax.tree_util.keystr(path)] = rs(g)
        metrics = {
            "loss_sum": loss_sum,
            "loss": loss_sum / (M * mb_rows * opts.seq_len * dp_total),
        }
        return metrics, grad_shards, expert_grads

    # ---- shard_map wrapper ------------------------------------------------
    batch_specs = make_batch_specs(model, opts)

    expert_out_specs = {
        jax.tree_util.keystr(path): spec
        for (path, spec), (_, flag) in zip(
            jax.tree_util.tree_leaves_with_path(partition.stage_specs),
            jax.tree_util.tree_leaves_with_path(partition.stage_data_sharded))
        if flag
    }

    fn = shard_map(
        device_fn,
        mesh=mesh,
        in_specs=(partition.stage_specs, partition.io_specs, batch_specs),
        out_specs=(
            {"loss_sum": P(), "loss": P()},
            grad_shard_specs(model, partition, opts),
            expert_out_specs,
        ),
        check_vma=False,
    )
    return fn, batch_specs


def grad_shard_specs(model: ArchModel, partition: ParamPartition,
                     opts: ExecOptions):
    """Out-spec dict for the per-leaf ZeRO-1 grad shards."""
    spec = P("model", opts.all_dp_axes)
    out = {}
    for (path, _), (_, flag) in zip(
            jax.tree_util.tree_leaves_with_path(partition.stage_specs),
            jax.tree_util.tree_leaves_with_path(
                partition.stage_data_sharded)):
        if not flag:
            out[jax.tree_util.keystr(path)] = spec
    for path, _ in jax.tree_util.tree_leaves_with_path(partition.io_specs):
        out["io:" + jax.tree_util.keystr(path)] = spec
    return out


def make_batch_specs(model: ArchModel, opts: ExecOptions):
    cfg = model.cfg
    specs = {"tokens": P(opts.all_dp_axes), "labels": P(opts.all_dp_axes)}
    if cfg.embed_input:
        specs["embeds"] = P(opts.all_dp_axes)
    if cfg.mrope:
        specs["mrope"] = P(None, opts.all_dp_axes)
    if cfg.encoder_layers:
        specs["enc_embeds"] = P(opts.all_dp_axes)
    return specs
