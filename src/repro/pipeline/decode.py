"""Serve-path executor: pipelined single-token decode with stage-local KV.

``serve_step`` advances every sequence in the batch by one token: M
micro-groups of the batch staircase through the S stages (F-only table),
caches updated in place.  For ``long_500k`` (batch 1) the attention caches
are sequence-sharded over the ``data`` axis and combined with the
distributed flash-decode (``sp_mode``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.build import ArchModel
from repro.models.layers import rmsnorm
from repro.pipeline.spec import OP_F, ScheduleTable


@dataclasses.dataclass(frozen=True)
class DecodeOptions:
    mb_rows: int          # rows per micro-group per data shard
    cache_len: int        # max KV length
    enc_len: int = 0
    sp_mode: bool = False  # sequence-parallel caches (long_500k, batch=1)
    dp_axes: tuple = ("data",)
    multi_pod: bool = False

    @property
    def all_dp_axes(self) -> tuple:
        return (("pod",) + self.dp_axes) if self.multi_pod else self.dp_axes


def cache_specs(model: ArchModel, opts: DecodeOptions):
    """PartitionSpecs for the stacked [S, l_max, b, ...] cache pytree."""
    one = model.init_layer_cache(1, 2, enc_len=max(1, opts.enc_len))

    def spec_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        nd = leaf.ndim + 2  # + (S, l_max)
        extra = [None] * (nd - 1)
        if opts.sp_mode:
            # attention caches: [S, l_max, b, seq, kv, hd] -> shard seq
            if names and names[-1] in ("k", "v", "xk", "xv"):
                extra[2] = opts.all_dp_axes
        else:
            extra[1] = opts.all_dp_axes  # shard batch
        return P("model", *extra)

    return jax.tree_util.tree_map_with_path(spec_for, one)


def make_serve_fn(model: ArchModel, mesh, opts: DecodeOptions, num_groups: int):
    """Returns fn(stage_params, io, caches, batch, pos) ->
    (next_tokens, new_caches).  ``batch`` carries tokens [B_loc] (or embeds
    [B_loc, 1, d] for embed_input archs); pos is the current position."""
    cfg = model.cfg
    S = model.num_stages
    M = num_groups
    T = M + S - 1
    d = cfg.d_model
    mb_rows = opts.mb_rows
    fwd_perm = [(i, i + 1) for i in range(S - 1)]
    rows_all = {k: jnp.asarray(v) for k, v in model.all_rows().items()}
    data_size = mesh.shape["data"]

    def device_fn(stage_params, io, caches, batch, pos):
        stage = jax.lax.axis_index("model")
        sp = jax.tree.map(lambda x: x[0], stage_params)
        my_cache = jax.tree.map(lambda x: x[0], caches)
        rows = {k: v[stage] for k, v in rows_all.items()}
        aux: dict[str, Any] = {
            "data_size": data_size,
            "moe_layout": model.moe_layout,
        }
        if opts.sp_mode:
            aux["sp_axis"] = "data"

        def embed_group(mb):
            if cfg.embed_input:
                e = jax.lax.dynamic_slice(
                    batch["embeds"], (mb * mb_rows, 0, 0), (mb_rows, 1, d))
                return e.astype(cfg.dtype)
            toks = jax.lax.dynamic_slice(batch["tokens"], (mb * mb_rows,),
                                         (mb_rows,))
            return io["embed"][toks][:, None]

        state = {
            "cache": my_cache,
            "send": (jnp.zeros((mb_rows, 1, d), cfg.dtype),
                     jnp.zeros((), jnp.int32), jnp.zeros((), jnp.bool_)),
            "act_buf": jnp.zeros((min(M, S) + 1, mb_rows, 1, d), cfg.dtype),
            "out_tokens": jnp.zeros((M * mb_rows,), jnp.int32),
        }
        K = state["act_buf"].shape[0]

        def tick_body(t, state):
            pa, pm, pv = state["send"]
            ra = jax.lax.ppermute(pa, "model", fwd_perm)
            rm = jax.lax.ppermute(pm, "model", fwd_perm)
            rv = jax.lax.ppermute(pv.astype(jnp.int32), "model", fwd_perm) > 0
            cur = jax.lax.dynamic_index_in_dim(
                state["act_buf"], rm % K, 0, keepdims=False)
            act_buf = jax.lax.dynamic_update_index_in_dim(
                state["act_buf"], jnp.where(rv, ra, cur), rm % K, 0)
            state = {**state, "act_buf": act_buf,
                     "send": (pa, pm, jnp.zeros((), jnp.bool_))}
            mb = t - stage
            run = (mb >= 0) & (mb < M)

            def do_f(state):
                mb_c = jnp.clip(mb, 0, M - 1)
                x = jax.lax.cond(
                    stage == 0,
                    lambda: embed_group(mb_c),
                    lambda: jax.lax.dynamic_index_in_dim(
                        state["act_buf"], mb_c % K, 0, keepdims=False),
                )
                # slice this micro-group's cache rows
                if opts.sp_mode:
                    cache_mb = state["cache"]  # batch=1: no slicing
                else:
                    cache_mb = jax.tree.map(
                        lambda c: jax.lax.dynamic_slice_in_dim(
                            c, mb_c * mb_rows, mb_rows, axis=1),
                        state["cache"])
                y, cache_mb = model.stage_decode(
                    sp, io, x, cache_mb, pos, aux, rows)
                if opts.sp_mode:
                    cache = cache_mb
                else:
                    cache = jax.tree.map(
                        lambda c, u: jax.lax.dynamic_update_slice_in_dim(
                            c, u, mb_c * mb_rows, axis=1),
                        state["cache"], cache_mb)
                # last stage: greedy next token
                def emit(state_tokens):
                    h = y[:, : 1]
                    logits = (rmsnorm(h, io["final_ln"], cfg.norm_eps)
                              @ io["head"].T).astype(jnp.float32)
                    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                    return jax.lax.dynamic_update_slice_in_dim(
                        state_tokens, nxt, mb_c * mb_rows, axis=0)

                out_tokens = jax.lax.cond(
                    stage == S - 1, emit, lambda ot: ot, state["out_tokens"])
                return {**state, "cache": cache, "out_tokens": out_tokens,
                        "send": (y, mb_c, stage < S - 1)}

            return jax.lax.cond(run, do_f, lambda s: s, state)

        state = jax.lax.fori_loop(0, T, tick_body, state)
        # out tokens live on the last stage row; broadcast via psum (masked)
        out = jnp.where(stage == S - 1, state["out_tokens"], 0)
        out = jax.lax.psum(out, "model")
        return out, jax.tree.map(lambda x: x[None], state["cache"])

    cspecs = cache_specs(model, opts)
    batch_specs: dict = {}
    if cfg.embed_input:
        batch_specs["embeds"] = P(opts.all_dp_axes if not opts.sp_mode else None)
    else:
        batch_specs["tokens"] = P(opts.all_dp_axes if not opts.sp_mode else None)

    from repro.pipeline.sharding import partition_for  # specs only

    def wrap(partition):
        return shard_map(
            device_fn,
            mesh=mesh,
            in_specs=(partition.stage_specs, partition.io_specs, cspecs,
                      batch_specs, P()),
            out_specs=(
                P(opts.all_dp_axes if not opts.sp_mode else None),
                cspecs,
            ),
            check_vma=False,
        )

    return wrap, cspecs, batch_specs
