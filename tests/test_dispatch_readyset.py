"""Property tests: the incremental ReadySet arbiter is decision-identical
to the reference sort-then-rank path.

The dispatch hot path was rebuilt around ``core.hints.ReadySet`` (lazy-
deletion heap per kind, O(log n) insert / O(1) peek) replacing
``arbiter.select(sorted(ready))`` (O(n log n) per decision).  The
non-negotiable invariant is that *every* arbitration decision is unchanged
— across hints, the ``w_defer_cap`` W-retirement path, and the Appendix C
backpressure drains, on chain and fan-in DAG specs, under arbitrary
interleavings of inserts, removals and selects.

Uses ``hypothesis`` when installed, the deterministic ``tests/_hyp_stub.py``
fallback otherwise (same properties, fixed example budget).
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # deterministic fallback (tests/_hyp_stub.py)
    from _hyp_stub import given, settings, strategies as st

from repro.core import CostModel, PipelineSpec, StageGraph
from repro.core.hints import (
    HintArbiter,
    HintKind,
    ReadySet,
    backpressure_drain,
    pick,
)
from repro.core.taskgraph import Kind, Task
from repro.runtime.rrfp import ActorConfig, ActorDriver

ALL_HINTS = [HintKind.BF, HintKind.FB, HintKind.B_PRIORITY,
             HintKind.F_PRIORITY, HintKind.BFW]

#: one chain spec and one fan-in DAG spec (diamond into a short chain) —
#: the two topologies whose task pools the interleavings draw from
CHAIN = PipelineSpec(3, 6, split_backward=True)
DAG = PipelineSpec(5, 4, graph=StageGraph(5, ((0, 2), (1, 2), (2, 3),
                                              (3, 4))))
SPECS = [CHAIN, DAG]


def _stage_pool(spec: PipelineSpec, stage: int) -> list[Task]:
    return [t for t in spec.tasks() if t.stage == stage]


def _apply_ops(seed: int, spec: PipelineSpec, stage: int, hint: HintKind,
               n_ops: int) -> None:
    """Drive a mirrored (reference set, ReadySet) pair through a randomized
    insert/remove/select interleaving; every decision must match."""
    rng = np.random.default_rng([0xD15, seed])
    pool = _stage_pool(spec, stage)
    ref: set[Task] = set()
    rs = ReadySet()
    done: set[Task] = set()
    ref_arb = HintArbiter(hint)
    inc_arb = HintArbiter(hint)
    drain_focus_ref = drain_focus_inc = 0
    for _ in range(n_ops):
        op = int(rng.integers(4))
        if op == 0 and len(ref) < len(pool):  # insert
            absent = [t for t in pool if t not in ref and t not in done]
            if absent:
                t = absent[int(rng.integers(len(absent)))]
                ref.add(t)
                rs.add(t)
        elif op == 1 and ref:  # out-of-band removal (lazy-deletion stress)
            t = sorted(ref)[int(rng.integers(len(ref)))]
            ref.discard(t)
            rs.discard(t)
        elif op == 2:  # arbited select (mutates round state on both sides)
            t_ref = ref_arb.select(sorted(ref))
            t_inc = inc_arb.select(rs)
            assert t_ref == t_inc, (
                f"hint {hint}: reference chose {t_ref}, incremental chose "
                f"{t_inc} on ready={sorted(ref)}")
            assert ref_arb.last_dir == inc_arb.last_dir
            if t_ref is not None:
                ref.discard(t_ref)
                rs.discard(t_ref)
                done.add(t_ref)
        else:  # auxiliary dispatch paths: wcap pick + backpressure drain
            assert pick(sorted(ref), Kind.W) == pick(rs, Kind.W)
            t_ref, drain_focus_ref = backpressure_drain(
                spec, stage, sorted(ref), done, drain_focus_ref)
            t_inc, drain_focus_inc = backpressure_drain(
                spec, stage, rs, done, drain_focus_inc)
            assert (t_ref, drain_focus_ref) == (t_inc, drain_focus_inc)
        # structural parity after every op
        assert len(rs) == len(ref)
        for kind in Kind:
            assert pick(rs, kind) == pick(sorted(ref), kind)


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000), hint_i=st.integers(0, len(ALL_HINTS) - 1),
       spec_i=st.integers(0, 1), n_ops=st.integers(5, 60))
def test_incremental_matches_reference_decisions(seed, hint_i, spec_i, n_ops):
    spec = SPECS[spec_i]
    stage = 2  # fan-in stage on the DAG; mid-chain stage on the chain
    _apply_ops(seed, spec, stage, ALL_HINTS[hint_i], n_ops)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n_ops=st.integers(5, 40))
def test_interleaved_backpressure_drain_matches(seed, n_ops):
    """Interleaved (multi-chunk) drains probe ReadySet membership, not just
    peeks — run the interleaving on a chunked chain spec."""
    spec = PipelineSpec(3, 3, num_chunks=2)
    _apply_ops(seed, spec, 1, HintKind.BF, n_ops)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), size=st.integers(1, 24))
def test_readyset_peek_is_min_over_live(seed, size):
    """After random add/discard churn, peek(kind) equals the reference
    minimum over live tasks of that kind (lazy deletion never surfaces a
    dead or wrong head)."""
    rng = np.random.default_rng([0x9EEB, seed])
    pool = _stage_pool(CHAIN, 1)
    rs = ReadySet()
    live: set[Task] = set()
    for _ in range(size * 3):
        t = pool[int(rng.integers(len(pool)))]
        if t in live and rng.random() < 0.5:
            live.discard(t)
            rs.discard(t)
        else:
            live.add(t)
            rs.add(t)
        for kind in Kind:
            assert pick(rs, kind) == pick(sorted(live), kind)
        assert set(rs) == live and len(rs) == len(live)


# ---------------------------------------------------------------------------
# end to end: same seed, fast vs reference arbitration, identical traces —
# through the full actor runtime (w_defer_cap + tight buffer_limit force the
# wcap and backpressure dispatch paths, not just the hint path)
# ---------------------------------------------------------------------------
def _paired_traces(spec, cfg_kwargs):
    cm = CostModel.uniform(spec.num_stages, w=0.5)
    events = []
    for ref in (False, True):
        cfg = ActorConfig(record_trace=True, reference_arbitration=ref,
                          **cfg_kwargs)
        res = ActorDriver(spec, cm, cfg).run()
        events.append([ev.to_json() for ev in res.trace.events])
    return events


def test_driver_trace_identical_chain_bfw_wcap_backpressure():
    spec = PipelineSpec(4, 8, split_backward=True)
    a, b = _paired_traces(spec, dict(
        mode="hint", hint=HintKind.BFW, w_defer_cap=2, buffer_limit=2,
        seed=3))
    assert a == b


def test_driver_trace_identical_dag():
    a, b = _paired_traces(DAG, dict(mode="hint", hint=HintKind.BF, seed=11))
    assert a == b


def test_driver_trace_identical_precommitted_fixed_order():
    """Fixed-order (precommitted) consumption probes ReadySet membership
    rather than peeks; the paired traces must still match byte for byte."""
    spec = PipelineSpec(4, 6)
    a, b = _paired_traces(spec, dict(
        mode="precommitted", fixed_order="1f1b", seed=2))
    assert a == b


def test_diff_snapshots_reconstruct_full_ready_sets():
    """The default incremental (``radd``) trace encoding must reconstruct
    the exact per-dispatch ready snapshots that opt-in full recording
    serializes — the conformance checker's hint-faithfulness invariant
    depends on it."""
    for spec in (PipelineSpec(4, 6, split_backward=True), DAG):
        cm = CostModel.uniform(spec.num_stages, w=0.5)
        hint = HintKind.BFW if spec.split_backward else HintKind.BF
        cap = 2 if spec.split_backward else 0
        traces = []
        for full in (False, True):
            cfg = ActorConfig(mode="hint", hint=hint, w_defer_cap=cap,
                              seed=5, record_trace=True,
                              trace_full_ready=full)
            traces.append(ActorDriver(spec, cm, cfg).run().trace)
        diff_t, full_t = traces
        assert diff_t.ready_sets() == full_t.ready_sets()
        # and the diff encoding is actually the cheaper one on the wire
        diff_payload = sum(len(ev.info.get("radd", ()))
                           for ev in diff_t.events)
        full_payload = sum(len(ev.info.get("ready", ()))
                           for ev in full_t.events)
        assert diff_payload < full_payload
