"""Cost and runtime-variability models (§2, §7.6).

Execution time in the engine is  base_cost(stage, kind) * jitter  plus, under
the RQ4 injection protocol, an EMA-tracked additive delay.  Communication
latency uses a heavy-tailed mixture calibrated to the paper's Figure 2
measurement that (p95-p5)/p50 reaches 0.73 for compute and 58.74 for
communication: most messages are near-instant relative to compute, a small
fraction are spiked by orders of magnitude.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass
class JitterModel:
    """Multiplicative lognormal jitter + heavy-tail spikes.

    sample = lognormal(sigma)  and, with prob ``spike_prob``, multiplied by
    ``1 + Exp(spike_scale)``.
    """

    sigma: float = 0.0
    spike_prob: float = 0.0
    spike_scale: float = 0.0

    def sample(self, rng: np.random.Generator) -> float:
        x = 1.0
        if self.sigma > 0:
            # mean-1 lognormal so expected cost equals the base cost
            x *= float(rng.lognormal(mean=-0.5 * self.sigma**2, sigma=self.sigma))
        if self.spike_prob > 0 and rng.random() < self.spike_prob:
            x *= 1.0 + float(rng.exponential(self.spike_scale))
        return x


#: Compute jitter calibrated to Fig. 2: (p95-p5)/p50 ~ 0.73 -> sigma ~ 0.22.
DEFAULT_COMPUTE_JITTER = JitterModel(sigma=0.22)
#: Comm jitter calibrated to Fig. 2: (p95-p5)/p50 ~ 58.7 -> rare huge spikes.
DEFAULT_COMM_JITTER = JitterModel(sigma=0.35, spike_prob=0.10, spike_scale=80.0)


@dataclasses.dataclass
class InjectionModel:
    """RQ4 compute-path delay injection (Table 6).

    With probability ``p``, after a compute task of measured duration c_t, add
    d_t = alpha * max(base, e_t) * (0.5 + U(0,1)) where e_t is the stage-local
    EMA  e_t = 0.9 e_{t-1} + 0.1 c_t .
    """

    p: float = 0.0
    base: float = 0.0  # "B" in the paper, seconds
    alpha: float = 0.0

    def make_state(self) -> dict:
        return {"ema": 0.0, "init": False}

    def sample_delay(self, state: dict, c_t: float, rng: np.random.Generator) -> float:
        if not state["init"]:
            state["ema"] = c_t
            state["init"] = True
        else:
            state["ema"] = 0.9 * state["ema"] + 0.1 * c_t
        if self.p <= 0 or rng.random() >= self.p:
            return 0.0
        return self.alpha * max(self.base, state["ema"]) * (0.5 + rng.random())


# The paper's jitter levels J0..J3 (Table 6).
INJECTION_LEVELS = {
    "J0": InjectionModel(p=0.0, base=0.000, alpha=0.0),
    "J1": InjectionModel(p=0.1, base=0.005, alpha=0.5),
    "J2": InjectionModel(p=0.2, base=0.010, alpha=1.0),
    "J3": InjectionModel(p=0.3, base=0.015, alpha=1.5),
}


@dataclasses.dataclass
class CostModel:
    """Per-(stage, kind) base costs with variability.

    ``f_cost[s]`` / ``b_cost[s]`` / ``w_cost[s]`` are seconds for one
    microbatch of F / B / W work at stage ``s`` (per chunk).  ``comm_base`` is
    the no-jitter point-to-point activation/gradient transfer latency.
    """

    f_cost: np.ndarray
    b_cost: np.ndarray
    w_cost: np.ndarray
    comm_base: float = 1e-4
    compute_jitter: JitterModel = dataclasses.field(
        default_factory=lambda: dataclasses.replace(DEFAULT_COMPUTE_JITTER)
    )
    comm_jitter: JitterModel = dataclasses.field(
        default_factory=lambda: dataclasses.replace(DEFAULT_COMM_JITTER)
    )
    injection: InjectionModel = dataclasses.field(default_factory=InjectionModel)
    #: per-(stage, microbatch) multiplicative workload skew (e.g. MoE routing,
    #: multimodal length mix); 1.0 = homogeneous.
    mb_skew: np.ndarray | None = None
    seed: int = 0

    def __post_init__(self) -> None:
        self.f_cost = np.asarray(self.f_cost, dtype=np.float64)
        self.b_cost = np.asarray(self.b_cost, dtype=np.float64)
        self.w_cost = np.asarray(self.w_cost, dtype=np.float64)

    @property
    def num_stages(self) -> int:
        return len(self.f_cost)

    # ---- constructors ------------------------------------------------------
    @staticmethod
    def uniform(
        num_stages: int,
        f: float = 1.0,
        b: float = 2.0,
        w: float = 0.0,
        **kw,
    ) -> "CostModel":
        return CostModel(
            f_cost=np.full(num_stages, f),
            b_cost=np.full(num_stages, b),
            w_cost=np.full(num_stages, w),
            **kw,
        )

    @staticmethod
    def from_stage_flops(
        stage_flops: np.ndarray,
        chip_flops: float = 197e12,
        efficiency: float = 0.4,
        bwd_ratio: float = 2.0,
        split_backward: bool = False,
        **kw,
    ) -> "CostModel":
        """Derive per-stage costs from per-stage forward FLOPs.

        With BFW decomposition, B (dX only) and W (dW only) each take roughly
        half of the full backward.
        """
        f = np.asarray(stage_flops, dtype=np.float64) / (chip_flops * efficiency)
        if split_backward:
            return CostModel(
                f_cost=f, b_cost=f * bwd_ratio * 0.5, w_cost=f * bwd_ratio * 0.5, **kw
            )
        return CostModel(f_cost=f, b_cost=f * bwd_ratio, w_cost=0.0 * f, **kw)

    # ---- sampling ----------------------------------------------------------
    def make_rng(self, seed_offset: int = 0) -> np.random.Generator:
        return np.random.default_rng(self.seed + seed_offset)

    def base_compute(self, kind: int, stage: int, mb: int) -> float:
        base = (self.f_cost, self.b_cost, self.w_cost)[kind][stage]
        if self.mb_skew is not None:
            base *= float(self.mb_skew[stage, mb % self.mb_skew.shape[1]])
        return float(base)

    def sample_compute(
        self, kind: int, stage: int, mb: int, rng: np.random.Generator
    ) -> float:
        return self.base_compute(kind, stage, mb) * self.compute_jitter.sample(rng)

    def sample_comm(self, rng: np.random.Generator) -> float:
        return self.comm_base * self.comm_jitter.sample(rng)

    def with_split_backward(self, dx_frac: float = 0.5) -> "CostModel":
        """BFW decomposition of this model's backward cost.

        The fused B cost splits into a dX-only B (``dx_frac`` of it, on the
        critical path) and a deferrable W carrying the rest — total backward
        work is conserved, so fused-vs-split comparisons isolate scheduling
        flexibility from compute volume.
        """
        if not 0.0 < dx_frac < 1.0:
            raise ValueError(f"dx_frac must be in (0, 1), got {dx_frac}")
        if np.any(self.w_cost):
            raise ValueError(
                "backward is already split (nonzero w_cost); splitting again "
                "would discard W work and break conservation")
        return dataclasses.replace(
            self,
            b_cost=self.b_cost * dx_frac,
            w_cost=self.b_cost * (1.0 - dx_frac),
        )

    def expected(self) -> "CostModel":
        """Jitter-free copy (used for schedule synthesis)."""
        return dataclasses.replace(
            self,
            compute_jitter=JitterModel(),
            comm_jitter=JitterModel(),
            injection=InjectionModel(),
        )


def multimodal_stage_flops(
    vision_flops: float,
    lm_flops: float,
    num_stages: int,
    vision_stage_frac: float = 0.25,
) -> np.ndarray:
    """Heterogeneous per-stage forward FLOPs for a ViT+LM pipeline.

    The first ``vision_stage_frac`` of stages carry the vision encoder; the
    remainder carry the language model.  Mirrors the paper's Heavy-LMM setup
    where naive layer-count splits leave vision stages with very different
    cost than LM stages.
    """
    n_vis = max(1, int(round(num_stages * vision_stage_frac)))
    n_lm = num_stages - n_vis
    out = np.empty(num_stages)
    out[:n_vis] = vision_flops / n_vis
    out[n_vis:] = lm_flops / n_lm
    return out


def normalized_spread(samples: np.ndarray) -> dict[str, float]:
    """The paper's Fig. 2 statistics: (p95-p5)/p50 and (p75-p25)/p50."""
    p5, p25, p50, p75, p95 = np.percentile(samples, [5, 25, 50, 75, 95])
    if p50 <= 0:
        return {"p95_p5": math.inf, "iqr": math.inf}
    return {"p95_p5": (p95 - p5) / p50, "iqr": (p75 - p25) / p50}
