"""Bubble-decomposition benchmark: WHERE the makespan gap comes from.

``benchmarks.multimodal_compare`` established THAT readiness-driven BFW
consumption beats pre-committed 1F1B on skewed multimodal DAG pipelines;
this benchmark explains WHY: it records one sim-substrate trace per
consumption mode on the same workloads (same CRN seed, so both modes face
the same realized variability), runs ``repro.obs.bubbles.decompose`` over
each, and reports the per-stage idle-time attribution side by side —
"BFW beats 1F1B 1.44x" becomes "because it removed X s of dependency-wait
on the LM stages".

Two hard checks ride along (CI gates):

* every decomposition accounts for 100% of per-stage idle time (the
  categories sum exactly to makespan - busy on every stage);
* the BFW-vs-1F1B comparison identifies a dominant removed bubble class
  with a positive removed amount — under pre-committed consumption that
  class is ``dependency_wait``, which here includes schedule misalignment
  (the fixed order's next entry being unready while other work was ready),
  exactly the component readiness-driven consumption eliminates.

    PYTHONPATH=src python -m benchmarks.run --backend actor --bubbles
    REPRO_SMOKE=1 PYTHONPATH=src python -m benchmarks.bubble_decomposition

Emits ``BENCH_bubbles.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.core import INJECTION_LEVELS, HintKind, PipelineSpec
from repro.obs import CATEGORIES, compare, decompose
from repro.runtime.rrfp import ActorConfig, ActorDriver

from benchmarks.multimodal_compare import (
    M,
    W_DEFER_CAP,
    workload_configs,
)

LEVEL = "J2"  # the mid jitter level both sweeps report headline numbers at
SEED = 7


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_SMOKE"))


def _recorded_trace(spec, cm, cfg):
    cfg = dataclasses.replace(cfg, record_trace=True, seed=SEED)
    return ActorDriver(spec, cm, cfg).run().trace


def decomposition_cells(microbatches: int) -> list[dict]:
    """Per (workload, mode): a full per-stage bubble table + comparison."""
    from repro.multimodal import multimodal_dag_costs

    out = []
    for wname, mm in workload_configs().items():
        graph = mm.stage_graph()
        fused = PipelineSpec(mm.num_stages, microbatches, graph=graph)
        split = PipelineSpec(mm.num_stages, microbatches,
                             split_backward=True, graph=graph)
        cm_f = dataclasses.replace(
            multimodal_dag_costs(mm, seed=0),
            injection=INJECTION_LEVELS[LEVEL])
        cm_s = cm_f.with_split_backward()
        reports = {
            "pre_1f1b": decompose(_recorded_trace(fused, cm_f, ActorConfig(
                mode="precommitted", fixed_order="1f1b"))),
            "hint_bfw": decompose(_recorded_trace(split, cm_s, ActorConfig(
                mode="hint", hint=HintKind.BFW,
                w_defer_cap=W_DEFER_CAP))),
        }
        cmp = compare(reports["pre_1f1b"], reports["hint_bfw"])
        out.append({
            "workload": wname,
            "level": LEVEL,
            "stages": mm.num_stages,
            "microbatches": microbatches,
            "modes": {name: rep.to_json() for name, rep in reports.items()},
            "bfw_vs_1f1b": cmp,
        })
    return out


def run_bubble_benchmark() -> dict:
    cells = decomposition_cells(8 if _smoke() else M)
    fully = all(
        mode_rep["idle_fully_attributed"]
        for c in cells for mode_rep in c["modes"].values())
    return {
        "spec": {"level": LEVEL, "seed": SEED, "categories": list(CATEGORIES),
                 "w_defer_cap": W_DEFER_CAP, "smoke": _smoke()},
        "cells": cells,
        "summary": {
            "all_idle_fully_attributed": fully,
            "top_removed_category_per_workload": {
                c["workload"]: c["bfw_vs_1f1b"]["top_removed_category"]
                for c in cells},
            "speedup_per_workload": {
                c["workload"]: c["bfw_vs_1f1b"]["speedup"] for c in cells},
        },
    }


def emit_json(path: str = "BENCH_bubbles.json") -> dict:
    report = run_bubble_benchmark()
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def bubble_rows(
    json_path: str = "BENCH_bubbles.json",
) -> list[tuple[str, float, str]]:
    """CSV rows for ``benchmarks.run``; raises if attribution is lossy."""
    report = emit_json(json_path)
    out = []
    for c in report["cells"]:
        cmp = c["bfw_vs_1f1b"]
        for mode, rep in c["modes"].items():
            tot = rep["category_totals"]
            top = max(tot, key=lambda k: tot[k])
            out.append((
                f"bubbles/{c['workload']}/{mode}",
                rep["makespan"] * 1e6,
                f"idle={sum(s['idle'] for s in rep['stages']):.3f}s,"
                f"top={top}",
            ))
        out.append((
            f"bubbles/{c['workload']}/bfw-removes",
            cmp["removed"][cmp["top_removed_category"]] * 1e6,
            f"category={cmp['top_removed_category']},"
            f"speedup={cmp['speedup']:.2f}x",
        ))
    s = report["summary"]
    if not s["all_idle_fully_attributed"]:
        raise SystemExit(
            "bubble decomposition failed to account for 100% of idle time "
            "(per-stage categories do not sum to makespan - busy)")
    for w, cat in s["top_removed_category_per_workload"].items():
        removed = next(c for c in report["cells"] if c["workload"] == w)[
            "bfw_vs_1f1b"]["removed"][cat]
        if removed <= 0:
            raise SystemExit(
                f"bubble decomposition: BFW removed no idle time on {w} "
                f"(top category {cat} delta {removed:.6f}s)")
    return out


# ---------------------------------------------------------------------------
# instrumented probe for `benchmarks.run --metrics-report / --export-perfetto`
# ---------------------------------------------------------------------------
def telemetry_probe(export_path: str | None = None,
                    metrics_report: bool = True) -> list[tuple[str, float, str]]:
    """One metrics-instrumented recorded run of the heavy-encoder DAG under
    BFW: prints the per-stage metrics table, optionally exports Perfetto."""
    from repro.multimodal import multimodal_dag_costs
    from repro.obs import MetricsRegistry, export_perfetto

    mm = workload_configs()["seamless-m4t-large-v2/heavy-encoder"]
    spec = PipelineSpec(mm.num_stages, 8 if _smoke() else M,
                        split_backward=True, graph=mm.stage_graph())
    cm = dataclasses.replace(
        multimodal_dag_costs(mm, seed=0),
        injection=INJECTION_LEVELS[LEVEL]).with_split_backward()
    registry = MetricsRegistry()
    cfg = ActorConfig(mode="hint", hint=HintKind.BFW,
                      w_defer_cap=W_DEFER_CAP, record_trace=True,
                      seed=SEED, metrics=registry)
    res = ActorDriver(spec, cm, cfg).run()
    if metrics_report:
        print("per-stage metrics (seamless-m4t heavy-encoder, BFW, J2):")
        print(registry.report())
    if export_path:
        export_perfetto(res.trace, export_path)
        print(f"perfetto export ({len(res.trace.events)} events) -> "
              f"{export_path}  (open at ui.perfetto.dev)")
    rep = decompose(res.trace)
    return [(
        "telemetry-probe/heavy-encoder/bfw", res.makespan * 1e6,
        f"idle_attributed={rep.idle_fully_attributed()},"
        f"divergences={sum(sh.hint_divergences() for sh in registry.shards())}",
    )]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in bubble_rows():
        print(f"{name},{us:.1f},{derived}")
