"""Schedule-table builders for the compiled executor.

Fixed baselines (GPipe / 1F1B / ZB-lite) come from the same per-stage order
generators the engine's pre-committed mode uses; the RRFP tables come from
``core.synthesis`` — the readiness-driven engine run on the (EMA-updated)
cost model.  All are just data to the executor: switching schedule never
recompiles.
"""
from __future__ import annotations

import numpy as np

from repro.core.costs import CostModel
from repro.core.hints import (
    HintKind,
    gpipe_order,
    one_f_one_b_order,
    zero_bubble_order,
)
from repro.core.synthesis import synthesize
from repro.core.taskgraph import Kind, PipelineSpec, Task
from repro.pipeline.spec import ScheduleTable, from_stage_orders


def gpipe(spec: PipelineSpec) -> ScheduleTable:
    return from_stage_orders(
        spec, [gpipe_order(spec, s) for s in range(spec.num_stages)]
    )


def one_f_one_b(spec: PipelineSpec) -> ScheduleTable:
    return from_stage_orders(
        spec, [one_f_one_b_order(spec, s) for s in range(spec.num_stages)]
    )


def zero_bubble(spec: PipelineSpec) -> ScheduleTable:
    assert spec.split_backward
    return from_stage_orders(
        spec, [zero_bubble_order(spec, s) for s in range(spec.num_stages)]
    )


def rrfp(
    spec: PipelineSpec,
    costs: CostModel | None = None,
    hint: HintKind = HintKind.BF,
    buffer_limit: int = 32,
) -> ScheduleTable:
    """Readiness-driven table: what the RRFP runtime would realize under the
    expected cost model (uniform costs if none provided)."""
    if costs is None:
        costs = CostModel.uniform(spec.num_stages)
    syn = synthesize(spec, costs, hint=hint, buffer_limit=buffer_limit)
    return from_stage_orders(spec, syn.stage_orders)


def decode_forward(spec: PipelineSpec) -> ScheduleTable:
    """F-only staircase for serve_step: M micro-groups through S stages."""
    S, M = spec.num_stages, spec.num_microbatches
    T = M + S - 1
    from repro.pipeline.spec import OP_F

    ops = np.zeros((S, T), np.int32)
    mbs = np.zeros((S, T), np.int32)
    for s in range(S):
        for j in range(M):
            ops[s, s + j] = OP_F
            mbs[s, s + j] = j
    return ScheduleTable(spec=spec, ops=ops, mbs=mbs)


BUILDERS = {
    "gpipe": gpipe,
    "1f1b": one_f_one_b,
    "zb": zero_bubble,
    "rrfp": rrfp,
}
