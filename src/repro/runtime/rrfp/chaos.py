"""Fault injection for the actor runtime: make variability a test input.

The paper's claim is that readiness-driven consumption stays correct *under
runtime variability*; this module turns variability into a controlled,
deterministic input instead of an accident of the host.  A
:class:`ChaosConfig` describes the perturbations, a :class:`ChaosEngine`
samples them with CRN keying (every draw is keyed by (seed, task, rank), not
pulled from a shared stream), so:

* the *same* chaos realization hits a hint-mode run and a precommitted run
  on the same seed — apples-to-apples correctness and makespan comparisons;
* a re-run with the same config is bit-identical, independent of thread
  interleaving — chaos scenarios are reproducible by (config, seed) alone.

Perturbations (all off by default):

* **per-edge latency** — extra heavy-tailed delay per pipeline edge
  (``latency_base`` scaled by ``edge_scale[(src, dst)]``), applied to every
  envelope on both substrates;
* **message reorder** — with ``reorder_prob``, an envelope is additionally
  delayed by up to ``reorder_window`` seconds, letting later sends overtake
  it in the mailbox;
* **message duplication** — with ``duplicate_prob``, up to
  ``max_duplicates`` extra copies of an envelope are delivered at their own
  sampled delays (the TP gate and mailbox must stay idempotent);
* **stragglers** — per-stage compute slowdown factors: multiplicative on
  the sim substrate's sampled durations, an extra keyed sleep on the thread
  substrate;
* **transient stalls** — with ``stall_prob`` per task, the stage blocks for
  an Exp(``stall_scale``) pause before executing (a GC pause / preemption
  analog);
* **drifting costs** — per-stage compute slowdowns that develop *across
  training steps* (``drift_profile``: a slow ramp or a step change),
  deterministic in (config, stage, step): the regime where a
  statically-synthesized schedule decays and adaptive re-synthesis
  (``runtime.adaptive``) holds its speedup;
* **fail-stop faults** — a stage *dies*: ``kill`` (the actor vanishes
  mid-task; its in-memory state is lost) or ``permanent_stall`` (the actor
  hangs forever — indistinguishable from death to the control plane, which
  must detect it by heartbeat deadline rather than by a closed connection).
  Either an explicit injection point (``fail_stage`` dies at its
  ``fail_after``-th dispatch), a multi-fault plan (``fail_stages``: several
  stages — or the same stage twice, death-during-recovery — each with its
  own kind and dispatch index), or CRN-sampled per stage via ``fail_prob``,
  keyed by (seed, stage) so a scenario's death point is a reproducible
  function of the config.  With ``ActorConfig.recover`` the driver's
  recovery coordinator survives the fault; without it, the fault is
  *promoted to a detectable failure*: the run raises :class:`StageFailure`
  instead of hanging.
* **lossy network** — ``drop_prob`` silently discards a wire transmission;
  ``corrupt_prob`` flips the envelope checksum in flight (detectable — the
  reliable receiver NACKs it, it is never admitted); ``partitions`` are
  bidirectional link blackouts ``(a, b, t_start, duration)`` during which
  every transmission (data and ACK) between stages ``a`` and ``b`` is
  dropped, healing at ``t_start + duration``.  All three require the
  reliable-delivery layer (``ActorConfig.reliable``) — without
  retransmission a dropped message is a silent hang — and every draw is
  keyed by (seed, task, rank, src, attempt), so retries re-roll the loss
  while record/replay of the whole scenario stays exact.
"""
from __future__ import annotations

import dataclasses
import threading
import zlib

import numpy as np

from repro.core.taskgraph import Task

from repro.runtime.rrfp.mailbox import Mailbox
from repro.runtime.rrfp.messages import Envelope

#: fail-stop fault kinds
FAIL_KINDS = ("kill", "permanent_stall")

#: drifting-cost profiles ("" = off): how a stage's compute slowdown
#: develops over training steps (see ChaosConfig.drift_scale)
DRIFT_PROFILES = ("", "ramp", "step")


class StageFailure(RuntimeError):
    """A stage died (fail-stop fault) and no recovery coordinator was armed.

    Raised instead of letting the run hang to its deadlock timeout: the
    chaos ``kill`` / ``permanent_stall`` faults are *detectable* failures,
    and an un-recovered run should fail fast and say why."""

    def __init__(self, stage: int, fail_kind: str, detail: str = ""):
        self.stage = stage
        self.fail_kind = fail_kind
        super().__init__(
            f"stage {stage} suffered a fail-stop fault ({fail_kind})"
            + (f": {detail}" if detail else "")
            + "; enable ActorConfig.recover for elastic recovery")


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """One fault-injection scenario (deterministic given ``seed``)."""

    seed: int = 0
    #: extra per-envelope latency: base seconds (0 disables)
    latency_base: float = 0.0
    #: lognormal sigma on the extra latency
    latency_sigma: float = 0.5
    #: per-(src_stage, dst_stage) multiplier on latency_base
    edge_scale: tuple[tuple[tuple[int, int], float], ...] = ()
    #: probability an envelope is held back by an extra uniform delay
    reorder_prob: float = 0.0
    reorder_window: float = 0.0
    #: probability an envelope is duplicated (each copy re-delayed)
    duplicate_prob: float = 0.0
    max_duplicates: int = 1
    #: per-stage compute slowdown: ((stage, factor), ...), factor >= 1
    straggler: tuple[tuple[int, float], ...] = ()
    #: thread substrate: seconds of extra sleep per unit of (factor - 1)
    straggler_unit: float = 1e-3
    #: per-task transient stage stall
    stall_prob: float = 0.0
    stall_scale: float = 0.0  # Exp() scale, seconds
    #: fail-stop fault: explicit injection — this stage dies at the dispatch
    #: of its ``fail_after``-th task (0-indexed; that task never completes
    #: and the stage makes no further progress).  -1 disables.
    fail_stage: int = -1
    fail_kind: str = "kill"  # "kill" | "permanent_stall"
    fail_after: int = 0
    #: fail-stop fault: CRN-sampled — each stage independently dies with
    #: this probability, at a death point drawn from (seed, stage)
    fail_prob: float = 0.0
    #: multi-fault plan: ((stage, kind, after), ...) — overlapping faults
    #: (concurrent deaths, or the same stage listed twice for
    #: death-during-recovery; ``after`` counts the stage's dispatches
    #: *across incarnations*, so a second entry must exceed the first)
    fail_stages: tuple[tuple[int, str, int], ...] = ()
    #: ---- lossy network (requires ActorConfig.reliable) -------------------
    #: probability one wire transmission (one attempt x one chaos copy) is
    #: silently dropped; ACK/NACK transmissions roll independently
    drop_prob: float = 0.0
    #: probability one wire transmission arrives with a corrupted checksum
    corrupt_prob: float = 0.0
    #: bidirectional link blackouts: ((a, b, t_start, duration), ...) in
    #: substrate seconds — between t_start and t_start + duration nothing
    #: crosses the a<->b edge in either direction
    partitions: tuple[tuple[int, int, float, float], ...] = ()
    #: ---- drifting compute costs (adaptive-scheduling scenarios) ----------
    #: "" (off) | "ramp" (slowdown grows linearly over drift_period steps,
    #: then holds) | "step" (slowdown switches on at step == drift_period)
    drift_profile: str = ""
    #: per-stage drift targets: ((stage, peak_factor), ...), factor >= 1 —
    #: the stage's compute slowdown once the drift has fully developed
    drift: tuple[tuple[int, float], ...] = ()
    #: steps to full ramp / the step-change point
    drift_period: int = 8
    #: the current training iteration — the drift's time axis.  The caller
    #: advances it between runs (``dataclasses.replace(chaos, step=k)``);
    #: within one run the scale is constant, so CRN keying is untouched.
    step: int = 0

    def __post_init__(self):
        if self.fail_kind not in FAIL_KINDS:
            raise ValueError(
                f"fail_kind must be one of {FAIL_KINDS}, "
                f"got {self.fail_kind!r}")
        if self.drift_profile not in DRIFT_PROFILES:
            raise ValueError(
                f"drift_profile must be one of {DRIFT_PROFILES}, "
                f"got {self.drift_profile!r}")
        for entry in self.fail_stages:
            s, kind, after = entry
            if kind not in FAIL_KINDS:
                raise ValueError(
                    f"fail_stages entry {entry!r}: kind must be one of "
                    f"{FAIL_KINDS}")
        for entry in self.partitions:
            if len(entry) != 4:
                raise ValueError(
                    f"partitions entry {entry!r}: expected "
                    f"(stage_a, stage_b, t_start, duration)")

    def active(self) -> bool:
        return (self.latency_base > 0 or self.reorder_prob > 0
                or self.duplicate_prob > 0 or bool(self.straggler)
                or self.stall_prob > 0 or self.fail_stage >= 0
                or self.fail_prob > 0 or bool(self.fail_stages)
                or self.lossy()
                or bool(self.drift_profile and self.drift))

    def lossy(self) -> bool:
        """True when messages can be lost or mangled outright — the regime
        that requires the reliable-delivery layer (``ActorConfig.reliable``)."""
        return (self.drop_prob > 0 or self.corrupt_prob > 0
                or bool(self.partitions))

    def drift_scale(self, stage: int) -> float:
        """Deterministic per-stage compute slowdown at ``self.step``.

        A pure function of (config, stage, step): no RNG draw, so drift
        composes with CRN chaos keying and replays exactly."""
        if not self.drift_profile:
            return 1.0
        mag = dict(self.drift).get(stage)
        if mag is None:
            return 1.0
        if self.drift_profile == "ramp":
            f = min(1.0, self.step / max(1, self.drift_period))
        else:  # "step"
            f = 1.0 if self.step >= self.drift_period else 0.0
        return 1.0 + (mag - 1.0) * f

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["edge_scale"] = [[list(k), v] for k, v in self.edge_scale]
        d["straggler"] = [list(kv) for kv in self.straggler]
        d["drift"] = [list(kv) for kv in self.drift]
        d["fail_stages"] = [list(kv) for kv in self.fail_stages]
        d["partitions"] = [list(kv) for kv in self.partitions]
        return d


#: Named intensity levels for sweeps and the CLI (C0 = control).
CHAOS_LEVELS = {
    "C0": ChaosConfig(),
    "C1": ChaosConfig(latency_base=5e-4, reorder_prob=0.1,
                      reorder_window=2e-3, duplicate_prob=0.05),
    "C2": ChaosConfig(latency_base=2e-3, reorder_prob=0.3, reorder_window=1e-2,
                      duplicate_prob=0.15, straggler=((1, 2.0),),
                      stall_prob=0.05, stall_scale=5e-3),
    "C3": ChaosConfig(latency_base=5e-3, latency_sigma=1.0, reorder_prob=0.5,
                      reorder_window=5e-2, duplicate_prob=0.3,
                      max_duplicates=2, straggler=((1, 3.0), (2, 2.0)),
                      stall_prob=0.15, stall_scale=2e-2),
}


#: Modality-aware fault profiles for heterogeneous (DAG) pipelines.  Each
#: profile targets one *branch* of a branch+fusion topology: which stages
#: straggle and which edges get scaled latency depend on the stage roles,
#: so the profile is a function of (encoder stages, decoder stages, fan-in
#: edges) rather than a fixed config.  Compose with a base intensity level:
#: ``modality_profile("slow_vision", ..., level="C2")``.
MODALITY_PROFILE_NAMES = ("slow_vision", "slow_decoder", "flaky_fusion_link")


def modality_profile(
    name: str,
    *,
    encoder_stages: tuple[int, ...] | list[int],
    decoder_stages: tuple[int, ...] | list[int],
    fanin_edges: tuple[tuple[int, int], ...] | list[tuple[int, int]] = (),
    level: str | ChaosConfig = "C1",
    seed: int | None = None,
) -> ChaosConfig:
    """Per-branch fault profile on top of a chaos intensity level.

    * ``slow_vision``      — the encoder branch straggles (3x on its slowest
      stage, 2x elsewhere in the branch): the regime where fixed orders
      tuned for balanced stages serialize on the cheap branch.
    * ``slow_decoder``     — the LM/decoder chain straggles instead: the
      encoder branch races ahead and fan-in buffering absorbs the skew.
    * ``flaky_fusion_link``— the fan-in edges into the fusion stage carry
      8x latency (and inherit the level's reorder/duplication): stresses
      the multi-predecessor admission gate under partial arrival.
    """
    base = CHAOS_LEVELS[level] if isinstance(level, str) else level
    if seed is not None:
        base = dataclasses.replace(base, seed=seed)
    enc = tuple(int(s) for s in encoder_stages)
    dec = tuple(int(s) for s in decoder_stages)
    if name == "slow_vision":
        strag = tuple((s, 3.0 if i == len(enc) - 1 else 2.0)
                      for i, s in enumerate(enc))
        return dataclasses.replace(base, straggler=strag)
    if name == "slow_decoder":
        strag = tuple((s, 2.5 if i == 0 else 2.0)
                      for i, s in enumerate(dec))
        return dataclasses.replace(base, straggler=strag)
    if name == "flaky_fusion_link":
        if not fanin_edges:
            raise ValueError(
                "flaky_fusion_link targets the fan-in edges; pass "
                "fanin_edges=((enc_last, fusion), (text, fusion), ...)")
        scale = tuple(((int(a), int(b)), 8.0) for a, b in fanin_edges)
        return dataclasses.replace(
            base,
            latency_base=max(base.latency_base, 5e-4),
            edge_scale=scale)
    raise ValueError(
        f"unknown modality profile {name!r}; "
        f"available: {MODALITY_PROFILE_NAMES}")


def drift_chaos(
    profile: str,
    targets: dict[int, float] | tuple[tuple[int, float], ...] | list[tuple[int, float]],
    period: int = 8,
    level: str | ChaosConfig = "C0",
    seed: int | None = None,
) -> ChaosConfig:
    """A drifting-cost scenario on top of a chaos intensity level.

    ``profile`` is ``"ramp"`` (slow creep — thermal throttling, a failing
    NIC's retransmits, a co-tenant warming up) or ``"step"`` (regime change
    — a remapped stage landing on a time-shared device, a frequency cap
    kicking in).  ``targets`` names the stages that slow down and their
    peak factors; the drift develops over ``period`` steps, advanced by
    the caller via ``dataclasses.replace(chaos, step=k)`` per iteration.
    This is the regime where a statically-synthesized hint decays and the
    adaptive re-synthesizer earns its keep (benchmarks/adaptive_compare).
    """
    base = CHAOS_LEVELS[level] if isinstance(level, str) else level
    if seed is not None:
        base = dataclasses.replace(base, seed=seed)
    pairs = targets.items() if isinstance(targets, dict) else targets
    return dataclasses.replace(
        base, drift_profile=profile,
        drift=tuple((int(s), float(f)) for s, f in pairs),
        drift_period=int(period))


#: parse_chaos key grammar (everything else is rejected, loudly)
_CHAOS_PAIR_KEYS = ("straggler", "drift")
_CHAOS_INT_KEYS = ("seed", "max_duplicates", "fail_stage", "fail_after",
                   "drift_period", "step")
_CHAOS_STR_KEYS = ("fail_kind", "drift_profile")
_CHAOS_FLOAT_KEYS = ("latency_base", "latency_sigma", "reorder_prob",
                     "reorder_window", "duplicate_prob", "straggler_unit",
                     "stall_prob", "stall_scale", "fail_prob", "drop_prob",
                     "corrupt_prob")
_CHAOS_STRUCT_KEYS = ("partition", "fail_stages")
CHAOS_SPEC_KEYS = (_CHAOS_PAIR_KEYS + _CHAOS_INT_KEYS + _CHAOS_STR_KEYS
                   + _CHAOS_FLOAT_KEYS + _CHAOS_STRUCT_KEYS)


def parse_chaos(spec: str) -> ChaosConfig:
    """CLI syntax: a level name and/or comma-separated key=value overrides.

        --chaos C2
        --chaos C1,reorder_prob=0.5,seed=7
        --chaos latency_base=1e-3,straggler=1:2.5+3:4.0
        --chaos drop_prob=0.05,corrupt_prob=0.01,partition=1:2:0.02:0.05
        --chaos fail_stages=1:kill:2+3:kill:4

    The level (at most one) is the base config regardless of where it
    appears; key=value parts override it in order.  ``partition`` entries
    are ``a:b:t_start:duration`` (``+``-joined for several); ``fail_stages``
    entries are ``stage:kind:after``.  Unknown keys and malformed values
    fail fast with the list of valid keys — a typo must never silently
    parse to "no chaos".
    """
    parts = list(filter(None, (p.strip() for p in spec.split(","))))
    levels = [p for p in parts if p in CHAOS_LEVELS]
    if len(levels) > 1:
        raise ValueError(f"at most one chaos level, got {levels}")
    cfg = CHAOS_LEVELS[levels[0]] if levels else ChaosConfig()
    for part in parts:
        if part in CHAOS_LEVELS:
            continue
        if "=" not in part:
            raise ValueError(
                f"bad chaos spec {part!r}: expected a level in "
                f"{sorted(CHAOS_LEVELS)} or key=value "
                f"(keys: {sorted(CHAOS_SPEC_KEYS)})")
        key, val = part.split("=", 1)
        if key not in CHAOS_SPEC_KEYS:
            raise ValueError(
                f"unknown chaos key {key!r} in {part!r}; valid keys: "
                f"{sorted(CHAOS_SPEC_KEYS)}")
        try:
            if key in _CHAOS_PAIR_KEYS:
                pairs = tuple(
                    (int(s), float(f))
                    for s, f in (kv.split(":") for kv in val.split("+")))
                cfg = dataclasses.replace(cfg, **{key: pairs})
            elif key == "partition":
                quads = tuple(
                    (int(a), int(b), float(t0), float(d))
                    for a, b, t0, d in
                    (kv.split(":") for kv in val.split("+")))
                cfg = dataclasses.replace(cfg, partitions=quads)
            elif key == "fail_stages":
                triples = tuple(
                    (int(s), kind, int(k))
                    for s, kind, k in
                    (kv.split(":") for kv in val.split("+")))
                cfg = dataclasses.replace(cfg, fail_stages=triples)
            elif key in _CHAOS_INT_KEYS:
                cfg = dataclasses.replace(cfg, **{key: int(val)})
            elif key in _CHAOS_STR_KEYS:
                cfg = dataclasses.replace(cfg, **{key: val})
            else:
                cfg = dataclasses.replace(cfg, **{key: float(val)})
        except ValueError as exc:
            # __post_init__ rejections (bad fail_kind etc.) are already
            # descriptive; wrap only raw conversion failures
            if "chaos" in str(exc) or "must be one of" in str(exc):
                raise
            raise ValueError(
                f"bad chaos value in {part!r}: {exc}") from exc
    return cfg


class ChaosEngine:
    """CRN-keyed sampler for one ChaosConfig.

    Stateless across calls: every sample is drawn from a generator keyed by
    (seed, purpose, task, rank), so results do not depend on call order,
    thread interleaving, or how many other samples were drawn — the property
    that makes chaotic runs replayable and mode comparisons fair.
    """

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self._edge = dict(cfg.edge_scale)
        self._straggler = dict(cfg.straggler)

    def _rng(self, purpose: str, task: Task, rank: int = 0,
             copy: int = 0, src: int = -1) -> np.random.Generator:
        return np.random.default_rng(
            [self.cfg.seed & 0x7FFFFFFF, zlib.crc32(purpose.encode()),
             int(task.kind), task.stage, task.mb, task.chunk, rank, copy,
             src & 0x7FFFFFFF])

    # ---- communication -----------------------------------------------------
    def comm_delay(self, env: Envelope, copy: int = 0) -> float:
        """Extra delivery delay for one envelope copy (0 when inactive).

        Keyed per (task, rank, copy, source edge): a DAG fan-in task's
        branch messages draw independent delays.
        """
        cfg, delay = self.cfg, 0.0
        if cfg.latency_base > 0:
            rng = self._rng("lat", env.task, env.rank, copy, env.src_stage)
            scale = self._edge.get((env.src_stage, env.dst_stage), 1.0)
            delay += cfg.latency_base * scale * float(rng.lognormal(
                mean=-0.5 * cfg.latency_sigma**2, sigma=cfg.latency_sigma))
        if cfg.reorder_prob > 0:
            rng = self._rng("reorder", env.task, env.rank, copy,
                            env.src_stage)
            if rng.random() < cfg.reorder_prob:
                delay += cfg.reorder_window * float(rng.random())
        return delay

    def copies(self, env: Envelope) -> int:
        """Total deliveries for this envelope (>= 1)."""
        if self.cfg.duplicate_prob <= 0:
            return 1
        rng = self._rng("dup", env.task, env.rank, src=env.src_stage)
        extra = 0
        while (extra < self.cfg.max_duplicates
               and rng.random() < self.cfg.duplicate_prob):
            extra += 1
        return 1 + extra

    # ---- compute -----------------------------------------------------------
    def compute_scale(self, stage: int) -> float:
        """Static straggler factor x the drift profile's step-``k`` factor
        (both deterministic; the product is what realized durations see)."""
        return self._straggler.get(stage, 1.0) * self.cfg.drift_scale(stage)

    def stall(self, task: Task) -> float:
        """Transient stage stall before executing ``task`` (seconds)."""
        if self.cfg.stall_prob <= 0:
            return 0.0
        rng = self._rng("stall", task)
        if rng.random() >= self.cfg.stall_prob:
            return 0.0
        return self.cfg.stall_scale * float(rng.exponential())

    def thread_delay(self, task: Task) -> float:
        """Thread substrate: total injected sleep before executing ``task``
        (stall + straggler emulation; compute itself cannot be scaled)."""
        factor = self.compute_scale(task.stage)
        return self.stall(task) + (factor - 1.0) * self.cfg.straggler_unit

    # ---- fail-stop ---------------------------------------------------------
    def fail_point(self, stage: int, n_tasks: int) -> tuple[str, int] | None:
        """Does ``stage`` suffer a fail-stop fault this run, and when?

        Returns ``(fail_kind, k)`` — the stage dies at the dispatch of its
        k-th task (0-indexed) — or None.  The sampled path is keyed by
        (seed, "fail", stage): a pure function of the config, so the same
        scenario kills the same stage at the same point in every consumption
        mode and on both substrates (CRN)."""
        cfg = self.cfg
        if cfg.fail_stage == stage:
            # clamp into the stage's dispatch range so an armed fault always
            # fires (a never-firing fault would hang the recovery coordinator)
            return (cfg.fail_kind, min(max(0, cfg.fail_after), n_tasks - 1))
        if cfg.fail_prob > 0:
            rng = np.random.default_rng(
                [cfg.seed & 0x7FFFFFFF, zlib.crc32(b"fail"), stage])
            if rng.random() < cfg.fail_prob:
                return (cfg.fail_kind, int(rng.integers(0, max(1, n_tasks))))
        return None

    def fail_points(self, stage: int, n_tasks: int) -> list[tuple[str, int]]:
        """All fail-stop faults planned for ``stage``, in dispatch order.

        Supersets :meth:`fail_point` with the ``fail_stages`` multi-fault
        plan: the same stage may appear several times (death-during-recovery)
        and several stages may carry overlapping windows.  Each entry's
        dispatch index is clamped into range so an armed fault always fires;
        duplicate indices on one stage are collapsed (a stage can only die
        once per dispatch)."""
        pts: list[tuple[str, int]] = []
        single = self.fail_point(stage, n_tasks)
        if single is not None:
            pts.append(single)
        for s, kind, after in self.cfg.fail_stages:
            if s == stage:
                pts.append((kind, min(max(0, after), max(0, n_tasks - 1))))
        pts.sort(key=lambda p: p[1])
        out: list[tuple[str, int]] = []
        for kind, k in pts:
            if not out or out[-1][1] != k:
                out.append((kind, k))
        return out

    # ---- lossy network -----------------------------------------------------
    def partitioned(self, a: int, b: int, now: float) -> bool:
        """Is the a<->b link blacked out at substrate time ``now``?"""
        for pa, pb, t0, dur in self.cfg.partitions:
            if {pa, pb} == {a, b} and t0 <= now < t0 + dur:
                return True
        return False

    def dropped(self, env: Envelope, now: float, attempt: int = 0,
                copy: int = 0) -> bool:
        """Is this wire transmission (one attempt x one copy) lost?

        Partitions drop deterministically (a blackout loses everything on
        the edge); otherwise ``drop_prob`` rolls per (task, rank, attempt,
        copy, src) — a retransmission re-rolls its fate, which is what lets
        bounded retry eventually get through a merely-lossy link while a
        partition defeats it until it heals or retry escalates."""
        if self.partitioned(env.src_stage, env.dst_stage, now):
            return True
        if self.cfg.drop_prob <= 0:
            return False
        rng = self._rng(f"drop:{attempt}:{copy}", env.task, env.rank,
                        src=env.src_stage)
        return bool(rng.random() < self.cfg.drop_prob)

    def corrupted(self, env: Envelope, attempt: int = 0) -> bool:
        """Does this transmission arrive with a mangled checksum?"""
        if self.cfg.corrupt_prob <= 0:
            return False
        rng = self._rng(f"corrupt:{attempt}", env.task, env.rank,
                        src=env.src_stage)
        return bool(rng.random() < self.cfg.corrupt_prob)

    def ack_dropped(self, env: Envelope, now: float,
                    attempt: int = 0) -> bool:
        """Is the ACK/NACK for this (env, attempt) lost on the way back?

        ACKs traverse the same lossy wire (reverse direction of the data
        edge) but carry no reliability of their own — a lost ACK is healed
        by the sender's retransmission plus receiver-side dedup."""
        if self.partitioned(env.src_stage, env.dst_stage, now):
            return True
        if self.cfg.drop_prob <= 0:
            return False
        rng = self._rng(f"ackdrop:{attempt}", env.task, env.rank,
                        src=env.src_stage)
        return bool(rng.random() < self.cfg.drop_prob)


class ChaosThreadTransport:
    """Thread-substrate transport applying chaos on the delivery path.

    Delayed or duplicated envelopes are delivered from daemon timer threads;
    an undelayed, unduplicated envelope takes the direct path (no timer).
    ``drain`` blocks until every outstanding delayed delivery has landed, so
    a driver can guarantee no timer outlives the run.
    """

    def __init__(self, mailboxes: dict[int, Mailbox], chaos: ChaosEngine,
                 on_send=None):
        self.mailboxes = mailboxes
        self.chaos = chaos
        self.on_send = on_send
        self.sent = 0
        self._pending = 0
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)

    def send(self, env: Envelope, now: float = 0.0) -> None:
        self.sent += 1
        if self.on_send is not None:
            self.on_send(env, now)
        n = self.chaos.copies(env)
        for copy in range(n):
            delay = self.chaos.comm_delay(env, copy)
            if copy == 0 and delay <= 0:
                self.mailboxes[env.dst_stage].deliver(env, now=now)
                continue
            with self._lock:
                self._pending += 1
            timer = threading.Timer(
                max(delay, 1e-6), self._deliver_late, args=(env, now + delay))
            timer.daemon = True
            timer.start()

    def _deliver_late(self, env: Envelope, at: float) -> None:
        try:
            self.mailboxes[env.dst_stage].deliver(env, now=at)
        finally:
            with self._lock:
                self._pending -= 1
                if self._pending == 0:
                    self._idle.notify_all()

    def drain(self, timeout: float = 30.0) -> bool:
        with self._lock:
            return self._idle.wait_for(lambda: self._pending == 0, timeout)
