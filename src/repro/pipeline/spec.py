"""ScheduleTable: the compiled executor's tick-grid schedule.

A table assigns every (stage, tick) one op (IDLE/F/B/W) and a microbatch id.
``from_stage_orders`` list-schedules per-stage task sequences (e.g. the
realized orders extracted from the RRFP engine) onto the grid under the
executor's communication model: one ring-permute hop per tick, so a message
produced at tick t is consumable at tick t+1.

``validate`` enforces exactly the paper's buffer-policy legality (App. C):
dependency order, one op per stage per tick, and bounded buffer-slot
occupancy (no two in-flight microbatches may collide in a slot).  The
returned occupancy maxima size the executor's on-device buffers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.taskgraph import Kind, PipelineSpec, Task

OP_IDLE, OP_F, OP_B, OP_W = 0, 1, 2, 3
OP_NAMES = {OP_IDLE: ".", OP_F: "F", OP_B: "B", OP_W: "W"}


@dataclasses.dataclass
class ScheduleTable:
    spec: PipelineSpec
    ops: np.ndarray  # [S, T] int32
    mbs: np.ndarray  # [S, T] int32

    @property
    def num_ticks(self) -> int:
        return self.ops.shape[1]

    # ------------------------------------------------------------------
    def tick_of(self) -> dict[Task, int]:
        out = {}
        S, T = self.ops.shape
        kind_of = {OP_F: Kind.F, OP_B: Kind.B, OP_W: Kind.W}
        for s in range(S):
            for t in range(T):
                if self.ops[s, t] != OP_IDLE:
                    out[Task(kind_of[int(self.ops[s, t])], s, int(self.mbs[s, t]))] = t
        return out

    # ------------------------------------------------------------------
    def validate(self) -> dict[str, int]:
        """Check legality; return buffer occupancy maxima.

        Occupancies (per stage):
          act   — activation received from prev stage, held until F runs
          res   — F's input saved for recompute, held until B (and W) run
          grad  — gradient received from next stage, held until B runs
        """
        spec = self.spec
        S, M = spec.num_stages, spec.num_microbatches
        tick = self.tick_of()
        expect = set(spec.tasks())
        got = set(tick)
        if got != expect:
            missing = sorted(expect - got)[:4]
            extra = sorted(got - expect)[:4]
            raise ValueError(f"schedule incomplete: missing={missing} extra={extra}")
        # dependencies (message deps need a full tick of transit)
        for task, t in tick.items():
            mp = spec.message_predecessor(task)
            if mp is not None and tick[mp] >= t:
                raise ValueError(f"{task}@{t} before message dep {mp}@{tick[mp]}")
            lp = spec.local_predecessor(task)
            if lp is not None and tick[lp] >= t:
                raise ValueError(f"{task}@{t} before local dep {lp}@{tick[lp]}")
        # buffer occupancy intervals; the executor keys slots by mb % K, so K
        # must cover the microbatch-index *span* of concurrently live entries
        occ = {"act": 0, "res": 0, "grad": 0,
               "act_span": 0, "res_span": 0, "grad_span": 0}
        for s in range(S):
            ivs = {"act": [], "res": [], "grad": []}
            for j in range(M):
                f_t = tick[Task(Kind.F, s, j)]
                b_t = tick[Task(Kind.B, s, j)]
                end_t = tick[Task(Kind.W, s, j)] if spec.split_backward else b_t
                if s > 0:
                    ivs["act"].append((tick[Task(Kind.F, s - 1, j)] + 1, f_t, j))
                ivs["res"].append((f_t, end_t, j))
                if s < S - 1:
                    end_g = (tick[Task(Kind.W, s, j)]
                             if spec.split_backward else b_t)
                    ivs["grad"].append((tick[Task(Kind.B, s + 1, j)] + 1, end_g, j))
            for name, iv in ivs.items():
                occ[name] = max(occ[name], _max_overlap([(a, b) for a, b, _ in iv]))
                occ[name + "_span"] = max(occ[name + "_span"], _max_span(iv))
        return occ

    def render(self) -> str:
        S, T = self.ops.shape
        rows = []
        for s in range(S):
            cells = [
                f"{OP_NAMES[int(self.ops[s, t])]}{int(self.mbs[s, t]):<2d}"
                if self.ops[s, t] != OP_IDLE else " . "
                for t in range(T)
            ]
            rows.append(f"s{s:<2d} " + " ".join(cells))
        return "\n".join(rows)

    def bubble_fraction(self) -> float:
        busy = (self.ops != OP_IDLE).sum()
        return 1.0 - busy / self.ops.size


def _max_overlap(intervals) -> int:
    events = []
    for a, b in intervals:
        events.append((a, 1))
        events.append((b + 1, -1))
    events.sort()
    cur = peak = 0
    for _, d in events:
        cur += d
        peak = max(peak, cur)
    return peak


def _max_span(intervals) -> int:
    """Max (max_j - min_j + 1) over microbatches live at the same tick."""
    if not intervals:
        return 0
    ticks = sorted({t for a, b, _ in intervals for t in (a, b)})
    span = 0
    for t in ticks:
        live = [j for a, b, j in intervals if a <= t <= b]
        if live:
            span = max(span, max(live) - min(live) + 1)
    return span


# ---------------------------------------------------------------------------
def from_stage_orders(
    spec: PipelineSpec, stage_orders: list[list[Task]]
) -> ScheduleTable:
    """Greedy list-schedule of per-stage task sequences onto the tick grid.

    Each stage executes its sequence in order; a task waits until its
    dependencies' completion ticks are strictly earlier (message deps need
    one transit tick, modeled by the strict inequality).
    """
    S, M = spec.num_stages, spec.num_microbatches
    tick: dict[Task, int] = {}
    ptr = [0] * S
    stage_free = [0] * S  # earliest tick the stage can run something
    placed = 0
    total = spec.total_tasks()
    ops = []
    while placed < total:
        progress = False
        for s in range(S):
            while ptr[s] < len(stage_orders[s]):
                task = stage_orders[s][ptr[s]]
                deps = spec.predecessors(task)
                ready_at = stage_free[s]
                ok = True
                for d in deps:
                    if d not in tick:
                        ok = False
                        break
                    ready_at = max(ready_at, tick[d] + 1)
                if not ok:
                    break
                tick[task] = ready_at
                stage_free[s] = ready_at + 1
                ptr[s] += 1
                placed += 1
                progress = True
        if not progress:
            stuck = [
                stage_orders[s][ptr[s]]
                for s in range(S)
                if ptr[s] < len(stage_orders[s])
            ]
            raise ValueError(f"cyclic stage orders; stuck at {stuck[:4]}")
    T = max(tick.values()) + 1
    ops_arr = np.zeros((S, T), np.int32)
    mbs_arr = np.zeros((S, T), np.int32)
    op_of = {Kind.F: OP_F, Kind.B: OP_B, Kind.W: OP_W}
    for task, t in tick.items():
        ops_arr[task.stage, t] = op_of[task.kind]
        mbs_arr[task.stage, t] = task.mb
    return ScheduleTable(spec=spec, ops=ops_arr, mbs=mbs_arr)
