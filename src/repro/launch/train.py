"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
        --devices 8 --stages 4 --steps 20 --schedule rrfp --ckpt-dir /tmp/ck

Runs on whatever devices exist (forced host devices for CPU runs), wiring
together: synthetic data prefetch, the schedule-table executor, ZeRO-1
AdamW, checkpoint/restart, straggler-driven re-synthesis, and (optionally)
jitter injection to demonstrate the RRFP loop end-to-end.

``--runtime actor`` (opt-in) swaps the compiled schedule-table executor for
the host actor runtime (``repro.runtime.rrfp``): thread-per-stage actors
dispatch real jitted stage callables by message arrival under hint-order
arbitration, accumulate grads per stage, and feed realized per-task timings
into the straggler monitor's EMA — the paper's runtime loop made executable:

    PYTHONPATH=src python -m repro.launch.train --runtime actor \
        --arch deepseek-7b --stages 2 --microbatches 4 --steps 5 --seq 32
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.store import CheckpointStore
from repro.configs import registry
from repro.core.costs import CostModel
from repro.core.hints import HintKind
from repro.core.taskgraph import PipelineSpec
from repro.data.synthetic import PrefetchIterator, synth_batch
from repro.launch.mesh import make_mesh
from repro.models.build import build
from repro.optim.adamw import AdamWConfig, make_optimizer
from repro.pipeline import schedules
from repro.pipeline.executor import ExecOptions, make_train_fn
from repro.pipeline.sharding import partition_for
from repro.runtime.straggler import StragglerMonitor


def build_trainer(arch: str, *, data: int, stages: int, layers: int | None,
                  mb_rows: int, microbatches: int, seq: int,
                  schedule: str = "rrfp", reduced: bool = True,
                  lr: float = 1e-3, total_steps: int = 1000):
    cfg = (registry.reduced_config(arch, num_layers=layers)
           if reduced else registry.get_arch(arch))
    model = build(cfg, num_stages=stages)
    mesh = make_mesh(data, stages)
    key = jax.random.key(0)
    stage_params = model.init_stage_params(key)
    io_params = model.init_io_params(jax.random.fold_in(key, 1))
    partition = partition_for(model, stage_params, io_params)

    spec = PipelineSpec(stages, microbatches,
                        split_backward=(schedule == "zb"))
    table = schedules.BUILDERS[schedule](spec)
    global_tokens = data * microbatches * mb_rows * seq
    opts = ExecOptions(mb_rows=mb_rows, seq_len=seq,
                       loss_scale=1.0 / global_tokens)
    exec_fn, _ = make_train_fn(model, table, mesh, opts, partition)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=20, total_steps=total_steps)
    opt_init, opt_update = make_optimizer(model, mesh, partition, opt_cfg)

    @jax.jit
    def train_step(stage_params, io_params, opt_state, batch, step):
        metrics, grad_shard, expert_grads = exec_fn(
            stage_params, io_params, batch)
        stage_params, io_params, opt_state, stats = opt_update(
            stage_params, io_params, opt_state, grad_shard, expert_grads,
            step)
        return stage_params, io_params, opt_state, {**metrics, **stats}

    opt_state = jax.jit(opt_init)(stage_params, io_params)
    batch_size = data * microbatches * mb_rows
    return dict(
        cfg=cfg, model=model, mesh=mesh, table=table, spec=spec,
        stage_params=stage_params, io_params=io_params,
        opt_state=opt_state, train_step=train_step,
        batch_size=batch_size, seq=seq, partition=partition,
        exec_fn=exec_fn, opts=opts,
    )


# ---------------------------------------------------------------------------
# observability (--metrics-report / --export-perfetto; actor runtime only)
# ---------------------------------------------------------------------------
def _obs_registry(args):
    """A MetricsRegistry when ``--metrics-report`` asked for one, else None
    (None keeps the runtime's metrics hooks at their zero-cost path)."""
    if not getattr(args, "metrics_report", False):
        return None
    from repro.obs import MetricsRegistry
    return MetricsRegistry()


def _obs_record_step0(args, step: int, first: int = 0) -> bool:
    """Record the first step's trace when any end-of-run consumer needs
    it (Perfetto export, the --explain health report, or --record-trace)."""
    return step == first and (
        bool(args.record_trace)
        or bool(getattr(args, "export_perfetto", None))
        or bool(getattr(args, "explain", False)))


def _obs_finish(args, registry, trace) -> None:
    """End-of-run sync point: print the summary table, export Perfetto."""
    if registry is not None and getattr(args, "metrics_report", False):
        print("\nper-stage metrics (accumulated over all steps):")
        print(registry.report())
    if getattr(args, "export_perfetto", None):
        from repro.obs import export_perfetto
        if trace is None:
            raise SystemExit(
                "--export-perfetto: no trace was recorded to export")
        export_perfetto(trace, args.export_perfetto)
        print(f"perfetto export ({len(trace.events)} events) -> "
              f"{args.export_perfetto}  (open at ui.perfetto.dev)")
    if getattr(args, "explain", False):
        from repro.obs.report import explain
        if trace is None:
            raise SystemExit(
                "--explain: no trace was recorded to analyze")
        print("\n" + explain(trace).format())


# ---------------------------------------------------------------------------
# multimodal DAG workload (--workload multimodal)
# ---------------------------------------------------------------------------
def _multimodal_stage_split(stages: int) -> tuple[int, int]:
    """Split a total stage budget into (encoder, LM) branch depths.

    Total stages = encoder branch + 1 text frontend + LM chain; the LM
    chain (fusion + decoder) gets at least as many stages as the encoder.
    """
    if stages < 3:
        raise SystemExit(
            "--workload multimodal needs --stages >= 3 "
            "(encoder branch + text frontend + fusion/LM chain)")
    enc = max(1, (stages - 1) // 2)
    return enc, stages - 1 - enc


def train_multimodal(args) -> list[float]:
    """Train the branch+fusion multimodal DAG pipeline on the actor runtime.

    ``--substrate thread`` (default) drives the real jitted encoder /
    fusion / LM stage callables with thread-per-stage actors, including
    variable-length vision/audio microbatches via shape bucketing and
    (optionally) BFW split backward.  ``--substrate sim`` runs the same
    DAG task graph through the virtual-clock actor substrate on the DES
    cost model of the same topology (per-microbatch skew from the shared
    modality length sampler) — useful for schedule experiments without a
    device.  Returns the loss history (thread) or makespan history (sim).
    """
    from repro.multimodal import (
        MultimodalStageFns, MultimodalStageProgram, multimodal_config,
        multimodal_dag_costs, multimodal_model)
    from repro.multimodal.model import MULTIMODAL_ARCHS
    from repro.multimodal.stagefn import MultimodalStageOptions
    from repro.optim.adamw import AdamWConfig, make_host_update
    from repro.runtime.rrfp import ActorConfig, ActorDriver, parse_chaos

    if args.arch is None:
        args.arch = "qwen2-vl-2b"
    if args.arch not in MULTIMODAL_ARCHS:
        raise SystemExit(
            f"--workload multimodal needs a multimodal arch, not "
            f"{args.arch!r}; registered: {sorted(MULTIMODAL_ARCHS)}")
    if args.replay_trace:
        raise SystemExit("--replay-trace is not supported for the "
                         "multimodal workload yet; record works")
    enc_stages, lm_stages = _multimodal_stage_split(args.stages)
    model = multimodal_model(
        args.arch, enc_stages=enc_stages, lm_stages=lm_stages,
        text_seq=args.seq, reduced=not args.full_size,
        num_layers=args.layers)
    cfg = model.cfg
    split = args.split_backward or args.schedule == "zb"
    hint = HintKind(args.hint)
    chaos = parse_chaos(args.chaos) if args.chaos else None
    spec = cfg.spec(args.microbatches, split_backward=split)
    if args.schedule == "rrfp":
        mode, fixed = "hint", "1f1b"
        if split != (hint == HintKind.BFW):
            raise SystemExit(
                "--hint bfw and --split-backward go together (the BFW hint "
                "needs W tasks, which only exist under split backward)")
    elif args.schedule in ("1f1b", "gpipe", "zb"):
        mode, fixed = "precommitted", args.schedule
        if (args.schedule == "zb") != split:
            raise SystemExit("--schedule zb is the split-backward baseline; "
                             "1f1b/gpipe are fused-only")
    else:
        raise SystemExit(
            f"--workload multimodal supports schedules rrfp/1f1b/gpipe/zb, "
            f"not {args.schedule!r}")
    registry = _obs_registry(args)
    acfg = ActorConfig(mode=mode, hint=hint, fixed_order=fixed,
                       w_defer_cap=args.w_defer_cap,
                       deadlock_timeout=args.deadlock_timeout,
                       chaos=chaos, seed=args.seed, metrics=registry)
    print(f"arch={args.arch} workload=multimodal modality={cfg.modality}  "
          f"substrate={args.substrate}  mode={mode}  hint={hint.value}  "
          f"split_backward={split}\n"
          f"  DAG: encoder x{enc_stages} | text | fusion + LM x"
          f"{lm_stages - 1}  edges={cfg.stage_graph().edges}  "
          f"buckets={cfg.buckets}")

    if args.substrate == "sim":
        # cost model from the FULL-SIZE arch (simulated timing should
        # reflect the real widths even when the jit path runs reduced)
        cost_cfg = multimodal_config(
            args.arch, enc_stages=enc_stages, lm_stages=lm_stages,
            text_seq=max(args.seq, 512), mean_enc_tokens=2048,
            buckets=(1024, 2048, 4096), reduced=False)
        costs = multimodal_dag_costs(cost_cfg, mb_rows=args.mb_rows,
                                     seed=args.seed)
        history = []
        obs_trace = None
        for step in range(args.steps):
            record_this = _obs_record_step0(args, step)
            cfg_i = dataclasses.replace(acfg, seed=args.seed + 1000 * step,
                                        record_trace=record_this)
            driver = ActorDriver(spec, costs, cfg_i)
            res = driver.run()
            if record_this:
                driver.trace.meta["step"] = step
                obs_trace = driver.trace
                if args.record_trace:
                    driver.trace.save(args.record_trace)
                    print(f"recorded step-0 trace "
                          f"({len(driver.trace.events)} events) "
                          f"-> {args.record_trace}")
            bd = res.breakdown()
            history.append(res.makespan)
            print(f"step {step:4d}  makespan {res.makespan*1e3:8.2f} ms  "
                  f"compute {bd['compute']*1e3:7.2f} ms  "
                  f"blocking {bd['blocking']*1e3:7.2f} ms")
        _obs_finish(args, registry, obs_trace)
        return history

    # ---- thread substrate: real jitted DAG training -------------------
    from repro.data.synthetic import multimodal_batch

    params = model.init_stage_params(jax.random.key(args.seed))
    tokens = args.microbatches * args.mb_rows * args.seq
    fns = MultimodalStageFns(model, MultimodalStageOptions(
        mb_rows=args.mb_rows, loss_scale=1.0 / tokens))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                          total_steps=max(args.steps, 1))
    mstate = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    vstate = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    apply_update = make_host_update(opt_cfg)

    losses: list[float] = []
    obs_trace = None
    for step in range(args.steps):
        batch = multimodal_batch(cfg, args.microbatches, args.mb_rows,
                                 seed=args.seed, step=step)
        programs = [
            MultimodalStageProgram(fns, s, params[s], batch,
                                   split_backward=split)
            for s in range(cfg.num_stages)
        ]
        t0 = time.time()
        record_this = _obs_record_step0(args, step)
        driver = ActorDriver(
            spec, None,
            dataclasses.replace(acfg, record_trace=True) if record_this
            else acfg)
        result = driver.run_threaded(list(programs))
        grads = [p.d_params for p in programs]
        params, mstate, vstate, lr = apply_update(
            params, grads, mstate, vstate, jnp.asarray(step, jnp.int32))
        loss = float(sum(p.loss_acc for p in programs)) / tokens
        losses.append(loss)
        if record_this:
            trace = driver.trace
            trace.meta["step"] = step
            trace.meta["final_loss"] = loss
            obs_trace = trace
            if args.record_trace:
                trace.save(args.record_trace)
                print(f"recorded step-0 trace ({len(trace.events)} events) "
                      f"-> {args.record_trace}")
        bd = result.breakdown()
        dt = time.time() - t0
        print(f"step {step:4d}  loss {loss:8.4f}  lr {float(lr):.2e}  "
              f"{dt*1e3:7.1f} ms  makespan {result.makespan*1e3:7.1f} ms  "
              f"blocking {bd['blocking']*1e3:6.1f} ms")
    caches = fns.compile_cache_sizes()
    enc_caches = {k: v for k, v in caches.items()
                  if cfg.role_of(k[1]) == "encoder"}
    if enc_caches:
        print(f"jit retraces on encoder stages: "
              f"max {max(enc_caches.values())} per op "
              f"(bucket count {len(cfg.buckets)})")
    _obs_finish(args, registry, obs_trace)
    return losses


# ---------------------------------------------------------------------------
# actor-runtime backend (opt-in via --runtime actor)
# ---------------------------------------------------------------------------
def train_actor(args) -> list[float]:
    """Train with thread-per-stage actors dispatching real stage callables.

    Single-process: stage s's parameters live with stage s's actor; AdamW
    runs host-side over the accumulated per-stage grads.  Returns the loss
    history (for tests)."""
    from repro.optim.adamw import make_host_update
    from repro.pipeline.stagefn import (
        ActorStageProgram, StageFnOptions, StageFns)
    from repro.runtime.rrfp import ActorConfig, ActorDriver, Trace, parse_chaos

    cfg = (registry.reduced_config(args.arch, num_layers=args.layers)
           if not args.full_size else registry.get_arch(args.arch))
    model = build(cfg, num_stages=args.stages)
    key = jax.random.key(0)
    stage_params = model.init_stage_params(key)
    io_params = model.init_io_params(jax.random.fold_in(key, 1))
    split = args.split_backward or args.schedule == "zb"
    hint = HintKind(args.hint)
    chaos = parse_chaos(args.chaos) if args.chaos else None
    replay = None
    if args.replay_trace:
        if args.chaos:
            raise SystemExit("--replay-trace replays the recorded arrival "
                             "order; combining it with --chaos is undefined")
        replay = Trace.load(args.replay_trace)
        meta = replay.meta
        for k, want in (("num_stages", args.stages),
                        ("num_microbatches", args.microbatches),
                        ("split_backward", split)):
            if meta.get(k) is not None and meta[k] != want:
                raise SystemExit(
                    f"--replay-trace {args.replay_trace}: recorded {k}="
                    f"{meta[k]} does not match this run's {want}")
    spec = PipelineSpec(args.stages, args.microbatches, split_backward=split)
    batch_size = args.microbatches * args.mb_rows
    tokens = batch_size * args.seq
    fns = StageFns(model, StageFnOptions(
        mb_rows=args.mb_rows, seq_len=args.seq, loss_scale=1.0 / tokens))
    if args.schedule == "rrfp":
        mode, fixed = "hint", "1f1b"
        if split != (hint == HintKind.BFW):
            raise SystemExit(
                "--hint bfw and --split-backward go together: the BFW hint "
                "needs W tasks, which only exist under split backward (and "
                "only the BFW hint dispatches them)")
    elif args.schedule == "zb":
        mode, fixed = "precommitted", "zb"
    elif args.schedule in ("1f1b", "gpipe"):
        if split:
            raise SystemExit(
                f"--split-backward is not defined for the fused-order "
                f"{args.schedule!r} baseline; use --schedule zb")
        mode, fixed = "precommitted", args.schedule
    else:
        raise SystemExit(
            f"--runtime actor supports schedules rrfp/1f1b/gpipe/zb, "
            f"not {args.schedule!r}")
    # NB: name must not shadow the module-level arch ``registry`` used above
    metrics_reg = _obs_registry(args)
    scheduler = None
    if args.adaptive:
        if mode != "hint":
            raise SystemExit("--adaptive re-synthesizes the hint table; it "
                             "requires --schedule rrfp")
        if args.replay_trace:
            raise SystemExit("--adaptive changes the hint table between "
                             "steps; combining it with --replay-trace is "
                             "undefined")
        from repro.obs import MetricsRegistry
        from repro.runtime.adaptive import AdaptiveConfig, AdaptiveScheduler

        if metrics_reg is None:
            metrics_reg = MetricsRegistry(args.stages)
        # synthesis prices tables on an expected cost model; the registry's
        # measured EWMAs (real stage timings) overwrite it cell by cell
        base_costs = CostModel.uniform(args.stages)
        if split:
            base_costs = base_costs.with_split_backward()
        scheduler = AdaptiveScheduler(
            spec, base_costs,
            AdaptiveConfig(resynth_every=args.resynth_every,
                           swap_threshold=args.swap_threshold,
                           hint=hint),
            registry=metrics_reg)
    acfg = ActorConfig(mode=mode, hint=hint, fixed_order=fixed,
                       w_defer_cap=args.w_defer_cap,
                       deadlock_timeout=args.deadlock_timeout,
                       chaos=chaos, recover=args.recover,
                       hb_deadline=args.hb_deadline,
                       replay=replay, metrics=metrics_reg)

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                          total_steps=max(args.steps, 1))
    params = {"sp": stage_params, "io": io_params}
    mstate = jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)
    vstate = jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)

    apply_update = make_host_update(opt_cfg)

    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if store and args.resume and store.latest_step() is not None:
        start_step = store.latest_step()
        state, _ = store.restore(
            start_step, {"params": params, "m": mstate, "v": vstate})
        params, mstate, vstate = state["params"], state["m"], state["v"]
        print(f"resumed from step {start_step}")

    # The monitor re-synthesizes precommitted tables through the DES engine,
    # whose baseline orders model a fused backward — feed it the fused twin
    # of the spec (same stages/microbatches, W folded into B).
    monitor = StragglerMonitor(
        spec=PipelineSpec(args.stages, args.microbatches),
        costs=CostModel.uniform(args.stages))
    print(f"arch={args.arch} N={cfg.param_count():,} params  runtime=actor "
          f"mode={mode}  hint={hint.value}  split_backward={split}  "
          f"stages={args.stages}  microbatches={args.microbatches}")
    losses: list[float] = []
    obs_trace = None
    for step in range(start_step, args.steps):
        batch = synth_batch(cfg, batch_size, args.seq, seed=args.seed,
                            step=step)
        sp, io = params["sp"], params["io"]
        programs = [
            ActorStageProgram(
                fns, s, jax.tree.map(lambda x, s=s: x[s], sp), io, batch,
                split_backward=split)
            for s in range(args.stages)
        ]

        def respawn(s, programs=programs, sp=sp, io=io, batch=batch):
            # the stage's in-memory state died with it: rebuild its program
            # from the latest checkpoint (under --ckpt-every 1 that is
            # exactly the params this step started from) or, before the
            # first checkpoint, from the live step-start params
            sp_r, io_r = sp, io
            if store is not None and store.latest_step() is not None:
                host, _ = store.restore_host(
                    store.latest_step(),
                    {"params": {"sp": sp, "io": io}})
                sp_r = jax.tree.map(jnp.asarray, host["params"]["sp"])
                io_r = jax.tree.map(jnp.asarray, host["params"]["io"])
                print(f"recover: stage {s} restored from checkpoint step "
                      f"{store.latest_step()}")
            programs[s] = ActorStageProgram(
                fns, s, jax.tree.map(lambda x: x[s], sp_r), io_r, batch,
                split_backward=split)
            return programs[s]

        t0 = time.time()
        # recording costs lock traffic on the dispatch path: enable it only
        # for the step whose trace is actually saved
        record_this = _obs_record_step0(args, step, first=start_step)
        acfg_step = dataclasses.replace(acfg, respawn=respawn) \
            if args.recover else acfg
        if scheduler is not None:
            # iteration-boundary quiesce point: adopt the scheduler's
            # current table (HINT_SWAP events mark mid-run adoptions only)
            acfg_step = dataclasses.replace(
                acfg_step, hint_table=scheduler.table,
                hint_table_version=scheduler.version)
        driver = ActorDriver(
            spec, None,
            dataclasses.replace(acfg_step, record_trace=True) if record_this
            else acfg_step)
        result = driver.run_threaded(programs)
        d_sp = jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[p.d_stage for p in programs])
        d_io = jax.tree.map(lambda *xs: sum(xs[1:], xs[0]),
                            *[p.d_io for p in programs])
        grads = jax.tree.map(lambda g: g.astype(jnp.float32),
                             {"sp": d_sp, "io": d_io})
        params, mstate, vstate, lr = apply_update(
            params, grads, mstate, vstate, jnp.asarray(step, jnp.int32))
        # single device sync per step: the programs accumulate the loss as a
        # device array (no float() in the F hot path)
        loss = float(sum(p.loss_acc for p in programs)) / tokens
        losses.append(loss)
        if record_this:
            trace = driver.trace
            trace.meta["step"] = step
            trace.meta["final_loss"] = loss
            obs_trace = trace
            if args.record_trace:
                trace.save(args.record_trace)
                print(f"recorded step-0 trace ({len(trace.events)} events) "
                      f"-> {args.record_trace}")
        bd = result.breakdown()
        new_table = monitor.observe_result(result)
        swap_note = ""
        if scheduler is not None:
            decision = scheduler.maybe_resynthesize(step)
            if decision.swapped:
                swap_note = (f"  [hint-swap v{scheduler.version} "
                             f"ratio={decision.ratio:.3f}]")
        dt = time.time() - t0
        print(f"step {step:4d}  loss {loss:8.4f}  lr {float(lr):.2e}  "
              f"{dt*1e3:7.1f} ms  makespan {result.makespan*1e3:7.1f} ms  "
              f"blocking {bd['blocking']*1e3:6.1f} ms"
              + ("  [replan]" if new_table is not None else "")
              + swap_note)
        if store and (step + 1) % args.ckpt_every == 0:
            store.save(step + 1,
                       {"params": params, "m": mstate, "v": vstate},
                       meta={"arch": args.arch, "step": step + 1})
    if monitor.replans:
        print(f"straggler monitor triggered {monitor.replans} replan(s)")
    if scheduler is not None and scheduler.swaps:
        print(f"adaptive scheduler swapped the hint table "
              f"{len(scheduler.swaps)} time(s) at step(s) {scheduler.swaps} "
              f"(table v{scheduler.version})")
    _obs_finish(args, metrics_reg, obs_trace)
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: deepseek-7b, or "
                         "qwen2-vl-2b for --workload multimodal)")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--mb-rows", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--schedule", default="rrfp",
                    choices=list(schedules.BUILDERS))
    ap.add_argument("--runtime", default="table", choices=("table", "actor"),
                    help="table: compiled schedule-table executor (default); "
                         "actor: thread-per-stage readiness-driven runtime")
    ap.add_argument("--workload", default="language",
                    choices=("language", "multimodal"),
                    help="language: linear-chain LM pipeline (default); "
                         "multimodal: branch+fusion DAG pipeline (encoder "
                         "branch || text frontend -> fusion -> LM chain) on "
                         "the actor runtime — archs qwen2-vl-2b / "
                         "seamless-m4t-large-v2")
    ap.add_argument("--substrate", default="thread",
                    choices=("thread", "sim"),
                    help="multimodal workload: thread = real jitted stage "
                         "callables (default); sim = virtual-clock actor "
                         "substrate on the DAG cost model")
    ap.add_argument("--hint", default="bf",
                    choices=[h.value for h in HintKind],
                    help="actor runtime, --schedule rrfp: hint order for "
                         "ready-set arbitration (bfw needs --split-backward)")
    ap.add_argument("--split-backward", action="store_true",
                    help="actor runtime: BFW decomposition — B computes dX "
                         "only, deferrable W tasks accumulate weight grads")
    ap.add_argument("--w-defer-cap", type=int, default=4,
                    help="actor runtime, split backward: max outstanding "
                         "un-executed W tasks per stage (activation-memory "
                         "bound; 0 = unbounded)")
    ap.add_argument("--deadlock-timeout", type=float, default=120.0,
                    help="actor runtime: seconds of stage starvation before "
                         "aborting with DeadlockError")
    ap.add_argument("--chaos", default=None,
                    help="actor runtime: fault-injection spec — a level "
                         "(C0..C3) and/or key=value overrides, e.g. "
                         "'C2' or 'C1,reorder_prob=0.5,straggler=1:2.0'")
    ap.add_argument("--record-trace", default=None, metavar="PATH",
                    help="actor runtime: record the step-0 event trace "
                         "(mailbox/TP-gate/dispatch events with logical "
                         "clocks) to PATH for replay and conformance checks")
    ap.add_argument("--replay-trace", default=None, metavar="PATH",
                    help="actor runtime: re-execute the per-stage dispatch "
                         "order recorded in PATH (order-exact replay; "
                         "reproduces the recorded loss bit pattern)")
    ap.add_argument("--metrics-report", action="store_true",
                    help="actor runtime: collect runtime telemetry "
                         "(repro.obs metrics shards) and print the "
                         "end-of-run per-stage summary table")
    ap.add_argument("--export-perfetto", default=None, metavar="PATH",
                    help="actor runtime: export the step-0 trace as Chrome "
                         "trace-event JSON (open at ui.perfetto.dev); "
                         "implies step-0 recording")
    ap.add_argument("--explain", action="store_true",
                    help="actor runtime: print the one-shot critical-path "
                         "health report of the step-0 trace (binding "
                         "bottleneck, what-if ranking, stragglers, bubble "
                         "cross-check); implies step-0 recording")
    ap.add_argument("--adaptive", action="store_true",
                    help="actor runtime, --schedule rrfp: close the "
                         "schedule loop — accumulate measured per-stage "
                         "timings, re-synthesize the hint table every "
                         "--resynth-every steps, and hot-swap it at the "
                         "iteration boundary when the drift detector fires "
                         "(docs/adaptive.md)")
    ap.add_argument("--resynth-every", type=int, default=1,
                    help="--adaptive: drift-detector cadence in steps")
    ap.add_argument("--swap-threshold", type=float, default=1.03,
                    help="--adaptive: required predicted-makespan "
                         "improvement factor (active/candidate) before a "
                         "check counts toward the swap hysteresis")
    ap.add_argument("--recover", action="store_true",
                    help="actor runtime: treat a fail-stop fault (--chaos "
                         "fail_stage=S[,fail_kind=kill|permanent_stall,"
                         "fail_after=K]) as recoverable — detect the death, "
                         "fence the stale epoch, respawn the stage from the "
                         "latest checkpoint (--ckpt-dir) or live params, and "
                         "replay its in-flight microbatches")
    ap.add_argument("--hb-deadline", type=float, default=2.0,
                    help="actor runtime, --recover: seconds without stage "
                         "progress before a permanent stall is declared dead")
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="checkpoint cadence in steps (default 10; under "
                         "--recover default 1, so the respawn path restores "
                         "exactly the params the failed step started from)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.ckpt_every is None:
        args.ckpt_every = 1 if args.recover else 10

    if args.recover and not (args.runtime == "actor"
                             and args.workload == "language"):
        raise SystemExit("--recover drives the thread-per-stage actor "
                         "runtime; add --runtime actor (language workload)")
    if args.adaptive and not (args.runtime == "actor"
                              and args.workload == "language"):
        raise SystemExit("--adaptive drives the thread-per-stage actor "
                         "runtime; add --runtime actor (language workload)")
    if args.workload == "multimodal":
        args.runtime = "actor"  # the DAG only runs on the actor runtime
        train_multimodal(args)
        return
    if args.arch is None:
        args.arch = "deepseek-7b"
    if args.runtime == "actor":
        train_actor(args)
        return
    if args.metrics_report or args.export_perfetto or args.explain:
        raise SystemExit("--metrics-report / --export-perfetto / --explain "
                         "instrument the actor runtime; add --runtime actor "
                         "(or --workload multimodal)")

    data = args.devices // args.stages
    assert data >= 1, "need devices >= stages"
    t = build_trainer(
        args.arch, data=data, stages=args.stages, layers=args.layers,
        mb_rows=args.mb_rows, microbatches=args.microbatches, seq=args.seq,
        schedule=args.schedule, reduced=not args.full_size, lr=args.lr,
        total_steps=args.steps)
    print(f"arch={args.arch} N={t['cfg'].param_count():,} params  "
          f"mesh=({data}×{args.stages})  schedule={args.schedule}  "
          f"bubble={t['table'].bubble_fraction():.2f}")

    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    state = {
        "stage_params": t["stage_params"], "io_params": t["io_params"],
        "opt_state": t["opt_state"],
    }
    if store and args.resume and store.latest_step() is not None:
        start_step = store.latest_step()
        state, meta = store.restore(start_step, state)
        print(f"resumed from step {start_step}")

    monitor = StragglerMonitor(
        spec=t["spec"],
        costs=CostModel.uniform(args.stages))

    def make(step):
        return synth_batch(t["cfg"], t["batch_size"], t["seq"],
                           seed=args.seed, step=step)

    it = PrefetchIterator(make, start_step=start_step)
    sp, io, opt = (state["stage_params"], state["io_params"],
                   state["opt_state"])
    try:
        for _ in range(args.steps - start_step):
            step, batch = next(it)
            t0 = time.time()
            sp, io, opt, m = t["train_step"](
                sp, io, opt, batch, jnp.asarray(step, jnp.int32))
            loss = float(m["loss"])
            dt = time.time() - t0
            print(f"step {step:4d}  loss {loss:8.4f}  gnorm "
                  f"{float(m['gnorm']):7.3f}  lr {float(m['lr']):.2e}  "
                  f"{dt*1e3:7.1f} ms")
            if store and (step + 1) % args.ckpt_every == 0:
                store.save(step + 1,
                           {"stage_params": sp, "io_params": io,
                            "opt_state": opt},
                           meta={"arch": args.arch, "step": step + 1},
                           asynchronous=True)
        if store:
            store.wait()
    finally:
        it.close()


if __name__ == "__main__":
    main()
