"""DAG task-graph semantics: StageGraph, fan-in/fan-out dependencies,
generalized fixed orders, and engine/actor execution on branch+fusion
topologies."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import (
    CostModel,
    EngineConfig,
    HintKind,
    PipelineSpec,
    StageGraph,
    run_iteration,
)
from repro.core.hints import (
    gpipe_order,
    modality_balanced_order,
    one_f_one_b_order,
    zero_bubble_order,
)
from repro.core.taskgraph import Kind, Task
from repro.runtime.rrfp import ActorConfig, ActorDriver


def fusion_graph() -> StageGraph:
    # enc0 -> enc1 -> fus ; txt -> fus ; fus -> lm
    return StageGraph(5, ((0, 1), (1, 3), (2, 3), (3, 4)))


class TestStageGraph:
    def test_structure(self):
        g = fusion_graph()
        assert g.sources() == (0, 2)
        assert g.sinks() == (4,)
        assert g.preds(3) == (1, 2)
        assert g.succs(3) == (4,)
        assert [g.depth(s) for s in range(5)] == [0, 1, 0, 2, 3]
        assert [g.dist_to_sink(s) for s in range(5)] == [3, 2, 2, 1, 0]

    def test_linear_normalizes_to_chain(self):
        spec = PipelineSpec(4, 2, graph=StageGraph.linear(4))
        assert spec.graph is None  # normalized: same semantics, same eq
        assert spec == PipelineSpec(4, 2)

    def test_cycle_rejected(self):
        with pytest.raises(ValueError, match="cycle"):
            StageGraph(3, ((0, 1), (1, 2), (2, 0)))

    def test_duplicate_edge_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            StageGraph(2, ((0, 1), (0, 1)))

    def test_chunks_require_chain(self):
        with pytest.raises(ValueError, match="chunk"):
            PipelineSpec(5, 2, num_chunks=2, graph=fusion_graph())


class TestDagDependencies:
    def setup_method(self):
        self.spec = PipelineSpec(5, 3, graph=fusion_graph())

    def test_fan_in_forward(self):
        f3 = Task(Kind.F, 3, 0)
        assert self.spec.message_predecessors(f3) == (
            Task(Kind.F, 1, 0), Task(Kind.F, 2, 0))
        assert self.spec.fan_in(f3) == 2
        with pytest.raises(ValueError, match="fan-in"):
            self.spec.message_predecessor(f3)

    def test_fan_out_backward(self):
        b3 = Task(Kind.B, 3, 0)
        assert self.spec.message_successors(b3) == (
            Task(Kind.B, 1, 0), Task(Kind.B, 2, 0))

    def test_sources_have_local_input(self):
        assert self.spec.message_predecessors(Task(Kind.F, 0, 0)) == ()
        assert self.spec.message_predecessors(Task(Kind.F, 2, 0)) == ()
        assert self.spec.source_stages() == (0, 2)

    def test_sink_loss_is_local(self):
        assert self.spec.message_predecessors(Task(Kind.B, 4, 0)) == ()
        assert self.spec.sink_stages() == (4,)

    def test_w_is_stage_local(self):
        spec = PipelineSpec(5, 2, split_backward=True, graph=fusion_graph())
        for s in range(5):
            assert spec.message_successors(Task(Kind.W, s, 0)) == ()

    def test_predecessors_include_all_edges(self):
        preds = self.spec.predecessors(Task(Kind.B, 3, 1))
        # gradient message from lm + local F
        assert Task(Kind.B, 4, 1) in preds
        assert Task(Kind.F, 3, 1) in preds

    def test_chain_behavior_unchanged(self):
        chain = PipelineSpec(4, 2)
        assert chain.message_predecessor(Task(Kind.F, 2, 0)) == \
            Task(Kind.F, 1, 0)
        assert chain.dist_to_sink(1) == 2
        assert chain.source_stages() == (0,)


class TestDagFixedOrders:
    def test_orders_cover_task_set(self):
        for split, builders in [
            (False, [gpipe_order, one_f_one_b_order]),
            (True, [gpipe_order, zero_bubble_order]),
        ]:
            spec = PipelineSpec(5, 4, split_backward=split,
                                graph=fusion_graph())
            for builder in builders:
                for s in range(5):
                    order = builder(spec, s)
                    want = [t for t in spec.tasks() if t.stage == s]
                    assert sorted(order) == sorted(want), builder.__name__

    def test_modality_balanced_covers_split_tasks(self):
        spec = PipelineSpec(5, 4, split_backward=True, graph=fusion_graph())
        for s in range(5):
            order = modality_balanced_order(spec, s, [1.0, 1.0, 2.0, 3.0, 3.0])
            want = [t for t in spec.tasks() if t.stage == s]
            assert sorted(order) == sorted(want)

    def test_warmup_uses_dag_depth(self):
        spec = PipelineSpec(5, 8, graph=fusion_graph())
        for s in range(5):
            order = one_f_one_b_order(spec, s)
            warmup = 0
            for t in order:
                if t.kind != Kind.F:
                    break
                warmup += 1
            assert warmup == min(spec.dist_to_sink(s), 8) or warmup >= 1


class TestDagExecution:
    def costs(self):
        return CostModel.uniform(5, f=1.0, b=2.0, comm_base=1e-3)

    def test_engine_hint_completes(self):
        spec = PipelineSpec(5, 6, graph=fusion_graph())
        r = run_iteration(spec, self.costs(), EngineConfig(mode="hint"))
        assert set(r.end) == set(spec.tasks())

    @pytest.mark.parametrize("fixed", ["1f1b", "gpipe"])
    @pytest.mark.parametrize("sync", [False, True])
    def test_engine_precommitted_completes(self, fixed, sync):
        spec = PipelineSpec(5, 6, graph=fusion_graph())
        r = run_iteration(spec, self.costs(), EngineConfig(
            mode="precommitted", fixed_order=fixed, sync_sends=sync))
        assert set(r.end) == set(spec.tasks())

    def test_engine_zb_split_completes(self):
        spec = PipelineSpec(5, 4, split_backward=True, graph=fusion_graph())
        cm = CostModel.uniform(5, f=1.0, b=1.0, w=1.0, comm_base=1e-3)
        r = run_iteration(spec, cm, EngineConfig(
            mode="precommitted", fixed_order="zb"))
        assert set(r.end) == set(spec.tasks())

    @pytest.mark.parametrize("tp", [1, 2])
    def test_actor_sim_hint_completes(self, tp):
        spec = PipelineSpec(5, 5, graph=fusion_graph())
        res = ActorDriver(spec, self.costs(), ActorConfig(
            mode="hint", tp_degree=tp)).run()
        assert set(res.end) == set(spec.tasks())

    def test_actor_bfw_cap_respected(self):
        spec = PipelineSpec(5, 6, split_backward=True, graph=fusion_graph())
        cm = CostModel.uniform(5, f=1.0, b=1.0, w=1.0, comm_base=1e-3)
        cfg = ActorConfig(mode="hint", hint=HintKind.BFW, w_defer_cap=2,
                          record_trace=True)
        res = ActorDriver(spec, cm, cfg).run()
        from repro.runtime.rrfp.conformance import check_all
        check_all(res.trace, spec, cfg)

    def test_seeded_makespans_reproducible(self):
        spec = PipelineSpec(5, 4, graph=fusion_graph())
        cfg = ActorConfig(mode="hint", seed=7)
        m1 = ActorDriver(spec, self.costs(), cfg).run().makespan
        m2 = ActorDriver(spec, self.costs(),
                         dataclasses.replace(cfg)).run().makespan
        assert m1 == m2


class TestDagCosts:
    def test_multimodal_dag_costs_shape(self):
        from repro.multimodal import multimodal_config, multimodal_dag_costs

        cfg = multimodal_config("qwen2-vl-2b", enc_stages=2, lm_stages=2)
        cm = multimodal_dag_costs(cfg)
        assert cm.num_stages == cfg.num_stages
        # encoder stages carry the modality skew, decoder stages barely
        enc = cfg.roles()["encoder"][0]
        dec = cfg.roles()["decoder"][0]
        assert np.std(cm.mb_skew[enc]) > np.std(cm.mb_skew[dec])
