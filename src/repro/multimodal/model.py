"""Branch+fusion multimodal pipeline model (real params, DAG topology).

Stage layout for an encoder branch of ``E`` stages and an LM chain of
``L`` stages (fusion first)::

    enc_0 -> enc_1 -> ... -> enc_{E-1} --\
                                          +--> fusion -> lm_1 -> ... -> lm_{L-1}
    text frontend ------------------------/

* **encoder stages** (vision patches / audio frames): non-causal
  transformer layers at width ``d_enc`` over *variable-length* token
  sequences.  Attention is computed in a bitwise padding-invariant form
  (every reduction along the variable axis is a ``dot_general``; the
  softmax max is ``stop_gradient``-ed), so padding a microbatch up to a
  shape bucket changes neither outputs nor gradients at valid positions —
  the property the bucketing parity tests pin down.
* **text frontend**: token embedding + causal decoder layers at
  ``d_model`` (built from ``models.layers``).
* **fusion stage**: segment-pools the encoder branch's valid positions
  into ``fusion_slots`` tokens, projects ``d_enc -> d_model``, prepends
  them to the text hidden states, then runs causal LM layers over the
  fused sequence.  Its forward has **two message predecessors** (the DAG
  fan-in); its backward emits one input gradient per branch (fan-out).
* **LM tail stages**: causal decoder layers; the last stage carries the
  LM head and the token cross-entropy over the text positions.

``multimodal_config`` derives all widths from a registered arch config
(``qwen2-vl-2b`` → vision modality, ``seamless-m4t-large-v2`` → audio),
reduced for CPU smoke runs or full-size.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.taskgraph import PipelineSpec, StageGraph
from repro.data.lengths import VISION_SIGMA
from repro.models.common import ArchConfig, dense_init, keygen
from repro.models.layers import (
    NEG_INF,
    attention_qkv,
    decoder_layer,
    ffn_block,
    init_decoder_layer,
    rmsnorm,
)

#: registered archs this subsystem knows how to lower onto the DAG
MULTIMODAL_ARCHS = {
    "qwen2-vl-2b": "vision",
    "seamless-m4t-large-v2": "audio",
}


@dataclasses.dataclass(frozen=True)
class MultimodalConfig:
    """Static description of one branch+fusion multimodal pipeline."""

    name: str
    modality: str            # "vision" | "audio"
    enc_stages: int          # encoder-branch stages (>= 1)
    lm_stages: int           # fusion + decoder-chain stages (>= 1)
    enc_layers_per_stage: int
    lm_layers_per_stage: int
    d_enc: int
    enc_heads: int
    d_model: int
    vocab_size: int
    text_seq: int
    fusion_slots: int        # pooled modality tokens entering the LM
    mean_enc_tokens: int     # mean encoder tokens per microbatch sample
    enc_sigma: float         # lognormal sigma of the per-mb length skew
    buckets: tuple[int, ...]  # padded encoder-length buckets (ascending)
    #: the LM-side ArchConfig the decoder layers are built from
    lm_cfg: ArchConfig = None  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if self.enc_stages < 1 or self.lm_stages < 1:
            raise ValueError("need >= 1 encoder and >= 1 LM stage")
        if not self.buckets or list(self.buckets) != sorted(self.buckets):
            raise ValueError("buckets must be a non-empty ascending tuple")
        if self.fusion_slots < 1:
            raise ValueError("fusion_slots must be >= 1")

    @property
    def num_stages(self) -> int:
        return self.enc_stages + 1 + self.lm_stages

    @property
    def text_stage(self) -> int:
        return self.enc_stages

    @property
    def fusion_stage(self) -> int:
        return self.enc_stages + 1

    @property
    def fused_seq(self) -> int:
        return self.fusion_slots + self.text_seq

    @property
    def enc_cfg(self) -> ArchConfig:
        """Layer-shim config for the encoder width (GELU, no GQA)."""
        return dataclasses.replace(
            self.lm_cfg, d_model=self.d_enc, num_heads=self.enc_heads,
            num_kv_heads=self.enc_heads, head_dim=0,
            d_ff=max(4 * self.d_enc, 8), act="gelu", qkv_bias=False,
            mrope=False, layer_pattern=None)

    # ---- topology ----------------------------------------------------------
    def stage_graph(self) -> StageGraph:
        E, S = self.enc_stages, self.num_stages
        edges = [(s, s + 1) for s in range(E - 1)]          # encoder chain
        edges.append((E - 1, self.fusion_stage))            # branch fan-in
        edges.append((self.text_stage, self.fusion_stage))  # text fan-in
        edges += [(s, s + 1) for s in range(self.fusion_stage, S - 1)]
        return StageGraph(S, tuple(edges))

    def spec(self, num_microbatches: int,
             split_backward: bool = False) -> PipelineSpec:
        return PipelineSpec(self.num_stages, num_microbatches,
                            split_backward=split_backward,
                            graph=self.stage_graph())

    def roles(self) -> dict[str, tuple[int, ...]]:
        """Stage-id sets per role (consumed by chaos modality profiles)."""
        return {
            "encoder": tuple(range(self.enc_stages)),
            "text": (self.text_stage,),
            "fusion": (self.fusion_stage,),
            "decoder": tuple(range(self.fusion_stage, self.num_stages)),
        }

    def fanin_edges(self) -> tuple[tuple[int, int], ...]:
        return ((self.enc_stages - 1, self.fusion_stage),
                (self.text_stage, self.fusion_stage))

    def role_of(self, stage: int) -> str:
        if stage < self.enc_stages:
            return "encoder"
        if stage == self.text_stage:
            return "text"
        if stage == self.fusion_stage:
            return "fusion"
        return "lm"


def multimodal_config(
    arch: str,
    *,
    enc_stages: int = 2,
    lm_stages: int = 2,
    enc_layers_per_stage: int = 2,
    lm_layers_per_stage: int = 2,
    text_seq: int = 32,
    fusion_slots: int = 4,
    mean_enc_tokens: int = 24,
    buckets: tuple[int, ...] = (16, 32, 48),
    reduced: bool = True,
    num_layers: int | None = None,
) -> MultimodalConfig:
    """Lower a registered multimodal arch onto the branch+fusion pipeline."""
    if arch not in MULTIMODAL_ARCHS:
        raise ValueError(
            f"{arch!r} is not a multimodal arch; available: "
            f"{sorted(MULTIMODAL_ARCHS)}")
    modality = MULTIMODAL_ARCHS[arch]
    cfg = (registry.reduced_config(arch, num_layers=num_layers)
           if reduced else registry.get_arch(arch))
    # encoder width: half the LM width (rounded to a head multiple) — cheap
    # per-token relative to the decoder, like a ViT/conformer frontend
    enc_heads = max(1, cfg.num_heads // 2)
    d_enc = max(8 * enc_heads, (cfg.d_model // 2) // enc_heads * enc_heads)
    # audio frames arrive longer but less spread than dynamic-res images
    sigma = VISION_SIGMA if modality == "vision" else 0.4
    return MultimodalConfig(
        name=cfg.name,
        modality=modality,
        enc_stages=enc_stages,
        lm_stages=lm_stages,
        enc_layers_per_stage=enc_layers_per_stage,
        lm_layers_per_stage=lm_layers_per_stage,
        d_enc=d_enc,
        enc_heads=enc_heads,
        d_model=cfg.d_model,
        vocab_size=cfg.vocab_size,
        text_seq=text_seq,
        fusion_slots=fusion_slots,
        mean_enc_tokens=mean_enc_tokens,
        enc_sigma=sigma,
        buckets=tuple(sorted(buckets)),
        lm_cfg=cfg,
    )


# ---------------------------------------------------------------------------
# bitwise padding-invariant encoder attention
# ---------------------------------------------------------------------------
#
# Why the inner block runs at a fixed length: XLA's lowering of a matmul /
# reduction is shape-dependent, and a shape-dependent lowering may change
# the floating-point accumulation order — measured on the CPU backend,
# `einsum("bhqk,bkhd->bqhd")` produces different bits for the same logical
# rows at k=49 vs k=64 even when the padding is exact zeros.  Position-wise
# ops (projections, norms, FFN) are bitwise-stable under row-count changes,
# but any op whose *sequence axis participates in a reduction or sets the
# output tile* must therefore run at one fixed shape.  So the attention
# inner block (and the fusion pooling) pads q/k/v up to ``pad_to`` — the
# largest bucket — computes at that fixed shape (identical lowering for
# every bucket ⇒ bitwise identity), and slices the result back.  The
# position-wise majority of the FLOPs still scales with the bucket.
def masked_encoder_attention(p, x, length, cfg: ArchConfig, pad_to: int):
    """Non-causal self-attention over a variable-length padded sequence.

    ``length``: [] valid token count; ``pad_to``: static inner length
    (>= x.shape[1]).  Valid positions' outputs — and all gradients — are
    bitwise independent of x's padded length.
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = attention_qkv(p, x, x, cfg)   # [b, s, h, hd] (bucket-sized)
    pad = ((0, 0), (0, pad_to - s), (0, 0), (0, 0))
    qf = jnp.pad((q * hd**-0.5).astype(jnp.float32), pad)
    kf = jnp.pad(k.astype(jnp.float32), pad)
    vf = jnp.pad(v.astype(jnp.float32), pad)
    valid = jnp.arange(pad_to) < length
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)      # [b, h, K, K]
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    probs = jnp.exp(scores - m) * valid[None, None, None, :]
    ones = jnp.ones((pad_to,), jnp.float32)
    denom = jnp.einsum("bhqk,k->bhq", probs, ones)      # [b, h, K]
    num = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    out = num / jnp.transpose(denom, (0, 2, 1))[..., None]
    out = out[:, :s].astype(x.dtype).reshape(b, s, -1)
    return out @ p["wo"]


def _fixed_len_rmsnorm(x, scale, eps: float, pad_to: int):
    """rmsnorm whose scale-gradient reduces at the fixed inner length.

    The norm itself is position-wise, but its scale VJP sums over the
    token axis; padding that reduction up to ``pad_to`` keeps the summed
    positions (valid rows + exact-zero rows) identical across buckets.
    """
    s = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (0, pad_to - s), (0, 0)))
    return rmsnorm(xp, scale, eps)[:, :s]


def encoder_layer(p, x, length, cfg: ArchConfig, pad_to: int):
    """Pre-norm encoder block: masked attention + FFN (GELU)."""
    h = _fixed_len_rmsnorm(x, p["ln1"], cfg.norm_eps, pad_to)
    x = x + masked_encoder_attention(p["attn"], h, length, cfg, pad_to)
    h = _fixed_len_rmsnorm(x, p["ln2"], cfg.norm_eps, pad_to)
    return x + ffn_block(p["ffn"], h, cfg.act)


def pool_weights(length, bucket: int, slots: int):
    """[slots, bucket] segment-mean pooling weights over valid positions.

    Integer segment assignment + exact-zero weights at padding: the pooled
    tokens are bitwise independent of the bucket size (the pooling matmul
    itself runs at the fixed inner length — see ``fusion_forward``).
    """
    pos = jnp.arange(bucket)
    length = jnp.maximum(length, 1)
    seg = jnp.minimum((pos * slots) // length, slots - 1)     # [bucket]
    valid = pos < length
    w = (seg[None, :] == jnp.arange(slots)[:, None]) & valid[None, :]
    w = w.astype(jnp.float32)
    count = jnp.einsum("sk,k->s", w, jnp.ones((bucket,), jnp.float32))
    return w / jnp.maximum(count, 1.0)[:, None]


# ---------------------------------------------------------------------------
# the model: params + pure per-stage forward bodies
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class MultimodalModel:
    cfg: MultimodalConfig

    # ---- params --------------------------------------------------------
    def init_stage_params(self, key) -> list[dict]:
        """One parameter pytree per pipeline stage (heterogeneous)."""
        cfg = self.cfg
        enc_cfg, lm_cfg = cfg.enc_cfg, cfg.lm_cfg
        dtype = lm_cfg.dtype
        out: list[dict] = []
        for s in range(cfg.num_stages):
            keys = keygen(jax.random.fold_in(key, s))
            role = cfg.role_of(s)
            p: dict[str, Any] = {}
            if role == "encoder":
                if s == 0:
                    p["pos_embed"] = dense_init(
                        next(keys), (max(cfg.buckets), cfg.d_enc), dtype,
                        scale=0.02)
                p["layers"] = [
                    init_decoder_layer(keys, enc_cfg)
                    for _ in range(cfg.enc_layers_per_stage)]
            elif role == "text":
                p["embed"] = dense_init(
                    next(keys), (cfg.vocab_size, cfg.d_model), dtype,
                    scale=0.02)
                p["layers"] = [
                    init_decoder_layer(keys, lm_cfg)
                    for _ in range(cfg.lm_layers_per_stage)]
            else:  # fusion / lm
                if role == "fusion":
                    p["proj_w"] = dense_init(
                        next(keys), (cfg.d_enc, cfg.d_model), dtype)
                    p["proj_b"] = jnp.zeros((cfg.d_model,), dtype)
                p["layers"] = [
                    init_decoder_layer(keys, lm_cfg)
                    for _ in range(cfg.lm_layers_per_stage)]
                if s == cfg.num_stages - 1:
                    p["final_ln"] = jnp.zeros((cfg.d_model,), dtype)
                    p["head"] = dense_init(
                        next(keys), (cfg.vocab_size, cfg.d_model), dtype)
            out.append(p)
        return out

    def param_count(self) -> int:
        key = jax.random.key(0)
        return sum(x.size for x in jax.tree.leaves(self.init_stage_params(key)))

    # ---- per-stage forward bodies (pure; jitted by MultimodalStageFns) --
    def encoder_forward(self, stage: int, p, x, length):
        """x: [rows, bucket, d_enc]; length: [] valid token count."""
        cfg = self.cfg
        if stage == 0:
            x = x + p["pos_embed"][:x.shape[1]][None]
        for lp in p["layers"]:
            x = encoder_layer(lp, x, length, cfg.enc_cfg, max(cfg.buckets))
        return x

    def text_forward(self, p, tokens):
        """tokens: [rows, text_seq] -> [rows, text_seq, d_model]."""
        cfg = self.cfg
        x = p["embed"][tokens]
        pos = jnp.broadcast_to(
            jnp.arange(cfg.text_seq, dtype=jnp.int32)[None], tokens.shape)
        for lp in p["layers"]:
            x = decoder_layer(lp, x, pos, cfg.lm_cfg)
        return x

    def fusion_forward(self, p, x_enc, length, x_txt):
        """Pool + project the branch, prepend to text, run LM layers."""
        cfg = self.cfg
        pad_to = max(cfg.buckets)
        x_full = jnp.pad(
            x_enc.astype(jnp.float32),
            ((0, 0), (0, pad_to - x_enc.shape[1]), (0, 0)))
        w = pool_weights(length, pad_to, cfg.fusion_slots)
        pooled = jnp.einsum("sk,bkd->bsd", w, x_full)
        pooled = pooled.astype(x_enc.dtype)
        slots = pooled @ p["proj_w"] + p["proj_b"]
        x = jnp.concatenate([slots, x_txt], axis=1)     # [rows, fused, d]
        return self._lm_layers(p, x)

    def lm_forward(self, p, x):
        return self._lm_layers(p, x)

    def _lm_layers(self, p, x):
        cfg = self.cfg
        pos = jnp.broadcast_to(
            jnp.arange(cfg.fused_seq, dtype=jnp.int32)[None],
            (x.shape[0], cfg.fused_seq))
        for lp in p["layers"]:
            x = decoder_layer(lp, x, pos, cfg.lm_cfg)
        return x

    def loss_sum(self, p, y, labels):
        """Token cross-entropy (sum) over the text positions of ``y``."""
        cfg = self.cfg
        h = rmsnorm(y[:, cfg.fusion_slots:], p["final_ln"],
                    cfg.lm_cfg.norm_eps)
        logits = (h @ p["head"].T).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        pick = jnp.take_along_axis(
            logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        w = (labels >= 0).astype(jnp.float32)
        return jnp.sum((lse - pick) * w)


def multimodal_model(arch: str, **kw) -> MultimodalModel:
    return MultimodalModel(multimodal_config(arch, **kw))
