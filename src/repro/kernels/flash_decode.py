"""Pallas TPU flash decode: one query position against a long KV cache.

Grid: (batch, q_heads, kv_blocks), kv innermost; online-softmax state in VMEM
scratch.  The valid cache length arrives via scalar prefetch (SMEM) so the
same compiled kernel serves every decode position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            window: int, block_k: int, num_k_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ki * block_k
    run = k_start < length
    if window > 0:
        run = jnp.logical_and(run, k_start + block_k > length - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [1, bk]
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = k_pos < length
        if window > 0:
            mask &= k_pos >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret")
)
def flash_decode(q, k_cache, v_cache, length, *, window: int = 0,
                 block_k: int = 512, interpret: bool = False):
    """q: [b, hq, 1, hd]; caches: [b, hkv, S, hd]; length: [] int32 scalar.

    Scale must be pre-applied to q.  Returns [b, hq, 1, hd].
    """
    b, hq, _, hd = q.shape
    _, hkv, S, _ = k_cache.shape
    g = hq // hkv
    block_k = min(block_k, S)
    nk = -(-S // block_k)
    pad = nk * block_k - S
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(
        _kernel, window=window, block_k=block_k, num_k_blocks=nk
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda bi, hi, ki, _len: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, ki, _len, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, hi, ki, _len, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda bi, hi, ki, _len: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32).reshape(1), q, k_cache, v_cache)
    return out
