"""Bubble decomposition: attribute every idle second to a cause (§6, §7).

Turns "BFW beats 1F1B by 1.44x" into "because it removed X ms of
dependency-wait on stage 2".  Operates purely on a recorded logical-clock
:class:`~repro.runtime.rrfp.trace.Trace` — no runtime hooks — so any saved
trace (sim or thread substrate, chaos or not) decomposes offline.

Per stage, the timeline [0, makespan] splits into *busy* intervals (each
DISPATCH..COMPLETE pair) and *idle* gaps.  Every gap is attributed to
exactly one category by walking monotone breakpoints toward the dispatch
that ends the gap:

``warmup``
    the leading gap before the stage's first dispatch — pipeline fill.
``dependency_wait``
    producers of the next task were still executing: the gap up to the
    latest predecessor COMPLETE.  On precommitted (fixed-order) runs this
    also covers *schedule misalignment* — the order's next entry being
    unready while other work was ready — which is exactly the class
    readiness-driven consumption removes.
``starvation``
    all producers done but the input message not yet admitted: transport
    latency, chaos delay, reordering, fan-in branch skew — plus, on the
    thread substrate, actor wakeup latency (the residual between a task
    becoming ready and the dispatch committing).
``tp_gate``
    the input message arrived on some TP rank but the all-ranks admission
    barrier held it (first TP_HOLD .. ENQUEUE).
``backpressure``
    the stage sat idle at its App. C F/B imbalance limit, or the ending
    dispatch itself took the backpressure-drain path.
``recovery``
    idle time inside a fault-recovery window (FAIL .. RECOVERY_END of any
    stage): the outage itself plus the time survivors spent stalled on the
    dead stage.  Without this category a killed stage's gap would be
    misattributed to ``dependency_wait``/``starvation``.
``drain``
    the trailing gap after the stage's last COMPLETE — pipeline drain.

Within a gap the precedence is dependency_wait -> starvation -> tp_gate ->
(backpressure | starvation); breakpoints are clamped monotone, and the last
segment absorbs the float residue, so per-stage categories sum *exactly* to
the stage's idle time (makespan - busy) — the invariant the acceptance
tests pin down.  Each interior segment's overlap with the run's (merged)
recovery windows is carved out into ``recovery``, which moves time between
categories without changing the total, so the exact-attribution invariant
survives recovered traces.  On such traces each task's DISPATCH/COMPLETE
pair is taken from its *highest-epoch* incarnation (a doomed dispatch that
never completed must not make the outage look busy).  ``warmup`` and
``drain`` are reported separately but form one paper-level category
(fill/drain bubbles).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.taskgraph import Kind, PipelineSpec, StageGraph, Task

from repro.runtime.rrfp import trace as _tr

#: attribution categories, report order (warmup/drain = the paper's
#: fill/drain class, split so leading and trailing bubbles stay visible)
CATEGORIES = ("warmup", "dependency_wait", "starvation", "tp_gate",
              "backpressure", "recovery", "drain")


def spec_from_meta(meta: dict) -> PipelineSpec:
    """Rebuild the :class:`PipelineSpec` a trace was recorded against."""
    graph = None
    edges = meta.get("graph")
    if edges:
        graph = StageGraph(num_stages=int(meta["num_stages"]),
                           edges=tuple(tuple(e) for e in edges))
    return PipelineSpec(
        num_stages=int(meta["num_stages"]),
        num_microbatches=int(meta["num_microbatches"]),
        num_chunks=int(meta.get("num_chunks", 1)),
        split_backward=bool(meta.get("split_backward", False)),
        graph=graph)


@dataclasses.dataclass
class StageBubbles:
    """One stage's idle-time attribution."""

    stage: int
    busy: float
    idle: float
    bubbles: dict[str, float]

    @property
    def attributed(self) -> float:
        return sum(self.bubbles.values())

    @property
    def residual(self) -> float:
        """Unattributed idle time; ~0 up to float rounding by construction."""
        return self.idle - self.attributed


@dataclasses.dataclass
class BubbleReport:
    """Per-stage decomposition + run-level aggregates."""

    makespan: float
    stages: list[StageBubbles]
    meta: dict

    def category_totals(self) -> dict[str, float]:
        out = {c: 0.0 for c in CATEGORIES}
        for sb in self.stages:
            for c, v in sb.bubbles.items():
                out[c] += v
        return out

    def total_idle(self) -> float:
        return sum(sb.idle for sb in self.stages)

    def idle_fully_attributed(self, rel_tol: float = 1e-9,
                              abs_tol: float = 1e-12) -> bool:
        """100%-accounting check: every stage's categories sum to its idle."""
        return all(
            math.isclose(sb.attributed, sb.idle, rel_tol=rel_tol,
                         abs_tol=max(abs_tol, rel_tol * self.makespan))
            for sb in self.stages)

    def table(self) -> str:
        """Per-stage attribution table (seconds)."""
        cols = ["stage", "busy", "idle"] + list(CATEGORIES)
        hdr = " ".join(f"{c:>12}" for c in cols)
        lines = [hdr, "-" * len(hdr)]
        for sb in self.stages:
            row = [f"{sb.stage:>12}", f"{sb.busy:>12.6f}", f"{sb.idle:>12.6f}"]
            row += [f"{sb.bubbles[c]:>12.6f}" for c in CATEGORIES]
            lines.append(" ".join(row))
        tot = self.category_totals()
        lines.append("-" * len(hdr))
        lines.append(" ".join(
            [f"{'total':>12}", f"{sum(s.busy for s in self.stages):>12.6f}",
             f"{self.total_idle():>12.6f}"]
            + [f"{tot[c]:>12.6f}" for c in CATEGORIES]))
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "makespan": self.makespan,
            "idle_fully_attributed": self.idle_fully_attributed(),
            "stages": [
                {"stage": sb.stage, "busy": sb.busy, "idle": sb.idle,
                 "bubbles": dict(sb.bubbles), "residual": sb.residual}
                for sb in self.stages],
            "category_totals": self.category_totals(),
        }


def decompose(trace: _tr.Trace, spec: PipelineSpec | None = None,
              buffer_limit: int | None = None) -> BubbleReport:
    """Attribute every stage's idle time to the :data:`CATEGORIES`.

    ``spec`` / ``buffer_limit`` default to the trace's recorded metadata;
    the trace must carry DISPATCH and COMPLETE events (i.e. be recorded
    with ``ActorConfig.record_trace``).
    """
    meta = trace.meta
    if spec is None:
        spec = spec_from_meta(meta)
    if buffer_limit is None:
        buffer_limit = int(meta.get("buffer_limit", 0) or 0)
    mode = meta.get("mode", "hint")
    S = spec.num_stages

    # Highest-epoch-first-occurrence projections: on a failure-free trace
    # (all epochs 0) this is plain first-event-wins (duplicate-tolerant);
    # on a recovered trace each task's dispatch/complete comes from its
    # final incarnation, so a doomed dispatch that never completed cannot
    # pair with its post-recovery completion and swallow the outage.
    best_disp: dict[Task, _tr.TraceEvent] = {}
    best_comp: dict[Task, _tr.TraceEvent] = {}
    enqueue_t: dict[Task, float] = {}
    tp_first_hold: dict[Task, float] = {}
    for ev in trace.events:
        if ev.kind == _tr.DISPATCH:
            cur = best_disp.get(ev.task)
            if cur is None or ev.epoch > cur.epoch:
                best_disp[ev.task] = ev
        elif ev.kind == _tr.COMPLETE:
            cur = best_comp.get(ev.task)
            if cur is None or ev.epoch > cur.epoch:
                best_comp[ev.task] = ev
        elif ev.kind == _tr.ENQUEUE:
            # last edge/rank admission = the task became consumable
            enqueue_t.setdefault(ev.task, ev.t)
        elif ev.kind == _tr.TP_HOLD:
            tp_first_hold.setdefault(ev.task, ev.t)
    dispatches: list[list[_tr.TraceEvent]] = [[] for _ in range(S)]
    for ev in sorted(best_disp.values(), key=lambda e: e.lc):
        dispatches[ev.stage].append(ev)
    complete_t: dict[Task, float] = {t: e.t for t, e in best_comp.items()}
    fb_completes: list[dict[Kind, list[float]]] = [
        {Kind.F: [], Kind.B: []} for _ in range(S)]
    for ev in sorted(best_comp.values(), key=lambda e: e.t):
        if ev.task.kind in (Kind.F, Kind.B):
            fb_completes[ev.stage][ev.task.kind].append(ev.t)

    # merged fault-recovery windows (FAIL .. RECOVERY_END), any stage
    rec_spans = sorted((w["t_fail"], w["t_end"])
                       for w in trace.recovery_windows())
    merged: list[tuple[float, float]] = []
    for w0, w1 in rec_spans:
        if merged and w0 <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], w1))
        else:
            merged.append((w0, w1))

    def rec_overlap(lo: float, hi: float) -> float:
        return sum(max(0.0, min(hi, w1) - max(lo, w0)) for w0, w1 in merged)

    makespan = float(meta.get("makespan") or
                     (max(complete_t.values()) if complete_t else 0.0))

    def fb_imbalance(stage: int, t: float) -> int:
        """n_f - n_b from completes at time <= t (the App. C counter)."""
        from bisect import bisect_right
        nf = bisect_right(fb_completes[stage][Kind.F], t)
        nb = bisect_right(fb_completes[stage][Kind.B], t)
        return nf - nb

    stages: list[StageBubbles] = []
    for s in range(S):
        bubbles = {c: 0.0 for c in CATEGORIES}
        evs = dispatches[s]
        busy = 0.0
        prev_end = 0.0
        first = True
        for ev in evs:
            a, b = prev_end, ev.t
            task = ev.task
            done_t = complete_t.get(task, b)
            busy += max(0.0, done_t - b)
            prev_end = max(prev_end, done_t)
            if b <= a:
                first = False
                continue
            gap = b - a
            if first:
                # an outage before the first dispatch is not pipeline fill
                ov = rec_overlap(a, b) if merged else 0.0
                bubbles["warmup"] += gap - ov
                bubbles["recovery"] += ov
                first = False
                continue
            # monotone breakpoints a <= p <= h <= r <= b
            preds = spec.message_predecessors(task)
            lp = spec.local_predecessor(task)
            p = a
            for q in preds:
                p = max(p, complete_t.get(q, a))
            if lp is not None:
                p = max(p, complete_t.get(lp, a))
            p = min(max(p, a), b)
            if preds:
                r = min(max(enqueue_t.get(task, p), p), b)
            else:
                r = p
            h = tp_first_hold.get(task)
            h = min(max(h, p), r) if h is not None else r
            dep = p - a
            starve = h - p
            tp = r - h
            tail = gap - dep - starve - tp  # exact residue: sums to gap
            if merged:
                # carve each segment's overlap with the recovery windows
                # out into "recovery": time moves between categories, the
                # total stays the gap, so exact attribution is preserved
                for seg, lo, hi in (("dep", a, p), ("starve", p, h),
                                    ("tp", h, r)):
                    ov = rec_overlap(lo, hi)
                    if seg == "dep":
                        dep -= ov
                    elif seg == "starve":
                        starve -= ov
                    else:
                        tp -= ov
                    bubbles["recovery"] += ov
            bubbles["dependency_wait"] += dep
            bubbles["starvation"] += starve
            bubbles["tp_gate"] += tp
            if tail > 0.0:
                if merged:
                    ov = min(rec_overlap(r, b), tail)
                    bubbles["recovery"] += ov
                    tail -= ov
                backpressured = (
                    ev.info.get("path") == "backpressure"
                    or (mode == "hint" and buffer_limit > 0
                        and fb_imbalance(s, a) >= buffer_limit))
                bubbles["backpressure" if backpressured
                        else "starvation"] += tail
        tail_gap = makespan - prev_end
        if evs and tail_gap > 0.0:
            ov = rec_overlap(prev_end, makespan) if merged else 0.0
            bubbles["drain"] += tail_gap - ov
            bubbles["recovery"] += ov
        elif not evs:
            # a stage that never dispatched is one long warmup bubble
            bubbles["warmup"] += makespan
        idle = makespan - busy
        stages.append(StageBubbles(stage=s, busy=busy, idle=idle,
                                   bubbles=bubbles))
    return BubbleReport(makespan=makespan, stages=stages, meta=dict(meta))


def compare(base: BubbleReport, other: BubbleReport) -> dict:
    """Category deltas ``base - other`` (what ``other`` removed)."""
    bt, ot = base.category_totals(), other.category_totals()
    removed = {c: bt[c] - ot[c] for c in CATEGORIES}
    top = max(removed, key=lambda c: removed[c])
    return {
        "base_makespan": base.makespan,
        "other_makespan": other.makespan,
        "speedup": (base.makespan / other.makespan
                    if other.makespan > 0 else math.inf),
        "removed": removed,
        "top_removed_category": top,
    }
