"""Pallas TPU Mamba-2 SSD chunked scan (zamba2's compute hot spot).

Grid: (batch, heads, chunks) with chunks innermost; the [hd, ds] inter-chunk
state lives in VMEM scratch.  Per chunk: dense intra-chunk attention-like
contraction (MXU) + rank-1 state update — the TPU-native re-blocking of the
paper-adjacent GPU SSD kernel (HBM->VMEM streaming instead of warp shuffles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_scr, *,
            chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)   # [Q, hd]
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # [Q]
    A = a_ref[0]                              # scalar (SMEM)
    B = b_ref[0].astype(jnp.float32)          # [Q, ds]
    C = c_ref[0].astype(jnp.float32)          # [Q, ds]
    D = d_ref[0]

    a = A * dt                                # [Q] per-step log decay
    cum = jnp.cumsum(a)                       # [Q] inclusive
    # intra-chunk: y[i] += sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
    g = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    w = g * decay * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, hd]
    # inter-chunk: y[i] += exp(cum_i) * C_i @ state^T
    state = state_scr[...]                    # [hd, ds]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # state update: state = exp(cum_Q) state + sum_j exp(cum_Q - cum_j) dt_j x_j B_j^T
    wj = (dt * jnp.exp(cum[-1] - cum))[:, None]
    state_scr[...] = jnp.exp(cum[-1]) * state + jax.lax.dot_general(
        x * wj, B, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, :, 0] = (y + D * x).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128, interpret: bool = False):
    """Chunked SSD.  x: [b, s, nh, hd]; dt: [b, s, nh]; A, D: [nh];
    B, C: [b, s, ds].  Returns y: [b, s, nh, hd].  Requires s % chunk == 0
    (ops.py pads).
    """
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, ds), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, hd), lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, nh, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), B, C, D.astype(jnp.float32))
    return out
