"""One benchmark per paper table/figure (§7), driven by the RRFP engine.

Every function returns a list of CSV rows: (name, us_per_call, derived).
``us_per_call`` is the simulated mean iteration time in microseconds;
``derived`` carries the table's headline quantity (speedup / fraction / ...).
"""
from __future__ import annotations

import numpy as np

from benchmarks.workloads import LARGE_SCALE, REPRESENTATIVE, stage_costs
from repro.core import (
    CostModel,
    EngineConfig,
    HintKind,
    INJECTION_LEVELS,
    JitterModel,
    Kind,
    PipelineSpec,
    average_makespan,
    run_iteration,
)
from repro.core.bounds import bottleneck_stats, check_theorem_6_1, corollary_terms
from repro.core.costs import normalized_spread
from repro.core.hints import modality_balanced_order
import dataclasses


def _cfg_1f1b():
    return EngineConfig(mode="precommitted", fixed_order="1f1b")


def _cfg_zb():
    return EngineConfig(mode="precommitted", fixed_order="zb")


def _cfg_rrfp(hint=HintKind.BF):
    return EngineConfig(mode="hint", hint=hint)


def _methods(spec_args, costs, iters=4):
    """(1F1B, ZB, RRFP, RRFP+BFW) mean makespans for one configuration."""
    spec = PipelineSpec(*spec_args)
    specw = PipelineSpec(*spec_args, split_backward=True)
    costsw = dataclasses.replace(
        costs, b_cost=costs.b_cost * 0.5, w_cost=costs.b_cost * 0.5)
    out = {}
    out["1f1b"], _, _ = average_makespan(spec, costs, _cfg_1f1b(), iters)
    out["zb"], _, _ = average_makespan(specw, costsw, _cfg_zb(), iters)
    out["rrfp"], _, _ = average_makespan(spec, costs, _cfg_rrfp(), iters)
    out["bfw"], _, _ = average_makespan(
        specw, costsw, _cfg_rrfp(HintKind.BFW), iters)
    return out


# ---------------------------------------------------------------------------
def table1_representative():
    """RQ1 Table 1: 12 configurations × 4 methods (speedup over 1F1B)."""
    rows = []
    for wl, (lm, vit, batch) in REPRESENTATIVE.items():
        for tp, pp in ((1, 8), (1, 16), (2, 8), (2, 16)):
            bsz = batch if not (wl.endswith("Big") and tp == 2) else batch // 2
            costs = stage_costs(lm, vit, pp, tp)
            m = _methods((pp, bsz), costs)
            for meth in ("1f1b", "zb", "rrfp", "bfw"):
                rows.append((
                    f"t1/{wl}/TP{tp}PP{pp}/{meth}",
                    m[meth] * 1e6,
                    f"speedup={m['1f1b'] / m[meth]:.2f}x",
                ))
    return rows


def table2_large_scale():
    """RQ1 Table 2: large-scale settings, 32–128 GPUs."""
    rows = []
    for gpus, wl, lm, vit, tp, pp, dp, batch in LARGE_SCALE:
        costs = stage_costs(lm, vit, pp, tp)
        m = _methods((pp, batch // dp), costs)
        for meth in ("1f1b", "zb", "rrfp", "bfw"):
            rows.append((
                f"t2/{gpus}gpu/{wl}/TP{tp}PP{pp}DP{dp}/{meth}",
                m[meth] * 1e6,
                f"speedup={m['1f1b'] / m[meth]:.2f}x",
            ))
    return rows


def table3_breakdown():
    """RQ2 Table 3: compute/blocking/TP-coord decomposition."""
    rows = []
    lm, vit, _ = REPRESENTATIVE["Qwen3-4B+ViT-Big"]
    for tp in (1, 2, 4):
        costs = stage_costs(lm, vit, 16, tp)
        spec = PipelineSpec(16, 32)
        for meth, cfg in (("1f1b", _cfg_1f1b()), ("rrfp", _cfg_rrfp())):
            cfg = dataclasses.replace(cfg, tp_degree=tp)
            _, _, results = average_makespan(spec, costs, cfg, 3)
            bd = {k: float(np.mean([r.breakdown()[k] for r in results]))
                  for k in ("iter", "compute", "blocking", "tp_coord")}
            rows.append((
                f"t3/TP{tp}PP16/{meth}",
                bd["iter"] * 1e6,
                f"compute={bd['compute']/bd['iter']:.1%}"
                f" blocking={bd['blocking']/bd['iter']:.1%}"
                f" tpcoord={bd['tp_coord']/bd['iter']:.2%}",
            ))
    return rows


def table45_cross_framework():
    """RQ3 Tables 4/5: vs DeepSpeed-like (GPipe order) and Cornstarch-like
    (modality-balanced, still pre-committed)."""
    rows = []
    for wl, (lm, vit, batch) in REPRESENTATIVE.items():
        for tp, pp in ((1, 8), (1, 16), (2, 8), (2, 16)):
            costs = stage_costs(lm, vit, pp, tp)
            spec = PipelineSpec(pp, batch)
            ds, _, _ = average_makespan(
                spec, costs, EngineConfig(mode="precommitted",
                                          fixed_order="gpipe"), 3)
            orders = [modality_balanced_order(spec, s, costs.f_cost)
                      for s in range(pp)]
            cs, _, _ = average_makespan(
                spec, costs, EngineConfig(mode="precommitted",
                                          custom_orders=orders), 3)
            rr, _, _ = average_makespan(spec, costs, _cfg_rrfp(), 3)
            best = min(ds, cs)
            rows.append((
                f"t45/{wl}/TP{tp}PP{pp}/rrfp-vs-ext",
                rr * 1e6,
                f"speedup_vs_best_ext={best / rr:.2f}x"
                f" (ds={ds:.2f}s cornstarch={cs:.2f}s)",
            ))
    return rows


def table6_jitter():
    """RQ4 Table 6: robustness under injected compute jitter J0–J3."""
    rows = []
    lm, vit, _ = REPRESENTATIVE["Qwen3-4B+ViT-Big"]
    base = stage_costs(lm, vit, 8, 2)
    spec = PipelineSpec(8, 96 // 2)
    baselines = {}
    for level, inj in INJECTION_LEVELS.items():
        costs = dataclasses.replace(base, injection=inj)
        for meth, cfg in (("1f1b", _cfg_1f1b()), ("rrfp", _cfg_rrfp())):
            mean, std, _ = average_makespan(spec, costs, cfg, 3)
            if level == "J0":
                baselines[meth] = mean
            slow = (mean / baselines[meth] - 1) * 100
            rows.append((
                f"t6/{level}/{meth}",
                mean * 1e6,
                f"slowdown={slow:+.2f}% std={std*1e3:.1f}ms",
            ))
    return rows


def table7_hint_sensitivity():
    """RQ5 Table 7: BF / FB / B-priority / F-priority hint orders."""
    rows = []
    lm, vit, batch = REPRESENTATIVE["Qwen3-1.7B+ViT-H"]
    costs = stage_costs(lm, vit, 8, 1)
    spec = PipelineSpec(8, batch)
    base = None
    for hint in (HintKind.BF, HintKind.FB, HintKind.B_PRIORITY,
                 HintKind.F_PRIORITY):
        mean, _, _ = average_makespan(spec, costs, _cfg_rrfp(hint), 3)
        if base is None:
            base = mean
        rows.append((
            f"t7/{hint.value}",
            mean * 1e6,
            f"slowdown={100 * (mean / base - 1):+.2f}%",
        ))
    return rows


def table8_scaling():
    """RQ6 Table 8: PP depth / modality imbalance / batch-size scaling."""
    rows = []
    # PP sweep
    for pp in (4, 8, 16):
        costs = stage_costs("qwen3-1.7b", "vit-h", pp, 1)
        m = _methods((pp, 192), costs, iters=3)
        rows.append((f"t8/pp/{pp}", m["rrfp"] * 1e6,
                     f"speedup={m['1f1b'] / m['rrfp']:.2f}x"))
    # ViT sweep
    for vit in ("vit-l", "vit-h", "vit-g", "vit-big"):
        costs = stage_costs("qwen3-1.7b", vit, 16, 1)
        m = _methods((16, 192), costs, iters=3)
        rows.append((f"t8/vit/{vit}", m["rrfp"] * 1e6,
                     f"speedup={m['1f1b'] / m['rrfp']:.2f}x"))
    # batch sweep
    for bsz in (64, 128, 192):
        costs = stage_costs("qwen3-4b", "vit-big", 16, 1)
        m = _methods((16, bsz), costs, iters=3)
        rows.append((f"t8/bsz/{bsz}", m["rrfp"] * 1e6,
                     f"speedup={m['1f1b'] / m['rrfp']:.2f}x"))
    return rows


def fig2_variability():
    """Fig. 2: normalized latency spread under fixed conditions."""
    costs = stage_costs("qwen3-1.7b", None, 8)
    rng = costs.make_rng(0)
    comp = np.array([costs.sample_compute(Kind.F, 0, 0, rng)
                     for _ in range(2000)])
    comm = np.array([costs.sample_comm(rng) for _ in range(2000)])
    cs, ms = normalized_spread(comp), normalized_spread(comm)
    return [
        ("f2/compute", float(comp.mean()) * 1e6,
         f"p95_p5={cs['p95_p5']:.2f} iqr={cs['iqr']:.2f}"),
        ("f2/comm", float(comm.mean()) * 1e6,
         f"p95_p5={ms['p95_p5']:.2f} iqr={ms['iqr']:.2f}"),
    ]


def fig5_buffer_sweep():
    """Fig. 5: iteration time vs buffer-size limit (saturates ~16)."""
    rows = []
    lm, vit, batch = REPRESENTATIVE["Qwen3-4B+ViT-Big"]
    costs = stage_costs(lm, vit, 8, 1)
    spec = PipelineSpec(8, batch)
    for limit in (4, 8, 16, 32, 48):
        cfg = dataclasses.replace(_cfg_rrfp(), buffer_limit=limit)
        mean, _, _ = average_makespan(spec, costs, cfg, 3)
        rows.append((f"f5/limit{limit}", mean * 1e6, f"iter={mean:.3f}s"))
    return rows


def fig6_bottleneck_and_bounds():
    """Fig. 6 bottleneck statistics + Theorem 6.1 / Corollary 6.2 check."""
    rows = []
    for wl in ("GPT3-Large", "Qwen3-4B+ViT-Big"):
        lm, vit, batch = REPRESENTATIVE[wl]
        costs = stage_costs(lm, vit, 8, 1)
        # Theorem 6.1's setting ignores communication (§6): near-zero latency
        costs = dataclasses.replace(costs, comm_base=1e-9)
        spec = PipelineSpec(8, min(batch, 64))
        r = run_iteration(spec, costs, _cfg_rrfp())
        f = r.durations(Kind.F)
        b = r.durations(Kind.B)
        stats = bottleneck_stats(f)
        rep = check_theorem_6_1(f, b, r.makespan)
        cor = corollary_terms(f, b)
        rows.append((
            f"f6/{wl}",
            r.makespan * 1e6,
            f"last_stage_share={stats['bottleneck_share'][-1]:.1%}"
            f" thm_holds={rep.holds} C/LB={rep.ratio_to_lb:.2f}"
            f" p={cor['p']:.2f}",
        ))
    return rows


def schedule_search():
    from benchmarks.schedule_search import schedule_search as _ss
    return _ss()


ALL_TABLES = {
    "table1": table1_representative,
    "table2": table2_large_scale,
    "table3": table3_breakdown,
    "table45": table45_cross_framework,
    "table6": table6_jitter,
    "table7": table7_hint_sensitivity,
    "table8": table8_scaling,
    "fig2": fig2_variability,
    "fig5": fig5_buffer_sweep,
    "fig6": fig6_bottleneck_and_bounds,
    # beyond-paper: schedule-as-data search on the compiled executor
    "schedule_search": schedule_search,
}


def _actor_runtime():
    # late import: keeps repro.runtime.rrfp out of the DES-only tables
    from benchmarks.actor_compare import actor_runtime_rows

    return actor_runtime_rows()


# host actor runtime: hint vs precommitted under jitter (+ JSON artifact)
ALL_TABLES["actor_runtime"] = _actor_runtime
