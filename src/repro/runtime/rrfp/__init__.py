"""RRFP host actor runtime: message-driven pipeline dispatch (§4–§5).

The executable counterpart of the DES engine in ``repro.core.engine``: each
pipeline stage is an actor with per-kind ready buffers fed by a message
transport, dispatching work by *arrival* under hint-order arbitration — not
by schedule-table tick.  See ``docs/runtime.md`` for the architecture.

Layering (bottom-up):
  trace     -- logical-clock event log: record / save / load / replay oracles
  messages  -- envelopes + per-TP-rank fan-out
  tp_group  -- §4.2 all-ranks admission barrier (duplicate-idempotent)
  mailbox   -- thread-safe per-kind arrival buffers
  transport -- SimTransport (virtual clock, injectable heavy-tailed latency)
               / ThreadTransport (thread-per-stage, real callables) /
               ReliableChannel + ReliableThreadTransport (per-edge seqnos,
               checksums, ACK/NACK, CRN-keyed retransmission: exactly-once
               delivery over a lossy wire, on both substrates)
  chaos     -- CRN-keyed fault injection: per-edge latency, reorder,
               duplication, stragglers, transient stalls, drifting costs
               (``drift_chaos``: the adaptive-rescheduling regime),
               fail-stop faults (kill / permanent_stall, concurrent and
               cascading via ``fail_stages``), and the lossy-network model
               (drop / corrupt / partition) — both substrates
  actor     -- ready-set arbitration + App. C backpressure + thread loop
  driver    -- builds/wires everything; emits core.engine.RunResult traces,
               records event traces, replays recorded runs; with
               ``ActorConfig.recover``, survives fail-stop faults (epoch
               fencing + respawn/re-map + restore + replay, exactly-once)

See ``docs/testing.md`` for the conformance invariants checked against
recorded traces and how to record/replay a run.
"""
from repro.runtime.rrfp.actor import StageActor, TaskTrace
from repro.runtime.rrfp.chaos import (
    CHAOS_LEVELS,
    DRIFT_PROFILES,
    FAIL_KINDS,
    MODALITY_PROFILE_NAMES,
    ChaosConfig,
    ChaosEngine,
    ChaosThreadTransport,
    StageFailure,
    drift_chaos,
    modality_profile,
    parse_chaos,
)
from repro.runtime.rrfp.driver import (
    ActorConfig,
    ActorDriver,
    average_makespan_actor,
    run_actor_iteration,
)
from repro.runtime.rrfp.mailbox import Mailbox
from repro.runtime.rrfp.messages import (
    EdgePayloads,
    Envelope,
    envelopes_for,
    payload_for_edge,
)
from repro.runtime.rrfp.tp_group import Admission, TPGroup
from repro.runtime.rrfp.trace import (
    ReplayOracle,
    Trace,
    TraceEvent,
    TraceRecorder,
    engine_replay_config,
)
from repro.runtime.rrfp.transport import (
    Ack,
    ReliableChannel,
    ReliableConfig,
    ReliableThreadTransport,
    SimTransport,
    ThreadTransport,
)

__all__ = [
    "Ack",
    "ActorConfig",
    "ActorDriver",
    "Admission",
    "ReliableChannel",
    "ReliableConfig",
    "ReliableThreadTransport",
    "CHAOS_LEVELS",
    "ChaosConfig",
    "DRIFT_PROFILES",
    "ChaosEngine",
    "ChaosThreadTransport",
    "EdgePayloads",
    "Envelope",
    "FAIL_KINDS",
    "MODALITY_PROFILE_NAMES",
    "Mailbox",
    "modality_profile",
    "payload_for_edge",
    "ReplayOracle",
    "SimTransport",
    "StageActor",
    "StageFailure",
    "TaskTrace",
    "ThreadTransport",
    "TPGroup",
    "Trace",
    "TraceEvent",
    "TraceRecorder",
    "average_makespan_actor",
    "drift_chaos",
    "engine_replay_config",
    "envelopes_for",
    "parse_chaos",
    "run_actor_iteration",
]
