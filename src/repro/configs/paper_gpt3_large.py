"""GPT3-Large — the paper's LLM workload (engine benchmarks; RQ1).
[arXiv:2005.14165]"""
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="paper-gpt3-large",
    family="dense",
    num_layers=24,
    d_model=1536,
    num_heads=16,
    num_kv_heads=16,
    d_ff=6144,
    vocab_size=50304,
    act="gelu",
    dtype=jnp.bfloat16,
)
