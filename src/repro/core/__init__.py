"""RRFP core: readiness-driven pipeline runtime (the paper's contribution).

Layering (bottom-up):
  taskgraph -- dependency-constrained task model (§3.1)
  costs     -- runtime-variability models (§2, RQ4 injection)
  hints     -- hint orders Π + fixed pre-committed orders (§5, App. A)
  engine    -- message-driven, ready-set-arbitrated event runtime (§4, App. C/D)
  bounds    -- Theorem 6.1 / Corollary 6.2 / Fig. 6 analysis (§6, App. B)
  synthesis -- engine -> static schedule table for the compiled executor
"""
from repro.core.costs import (
    CostModel,
    InjectionModel,
    INJECTION_LEVELS,
    JitterModel,
    multimodal_stage_flops,
)
from repro.core.engine import (
    DeadlockError,
    Engine,
    EngineConfig,
    RunResult,
    average_makespan,
    run_iteration,
)
from repro.core.hints import HintArbiter, HintKind, ReadySet
from repro.core.synthesis import SynthesisResult, ema_update_costs, synthesize
from repro.core.taskgraph import Kind, PipelineSpec, StageGraph, Task

__all__ = [
    "CostModel", "InjectionModel", "INJECTION_LEVELS", "JitterModel",
    "multimodal_stage_flops", "DeadlockError", "Engine", "EngineConfig",
    "RunResult", "average_makespan", "run_iteration", "HintArbiter",
    "HintKind", "ReadySet", "SynthesisResult", "ema_update_costs",
    "synthesize",
    "Kind", "PipelineSpec", "StageGraph", "Task",
]
