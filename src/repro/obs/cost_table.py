"""Online per-(stage, op) cost tables from realized durations (ROADMAP 3).

The paper's Table-3-style cost models are *inputs* to hint synthesis; this
module closes the loop by measuring them *online*: every COMPLETE event's
realized duration feeds a per-(stage, kind) EWMA — the same 0.9/0.1 EMA the
paper's injection protocol uses for delay tracking — and the resulting table
snapshots into a :class:`~repro.core.costs.CostModel` that hint re-synthesis
(ROADMAP item 3) can consume directly.

Two feeding paths:

* **live** — :class:`~repro.obs.metrics.MetricsRegistry` maintains the EWMAs
  on the runtime's completion hook and assembles an ``OnlineCostTable``
  snapshot at any sync point (``registry.cost_table()``);
* **offline** — :meth:`OnlineCostTable.update_from_trace` folds a recorded
  :class:`~repro.runtime.rrfp.trace.Trace`'s COMPLETE durations (in
  logical-clock order) and SEND→DELIVER transport latencies into the table.
"""
from __future__ import annotations

import numpy as np

from repro.core.costs import CostModel, InjectionModel, JitterModel
from repro.core.taskgraph import Kind


class Ewma:
    """Exponentially-weighted moving average: v <- (1-a) v + a x.

    Deferred like ``repro.obs.metrics.Histogram``: ``observe`` is a bare
    list append on the single-writer hot path; the order-sensitive fold
    runs lazily at the first ``value``/``count`` read (sync points)."""

    __slots__ = ("alpha", "_value", "_count", "_pending")

    def __init__(self, alpha: float = 0.1,
                 value: float | None = None, count: int = 0):
        self.alpha = alpha
        self._value = value
        self._count = count
        self._pending: list[float] = []

    def observe(self, x: float) -> None:
        self._pending.append(x)

    def _fold(self) -> None:
        pending = self._pending
        if not pending:
            return
        v, a = self._value, self.alpha
        for x in pending:
            v = x if v is None else (1.0 - a) * v + a * x
        self._value = v
        self._count += len(pending)
        self._pending = []

    @property
    def value(self) -> float | None:
        self._fold()
        return self._value

    @property
    def count(self) -> int:
        self._fold()
        return self._count

    def seed(self, value: float, count: int) -> None:
        """Adopt an externally-maintained state (registry snapshots)."""
        self._pending = []
        self._value = value
        self._count = count

    def downweight(self, keep: int = 1) -> None:
        """Collapse history to a weak prior (recovery boundaries).

        The current value survives as a prior worth ``keep`` samples, so
        post-recovery observations dominate quickly while cold cells still
        have a sane starting point.  No-op on an empty cell.
        """
        self._fold()
        if self._value is not None:
            self._count = min(self._count, max(0, keep))

    def __repr__(self) -> str:
        return f"Ewma(alpha={self.alpha}, value={self.value}, count={self.count})"


class OnlineCostTable:
    """Per-(stage, kind) duration EWMAs + a transport-latency EWMA."""

    def __init__(self, num_stages: int, alpha: float = 0.1):
        self.num_stages = num_stages
        self.alpha = alpha
        self._cells: dict[tuple[int, Kind], Ewma] = {}
        self.comm = Ewma(alpha)

    def _cell(self, stage: int, kind: Kind) -> Ewma:
        if stage >= self.num_stages:
            self.num_stages = stage + 1
        key = (stage, kind)
        cell = self._cells.get(key)
        if cell is None:
            cell = self._cells[key] = Ewma(self.alpha)
        return cell

    # ---- feeding -----------------------------------------------------------
    def observe(self, stage: int, kind: Kind, dur: float) -> None:
        self._cell(stage, kind).observe(dur)

    def observe_comm(self, latency: float) -> None:
        if latency >= 0.0:
            self.comm.observe(latency)

    def seed(self, stage: int, kind: Kind, value: float, count: int) -> None:
        self._cell(stage, kind).seed(value, count)

    def seed_comm(self, value: float, count: int) -> None:
        # merging shards: weight each stage's comm EWMA by its sample count
        if self.comm.value is None:
            self.comm.seed(value, count)
        else:
            total = self.comm.count + count
            self.comm.seed(
                (self.comm.value * self.comm.count + value * count) / total,
                total)

    def update_from_trace(self, trace) -> "OnlineCostTable":
        """Fold a recorded trace's durations + transport latencies in.

        COMPLETE events are consumed in logical-clock order (the EWMA is
        order-sensitive); SEND→DELIVER pairs match on envelope ``seq``, so
        chaos-duplicated copies each contribute their own latency sample.

        Recovered traces need epoch hygiene: a FENCEd delivery is a
        stale-epoch envelope the mailbox rejected, and a SEND→DELIVER pair
        straddling an epoch bump spans the recovery outage itself — neither
        is a transport-latency sample.  Only same-epoch, non-fenced pairs
        feed the comm EWMA.
        """
        from repro.runtime.rrfp import trace as _tr

        fenced = {int(ev.info["seq"]) for ev in trace.events
                  if ev.kind == _tr.FENCE and "seq" in ev.info}
        sends: dict[int, tuple[float, int]] = {}
        for ev in trace.events:
            if ev.kind == _tr.COMPLETE and "dur" in ev.info:
                self.observe(ev.stage, ev.task.kind, float(ev.info["dur"]))
            elif ev.kind == _tr.SEND and "seq" in ev.info:
                sends.setdefault(int(ev.info["seq"]), (ev.t, ev.epoch))
            elif ev.kind == _tr.DELIVER and "seq" in ev.info:
                seq = int(ev.info["seq"])
                rec = sends.get(seq)
                if rec is not None and rec[1] == ev.epoch and seq not in fenced:
                    self.observe_comm(ev.t - rec[0])
        return self

    # ---- reading -----------------------------------------------------------
    def value(self, stage: int, kind: Kind) -> float | None:
        cell = self._cells.get((stage, kind))
        return cell.value if cell is not None else None

    def samples(self, stage: int, kind: Kind) -> int:
        cell = self._cells.get((stage, kind))
        return cell.count if cell is not None else 0

    def as_cost_model(self, default: CostModel | None = None) -> CostModel:
        """Jitter-free :class:`CostModel` snapshot of the current EWMAs.

        Cells with no observations fall back to ``default``'s base costs
        (or 0.0) — e.g. W rows on fused-backward pipelines.  The snapshot is
        an *expected* model (no jitter/injection): realized variability is
        already baked into the measured EWMAs, and synthesis wants the
        central tendency.
        """
        arrays = {}
        for kind, name in ((Kind.F, "f_cost"), (Kind.B, "b_cost"),
                           (Kind.W, "w_cost")):
            fallback = (getattr(default, name)
                        if default is not None else None)
            col = np.zeros(self.num_stages)
            for s in range(self.num_stages):
                v = self.value(s, kind)
                if v is None and fallback is not None:
                    v = float(fallback[s])
                col[s] = v if v is not None else 0.0
            arrays[name] = col
        comm = (self.comm.value if self.comm.value is not None
                else (default.comm_base if default is not None else 1e-4))
        return CostModel(
            comm_base=float(comm),
            compute_jitter=JitterModel(),
            comm_jitter=JitterModel(),
            injection=InjectionModel(),
            **arrays,
        )

    def to_json(self) -> dict:
        return {
            "num_stages": self.num_stages,
            "alpha": self.alpha,
            "cells": [
                {"stage": s, "kind": k.name, "ewma": c.value,
                 "count": c.count}
                for (s, k), c in sorted(
                    self._cells.items(), key=lambda kv: (kv[0][0], kv[0][1]))
            ],
            "comm": {"ewma": self.comm.value, "count": self.comm.count},
        }
