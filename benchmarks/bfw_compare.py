"""BFW paper-table sweep: split-backward W deferral, end to end.

The paper's headline numbers come from the BFW hint — backward split into a
dX-only B on the critical path and a deferrable weight-gradient W task.  This
benchmark reproduces that claim at two altitudes and emits ``BENCH_bfw.json``:

* **Simulated sweep** — hints × jitter levels × language/multimodal
  workloads (``benchmarks.workloads``) × both backends (DES engine and actor
  runtime).  Fused-vs-split cost models conserve total backward work
  (``CostModel.with_split_backward``), so the BFW-vs-BF ratio isolates
  scheduling flexibility.  The compared methods:

  - ``pre_1f1b``  — precommitted 1F1B, fused backward (the baseline)
  - ``pre_zb``    — precommitted ZB-H1 fixed order, split backward
  - ``hint_bf``   — readiness-driven BF hint, fused backward
  - ``hint_bfw``  — readiness-driven BFW hint, split backward, W deferral
                    capped at ``W_DEFER_CAP`` outstanding stashes per stage

* **Real threaded smoke** — thread-per-stage actors driving *real jitted*
  stage callables (``pipeline.stagefn``) through the same runtime, BFW split
  vs. BF fused on a tiny model: proves the W path executes end to end (loss
  parity, grads accumulated, deferral cap honored).

    PYTHONPATH=src python -m benchmarks.run --backend actor --hint bfw --split-backward

Set ``REPRO_SMOKE=1`` to shrink the sweep for CI smoke runs.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import (
    EngineConfig,
    HintKind,
    INJECTION_LEVELS,
    PipelineSpec,
    average_makespan,
)
from repro.runtime.rrfp import ActorConfig, average_makespan_actor

from benchmarks.workloads import stage_costs

S, M = 8, 24
ITERS = 4
W_DEFER_CAP = 4

WORKLOADS = {
    "language/GPT3-Large": ("gpt3-large", None),
    "multimodal/Qwen3-1.7B+ViT-H": ("qwen3-1.7b", "vit-h"),
}


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_SMOKE"))


def _mean_engine(spec, cm, cfg, iters):
    m, _, _ = average_makespan(spec, cm, cfg, iters)
    return m


def _mean_actor(spec, cm, cfg, iters):
    m, _, _ = average_makespan_actor(spec, cm, cfg, iters)
    return m


def sweep_rows(iters: int = ITERS) -> list[dict]:
    """Mean makespans for every (workload, jitter level, backend) cell."""
    levels = ["J0", "J2"] if _smoke() else list(INJECTION_LEVELS)
    workloads = (dict(list(WORKLOADS.items())[:1]) if _smoke() else WORKLOADS)
    iters = 1 if _smoke() else iters
    fused = PipelineSpec(S, M)
    split = PipelineSpec(S, M, split_backward=True)
    out = []
    for wname, (lm, vit) in workloads.items():
        base = stage_costs(lm, vit, pp=S)
        for level in levels:
            cm_f = dataclasses.replace(base, injection=INJECTION_LEVELS[level])
            cm_s = cm_f.with_split_backward()
            for backend in ("engine", "actor"):
                if backend == "engine":
                    ms = {
                        "pre_1f1b": _mean_engine(fused, cm_f, EngineConfig(
                            mode="precommitted", fixed_order="1f1b"), iters),
                        "pre_zb": _mean_engine(split, cm_s, EngineConfig(
                            mode="precommitted", fixed_order="zb"), iters),
                        "hint_bf": _mean_engine(fused, cm_f, EngineConfig(
                            mode="hint", hint=HintKind.BF), iters),
                        "hint_bfw": _mean_engine(split, cm_s, EngineConfig(
                            mode="hint", hint=HintKind.BFW), iters),
                    }
                else:
                    ms = {
                        "pre_1f1b": _mean_actor(fused, cm_f, ActorConfig(
                            mode="precommitted", fixed_order="1f1b"), iters),
                        "pre_zb": _mean_actor(split, cm_s, ActorConfig(
                            mode="precommitted", fixed_order="zb"), iters),
                        "hint_bf": _mean_actor(fused, cm_f, ActorConfig(
                            mode="hint", hint=HintKind.BF), iters),
                        "hint_bfw": _mean_actor(split, cm_s, ActorConfig(
                            mode="hint", hint=HintKind.BFW,
                            w_defer_cap=W_DEFER_CAP), iters),
                    }
                out.append({
                    "workload": wname,
                    "level": level,
                    "backend": backend,
                    "makespan_s": ms,
                    "speedups": {
                        "bfw_vs_bf": ms["hint_bf"] / ms["hint_bfw"],
                        "bfw_vs_1f1b": ms["pre_1f1b"] / ms["hint_bfw"],
                        "bfw_vs_zb": ms["pre_zb"] / ms["hint_bfw"],
                    },
                })
    return out


def real_threaded_bfw(steps: int = 2) -> dict:
    """BFW on *real* jitted stage callables: the executed (not simulated)
    W path.  Verifies completion, loss parity with the fused backward, and
    the activation-memory deferral cap."""
    import jax

    from repro.configs import registry
    from repro.data.synthetic import synth_batch
    from repro.models.build import build
    from repro.pipeline.stagefn import (
        ActorStageProgram, StageFnOptions, StageFns)
    from repro.runtime.rrfp import ActorDriver

    S2, M2, mb_rows, seq, cap = 2, 4, 2, 16, 2
    cfg = registry.reduced_config("deepseek-7b", num_layers=4)
    model = build(cfg, num_stages=S2)
    key = jax.random.key(0)
    sp = model.init_stage_params(key)
    io = model.init_io_params(jax.random.fold_in(key, 1))
    tokens = M2 * mb_rows * seq
    fns = StageFns(model, StageFnOptions(
        mb_rows=mb_rows, seq_len=seq, loss_scale=1.0 / tokens))

    def run(split: bool) -> dict:
        spec = PipelineSpec(S2, M2, split_backward=split)
        acfg = ActorConfig(
            mode="hint",
            hint=HintKind.BFW if split else HintKind.BF,
            w_defer_cap=cap if split else 0,
            deadlock_timeout=300.0)
        step_ms, losses, w_high = [], [], 0
        for step in range(steps):
            batch = synth_batch(cfg, M2 * mb_rows, seq, step=step)
            programs = [
                ActorStageProgram(
                    fns, s, jax.tree.map(lambda x, s=s: x[s], sp), io, batch,
                    split_backward=split)
                for s in range(S2)
            ]
            res = ActorDriver(spec, None, acfg).run_threaded(list(programs))
            assert len(res.end) == spec.total_tasks(), "tasks went missing"
            step_ms.append(res.makespan * 1e3)
            losses.append(float(sum(p.loss_acc for p in programs)) / tokens)
            w_high = max(w_high, *(p.w_high_water for p in programs))
        return {"step_ms": step_ms, "loss": losses, "w_high_water": w_high,
                "tasks": spec.total_tasks()}

    fused = run(split=False)
    bfw = run(split=True)
    assert bfw["w_high_water"] <= cap, (bfw["w_high_water"], cap)
    assert abs(bfw["loss"][0] - fused["loss"][0]) < 1e-4 * max(
        1.0, abs(fused["loss"][0])), (bfw["loss"], fused["loss"])
    return {
        "model": "deepseek-7b (reduced, 4 layers)",
        "stages": S2, "microbatches": M2, "w_defer_cap": cap,
        "bf_fused": fused, "bfw_split": bfw,
        "loss_parity": True,
    }


def run_bfw_benchmark() -> dict:
    rows = sweep_rows()
    actor_jittered = [
        r for r in rows if r["backend"] == "actor" and r["level"] != "J0"]
    bfw_le_bf = all(
        r["makespan_s"]["hint_bfw"] <= r["makespan_s"]["hint_bf"]
        for r in actor_jittered)
    mean_ratio = float(np.mean(
        [r["speedups"]["bfw_vs_bf"] for r in actor_jittered]))
    return {
        "spec": {"stages": S, "microbatches": M,
                 "iters": 1 if _smoke() else ITERS,
                 "w_defer_cap": W_DEFER_CAP, "smoke": _smoke()},
        "sweep": rows,
        "real_threaded": real_threaded_bfw(),
        "summary": {
            "bfw_le_bf_on_jittered_actor_sweep": bfw_le_bf,
            "mean_bfw_vs_bf_speedup_jittered_actor": mean_ratio,
        },
    }


def emit_json(path: str = "BENCH_bfw.json") -> dict:
    report = run_bfw_benchmark()
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def bfw_rows(json_path: str = "BENCH_bfw.json") -> list[tuple[str, float, str]]:
    """CSV rows for ``benchmarks.run`` (name, us_per_call, derived)."""
    report = emit_json(json_path)
    out = []
    for r in report["sweep"]:
        tag = f"bfw/{r['workload']}/{r['level']}/{r['backend']}"
        ms = r["makespan_s"]
        sp = r["speedups"]
        out.append((f"{tag}/hint-bfw", ms["hint_bfw"] * 1e6,
                    f"vs_bf={sp['bfw_vs_bf']:.2f}x"))
        out.append((f"{tag}/hint-bf", ms["hint_bf"] * 1e6,
                    f"vs_1f1b={sp['bfw_vs_1f1b']:.2f}x"))
    rt = report["real_threaded"]
    out.append(("bfw/real-threaded/bfw-split",
                float(np.mean(rt["bfw_split"]["step_ms"])) * 1e3,
                f"w_high_water={rt['bfw_split']['w_high_water']}"))
    out.append(("bfw/real-threaded/bf-fused",
                float(np.mean(rt["bf_fused"]["step_ms"])) * 1e3,
                f"loss_parity={rt['loss_parity']}"))
    return out
