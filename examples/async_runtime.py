"""Actor-runtime quickstart: dispatch by arrival, not by table tick.

Part 1 — simulated transport: the same 8-stage/32-microbatch pipeline run
through the actor runtime in both consumption modes on identical sampled
latencies (CRN keying), across the paper's jitter levels.

Part 2 — thread transport: a tiny real model trained for a few steps with
thread-per-stage actors driving jitted stage callables (forward/backward
factored out of the compiled executor).

    PYTHONPATH=src python examples/async_runtime.py
"""
import dataclasses

import jax

from repro.core import (
    CostModel, INJECTION_LEVELS, PipelineSpec, multimodal_stage_flops,
)
from repro.runtime.rrfp import ActorConfig, average_makespan_actor

# ---------------------------------------------------------------------------
print("=== simulated transport: hint vs precommitted under jitter ===")
S, M = 8, 32
spec = PipelineSpec(S, M)
base = CostModel.from_stage_flops(
    multimodal_stage_flops(4e12, 2e12, S), comm_base=2e-3)

print(f"{'level':>6} {'1F1B (s)':>10} {'RRFP (s)':>10} {'speedup':>8}")
for level, inj in INJECTION_LEVELS.items():
    costs = dataclasses.replace(base, injection=inj)
    pre, _, _ = average_makespan_actor(
        spec, costs, ActorConfig(mode="precommitted", fixed_order="1f1b"), 3)
    hint, _, _ = average_makespan_actor(
        spec, costs, ActorConfig(mode="hint"), 3)
    print(f"{level:>6} {pre:>10.3f} {hint:>10.3f} {pre / hint:>7.2f}x")

# ---------------------------------------------------------------------------
print("\n=== thread transport: real jitted stage callables ===")
from repro.configs import registry                      # noqa: E402
from repro.core.taskgraph import PipelineSpec as PS     # noqa: E402
from repro.models.build import build                    # noqa: E402
from repro.pipeline.stagefn import (                    # noqa: E402
    ActorStageProgram, StageFnOptions, StageFns)
from repro.data.synthetic import synth_batch            # noqa: E402
from repro.runtime.rrfp import ActorDriver              # noqa: E402

S2, M2, mb_rows, seq = 2, 4, 2, 16
cfg = registry.reduced_config("deepseek-7b", num_layers=4)
model = build(cfg, num_stages=S2)
key = jax.random.key(0)
sp = model.init_stage_params(key)
io = model.init_io_params(jax.random.fold_in(key, 1))
tokens = M2 * mb_rows * seq
fns = StageFns(model, StageFnOptions(
    mb_rows=mb_rows, seq_len=seq, loss_scale=1.0 / tokens))
spec2 = PS(S2, M2)
for step in range(3):
    batch = synth_batch(cfg, M2 * mb_rows, seq, step=step)
    programs = [
        ActorStageProgram(
            fns, s, jax.tree.map(lambda x, s=s: x[s], sp), io, batch)
        for s in range(S2)
    ]
    res = ActorDriver(spec2, None, ActorConfig(mode="hint")).run_threaded(
        list(programs))
    loss = sum(p.loss_sum for p in programs) / tokens
    print(f"step {step}: loss {loss:.4f}  wall makespan "
          f"{res.makespan * 1e3:.1f} ms  tasks {len(res.end)}")
print("\nSame runtime, two transports: simulation for schedule studies, "
      "threads for real execution.")
