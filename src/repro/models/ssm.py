"""Mamba-2 block (zamba2's backbone layer) in pure JAX.

Train path uses the chunked SSD contraction (Pallas kernel or the XLA
equivalent via ``repro.kernels.ops.ssd``); decode keeps a (conv, ssm) state
pair per layer, so long_500k decode is O(1) in sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import ArchConfig, dense_init


def init_mamba_layer(keys, cfg: ArchConfig) -> dict:
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.d_inner(d)
    nh = ssm.num_heads(d)
    ds = ssm.d_state
    conv_dim = di + 2 * ds
    return {
        "ln": jnp.zeros((d,), cfg.dtype),
        "in_proj": dense_init(next(keys), (d, 2 * di + 2 * ds + nh), cfg.dtype),
        "conv_w": dense_init(next(keys), (ssm.d_conv, conv_dim), cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(a_log)
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_ln": jnp.zeros((di,), cfg.dtype),
        "out_proj": dense_init(next(keys), (di, d), cfg.dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv over seq.  x: [b, s, c]; w: [k, c]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _split_proj(proj, cfg: ArchConfig):
    ssm = cfg.ssm
    di = ssm.d_inner(cfg.d_model)
    nh = ssm.num_heads(cfg.d_model)
    ds = ssm.d_state
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * ds], axis=-1)
    return z, xbc, dt, (di, nh, ds)


def mamba_layer(p, x, cfg: ArchConfig):
    """x: [b, s, d] -> [b, s, d] (pre-norm residual handled here)."""
    from repro.models.layers import rmsnorm

    b, s, d = x.shape
    ssm = cfg.ssm
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    proj = h @ p["in_proj"]
    z, xbc, dt, (di, nh, ds) = _split_proj(proj, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, B, C = jnp.split(xbc, [di, di + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y = ops.ssd(
        xs.reshape(b, s, nh, ssm.head_dim), dt, A, B, C, p["d_skip"],
        chunk=ssm.chunk,
    ).reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_ln"], cfg.norm_eps)
    return x + y @ p["out_proj"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_mamba_cache(batch: int, cfg: ArchConfig, dtype=None):
    ssm = cfg.ssm
    d = cfg.d_model
    di = ssm.d_inner(d)
    nh = ssm.num_heads(d)
    ds = ssm.d_state
    dtype = dtype or cfg.dtype
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, di + 2 * ds), dtype),
        "ssm": jnp.zeros((batch, nh, ssm.head_dim, ds), jnp.float32),
    }


def mamba_layer_decode(p, x, cache, cfg: ArchConfig):
    """x: [b, 1, d]; cache: {conv [b,k-1,c], ssm [b,nh,hd,ds]}."""
    from repro.models.layers import rmsnorm

    b = x.shape[0]
    ssm = cfg.ssm
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    proj = h @ p["in_proj"]
    z, xbc, dt, (di, nh, ds) = _split_proj(proj[:, 0], cfg)
    # rolling conv state
    window = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # [b,k,c]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc_t = jax.nn.silu(conv_out)
    xs, B, C = jnp.split(xbc_t, [di, di + ds], axis=-1)
    dt_t = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y, ssm_state = ops.ssd_decode_step(
        cache["ssm"], xs.reshape(b, nh, ssm.head_dim), dt_t, A, B, C, p["d_skip"]
    )
    y = y.reshape(b, di)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_ln"], cfg.norm_eps)
    out = x + (y @ p["out_proj"])[:, None]
    return out, {"conv": window[:, 1:], "ssm": ssm_state}
