"""Split-backward (BFW) end-to-end: numerics, runtime W path, deferral cap."""
import threading
import time

import pytest

from repro.core import (
    CostModel,
    EngineConfig,
    HintKind,
    JitterModel,
    Kind,
    PipelineSpec,
    Task,
    run_iteration,
)
from repro.runtime.rrfp import ActorConfig, ActorDriver, run_actor_iteration


def det_costs(S, f=1.0, b=1.0, w=1.0, comm=1e-6, **kw):
    return CostModel.uniform(
        S, f=f, b=b, w=w, comm_base=comm,
        compute_jitter=JitterModel(), comm_jitter=JitterModel(), **kw,
    )


def _w_backlog_max(tasks_in_completion_order):
    """Max running (B done - W done) over one stage's completion sequence."""
    d = mx = 0
    for t in tasks_in_completion_order:
        if t.kind == Kind.B:
            d += 1
        elif t.kind == Kind.W:
            d -= 1
        mx = max(mx, d)
    return mx


# ---------------------------------------------------------------------------
# Numerics: B(dX) + W(dW) must reproduce the fused backward
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_split_backward_matches_fused_gradients():
    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.models.build import build
    from repro.pipeline.stagefn import StageFnOptions, StageFns, microbatch

    S, mb_rows, seq = 2, 2, 16
    cfg = registry.reduced_config("deepseek-7b", num_layers=4)
    model = build(cfg, num_stages=S)
    key = jax.random.key(0)
    sp = model.init_stage_params(key)
    io = model.init_io_params(jax.random.fold_in(key, 1))
    batch = {
        "tokens": jax.random.randint(
            jax.random.key(2), (mb_rows, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(
            jax.random.key(3), (mb_rows, seq), 0, cfg.vocab_size),
    }
    fns = StageFns(model, StageFnOptions(mb_rows=mb_rows, seq_len=seq))
    bm = microbatch(batch, 0, mb_rows)
    sp0 = jax.tree.map(lambda x: x[0], sp)
    sp1 = jax.tree.map(lambda x: x[1], sp)
    y0, _ = fns.forward(0)(sp0, io, None, bm)
    g_in = jnp.zeros_like(y0)  # last stage: CE is the objective, g_in unused

    dx_f, dsp_f, dio_f = fns.backward(1)(sp1, io, y0, g_in, bm)
    dx_s = fns.backward_dx(1)(sp1, io, y0, g_in, bm)
    dsp_s, dio_s = fns.weight_grad(1)(sp1, io, y0, g_in, bm)

    def max_diff(a, b):
        return max(
            float(jnp.max(jnp.abs(
                x.astype(jnp.float32) - y.astype(jnp.float32))))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    # same objective, same remat recipe -> bitwise-equal partials
    assert max_diff(dx_f, dx_s) == 0.0
    assert max_diff(dsp_f, dsp_s) == 0.0
    assert max_diff(dio_f, dio_s) == 0.0


@pytest.mark.slow
def test_threaded_bfw_matches_fused_run():
    """BFW split-backward through the real threaded runtime reproduces the
    fused run's loss and accumulated parameter grads, and honors the cap."""
    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.models.build import build
    from repro.pipeline.stagefn import (
        ActorStageProgram, StageFnOptions, StageFns)

    S, M, mb_rows, seq, cap = 2, 4, 2, 16, 2
    cfg = registry.reduced_config("deepseek-7b", num_layers=4)
    model = build(cfg, num_stages=S)
    key = jax.random.key(0)
    sp = model.init_stage_params(key)
    io = model.init_io_params(jax.random.fold_in(key, 1))
    B_rows = M * mb_rows
    batch = {
        "tokens": jax.random.randint(
            jax.random.key(2), (B_rows, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(
            jax.random.key(3), (B_rows, seq), 0, cfg.vocab_size),
    }
    tokens = B_rows * seq
    fns = StageFns(model, StageFnOptions(
        mb_rows=mb_rows, seq_len=seq, loss_scale=1.0 / tokens))

    def run(split: bool):
        spec = PipelineSpec(S, M, split_backward=split)
        programs = [
            ActorStageProgram(
                fns, s, jax.tree.map(lambda x, s=s: x[s], sp), io, batch,
                split_backward=split)
            for s in range(S)
        ]
        acfg = ActorConfig(
            mode="hint", hint=HintKind.BFW if split else HintKind.BF,
            w_defer_cap=cap if split else 0, deadlock_timeout=300.0)
        r = ActorDriver(spec, None, acfg).run_threaded(list(programs))
        assert set(r.end) == set(spec.tasks())  # W tasks really executed
        return programs

    fused = run(split=False)
    bfw = run(split=True)

    # loss is accumulated on device; one materialization here
    loss_f = sum(p.loss_sum for p in fused) / tokens
    loss_w = sum(p.loss_sum for p in bfw) / tokens
    assert abs(loss_f - loss_w) < 1e-5 * max(1.0, abs(loss_f))

    for pf, pw in zip(fused, bfw):
        assert pw.w_high_water <= cap
        assert pw.w_outstanding() == 0  # every stash was consumed by its W
        for gf, gw in zip(jax.tree.leaves(pf.d_stage),
                          jax.tree.leaves(pw.d_stage)):
            scale = float(jnp.max(jnp.abs(gf.astype(jnp.float32)))) + 1e-8
            diff = float(jnp.max(jnp.abs(
                gf.astype(jnp.float32) - gw.astype(jnp.float32))))
            assert diff <= 1e-5 * scale, (diff, scale)


def test_fused_program_rejects_w_task():
    import numpy as np

    from repro.pipeline.stagefn import ActorStageProgram

    prog = ActorStageProgram.__new__(ActorStageProgram)
    prog.split_backward = False
    prog.batch = {"tokens": np.zeros((2, 4), np.int32)}
    prog.fns = type("F", (), {"opts": type("O", (), {"mb_rows": 1})()})()
    with pytest.raises(ValueError, match="split_backward=True"):
        ActorStageProgram.__call__(prog, Task(Kind.W, 0, 0), None)


# ---------------------------------------------------------------------------
# W-deferral cap (activation-memory backpressure)
# ---------------------------------------------------------------------------
class TestWDeferCap:
    def test_cap_never_exceeded_in_sim(self):
        S, M, cap = 4, 16, 3
        spec = PipelineSpec(S, M, split_backward=True)
        cm = det_costs(S, f=1.0, b=0.5, w=0.5, comm=1e-3)
        r = run_actor_iteration(spec, cm, ActorConfig(
            mode="hint", hint=HintKind.BFW, w_defer_cap=cap))
        assert set(r.end) == set(spec.tasks())
        for s in range(S):
            ev = [t for _, t in sorted(
                (r.end[t], t) for t in r.end if t.stage == s)]
            assert _w_backlog_max(ev) <= cap

    def test_cap_never_exceeded_in_threaded_run(self):
        S, M, cap = 3, 8, 2
        spec = PipelineSpec(S, M, split_backward=True)
        lock = threading.Lock()
        completion: dict[int, list[Task]] = {s: [] for s in range(S)}

        def work(task, payload):
            time.sleep(0.001)
            with lock:
                completion[task.stage].append(task)
            return None

        r = ActorDriver(spec, None, ActorConfig(
            mode="hint", hint=HintKind.BFW,
            w_defer_cap=cap)).run_threaded(work)
        assert len(r.end) == spec.total_tasks()
        for s in range(S):
            assert _w_backlog_max(completion[s]) <= cap

    def test_uncapped_deferral_can_exceed_cap_value(self):
        """Sanity: with w_defer_cap=0 (unbounded) the same workload defers
        more than the cap would allow — the knob is load-bearing."""
        S, M, cap = 4, 16, 3
        spec = PipelineSpec(S, M, split_backward=True)
        cm = det_costs(S, f=1.0, b=0.5, w=0.5, comm=1e-3)
        r = run_actor_iteration(spec, cm, ActorConfig(
            mode="hint", hint=HintKind.BFW, w_defer_cap=0))
        worst = max(
            _w_backlog_max([t for _, t in sorted(
                (r.end[t], t) for t in r.end if t.stage == s)])
            for s in range(S))
        assert worst > cap

    def test_cap_does_not_apply_to_precommitted(self):
        """Precommitted zb fixes W placement in its order; the cap knob is a
        hint-mode memory bound and must not perturb fixed-order runs."""
        S, M = 4, 8
        spec = PipelineSpec(S, M, split_backward=True)
        cm = det_costs(S)
        a = run_actor_iteration(spec, cm, ActorConfig(
            mode="precommitted", fixed_order="zb", w_defer_cap=1))
        b = run_actor_iteration(spec, cm, ActorConfig(
            mode="precommitted", fixed_order="zb", w_defer_cap=0))
        assert a.stage_orders() == b.stage_orders()


# ---------------------------------------------------------------------------
# Consistency validation: hint mode on a split spec requires the BFW hint
# ---------------------------------------------------------------------------
class TestSplitSpecValidation:
    def test_actor_driver_rejects_non_bfw_hint(self):
        spec = PipelineSpec(2, 2, split_backward=True)
        with pytest.raises(ValueError, match="BFW"):
            ActorDriver(spec, det_costs(2), ActorConfig(
                mode="hint", hint=HintKind.BF))

    def test_engine_rejects_non_bfw_hint(self):
        spec = PipelineSpec(2, 2, split_backward=True)
        with pytest.raises(ValueError, match="BFW"):
            run_iteration(spec, det_costs(2), EngineConfig(
                mode="hint", hint=HintKind.FB))

    def test_straggler_replan_from_split_backward_trace(self):
        """A split-backward RunResult must feed the straggler monitor's EMA
        without tripping synthesis (which models fused backward and is fed
        the fused twin of the spec, as launch.train does)."""
        from repro.runtime.straggler import StragglerMonitor

        S, M = 4, 8
        spec = PipelineSpec(S, M, split_backward=True)
        skewed = CostModel.uniform(S, b=0.5, w=0.5, comm_base=1e-4)
        skewed.f_cost[2] *= 4.0
        r = run_actor_iteration(spec, skewed, ActorConfig(
            mode="hint", hint=HintKind.BFW, w_defer_cap=4))
        mon = StragglerMonitor(
            spec=PipelineSpec(S, M), costs=CostModel.uniform(S),
            min_steps_between_replans=1, decay=0.0)
        table = mon.observe_result(r)
        assert mon.replans == 1 and table is not None
        table.validate()

    def test_w_is_stage_local_in_taskgraph(self):
        spec = PipelineSpec(4, 4, num_chunks=2, split_backward=True)
        for t in spec.tasks():
            if t.kind == Kind.W:
                assert spec.message_successor(t) is None
                assert spec.message_predecessor(t) is None
                assert spec.local_predecessor(t) == Task(
                    Kind.B, t.stage, t.mb, t.chunk)
