"""Chrome trace-event / Perfetto export of recorded runtime traces.

Renders a logical-clock :class:`~repro.runtime.rrfp.trace.Trace` in the
Chrome trace-event JSON format (the JSON flavor Perfetto ingests directly —
open ``ui.perfetto.dev`` and drop the file, or ``chrome://tracing``):

* one *process* (track group) per pipeline stage, with complete-event
  (``ph: "X"``) slices named ``F``/``B``/``W`` (``dX``/``dW`` on
  split-backward specs) for every DISPATCH..COMPLETE pair;
* flow arrows (``ph: "s"`` / ``"f"``) from each SEND to its DELIVER,
  matched on envelope ``seq`` — chaos-duplicated copies each get their own
  arrow — visualizing the message weather the runtime is absorbing;
* counter tracks (``ph: "C"``): per-kind mailbox queue depth (from
  ENQUEUE/DEQUEUE) and the deferred-W backlog (from COMPLETE info), the two
  gauges backpressure and the W cap act on.

Timestamps are exported in microseconds (the format's unit); the sim
substrate's virtual seconds and the thread substrate's wall-clock seconds
both scale through unchanged.
"""
from __future__ import annotations

import json

from repro.core.taskgraph import Kind

from repro.runtime.rrfp import trace as _tr

_US = 1e6  # trace-event timestamps are microseconds


def _slice_name(task, split_backward: bool) -> str:
    if split_backward:
        labels = {Kind.F: "F", Kind.B: "dX", Kind.W: "dW"}
    else:
        labels = {Kind.F: "F", Kind.B: "B", Kind.W: "W"}
    name = f"{labels[task.kind]} m{task.mb}"
    if task.chunk:
        name += f" c{task.chunk}"
    return name


def to_perfetto(trace: _tr.Trace, critical_path: bool = False) -> dict:
    """Convert a recorded trace to a Chrome trace-event JSON object.

    With ``critical_path=True`` (opt-in: the default output stays
    byte-stable) the export additionally runs ``obs.critpath`` over the
    trace and (a) shades every task slice by its scheduling slack —
    critical-path slices red (``cname: terrible``), near-critical ones
    progressively lighter, with ``slack_s``/``critical`` in the slice args
    — and (b) appends a dedicated "critical path" track (one synthetic
    process after the per-stage ones) holding only the binding chain,
    recovery windows included, so the bounding sequence reads left-to-right
    at ui.perfetto.dev.
    """
    meta = trace.meta
    split = bool(meta.get("split_backward", False))
    num_stages = int(meta.get("num_stages", 0) or
                     1 + max((ev.stage for ev in trace.events), default=0))
    cp_by_dlc: dict[int, tuple[float, bool]] = {}
    cp_path: list = []
    if critical_path:
        # lazy import: export must stay loadable without the engine
        from repro.obs.critpath import ROOT_KEY, ExecGraph

        graph = ExecGraph.build(trace)
        slacks = graph.slack()
        mk = max(graph.makespan, 1e-300)
        on_path = {n.key for n, _ in graph.critical_path()}
        for key, node in graph.nodes.items():
            if key == ROOT_KEY or node.dispatch_lc < 0:
                continue
            cp_by_dlc[node.dispatch_lc] = (slacks[key], key in on_path)
        cp_path = [(n, e) for n, e in graph.critical_path()
                   if n.key != ROOT_KEY]

        def _shade(slack: float, critical: bool) -> str | None:
            if critical:
                return "terrible"
            if slack < 0.05 * mk:
                return "bad"
            if slack < 0.20 * mk:
                return "generally_bad"
            return None
    events: list[dict] = []
    for s in range(num_stages):
        events.append({"ph": "M", "name": "process_name", "pid": s, "tid": 0,
                       "args": {"name": f"stage {s}"}})
        events.append({"ph": "M", "name": "process_sort_index", "pid": s,
                       "tid": 0, "args": {"sort_index": s}})
        events.append({"ph": "M", "name": "thread_name", "pid": s, "tid": 0,
                       "args": {"name": "exec"}})

    dispatch_ev: dict = {}
    send_t: dict[int, tuple[int, float]] = {}  # seq -> (src stage, t)
    depth: dict[int, dict[Kind, int]] = {}
    backlog_seen: set[int] = set()
    for ev in trace.events:
        ts = ev.t * _US
        if ev.kind == _tr.DISPATCH:
            dispatch_ev.setdefault(ev.task, ev)
        elif ev.kind == _tr.COMPLETE:
            d = dispatch_ev.pop(ev.task, None)
            if d is not None:
                args = {"lc": ev.lc, "mb": ev.task.mb, "chunk": ev.task.chunk}
                path = d.info.get("path")
                if path:
                    args["path"] = path
                if "dur" in ev.info:
                    args["dur_s"] = ev.info["dur"]
                slice_ev = {
                    "ph": "X", "name": _slice_name(ev.task, split),
                    "cat": "task", "pid": ev.stage, "tid": 0,
                    "ts": d.t * _US, "dur": max(0.0, (ev.t - d.t) * _US),
                    "args": args}
                if d.lc in cp_by_dlc:
                    slack, critical = cp_by_dlc[d.lc]
                    args["slack_s"] = slack
                    args["critical"] = critical
                    shade = _shade(slack, critical)
                    if shade is not None:
                        slice_ev["cname"] = shade
                events.append(slice_ev)
            wb = ev.info.get("w_backlog")
            if wb is not None:
                backlog_seen.add(ev.stage)
                events.append({
                    "ph": "C", "name": "w_backlog", "pid": ev.stage,
                    "ts": ts, "args": {"deferred W": wb}})
        elif ev.kind == _tr.SEND:
            seq = ev.info.get("seq")
            if seq is not None:
                send_t[int(seq)] = (ev.stage, ev.t)
        elif ev.kind == _tr.DELIVER:
            seq = ev.info.get("seq")
            src = send_t.get(int(seq)) if seq is not None else None
            if src is not None:
                name = _slice_name(ev.task, split)
                flow = {"cat": "msg", "name": name, "id": int(seq)}
                events.append({"ph": "s", "pid": src[0], "tid": 0,
                               "ts": src[1] * _US, **flow})
                events.append({"ph": "f", "bp": "e", "pid": ev.stage,
                               "tid": 0, "ts": max(ts, src[1] * _US), **flow})
        elif ev.kind in (_tr.ENQUEUE, _tr.DEQUEUE):
            d = depth.setdefault(ev.stage, {k: 0 for k in Kind})
            d[ev.task.kind] += 1 if ev.kind == _tr.ENQUEUE else -1
            events.append({
                "ph": "C", "name": "queue_depth", "pid": ev.stage, "ts": ts,
                "args": {k.name: d[k] for k in Kind}})
        elif ev.kind == _tr.STALL:
            events.append({
                "ph": "X", "name": "chaos stall", "cat": "chaos",
                "pid": ev.stage, "tid": 0, "ts": ts,
                "dur": float(ev.info.get("dur", 0.0)) * _US,
                "args": {"lc": ev.lc}})
    if cp_path:
        cp_pid = num_stages  # one synthetic process after the stage tracks
        events.append({"ph": "M", "name": "process_name", "pid": cp_pid,
                       "tid": 0, "args": {"name": "critical path"}})
        events.append({"ph": "M", "name": "process_sort_index", "pid": cp_pid,
                       "tid": 0, "args": {"sort_index": cp_pid}})
        events.append({"ph": "M", "name": "thread_name", "pid": cp_pid,
                       "tid": 0, "args": {"name": "binding chain"}})
        for node, edge in cp_path:
            if node.op == "recovery":
                name = f"recovery s{node.stage}"
            else:
                name = f"{_slice_name(node.task, split)} s{node.stage}"
            events.append({
                "ph": "X", "name": name, "cat": "critical_path",
                "pid": cp_pid, "tid": 0, "ts": node.dispatch_t * _US,
                "dur": max(0.0, (node.end_t - node.dispatch_t) * _US),
                "cname": "terrible",
                "args": {"stage": node.stage, "op": node.op,
                         "via": edge.kind if edge is not None else "root",
                         "slack_s": 0.0, "critical": True}})
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {k: v for k, v in meta.items() if v is not None},
    }


def export_perfetto(trace: _tr.Trace, path: str,
                    critical_path: bool = False) -> None:
    """Write the Chrome trace-event JSON for ``trace`` to ``path``."""
    with open(path, "w") as f:
        json.dump(to_perfetto(trace, critical_path=critical_path), f)


# ---- schema validation (used by tests and the conformance harness) --------
_PH_REQUIRED: dict[str, tuple[str, ...]] = {
    "X": ("name", "pid", "tid", "ts", "dur"),
    "M": ("name", "pid", "args"),
    "C": ("name", "pid", "ts", "args"),
    "s": ("name", "pid", "tid", "ts", "id"),
    "f": ("name", "pid", "tid", "ts", "id"),
}


def validate_chrome_trace(doc: dict) -> None:
    """Assert ``doc`` is structurally valid Chrome trace-event JSON.

    Checks the subset of the format the exporter emits: required fields per
    phase type, numeric non-negative timestamps/durations, int pid/tid, and
    that every flow-start ``s`` has a matching finish ``f`` (same id) at an
    equal-or-later timestamp.  Raises :class:`AssertionError` on violation.
    """
    assert isinstance(doc, dict), "top level must be a JSON object"
    evs = doc.get("traceEvents")
    assert isinstance(evs, list) and evs, "traceEvents must be non-empty list"
    flows: dict[int, list[float]] = {}
    finishes: dict[int, list[float]] = {}
    for i, ev in enumerate(evs):
        assert isinstance(ev, dict), f"event {i} not an object"
        ph = ev.get("ph")
        assert ph in _PH_REQUIRED, f"event {i}: unknown phase {ph!r}"
        for field in _PH_REQUIRED[ph]:
            assert field in ev, f"event {i} (ph={ph}) missing {field!r}"
        if "pid" in ev:
            assert isinstance(ev["pid"], int), f"event {i}: pid must be int"
        if "tid" in ev:
            assert isinstance(ev["tid"], int), f"event {i}: tid must be int"
        if "ts" in ev:
            assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0, (
                f"event {i}: bad ts {ev.get('ts')!r}")
        if ph == "X":
            assert isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0, (
                f"event {i}: bad dur {ev.get('dur')!r}")
        if ph == "s":
            flows.setdefault(ev["id"], []).append(ev["ts"])
        elif ph == "f":
            finishes.setdefault(ev["id"], []).append(ev["ts"])
    for fid, starts in flows.items():
        ends = finishes.get(fid)
        assert ends, f"flow id {fid} started but never finished"
        assert min(ends) >= min(starts), (
            f"flow id {fid} finishes before it starts")
    json.dumps(doc)  # must be serializable end-to-end
