"""Qwen2-VL-2B backbone — M-RoPE, dynamic resolution (vision frontend stubbed:
input_specs provides patch+text embeddings and 3-axis position ids).
[arXiv:2409.12191; hf]"""
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    mrope=True,
    rope_theta=1_000_000.0,
    embed_input=True,       # frontend stub supplies embeddings
    dtype=jnp.bfloat16,
)
