"""Perfetto / Chrome trace-event export (repro.obs.export).

The exporter's output is consumed by an external tool (ui.perfetto.dev),
so these tests pin the *format contract*: structural schema validity, one
complete-event slice per executed task, paired flow arrows per delivered
envelope, and a lossless JSON round trip — on chain, DAG, split-backward
and chaos runs from both recording substrates.
"""
import json

import pytest

from repro.core import CostModel, HintKind, JitterModel, PipelineSpec, StageGraph
from repro.obs import export_perfetto, to_perfetto, validate_chrome_trace
from repro.runtime.rrfp import CHAOS_LEVELS, ActorConfig, ActorDriver
from repro.runtime.rrfp import trace as _tr


def recorded_trace(spec, cm, **cfg_kw):
    driver = ActorDriver(spec, cm, ActorConfig(record_trace=True, **cfg_kw))
    driver.run()
    return driver.trace


def det_costs(S, **kw):
    return CostModel.uniform(S, comm_base=1e-3,
                             compute_jitter=JitterModel(),
                             comm_jitter=JitterModel(), **kw)


def dag_spec(num_mb=4):
    g = StageGraph(5, ((0, 2), (1, 2), (2, 3), (3, 4)))
    return PipelineSpec(5, num_mb, graph=g)


def slices(doc):
    return [e for e in doc["traceEvents"]
            if e["ph"] == "X" and e.get("cat") == "task"]


class TestPerfettoExport:
    def test_chain_schema_and_slice_count(self):
        spec = PipelineSpec(4, 6)
        trace = recorded_trace(spec, det_costs(4), mode="hint",
                               hint=HintKind.BF, seed=7)
        doc = to_perfetto(trace)
        validate_chrome_trace(doc)
        # one X slice per executed task, on the right process track
        xs = slices(doc)
        assert len(xs) == spec.total_tasks()
        assert {e["pid"] for e in xs} == set(range(spec.num_stages))
        # process metadata names every stage track
        names = [e for e in doc["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "process_name"]
        assert len(names) == spec.num_stages
        # queue-depth counters ride along
        assert any(e["ph"] == "C" and e["name"] == "queue_depth"
                   for e in doc["traceEvents"])

    def test_flow_arrows_pair_send_to_deliver(self):
        spec = PipelineSpec(3, 4)
        trace = recorded_trace(spec, det_costs(3), mode="hint",
                               hint=HintKind.BF, seed=7)
        doc = to_perfetto(trace)
        validate_chrome_trace(doc)  # includes s/f pairing + ordering
        starts = [e for e in doc["traceEvents"] if e["ph"] == "s"]
        finishes = [e for e in doc["traceEvents"] if e["ph"] == "f"]
        delivered = sum(1 for ev in trace.events if ev.kind == _tr.DELIVER)
        assert len(starts) == len(finishes) == delivered
        # every arrow originates at its SEND's stage and time
        send_by_seq = {ev.info["seq"]: ev for ev in trace.events
                       if ev.kind == _tr.SEND}
        for s in starts:
            ev = send_by_seq[s["id"]]
            assert s["pid"] == ev.stage
            assert s["ts"] == pytest.approx(ev.t * 1e6)

    def test_split_backward_slice_names(self):
        spec = PipelineSpec(3, 4, split_backward=True)
        cm = det_costs(3).with_split_backward()
        trace = recorded_trace(spec, cm, mode="hint", hint=HintKind.BFW,
                               seed=7)
        doc = to_perfetto(trace)
        validate_chrome_trace(doc)
        names = {e["name"].split()[0] for e in slices(doc)}
        assert names == {"F", "dX", "dW"}
        # the deferred-W backlog counter is emitted on split specs
        assert any(e["ph"] == "C" and e["name"] == "w_backlog"
                   for e in doc["traceEvents"])

    def test_dag_and_chaos_traces_validate(self):
        for spec, kw in (
            (dag_spec(4), {}),
            (PipelineSpec(4, 4), {"chaos": CHAOS_LEVELS["C2"]}),
        ):
            cm = CostModel.uniform(spec.num_stages, seed=3)
            trace = recorded_trace(spec, cm, mode="hint", hint=HintKind.BF,
                                   seed=3, **kw)
            doc = to_perfetto(trace)
            validate_chrome_trace(doc)
            assert len(slices(doc)) == spec.total_tasks()

    def test_chaos_duplicates_get_their_own_arrows(self):
        spec = PipelineSpec(4, 6)
        cm = CostModel.uniform(4, seed=21)
        trace = recorded_trace(spec, cm, mode="hint", hint=HintKind.BF,
                               seed=21, chaos=CHAOS_LEVELS["C3"])
        doc = to_perfetto(trace)
        validate_chrome_trace(doc)
        finishes = [e for e in doc["traceEvents"] if e["ph"] == "f"]
        delivered = sum(1 for ev in trace.events if ev.kind == _tr.DELIVER)
        assert len(finishes) == delivered  # duplicates included
        # C3 injects stalls; they render as chaos-category slices
        if any(ev.kind == _tr.STALL for ev in trace.events):
            assert any(e.get("cat") == "chaos" for e in doc["traceEvents"])

    def test_json_roundtrip_and_file_export(self, tmp_path):
        spec = PipelineSpec(3, 4)
        trace = recorded_trace(spec, det_costs(3), mode="hint",
                               hint=HintKind.BF, seed=7)
        path = tmp_path / "trace.perfetto.json"
        export_perfetto(trace, str(path))
        doc = json.loads(path.read_text())
        validate_chrome_trace(doc)
        assert doc == json.loads(json.dumps(to_perfetto(trace)))
        # Trace.to_perfetto delegates to the same renderer
        assert trace.to_perfetto() == to_perfetto(trace)
        assert doc["otherData"]["num_stages"] == 3

    def test_validator_rejects_malformed_docs(self):
        with pytest.raises(AssertionError):
            validate_chrome_trace({"traceEvents": []})
        with pytest.raises(AssertionError):
            validate_chrome_trace({"traceEvents": [{"ph": "??"}]})
        with pytest.raises(AssertionError):  # X slice missing dur
            validate_chrome_trace({"traceEvents": [
                {"ph": "X", "name": "F", "pid": 0, "tid": 0, "ts": 1.0}]})
        with pytest.raises(AssertionError):  # dangling flow start
            validate_chrome_trace({"traceEvents": [
                {"ph": "s", "name": "m", "pid": 0, "tid": 0, "ts": 1.0,
                 "id": 4}]})
        with pytest.raises(AssertionError):  # flow finishing before start
            validate_chrome_trace({"traceEvents": [
                {"ph": "s", "name": "m", "pid": 0, "tid": 0, "ts": 5.0,
                 "id": 4},
                {"ph": "f", "name": "m", "pid": 1, "tid": 0, "ts": 1.0,
                 "id": 4}]})
