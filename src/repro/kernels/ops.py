"""Public kernel API: jit'd wrappers dispatching XLA <-> Pallas backends.

Backends:
  "xla"       — pure-jnp blocked implementations (differentiable, compiles on
                any backend; the multi-pod dry-run uses this path).
  "pallas"    — the TPU kernels (pl.pallas_call), forward custom-vjp'd onto
                the XLA backward (recompute), TPU-only.
  "interpret" — the Pallas kernels executed by the interpreter (CPU tests).

Select globally with ``set_backend`` or per-call with ``backend=``.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref as _ref

Backend = Literal["xla", "pallas", "interpret"]
_BACKEND: Backend = "xla"


def set_backend(b: Backend) -> None:
    global _BACKEND
    assert b in ("xla", "pallas", "interpret"), b
    _BACKEND = b


def get_backend() -> Backend:
    return _BACKEND


def _resolve(backend: Backend | None) -> Backend:
    return backend or _BACKEND


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, positions, *, causal: bool = True, window: int = 0,
                    backend: Backend | None = None):
    """q: [b, sq, hq, hd]; k, v: [b, sk, hkv, hd]; positions: [b, sq]."""
    be = _resolve(backend)
    if be == "xla":
        from repro.models.layers import blocked_attention

        return blocked_attention(q, k, v, positions, causal, window, 256)
    # Pallas path assumes training self-attention: positions == arange(sq).
    hd = q.shape[-1]
    qt = jnp.swapaxes(q, 1, 2) * (hd ** -0.5)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _pallas_attention(qt.astype(q.dtype), kt, vt, causal, window,
                            be == "interpret")
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _pallas_attention(q, k, v, causal, window, interpret):
    return _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   interpret=interpret)


def _pallas_attention_fwd(q, k, v, causal, window, interpret):
    return _pallas_attention(q, k, v, causal, window, interpret), (q, k, v)


def _pallas_attention_bwd(causal, window, interpret, res, g):
    q, k, v = res
    # Recompute-based backward through the XLA oracle (same math).
    def f(q_, k_, v_):
        b, h, sq, hd = q_.shape
        pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
        o = _ref.attention_ref(
            jnp.swapaxes(q_ * hd**0.5, 1, 2), jnp.swapaxes(k_, 1, 2),
            jnp.swapaxes(v_, 1, 2), pos, causal, window)
        return jnp.swapaxes(o, 1, 2)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def decode_attention(q, k_cache, v_cache, length, *, window: int = 0,
                     backend: Backend | None = None):
    """q: [b, 1, hq, hd]; caches: [b, S, hkv, hd]; length: scalar int."""
    be = _resolve(backend)
    b = q.shape[0]
    if be == "xla":
        lengths = jnp.full((b,), length, jnp.int32)
        return _ref.decode_ref(q, k_cache, v_cache, lengths, window=window)
    hd = q.shape[-1]
    qt = jnp.swapaxes(q, 1, 2) * (hd ** -0.5)
    out = _fd.flash_decode(
        qt.astype(q.dtype), jnp.swapaxes(k_cache, 1, 2),
        jnp.swapaxes(v_cache, 1, 2), length, window=window,
        interpret=be == "interpret")
    return jnp.swapaxes(out, 1, 2)


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------
def ssd(x, dt, A, B, C, D, *, chunk: int = 128, backend: Backend | None = None):
    """x: [b, s, nh, hd]; dt: [b, s, nh]; A, D: [nh]; B, C: [b, s, ds]."""
    be = _resolve(backend)
    if be == "xla":
        return _ssd_xla_chunked(x, dt, A, B, C, D, chunk)
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y = _pallas_ssd(x, dt, A, B, C, D, chunk, be == "interpret")
    return y[:, :s]


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _pallas_ssd(x, dt, A, B, C, D, chunk, interpret):
    return _ssd.ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=interpret)


def _pallas_ssd_fwd(x, dt, A, B, C, D, chunk, interpret):
    return _pallas_ssd(x, dt, A, B, C, D, chunk, interpret), (x, dt, A, B, C, D)


def _pallas_ssd_bwd(chunk, interpret, res, g):
    x, dt, A, B, C, D = res
    _, vjp = jax.vjp(lambda *a: _ssd_xla_chunked(*a, chunk), x, dt, A, B, C, D)
    return vjp(g)


_pallas_ssd.defvjp(_pallas_ssd_fwd, _pallas_ssd_bwd)


def _ssd_xla_chunked(x, dt, A, B, C, D, chunk: int):
    """Chunked SSD in pure jnp (same algorithm as the kernel, batched).

    NOT inner-checkpointed: the executor already remats per layer slot, and
    a nested checkpoint made B recompute the scan 3x (EXPERIMENTS §Perf).
    Contractions run in bf16 with fp32 accumulation (gates/cumsums fp32).
    """
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk
    ct = jnp.bfloat16 if x.dtype == jnp.bfloat16 else jnp.float32
    xc = x.reshape(b, nc, chunk, nh, hd)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, nh)
    Bc = B.astype(ct).reshape(b, nc, chunk, ds)
    Cc = C.astype(ct).reshape(b, nc, chunk, ds)
    Af = A.astype(jnp.float32)

    a = Af[None, None, None, :] * dtf  # [b, nc, Q, nh]
    cum = jnp.cumsum(a, axis=2)
    g = jnp.einsum("bcid,bcjd->bcij", Cc, Bc,
                   preferred_element_type=jnp.float32)  # [b, nc, Q, Q]
    ii = jnp.arange(chunk)
    tri = ii[:, None] >= ii[None, :]
    decay = jnp.where(
        tri[None, None, :, :, None],
        jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :]),
        0.0,
    )  # [b, nc, Q, Q, nh]
    w = (g[..., None] * decay * dtf[:, :, None, :, :]).astype(ct)
    y_intra = jnp.einsum("bcijn,bcjnd->bcind", w, xc.astype(ct),
                         preferred_element_type=jnp.float32)

    # inter-chunk state passing (scan over chunks)
    xf = xc.astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)
    chunk_in = jnp.einsum(
        "bcjn,bcjnd,bcjs->bcnds", dtf * jnp.exp(cum[:, :, -1:, :] - cum), xf, Bf
    )  # [b, nc, nh, hd, ds]
    total_decay = jnp.exp(cum[:, :, -1])  # [b, nc, nh]

    def scan_fn(h, inp):
        dec, cin = inp
        h_new = h * dec[..., None, None] + cin
        return h_new, h  # emit the state *entering* this chunk

    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    _, h_in = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(total_decay, 1, 0), jnp.moveaxis(chunk_in, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # [b, nc, nh, hd, ds]
    y_inter = jnp.einsum(
        "bcis,bcnds,bcin->bcind", Cf, h_in, jnp.exp(cum)
    )
    y = (y_intra + y_inter).reshape(b, sp, nh, hd)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y[:, :s].astype(x.dtype) if pad else y.astype(x.dtype)


def ssd_decode_step(state, x, dt, A, B, C, D):
    """Single-token SSD update.  state: [b, nh, hd, ds]; x: [b, nh, hd];
    dt: [b, nh]; B, C: [b, ds].  Returns (y [b, nh, hd], new_state)."""
    decay = jnp.exp(A.astype(jnp.float32)[None, :] * dt.astype(jnp.float32))
    upd = jnp.einsum("bnh,bs->bnhs", x.astype(jnp.float32) * dt[..., None], B.astype(jnp.float32))
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bnhs,bs->bnh", state, C.astype(jnp.float32))
    y = y + D.astype(jnp.float32)[None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, *, eps: float = 1e-5, backend: Backend | None = None):
    be = _resolve(backend)
    if be == "xla":
        return _ref.rmsnorm_ref(x, scale, eps)
    return _rn.rmsnorm(x, scale, eps=eps, interpret=be == "interpret")
