"""Assigned architecture configs (exact, from public literature) + the
paper's own workloads.  Select with --arch <id> via repro.configs.registry."""
from repro.configs.registry import ARCHS, get_arch, list_archs, reduced_config
