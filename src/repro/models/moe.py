"""Mixture-of-Experts layers with static-capacity dispatch.

Two distributed layouts over the ``data`` mesh axis (DESIGN §3):

* ``ep``  — true expert parallelism (deepseek-moe: 64 experts / 16 devices =
            4 per device), token exchange via all_to_all.
* ``tp``  — expert-FFN tensor parallelism on d_ff (grok-1: 8 experts < 16
            devices), token all-gather + partial compute + reduce-scatter.
* ``none``— single-device / smoke-test path.

Dispatch is scatter-based (position-in-expert via cumsum of the one-hot
assignment), never one-hot-matmul, so dispatch FLOPs stay linear in tokens —
this keeps the compiled roofline compute term honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models.common import ArchConfig, dense_init
from repro.models.layers import ffn_block, init_ffn


def init_moe_ffn(keys, cfg: ArchConfig) -> dict:
    moe = cfg.moe
    d, f = cfg.d_model, cfg.d_ff
    e = moe.num_experts
    glu = cfg.act in ("swiglu", "geglu")
    p = {
        "router": dense_init(next(keys), (d, e), jnp.float32),
        "wi": dense_init(next(keys), (e, d, f), cfg.dtype),
        "wo": dense_init(next(keys), (e, f, d), cfg.dtype),
    }
    if glu:
        p["wg"] = dense_init(next(keys), (e, d, f), cfg.dtype)
    for i in range(moe.num_shared):
        p[f"shared{i}"] = init_ffn(keys, cfg)
    return p


def _route(x2, router, top_k: int):
    """x2: [T, d] -> (weights [T, k], experts [T, k]) with softmax-over-topk."""
    logits = x2.astype(jnp.float32) @ router  # [T, E]
    w, idx = jax.lax.top_k(logits, top_k)
    w = jax.nn.softmax(w, axis=-1)
    return w, idx


def _dispatch(x2, idx, capacity: int, num_experts: int):
    """Scatter tokens into [E, C, d] expert buffers.

    Returns (buffers, slot [T, k], valid [T, k]).  Over-capacity tokens are
    dropped (standard static-capacity semantics).
    """
    T, k = idx.shape
    flat_e = idx.reshape(-1)  # [T*k]
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)  # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # [T*k]
    valid = slot < capacity
    slot_c = jnp.where(valid, slot, capacity - 1)
    buffers = jnp.zeros((num_experts, capacity, x2.shape[1]), x2.dtype)
    tok = jnp.repeat(jnp.arange(T), k)
    buffers = buffers.at[flat_e, slot_c].add(
        jnp.where(valid[:, None], x2[tok], 0).astype(x2.dtype)
    )
    return buffers, slot_c.reshape(T, k), valid.reshape(T, k)


def _combine(out_buffers, idx, slot, valid, weights):
    """Gather expert outputs back to tokens and mix with router weights."""
    T, k = idx.shape
    gathered = out_buffers[idx.reshape(-1), slot.reshape(-1)]  # [T*k, d]
    gathered = gathered.reshape(T, k, -1)
    w = (weights * valid).astype(gathered.dtype)
    return jnp.einsum("tkd,tk->td", gathered, w)


def _expert_ffn(p, buffers, act: str, slot_range=None):
    """buffers: [E(, ...), C, d] -> same shape through per-expert GLU FFN."""
    wi, wo = p["wi"], p["wo"]
    h = jnp.einsum("ecd,edf->ecf", buffers, wi)
    if "wg" in p:
        g = jnp.einsum("ecd,edf->ecf", buffers, p["wg"])
        h = jax.nn.silu(g) * h if act == "swiglu" else jax.nn.gelu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_ffn(p, x, cfg: ArchConfig, *, layout: str = "none",
            axis_name: str = "data", axis_size: int = 1):
    """x: [b, s, d] -> [b, s, d].

    layout "ep": p["wi"/"wg"/"wo"] hold the *local* expert shard [E/axis, d, f]
    and tokens travel via all_to_all.  layout "tp": they hold the f shard
    [E, d, f/axis] and activations travel via all-gather/reduce-scatter.
    """
    moe = cfg.moe
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    T = x2.shape[0]
    w, idx = _route(x2, p["router"], moe.top_k)

    if layout == "none":
        capacity = max(1, int(T * moe.top_k / moe.num_experts * moe.capacity_factor))
        buffers, slot, valid = _dispatch(x2, idx, capacity, moe.num_experts)
        out_buf = _expert_ffn(p, buffers, cfg.act)
        y = _combine(out_buf, idx, slot, valid, w)

    elif layout == "ep":
        # local experts: E_local = E / axis_size; capacity covers the worst
        # per-device load after exchange.
        e_local = moe.num_experts // axis_size
        capacity = max(1, int(T * moe.top_k / moe.num_experts * moe.capacity_factor))
        buffers, slot, valid = _dispatch(x2, idx, capacity, moe.num_experts)
        # [E, C, d] -> all_to_all: each device keeps its e_local experts,
        # gathering every peer's contribution for them.
        buffers = buffers.reshape(axis_size, e_local, capacity, d)
        buffers = jax.lax.all_to_all(buffers, axis_name, 0, 0, tiled=False)
        # [axis, e_local, C, d]: leading dim = sending peer.  Saved under
        # the executor's remat policy so the B pass does not re-issue the
        # forward all_to_all (EXPERIMENTS §Perf, deepseek-moe iteration).
        eb = jnp.moveaxis(buffers, 0, 1).reshape(e_local, axis_size * capacity, d)
        eb = checkpoint_name(eb, "moe_dispatched")
        out = _expert_ffn(
            {k: p[k] for k in ("wi", "wo", *(["wg"] if "wg" in p else []))},
            eb, cfg.act)
        out = jnp.moveaxis(out.reshape(e_local, axis_size, capacity, d), 1, 0)
        out = jax.lax.all_to_all(out, axis_name, 0, 0, tiled=False)
        out_buf = out.reshape(moe.num_experts, capacity, d)
        y = _combine(out_buf, idx, slot, valid, w)

    elif layout == "tp":
        # f-sharded experts: all peers' tokens fold into the capacity dim,
        # compute against the local f-slice, reduce-scatter the partials.
        capacity = max(1, int(T * moe.top_k / moe.num_experts * moe.capacity_factor))
        buffers, slot, valid = _dispatch(x2, idx, capacity, moe.num_experts)
        gathered = jax.lax.all_gather(buffers, axis_name, tiled=False)
        # [axis, E, C, d] -> [E, axis*C, d]
        ge = jnp.moveaxis(gathered, 0, 1).reshape(
            moe.num_experts, axis_size * capacity, d)
        pp = {"wi": p["wi"], "wo": p["wo"]}
        if "wg" in p:
            pp["wg"] = p["wg"]
        out = _expert_ffn(pp, ge, cfg.act)  # partial sums (f-shard)
        out = jnp.moveaxis(
            out.reshape(moe.num_experts, axis_size, capacity, d), 1, 0)
        out_buf = jax.lax.psum_scatter(out, axis_name, scatter_dimension=0,
                                       tiled=False)
        y = _combine(out_buf, idx, slot, valid, w)
    else:
        raise ValueError(layout)

    for i in range(cfg.moe.num_shared):
        y = y + ffn_block(p[f"shared{i}"], x2, cfg.act)
    return y.reshape(b, s, d).astype(x.dtype)
