"""repro: RRFP — readiness-driven pipeline-parallel training in JAX."""
__version__ = "1.0.0"
