"""Deterministic event traces for the actor runtime (record / replay).

Every observable scheduling decision in the runtime — mailbox enqueue and
dequeue, TP-gate hold/admit/duplicate, dispatch (with the arbitration path
taken and an *incremental* ready-set snapshot: by default only the tasks
added since the stage's previous dispatch are serialized — ``radd`` — and
:meth:`Trace.ready_sets` reconstructs the full per-dispatch snapshots
offline; ``ActorConfig.trace_full_ready`` opts into verbose full
snapshots), completion (with the realized duration and
the W-deferral backlog), and every transport send/delivery — is recorded as a
structured :class:`TraceEvent` stamped with a *logical clock*: a process-wide
monotone counter assigned under one lock, giving a total order over events
that is meaningful on both substrates (the sim driver's virtual clock and the
thread runtime's wall clock).

A :class:`Trace` is the recorded sequence plus run metadata.  It serializes
to JSON lines, diffs against another trace (:meth:`signature`), and projects
out the two replay artifacts:

* :meth:`delivery_schedule` — for the sim substrate, the exact virtual time
  of every envelope delivery (including chaos-injected duplicates), letting
  :meth:`~repro.runtime.rrfp.driver.ActorDriver.run` re-execute a recorded
  arrival order *exactly* — same heap evolution, same event sequence, same
  makespan — without touching a random stream;
* :meth:`dispatch_orders` — the realized per-stage execution order, which
  the thread substrate (and the DES engine via
  :func:`engine_replay_config`) re-executes as a pre-committed order, pinning
  the floating-point reduction order and therefore the loss bit pattern.

The conformance suite (``tests/conformance``) checks runtime invariants —
exactly-once execution, w_defer_cap, hint faithfulness — directly against
recorded traces, so "robust under variability" is a property of the event
log, not of any particular end-to-end metric.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Any, Iterable

from repro.core.taskgraph import Kind, PipelineSpec, Task

# Event kinds (strings, not an enum: traces are a serialization format first).
SEND = "send"          # envelope handed to a transport
DELIVER = "deliver"    # envelope arrived at the destination mailbox
TP_HOLD = "tp_hold"    # TP gate holds a rank copy (rank set incomplete)
TP_ADMIT = "tp_admit"  # TP gate admitted: all ranks hold the message
TP_DUP = "tp_dup"      # duplicate / post-admission copy ignored
ENQUEUE = "enqueue"    # task appended to a per-kind arrival buffer
DEQUEUE = "dequeue"    # task consumed from its arrival buffer at dispatch
DISPATCH = "dispatch"  # actor committed to execute a task
COMPLETE = "complete"  # task finished executing
STALL = "stall"        # chaos: transient stage stall injected
FANIN_HOLD = "fanin_hold"  # DAG fan-in: edge admitted, other branch missing
FAIL = "fail"          # fail-stop fault: a stage died (kill/permanent_stall)
RECOVERY_BEGIN = "recovery_begin"  # coordinator detected the death; quiesce
RECOVERY_END = "recovery_end"      # stage respawned/re-mapped; epoch bumped
FENCE = "fence"        # stale (pre-recovery epoch) envelope dropped
HINT_SWAP = "hint_swap"  # adaptive: a stage adopted a re-synthesized table
DROP = "drop"          # lossy wire: one transmission (attempt x copy) lost
CORRUPT = "corrupt"    # lossy wire: checksum mismatch detected -> NACK
RETRANSMIT = "retransmit"  # reliable sender re-sent after RTO/NACK
RDUP = "rdup"          # reliable receiver deduplicated an already-seen eseq
LINK_FAIL = "link_fail"  # retry budget exhausted: edge escalated to a fault
EVENT_KINDS = (SEND, DELIVER, TP_HOLD, TP_ADMIT, TP_DUP, ENQUEUE, DEQUEUE,
               DISPATCH, COMPLETE, STALL, FANIN_HOLD, FAIL, RECOVERY_BEGIN,
               RECOVERY_END, FENCE, HINT_SWAP, DROP, CORRUPT, RETRANSMIT,
               RDUP, LINK_FAIL)


def task_key(t: Task) -> list[int]:
    """JSON-stable task identity: [kind, stage, mb, chunk]."""
    return [int(t.kind), t.stage, t.mb, t.chunk]


def _jsonable(v: Any):
    """Coerce an info value to a plain-JSON type (numpy scalars -> Python)."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if hasattr(v, "item"):  # numpy scalar
        return v.item()
    return v


def task_from_key(k: Iterable[int]) -> Task:
    kind, stage, mb, chunk = k
    return Task(Kind(kind), stage, mb, chunk)


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One recorded runtime event, totally ordered by logical clock ``lc``.

    ``epoch`` is the recovery generation the event belongs to: 0 until a
    fail-stop recovery bumps it, so a recovered run's logical clock is
    (epoch, lc) and the conformance checkers can tell a pre-failure
    completion from its post-recovery re-execution.  Serialized only when
    nonzero, so traces of failure-free runs are byte-identical to those
    recorded before recovery existed.
    """

    lc: int
    kind: str
    stage: int
    task: Task | None = None
    rank: int = 0
    t: float = 0.0  # substrate time: virtual (sim) or wall (thread)
    epoch: int = 0
    info: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        d: dict[str, Any] = {"lc": self.lc, "kind": self.kind,
                             "stage": self.stage, "rank": self.rank,
                             "t": self.t}
        if self.epoch:
            d["epoch"] = self.epoch
        if self.task is not None:
            d["task"] = task_key(self.task)
        if self.info:
            # info may carry extra annotations (e.g. metrics-enabled runs
            # stamp EWMA values) whose values can be numpy scalars; coerce
            # so save/load round-trips any recorded run
            d["info"] = {k: _jsonable(v) for k, v in self.info.items()}
        return d

    @staticmethod
    def from_json(d: dict) -> "TraceEvent":
        return TraceEvent(
            lc=d["lc"], kind=d["kind"], stage=d["stage"],
            task=task_from_key(d["task"]) if "task" in d else None,
            rank=d.get("rank", 0), t=d.get("t", 0.0),
            epoch=d.get("epoch", 0), info=d.get("info", {}))


class TraceRecorder:
    """Thread-safe event sink assigning the logical clock.

    One recorder instance is threaded through the mailboxes, TP groups,
    transports and actors of a single run; ``record`` is called under
    whatever lock the caller already holds (or none), and serializes event
    ordering itself.
    """

    def __init__(self, meta: dict | None = None):
        self._lock = threading.Lock()
        self._events: list[TraceEvent] = []
        self.meta = dict(meta or {})
        #: current recovery generation; the recovery coordinator bumps this
        #: so every subsequent event is stamped with the new epoch
        self.epoch = 0

    def record(self, kind: str, stage: int, task: Task | None = None,
               rank: int = 0, t: float = 0.0, **info) -> None:
        with self._lock:
            self._events.append(TraceEvent(
                lc=len(self._events), kind=kind, stage=stage, task=task,
                rank=rank, t=t, epoch=self.epoch, info=info))

    def completed_tasks(self, stage: int) -> set:
        """Tasks this stage has COMPLETEd so far — the progress the recovery
        coordinator restores into a respawned actor ("replay from trace")."""
        with self._lock:
            return {ev.task for ev in self._events
                    if ev.kind == COMPLETE and ev.stage == stage}

    def trace(self) -> "Trace":
        with self._lock:
            return Trace(meta=dict(self.meta), events=list(self._events))


@dataclasses.dataclass
class Trace:
    """A completed run's event log + metadata."""

    meta: dict
    events: list[TraceEvent]

    # ---- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        """JSON-lines: first line metadata, one event per following line."""
        with open(path, "w") as f:
            f.write(json.dumps({"meta": self.meta}) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev.to_json()) + "\n")

    @staticmethod
    def load(path: str) -> "Trace":
        with open(path) as f:
            head = json.loads(f.readline())
            events = [TraceEvent.from_json(json.loads(line))
                      for line in f if line.strip()]
        return Trace(meta=head.get("meta", {}), events=events)

    # ---- comparison --------------------------------------------------------
    def signature(self, include_time: bool = True,
                  kinds: Iterable[str] | None = None) -> list[tuple]:
        """Hashable per-event identity for replay-equivalence checks.

        With ``include_time`` the virtual-clock timestamps must match too
        (sim replays are exact); without it only the event sequence is
        compared (thread replays reproduce order, not wall time).  ``kinds``
        restricts the signature to a subset of event kinds (e.g. compare
        only the wire-level DROP/RETRANSMIT realization of two lossy runs).
        """
        want = set(kinds) if kinds is not None else None
        out = []
        for ev in self.events:
            if want is not None and ev.kind not in want:
                continue
            tk = tuple(task_key(ev.task)) if ev.task is not None else None
            key = (ev.kind, ev.stage, tk, ev.rank, ev.info.get("src", -1))
            if include_time:
                key += (round(ev.t, 12),)
            out.append(key)
        return out

    def select(self, *kinds: str) -> list[TraceEvent]:
        want = set(kinds)
        return [ev for ev in self.events if ev.kind in want]

    # ---- replay projections ------------------------------------------------
    def dispatch_orders(self, num_stages: int | None = None) -> list[list[Task]]:
        """Realized per-stage execution order (logical-clock order)."""
        if num_stages is None:
            num_stages = int(self.meta.get("num_stages", 0)) or 1 + max(
                ev.stage for ev in self.events)
        orders: list[list[Task]] = [[] for _ in range(num_stages)]
        for ev in self.select(DISPATCH):
            orders[ev.stage].append(ev.task)
        return orders

    def delivery_schedule(self) -> dict[tuple[tuple, int, int], list[float]]:
        """(task, rank, src_stage) -> recorded delivery times, in
        logical-clock order.

        Chaos-duplicated envelopes appear as extra entries; the sim replay
        re-schedules every one of them at its recorded virtual time.  DAG
        fan-in tasks receive one entry stream per source edge.  Traces
        recorded before source stamping use src=-1 (single-edge chains only,
        where the source is unambiguous).
        """
        sched: dict[tuple[tuple, int, int], list[float]] = {}
        for ev in self.select(DELIVER):
            key = (tuple(task_key(ev.task)), ev.rank,
                   int(ev.info.get("src", -1)))
            sched.setdefault(key, []).append(ev.t)
        return sched

    def ready_sets(self) -> dict[int, list[Task]]:
        """DISPATCH event lc -> the full ready-set snapshot at that dispatch.

        Decodes both snapshot encodings: the verbose ``ready`` form (a full
        sorted task list per dispatch, opt-in via
        ``ActorConfig.trace_full_ready``) and the default incremental
        ``radd`` form, which records only the tasks *added* to the stage's
        ready set since its previous dispatch.  The diff reconstruction
        relies on the runtime invariant that between two dispatches the only
        task ever *removed* from a stage's ready set is the one the earlier
        dispatch committed to — so replaying adds and removing each
        dispatched task recovers every snapshot exactly.
        """
        out: dict[int, list[Task]] = {}
        running: dict[int, set[Task]] = {}
        for ev in self.events:
            if ev.kind == RECOVERY_BEGIN:
                # the failed stage's in-memory ready set died with it; the
                # respawned incarnation re-derives readiness from replayed
                # deliveries, so the diff reconstruction restarts empty
                running.pop(ev.stage, None)
                continue
            if ev.kind != DISPATCH:
                continue
            if "ready" in ev.info:
                out[ev.lc] = [task_from_key(k) for k in ev.info["ready"]]
                continue
            cur = running.setdefault(ev.stage, set())
            cur.update(task_from_key(k) for k in ev.info.get("radd", ()))
            out[ev.lc] = sorted(cur)
            cur.discard(ev.task)
        return out

    def durations(self) -> dict[tuple, float]:
        """Full task identity (kind, stage, mb, chunk) -> realized compute
        duration (chaos effects included).

        Keys carry the *complete* task key, so two tasks differing only in
        kind, stage, microbatch or chunk never collapse onto one entry; on
        a malformed trace with duplicate COMPLETEs for the same task the
        first (logical-clock order) duration wins rather than the last
        silently overwriting it — replay consumes the duration the heap
        actually used."""
        out: dict[tuple, float] = {}
        for ev in self.select(COMPLETE):
            if "dur" in ev.info:
                out.setdefault(tuple(task_key(ev.task)), ev.info["dur"])
        return out

    def recovery_windows(self) -> list[dict]:
        """Fail-stop recovery episodes, in order: one dict per FAIL with the
        matching RECOVERY_BEGIN/RECOVERY_END times and the epoch transition.

        ``t_fail`` is when the stage died, ``t_detect`` when the coordinator
        declared it (heartbeat deadline), ``t_end`` when the respawned or
        re-mapped incarnation was back in service; ``t_end - t_fail`` is the
        episode's time-to-recover (the benchmark's MTTR numerator).
        """
        out: list[dict] = []
        open_by_stage: dict[int, dict] = {}
        for ev in self.events:
            if ev.kind == FAIL:
                w = {"stage": ev.stage, "t_fail": ev.t,
                     "fail_kind": ev.info.get("fail_kind", "kill"),
                     "t_detect": None, "t_end": None,
                     "epoch_from": ev.epoch, "epoch_to": None}
                open_by_stage[ev.stage] = w
                out.append(w)
            elif ev.kind == RECOVERY_BEGIN:
                w = open_by_stage.get(ev.stage)
                if w is not None:
                    w["t_detect"] = ev.t
            elif ev.kind == RECOVERY_END:
                w = open_by_stage.pop(ev.stage, None)
                if w is not None:
                    w["t_end"] = ev.t
                    w["epoch_to"] = ev.epoch
                    w["mode"] = ev.info.get("mode", "respawn")
        return out

    def max_epoch(self) -> int:
        return max((ev.epoch for ev in self.events), default=0)

    def to_perfetto(self) -> dict:
        """Chrome trace-event JSON view of this trace (Perfetto-loadable).

        Delegates to :func:`repro.obs.export.to_perfetto`; imported lazily
        so the runtime layer does not depend on the observability layer."""
        from repro.obs.export import to_perfetto

        return to_perfetto(self)

    def final_loss(self) -> float | None:
        return self.meta.get("final_loss")


def engine_replay_config(trace: Trace, base=None):
    """DES-engine replay: consume a recorded trace as a pre-committed order.

    Returns an :class:`~repro.core.engine.EngineConfig` with
    ``replay_trace`` set; the engine resolves it into the trace's realized
    per-stage dispatch orders (order-exact; timing is re-sampled by the
    engine's own cost model — use the actor driver's replay for time-exact
    reproduction).
    """
    import dataclasses as _dc

    from repro.core.engine import EngineConfig

    base = base if base is not None else EngineConfig()
    return _dc.replace(base, replay_trace=trace)


class ReplayOracle:
    """Answers the sim driver's two questions from a recorded trace:
    when does each envelope arrive, and how long does each task run.

    Delivery times are consumed per (task, rank) in recorded order, so a
    chaos duplicate's second copy replays at its own recorded time.
    """

    def __init__(self, trace: Trace):
        self._sched = {k: list(v) for k, v in trace.delivery_schedule().items()}
        self._dur = trace.durations()

    def delivery_times(self, task: Task, rank: int,
                       src_stage: int = -1) -> list[float]:
        key = (tuple(task_key(task)), rank, src_stage)
        if key not in self._sched and src_stage != -1:
            # pre-source-stamp traces: single-edge chains recorded src=-1
            key = (tuple(task_key(task)), rank, -1)
        return self._sched.pop(key, [])

    def duration(self, task: Task) -> float:
        return self._dur[tuple(task_key(task))]
