"""Pallas TPU fused RMSNorm (memory-bound row kernel)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [br, d]
    scale = s_ref[...].astype(jnp.float32)  # [1, d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * (1.0 + scale)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = False):
    """x: [..., d]; scale: [d]."""
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    nr = -(-rows // block_rows)
    pad = nr * block_rows - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * block_rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale.reshape(1, d))
    return out[:rows].reshape(shape)
