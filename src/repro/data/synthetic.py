"""Deterministic synthetic data pipeline with host-side prefetch.

Batches are reproducible functions of (seed, step) — restart-safe: resuming
from a checkpoint at step k regenerates exactly the stream the crashed run
would have seen.  Token streams follow a Zipfian unigram mix with induced
bigram structure so the LM loss has signal to descend.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.data.lengths import bucket_for, sample_token_lengths
from repro.models.common import ArchConfig


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synth_batch(cfg: ArchConfig, batch: int, seq: int, *, seed: int = 0,
                step: int = 0, enc_len: int = 0) -> dict:
    """One global batch for ``cfg``: tokens/labels (+ stub embeddings)."""
    rng = _rng(seed, step)
    toks = _token_stream(rng, cfg.vocab_size, batch, seq)
    out = {
        "tokens": toks[:, :seq].astype(np.int32),
        "labels": toks[:, 1 : seq + 1].astype(np.int32),
    }
    if cfg.embed_input:
        out["embeds"] = (rng.standard_normal((batch, seq, cfg.d_model)) * 0.02
                         ).astype(np.float32)
    if cfg.mrope:
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32), (3, batch, seq))
        out["mrope"] = pos.copy()
    if cfg.encoder_layers:
        out["enc_embeds"] = (
            rng.standard_normal((batch, enc_len or seq, cfg.d_model)) * 0.02
        ).astype(np.float32)
    return out


def _token_stream(rng: np.random.Generator, v: int, batch: int,
                  seq: int) -> np.ndarray:
    """Zipf unigram + deterministic bigram successors (learnable signal)."""
    base = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64) % v
    succ = (np.arange(v) * 31 + 7) % v
    follow = rng.random((batch, seq + 1)) < 0.5
    toks = base.copy()
    toks[:, 1:] = np.where(follow[:, 1:], succ[toks[:, :-1]], base[:, 1:])
    return toks


def multimodal_batch(mm_cfg, num_microbatches: int, mb_rows: int, *,
                     seed: int = 0, step: int = 0,
                     bucketing: bool = True) -> dict:
    """One global batch for a branch+fusion multimodal pipeline.

    Per-microbatch encoder-token counts come from the shared modality
    length sampler (``repro.data.lengths`` — the same distribution the DES
    cost models use for compute skew).  With ``bucketing`` each
    microbatch's encoder embeddings are zero-padded up to the smallest
    config bucket that fits (bounding jit retraces by the bucket count);
    without it they stay at their exact length (one retrace per distinct
    length — the reference the bitwise parity tests compare against).

    Returns ``tokens``/``labels`` ([M*mb_rows, text_seq]), ``enc_embeds``
    (list of M ``[mb_rows, padded_len, d_enc]`` float32 arrays) and
    ``enc_lens`` ([M] valid token counts).
    """
    rng = _rng(seed, step)
    batch = num_microbatches * mb_rows
    toks = _token_stream(rng, mm_cfg.vocab_size, batch, mm_cfg.text_seq)
    lens = sample_token_lengths(
        num_microbatches, mm_cfg.mean_enc_tokens, mm_cfg.enc_sigma,
        seed=seed, step=step, lo=mm_cfg.fusion_slots,
        hi=max(mm_cfg.buckets))
    enc_embeds = []
    for j in range(num_microbatches):
        n = int(lens[j])
        pad = bucket_for(n, mm_cfg.buckets) if bucketing else n
        x = np.zeros((mb_rows, pad, mm_cfg.d_enc), np.float32)
        x[:, :n] = (rng.standard_normal((mb_rows, n, mm_cfg.d_enc))
                    * 0.02).astype(np.float32)
        enc_embeds.append(x)
    return {
        "tokens": toks[:, :mm_cfg.text_seq].astype(np.int32),
        "labels": toks[:, 1:mm_cfg.text_seq + 1].astype(np.int32),
        "enc_embeds": enc_embeds,
        "enc_lens": lens.astype(np.int32),
    }


class PrefetchIterator:
    """Host-side prefetch: a producer thread keeps ``depth`` batches ready so
    input generation overlaps device compute (the data-pipeline half of
    compute/IO overlap at scale)."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
