"""The (architecture × shape) dry-run matrix: input specs + step builders.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input (no allocation); ``build_cell`` wires model, schedule
table, executor options and specs for one cell on a given mesh.

Shape semantics (DESIGN §4):
  train_4k / prefill_32k -> train_step;  decode_32k / long_500k -> serve_step
  (one token against a seq_len KV cache).  long_500k runs only for
  sub-quadratic archs (gemma3 local:global, zamba2, xlstm).  seamless
  train splits the cell's seq_len into dec seq/2 + enc frames seq/2;
  its decode uses an enc cross-cache of seq_len.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core.taskgraph import PipelineSpec
from repro.models.build import ArchModel, build
from repro.models.common import SHAPES, ShapeCell
from repro.pipeline import schedules
from repro.pipeline.decode import DecodeOptions, cache_specs, make_serve_fn
from repro.pipeline.executor import ExecOptions, make_train_fn
from repro.pipeline.sharding import partition_for
from repro.pipeline.spec import ScheduleTable

#: archs whose optimizer/grad state must stay in bf16 to fit HBM
_BF16_GRAD_ARCHS = {"grok-1-314b", "granite-34b", "qwen1.5-32b"}


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    cfg = registry.get_arch(arch)
    cell = SHAPES[shape]
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 524k context excluded (DESIGN §4)"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in registry.ARCHS:
        if arch.startswith("paper-"):
            continue
        for shape in SHAPES:
            ok, _ = cell_is_runnable(arch, shape)
            if ok:
                out.append((arch, shape))
    return out


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: str
    model: ArchModel
    cell: ShapeCell
    step: str              # train | decode
    dp_total: int
    mb_rows: int
    num_microbatches: int
    seq_len: int           # decoder-token length per row
    enc_len: int
    sp_mode: bool
    multi_pod: bool

    @property
    def tokens_per_step(self) -> int:
        return self.cell.global_batch * (
            self.seq_len if self.step == "train" else 1)


def plan_cell(arch: str, shape: str, mesh, num_stages: int = 16) -> CellPlan:
    cfg = registry.get_arch(arch)
    cell = SHAPES[shape]
    model = build(cfg, num_stages=num_stages)
    multi_pod = "pod" in mesh.shape
    dp_total = mesh.shape["data"] * (mesh.shape["pod"] if multi_pod else 1)
    seq = cell.seq_len
    enc_len = 0
    if cfg.encoder_layers:
        if cell.step == "train":
            seq = cell.seq_len // 2
            enc_len = cell.seq_len // 2
        else:
            seq = cell.seq_len
            enc_len = cell.seq_len
    if cell.step == "train":
        rows = max(1, cell.global_batch // dp_total)
        # microbatch rows of 1 maximize pipeline overlap (M = rows)
        mb_rows = 1
        M = rows
        sp_mode = False
    else:
        sp_mode = cell.global_batch < dp_total  # long_500k: batch 1
        if sp_mode:
            mb_rows, M = cell.global_batch, 1
        else:
            rows = max(1, cell.global_batch // dp_total)
            mb_rows = 1
            M = rows
    return CellPlan(
        arch=arch, shape=shape, model=model, cell=cell, step=cell.step,
        dp_total=dp_total, mb_rows=mb_rows, num_microbatches=M,
        seq_len=seq, enc_len=enc_len, sp_mode=sp_mode, multi_pod=multi_pod,
    )


# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(plan: CellPlan) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the global batch (train) or the decode
    step inputs (decode)."""
    cfg = plan.model.cfg
    gb = plan.cell.global_batch
    d = cfg.d_model
    if plan.step == "train":
        out = {
            "tokens": _sds((gb, plan.seq_len), jnp.int32),
            "labels": _sds((gb, plan.seq_len), jnp.int32),
        }
        if cfg.embed_input:
            out["embeds"] = _sds((gb, plan.seq_len, d), jnp.float32)
        if cfg.mrope:
            out["mrope"] = _sds((3, gb, plan.seq_len), jnp.int32)
        if cfg.encoder_layers:
            out["enc_embeds"] = _sds((gb, plan.enc_len, d), jnp.float32)
        return out
    if cfg.embed_input:
        return {"embeds": _sds((gb, 1, d), jnp.float32)}
    return {"tokens": _sds((gb,), jnp.int32)}


def cache_struct(plan: CellPlan):
    """ShapeDtypeStruct pytree for the decode caches (global shapes)."""
    model = plan.model
    gb = plan.cell.global_batch
    one = model.init_layer_cache(1, 1, enc_len=1)

    def expand(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        shape = list(leaf.shape)
        shape[0] = gb
        if names and names[-1] in ("k", "v"):
            shape[1] = plan.cell.seq_len
        if names and names[-1] in ("xk", "xv"):
            shape[1] = plan.enc_len
        return _sds((model.num_stages, model.l_max, *shape), leaf.dtype)

    return jax.tree_util.tree_map_with_path(expand, one)


# ---------------------------------------------------------------------------
def build_cell(plan: CellPlan, mesh, schedule: str = "1f1b",
               split_backward: bool = False):
    """Returns (step_fn, arg_structs, batch_specs) ready to lower."""
    model = plan.model
    cfg = model.cfg
    key = jax.random.key(0)
    # params as ShapeDtypeStructs via eval_shape (no allocation)
    sp_struct = jax.eval_shape(model.init_stage_params, key)
    io_struct = jax.eval_shape(model.init_io_params, key)
    partition = partition_for(model, sp_struct, io_struct)

    grad_dtype = jnp.bfloat16 if plan.arch in _BF16_GRAD_ARCHS else jnp.float32

    if plan.step == "train":
        spec = PipelineSpec(model.num_stages, plan.num_microbatches,
                            split_backward=split_backward)
        if schedule == "rrfp":
            table = schedules.rrfp(spec)
        elif schedule == "zb":
            table = schedules.zero_bubble(spec)
        elif schedule == "gpipe":
            table = schedules.gpipe(spec)
        else:
            table = schedules.one_f_one_b(spec)
        opts = ExecOptions(
            mb_rows=plan.mb_rows, seq_len=plan.seq_len, enc_len=plan.enc_len,
            grad_dtype=grad_dtype,
            loss_scale=1.0 / plan.tokens_per_step,
            multi_pod=plan.multi_pod,
        )
        fn, batch_specs = make_train_fn(model, table, mesh, opts, partition)
        return fn, (sp_struct, io_struct, input_specs(plan)), batch_specs

    opts = DecodeOptions(
        mb_rows=plan.mb_rows, cache_len=plan.cell.seq_len,
        enc_len=plan.enc_len, sp_mode=plan.sp_mode, multi_pod=plan.multi_pod)
    wrap, cspecs, batch_specs = make_serve_fn(
        model, mesh, opts, num_groups=plan.num_microbatches)
    fn = wrap(partition)
    args = (sp_struct, io_struct, cache_struct(plan), input_specs(plan),
            _sds((), jnp.int32))
    return fn, args, batch_specs
