"""Critical-path engine: explain a recorded run's *makespan*, not its idle.

``bubbles.py`` attributes each stage's idle seconds locally; this module
answers the global question — which chain of task executions, message hops,
gate admissions and dispatch waits actually *bounded* the run.  It lowers
any recorded logical-clock :class:`~repro.runtime.rrfp.trace.Trace` (chain
or DAG spec, chaos, fail-stop recovery windows, mid-run ``HINT_SWAP``) into
an execution DAG:

* **nodes** are task *executions* — one per DISPATCH..COMPLETE pair, so a
  task re-executed after a fail-stop recovery contributes one node per
  incarnation — plus a virtual ROOT (t=0) and one *recovery node* per
  completed FAIL..RECOVERY_END window (spanning the outage);
* **edges** are the run's observed happens-before constraints, each stamped
  with the *absolute recorded time* the constraint was satisfied
  (``arrival``): per-stage serialization order, same-stage local
  dependencies (B after F, W after B), message readiness chains
  (producer COMPLETE -> SEND -> DELIVER -> ENQUEUE, carrying the
  SEND->DELIVER latency as ``comm`` and the admission residual —
  TP all-ranks gate, DAG fan-in skew — as ``gate``), and recovery edges
  (replayed deliveries and post-outage re-dispatches depend on the
  window's RECOVERY_END).

The *binding* in-edge of a node is the candidate with the latest arrival;
whatever slice of the dispatch wait no candidate explains (App. C
backpressure, the W-deferral cap, hint-swap-triggered re-arbitration,
thread wakeup latency, remap co-host contention) lands in the node's
``residual``.  Because the walk uses recorded absolute times — not summed
edge weights, which IEEE float addition would smear — the longest path
reconstructs the sim trace's makespan **bit-exactly**: the sink's recorded
COMPLETE time *is* ``meta["makespan"]`` by construction, and
:meth:`ExecGraph.verify` separately checks that the generative recurrence
(max over in-edges, plus residual/coordination/duration) regenerates every
node's recorded completion to ~1e-9 relative.

:meth:`ExecGraph.decompose` folds the critical path into per-category
seconds — ``compute`` (by op: F / B / W, or F / dX / dW on split-backward
specs), ``comm``, ``gate``, ``dispatch``, ``recovery`` — that sum
*exactly* to the makespan (the float residue is folded into the largest
bucket, the same idiom ``bubbles.py`` uses for exact idle attribution).
:meth:`ExecGraph.slack` gives every node its scheduling slack (how much
later it could have finished without moving the makespan): ``0`` on the
critical path, ``>= 0`` everywhere.

The graph is also the substrate for ``obs.whatif``'s Coz-style virtual
speedups: the recurrence re-runs with scaled durations/latencies while
recovery nodes stay *pinned* at their recorded end time — MTTR is
attributed, never "sped up".
"""
from __future__ import annotations

import dataclasses
from bisect import bisect_left

from repro.core.taskgraph import Kind, PipelineSpec, Task

from repro.obs.bubbles import spec_from_meta
from repro.runtime.rrfp import trace as _tr

#: critical-path decomposition categories (report order)
CP_CATEGORIES = ("compute", "comm", "gate", "dispatch", "recovery")

#: the virtual source node's key
ROOT_KEY = ("root",)

#: binding tie-break priority: at equal arrival prefer the edge that
#: carries the richest attribution (a message chain over a serialization
#: order over a fallback)
_EDGE_PRIORITY = {"msg": 4, "recovery": 3, "serial": 2, "local": 1, "root": 0}


def op_label(task: Task, split_backward: bool) -> str:
    """Human op-class label: F/B/W, or F/dX/dW on split-backward specs."""
    if split_backward:
        return {Kind.F: "F", Kind.B: "dX", Kind.W: "dW"}[task.kind]
    return {Kind.F: "F", Kind.B: "B", Kind.W: "W"}[task.kind]


@dataclasses.dataclass
class Edge:
    """One observed happens-before constraint into a node.

    ``arrival`` is the absolute recorded time the constraint was satisfied
    (producer completion + comm + gate for message edges; the predecessor's
    completion for serialization/local edges; RECOVERY_END for recovery
    edges) — by runtime construction ``arrival <= dst.dispatch_t``.
    """

    src: tuple            # key of the source node
    kind: str             # "msg" | "serial" | "local" | "recovery" | "root"
    arrival: float
    comm: float = 0.0     # SEND -> DELIVER latency (message edges)
    gate: float = 0.0     # admission residual: TP gate / fan-in skew


@dataclasses.dataclass
class Node:
    """One task execution (or the ROOT / a recovery window)."""

    key: tuple
    stage: int
    task: Task | None
    op: str               # "F"/"B"/"W"/"dX"/"dW", "recovery", "root"
    dispatch_t: float     # recorded DISPATCH time (FAIL time for recovery)
    end_t: float          # recorded COMPLETE time (RECOVERY_END for recovery)
    dur: float            # compute duration (outage span for recovery nodes)
    coord: float          # TP coordination / wakeup before compute starts
    residual: float = 0.0  # dispatch wait no candidate edge explains
    epoch: int = 0
    dispatch_lc: int = -1
    complete_lc: int = -1
    in_edges: list[Edge] = dataclasses.field(default_factory=list)
    binding: Edge | None = None


@dataclasses.dataclass
class CritPathReport:
    """Per-category critical-path decomposition; sums exactly to makespan."""

    makespan: float
    categories: dict[str, float]        # CP_CATEGORIES -> seconds (folded)
    compute_by_op: dict[str, float]     # op label -> seconds on the path
    compute_by_stage: dict[int, float]  # stage -> compute seconds on path
    fold: float                         # float residue folded (|fold| ~ ulp)
    path_nodes: int
    recovery_windows: int
    path: list[dict]                    # node summaries, root -> sink

    def fractions(self) -> dict[str, float]:
        if not self.makespan:
            return {c: 0.0 for c in CP_CATEGORIES}
        return {c: v / self.makespan for c, v in self.categories.items()}

    def top_category(self) -> str:
        return max(self.categories, key=lambda c: self.categories[c])

    def table(self) -> str:
        lines = [f"{'category':>12} {'seconds':>14} {'share':>8}"]
        lines.append("-" * len(lines[0]))
        for c in CP_CATEGORIES:
            v = self.categories[c]
            frac = v / self.makespan if self.makespan else 0.0
            lines.append(f"{c:>12} {v:>14.6f} {frac:>7.1%}")
            if c == "compute" and self.compute_by_op:
                for op in sorted(self.compute_by_op):
                    ov = self.compute_by_op[op]
                    of = ov / self.makespan if self.makespan else 0.0
                    lines.append(f"{'  ' + op:>12} {ov:>14.6f} {of:>7.1%}")
        lines.append("-" * len(lines[0]))
        lines.append(f"{'makespan':>12} {self.makespan:>14.6f} {1:>7.1%}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "makespan": self.makespan,
            "categories": dict(self.categories),
            "fractions": self.fractions(),
            "compute_by_op": dict(self.compute_by_op),
            "compute_by_stage": {str(s): v
                                 for s, v in self.compute_by_stage.items()},
            "fold": self.fold,
            "path_nodes": self.path_nodes,
            "recovery_windows": self.recovery_windows,
            "top_category": self.top_category(),
        }


class ExecGraph:
    """The execution DAG lowered from one recorded trace."""

    def __init__(self, nodes: dict[tuple, Node], order: list[tuple],
                 sink_key: tuple, meta: dict, spec: PipelineSpec):
        self.nodes = nodes
        #: keys in topological (recorded completion) order, ROOT first
        self.order = order
        self.sink_key = sink_key
        self.meta = meta
        self.spec = spec

    # ------------------------------------------------------------------
    @property
    def makespan(self) -> float:
        """The sink's recorded completion — bit-identical to the recorded
        makespan on sim traces (it *is* the same float)."""
        return self.nodes[self.sink_key].end_t

    @property
    def num_recovery_windows(self) -> int:
        return sum(1 for k in self.nodes if k[0] == "recovery")

    # ------------------------------------------------------------------
    @staticmethod
    def build(trace: _tr.Trace, spec: PipelineSpec | None = None
              ) -> "ExecGraph":
        return _build(trace, spec)

    # ------------------------------------------------------------------
    def critical_path(self) -> list[tuple[Node, Edge | None]]:
        """Binding-edge walk sink -> ROOT, returned root-first.

        Each entry is (node, binding edge *into* that node); the ROOT (and
        any node whose only constraint is the ROOT seed) anchors the walk.
        """
        out: list[tuple[Node, Edge | None]] = []
        key = self.sink_key
        seen = set()
        while key is not None and key not in seen:
            seen.add(key)
            n = self.nodes[key]
            out.append((n, n.binding))
            key = n.binding.src if n.binding is not None else None
        out.reverse()
        return out

    def slack(self) -> dict[tuple, float]:
        """Per-node scheduling slack (seconds), clamped at 0.

        ``slack(n) = makespan - end(n) - tail(n)`` where ``tail`` is the
        longest downstream chain; exactly 0 along the critical path,
        ``>= 0`` everywhere by construction.
        """
        tail: dict[tuple, float] = {k: 0.0 for k in self.nodes}
        for key in reversed(self.order):
            n = self.nodes[key]
            for e in n.in_edges:
                if e is n.binding:
                    c = (n.end_t - self.nodes[e.src].end_t) + tail[key]
                else:
                    c = ((e.arrival - self.nodes[e.src].end_t)
                         + (n.end_t - n.dispatch_t) + tail[key])
                if c > tail[e.src]:
                    tail[e.src] = c
        mk = self.makespan
        out = {k: max(0.0, mk - self.nodes[k].end_t - tail[k])
               for k in self.nodes}
        # the binding chain has zero slack by definition; the backward
        # accumulation can leave an ulp of float residue there — pin it
        for node, _ in self.critical_path():
            out[node.key] = 0.0
        return out

    def verify(self) -> float:
        """Re-derive every completion from the generative recurrence.

        ``end(n) = max_e(end(src_e) + comm_e + gate_e) + residual + coord +
        dur``; returns the max relative error vs the recorded completion
        times (~1e-9 on sim traces — the float-sum view of the same
        identity the absolute-time walk states exactly).
        """
        new_end: dict[tuple, float] = {ROOT_KEY: 0.0}
        worst = 0.0
        scale = max(1.0, self.makespan)
        for key in self.order:
            if key == ROOT_KEY:
                continue
            n = self.nodes[key]
            arr = max((new_end.get(e.src, self.nodes[e.src].end_t)
                       + e.comm + e.gate for e in n.in_edges), default=0.0)
            ne = arr + n.residual + n.coord + n.dur
            new_end[key] = ne
            worst = max(worst, abs(ne - n.end_t) / scale)
        return worst

    def decompose(self) -> CritPathReport:
        """Fold the critical path into per-category seconds.

        The telescoping identity ``end(n) - end(prev) = comm + gate +
        residual + coord + dur`` holds per binding edge, so the category
        sums cover the whole makespan; the float-addition residue is folded
        into the largest bucket (``bubbles.py``'s exact-attribution idiom),
        making the reported categories sum *exactly* to the makespan.
        """
        path = self.critical_path()
        cats = {c: 0.0 for c in CP_CATEGORIES}
        by_op: dict[str, float] = {}
        by_stage: dict[int, float] = {}
        summary: list[dict] = []
        for node, edge in path:
            if node.key == ROOT_KEY:
                continue
            if edge is not None:
                cats["comm"] += edge.comm
                cats["gate"] += edge.gate
            cats["dispatch"] += node.residual
            if node.op == "recovery":
                cats["recovery"] += node.dur
            else:
                cats["gate"] += node.coord
                cats["compute"] += node.dur
                by_op[node.op] = by_op.get(node.op, 0.0) + node.dur
                by_stage[node.stage] = by_stage.get(node.stage, 0.0) + node.dur
            summary.append({
                "node": "recovery" if node.op == "recovery" else "exec",
                "stage": node.stage,
                "task": list(_tr.task_key(node.task))
                        if node.task is not None else None,
                "op": node.op,
                "start": node.dispatch_t,
                "end": node.end_t,
                "via": edge.kind if edge is not None else None,
            })
        fold = _fold_exact(cats, self.makespan)
        return CritPathReport(
            makespan=self.makespan, categories=cats, compute_by_op=by_op,
            compute_by_stage=by_stage, fold=fold, path_nodes=len(summary),
            recovery_windows=self.num_recovery_windows, path=summary)


def _fold_exact(cats: dict[str, float], makespan: float) -> float:
    """Fold the float residue so ``sum(cats.values()) == makespan`` exactly.

    A single ``makespan - sum`` correction can leave the re-summed
    left-fold an ulp off (float addition is non-associative), and nudging
    an arbitrary bucket cannot always help: round-to-even on the downstream
    additions can make the makespan unreachable from that bucket's grid.
    The robust move is the *last nonzero* bucket in fold order — every
    later addend is exactly ``0.0``, so the left-fold ends
    ``prefix + cats[target]`` and assigning ``makespan - prefix`` is exact
    by Sterbenz whenever ``prefix`` is close to the makespan (it always is:
    the residue being absorbed is a few ulps).  Earlier buckets serve as
    fallback targets, each with a coarse-correction loop plus a bounded ulp
    sweep, for the degenerate alignments.
    """
    import math

    def left_fold() -> float:
        s = 0.0
        for c in CP_CATEGORIES:
            s += cats[c]
        return s

    orig = dict(cats)
    nonzero = [c for c in CP_CATEGORIES if cats[c] != 0.0]
    if not nonzero:
        cats["compute"] = makespan
        return makespan
    for target in [nonzero[-1]] + nonzero[:-1][::-1]:
        cats.update(orig)
        if target == nonzero[-1]:
            prefix = 0.0
            for c in CP_CATEGORIES:
                if c == target:
                    break
                prefix += cats[c]
            cats[target] = makespan - prefix
        else:
            cats[target] += makespan - left_fold()
        for _ in range(8):  # coarse corrections
            if left_fold() == makespan:
                return cats[target] - orig[target]
            cats[target] += makespan - left_fold()
        for _ in range(64):  # last-resort ulp sweep
            s = left_fold()
            if s == makespan:
                return cats[target] - orig[target]
            cats[target] = math.nextafter(
                cats[target], math.inf if s < makespan else -math.inf)
    cats.update(orig)  # no target landed: leave the raw decomposition
    return makespan - left_fold()


# ---------------------------------------------------------------------------
# graph construction
# ---------------------------------------------------------------------------
def _build(trace: _tr.Trace, spec: PipelineSpec | None) -> ExecGraph:
    meta = trace.meta or {}
    if spec is None:
        spec = spec_from_meta(meta)
    split = bool(spec.split_backward)

    sends: dict[int, _tr.TraceEvent] = {}
    delivers: dict[Task, list[_tr.TraceEvent]] = {}
    enqueues: dict[Task, list[_tr.TraceEvent]] = {}
    pairs: dict[Task, list[list]] = {}  # task -> [[dispatch, complete|None]]
    windows: list[dict] = []            # {"fail": ev, "end": ev|None}
    open_by_stage: dict[int, dict] = {}
    for ev in trace.events:
        k = ev.kind
        if k == _tr.DISPATCH:
            pairs.setdefault(ev.task, []).append([ev, None])
        elif k == _tr.COMPLETE:
            lst = pairs.setdefault(ev.task, [])
            # pair with the *latest* unmatched dispatch before this
            # complete: an earlier doomed incarnation stays unmatched
            for pr in reversed(lst):
                if pr[1] is None and pr[0].lc < ev.lc:
                    pr[1] = ev
                    break
        elif k == _tr.SEND:
            seq = ev.info.get("seq")
            if seq is not None:
                sends.setdefault(int(seq), ev)
        elif k == _tr.DELIVER:
            delivers.setdefault(ev.task, []).append(ev)
        elif k == _tr.ENQUEUE:
            enqueues.setdefault(ev.task, []).append(ev)
        elif k == _tr.FAIL:
            w = {"fail": ev, "end": None}
            open_by_stage[ev.stage] = w
            windows.append(w)
        elif k == _tr.RECOVERY_END:
            w = open_by_stage.pop(ev.stage, None)
            if w is not None:
                w["end"] = ev

    nodes: dict[tuple, Node] = {ROOT_KEY: Node(
        key=ROOT_KEY, stage=-1, task=None, op="root", dispatch_t=0.0,
        end_t=0.0, dur=0.0, coord=0.0)}

    # recovery nodes: one per completed FAIL..RECOVERY_END window
    rec_by_epoch: dict[int, tuple] = {}
    rec_by_stage: dict[int, list[tuple]] = {}
    for wi, w in enumerate(windows):
        if w["end"] is None:
            continue
        fe, ee = w["fail"], w["end"]
        key = ("recovery", wi)
        nodes[key] = Node(
            key=key, stage=fe.stage, task=None, op="recovery",
            dispatch_t=fe.t, end_t=ee.t, dur=max(0.0, ee.t - fe.t),
            coord=0.0, epoch=ee.epoch, dispatch_lc=fe.lc, complete_lc=ee.lc)
        rec_by_epoch[ee.epoch] = key
        rec_by_stage.setdefault(fe.stage, []).append(key)

    # exec nodes: one per paired DISPATCH..COMPLETE incarnation
    exec_by_task: dict[Task, list[Node]] = {}
    stage_execs: dict[int, list[Node]] = {}
    doomed: dict[Task, list[_tr.TraceEvent]] = {}
    for task, lst in pairs.items():
        for i, (d, c) in enumerate(lst):
            if c is None:
                doomed.setdefault(task, []).append(d)
                continue
            dur = float(c.info.get("dur", c.t - d.t))
            coord = max(0.0, (c.t - d.t) - dur)
            key = ("exec", tuple(_tr.task_key(task)), i)
            n = Node(key=key, stage=task.stage, task=task,
                     op=op_label(task, split), dispatch_t=d.t, end_t=c.t,
                     dur=min(dur, max(0.0, c.t - d.t)), coord=coord,
                     epoch=d.epoch, dispatch_lc=d.lc, complete_lc=c.lc)
            nodes[key] = n
            exec_by_task.setdefault(task, []).append(n)
            stage_execs.setdefault(task.stage, []).append(n)
    for lst in exec_by_task.values():
        lst.sort(key=lambda n: n.dispatch_lc)
    stage_lcs: dict[int, list[int]] = {}
    for s, lst in stage_execs.items():
        lst.sort(key=lambda n: n.dispatch_lc)
        stage_lcs[s] = [n.dispatch_lc for n in lst]

    def latest_exec_before(task: Task, lc: int) -> Node | None:
        """Latest execution of ``task`` whose COMPLETE precedes ``lc``."""
        best = None
        for n in exec_by_task.get(task, ()):
            if n.complete_lc < lc:
                best = n
        return best

    def candidates(task: Task, stage: int, d_lc: int, d_epoch: int
                   ) -> list[Edge]:
        edges: list[Edge] = []
        # (a) per-stage serialization: the previous completed execution
        lst = stage_execs.get(stage, [])
        i = bisect_left(stage_lcs.get(stage, []), d_lc) - 1
        while i >= 0 and lst[i].complete_lc >= d_lc:
            i -= 1
        if i >= 0:
            edges.append(Edge(lst[i].key, "serial", arrival=lst[i].end_t))
        # (b) same-stage local dependency (B after F, W after B)
        lp = spec.local_predecessor(task)
        if lp is not None:
            pn = latest_exec_before(lp, d_lc)
            if pn is not None:
                edges.append(Edge(pn.key, "local", arrival=pn.end_t))
        # (c) readiness: the binding ENQUEUE and its delivery chain
        eqs = enqueues.get(task, [])
        j = -1
        for idx, eq in enumerate(eqs):
            if eq.lc < d_lc:
                j = idx
        if j >= 0:
            eq = eqs[j]
            lo = eqs[j - 1].lc if j > 0 else -1
            preds = spec.message_predecessors(task)
            if eq.info.get("src") == "local" or not preds:
                rk = rec_by_epoch.get(eq.epoch) if eq.epoch > 0 else None
                edges.append(Edge(rk if rk is not None else ROOT_KEY,
                                  "recovery" if rk is not None else "root",
                                  arrival=eq.t))
            else:
                msg_edges: list[Edge] = []
                first: dict[tuple, _tr.TraceEvent] = {}
                for dv in delivers.get(task, ()):
                    if lo < dv.lc < eq.lc:
                        # first copy per (src, rank) wins at the gate;
                        # chaos duplicates only re-deliver
                        first.setdefault(
                            (int(dv.info.get("src", -1)), dv.rank), dv)
                for (src_stage, _rank), dv in first.items():
                    seq = dv.info.get("seq")
                    sv = sends.get(int(seq)) if seq is not None else None
                    if sv is None:
                        # replayed delivery (recovery restores have fresh
                        # seqs and no SEND record): charge the window
                        rk = rec_by_epoch.get(dv.epoch)
                        msg_edges.append(Edge(
                            rk if rk is not None else ROOT_KEY,
                            "recovery" if rk is not None else "root",
                            arrival=dv.t))
                        continue
                    prod = next((p for p in preds if p.stage == sv.stage),
                                None)
                    pn = (latest_exec_before(prod, sv.lc)
                          if prod is not None else None)
                    if pn is None:
                        msg_edges.append(Edge(ROOT_KEY, "root", arrival=dv.t))
                    else:
                        msg_edges.append(Edge(
                            pn.key, "msg", arrival=dv.t,
                            comm=max(0.0, dv.t - sv.t)))
                if msg_edges:
                    # the admission residual (TP gate / fan-in skew) rides
                    # the last-arriving copy: ENQUEUE - max(DELIVER)
                    bind = max(msg_edges, key=lambda e: e.arrival)
                    bind.gate = max(0.0, eq.t - bind.arrival)
                    bind.arrival = eq.t
                    edges.extend(msg_edges)
                else:
                    edges.append(Edge(ROOT_KEY, "root", arrival=eq.t))
        # (d) a post-outage execution at the failed stage waits for the
        # window to close even if its inputs survived
        for rk in rec_by_stage.get(stage, ()):
            rn = nodes[rk]
            if rn.complete_lc < d_lc and d_epoch >= rn.epoch:
                edges.append(Edge(rk, "recovery", arrival=rn.end_t))
        return edges

    def attach(n: Node, edges: list[Edge]) -> None:
        # safety valve: a candidate arriving *after* the dispatch cannot
        # be a constraint (thread-substrate interleavings around recovery
        # re-seeds); drop it so residual stays >= 0
        tol = 1e-9 * max(1.0, abs(n.dispatch_t))
        edges = [e for e in edges if e.arrival <= n.dispatch_t + tol]
        if not edges:
            edges = [Edge(ROOT_KEY, "root", arrival=0.0)]
        n.in_edges = edges
        n.binding = max(edges, key=lambda e: (e.arrival,
                                              _EDGE_PRIORITY[e.kind]))
        n.residual = max(0.0, n.dispatch_t - n.binding.arrival)

    for key, n in nodes.items():
        if key[0] != "exec":
            continue
        attach(n, candidates(n.task, n.stage, n.dispatch_lc, n.epoch))

    # recovery node in-edges: the doomed dispatch's own constraints (the
    # outage starts where the doomed incarnation's inputs ended)
    for wi, w in enumerate(windows):
        key = ("recovery", wi)
        if key not in nodes:
            continue
        rn = nodes[key]
        fe = w["fail"]
        edges: list[Edge] = []
        if fe.task is not None:
            dd = None
            for d in doomed.get(fe.task, ()):
                if d.lc <= fe.lc:
                    dd = d
            if dd is not None:
                edges = candidates(fe.task, fe.stage, dd.lc, dd.epoch)
        if not edges:
            prev = None
            for n2 in stage_execs.get(fe.stage, ()):
                if n2.complete_lc < fe.lc:
                    prev = n2
            if prev is not None:
                edges = [Edge(prev.key, "serial", arrival=prev.end_t)]
        attach(rn, edges)

    # topological order: recorded completion order is a valid topological
    # sort (every edge's source completes strictly before its target's
    # dispatch commits, by logical-clock construction)
    order = sorted(nodes, key=lambda k: (nodes[k].complete_lc, k))
    sink_key = max(nodes, key=lambda k: (nodes[k].end_t, nodes[k].complete_lc))
    return ExecGraph(nodes=nodes, order=order, sink_key=sink_key,
                     meta=dict(meta), spec=spec)
