"""Grok-1 (314B) — MoE 8 experts top-2, GQA kv=8.  [hf:xai-org/grok-1;
unverified]  Expert layout: TP on d_ff over the data axis (8 experts < 16
devices), DESIGN §3."""
import jax.numpy as jnp
from repro.models.common import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    act="geglu",
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    dtype=jnp.bfloat16,
)
