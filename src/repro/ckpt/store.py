"""Sharded checkpoint store: npz payloads + json manifest, async writes.

Layout:
  <dir>/step_<k>/manifest.json       — step, arch, mesh shape, leaf index
  <dir>/step_<k>/shard_<p>.npz       — one payload per writer process
  <dir>/LATEST                       — atomic pointer (rename) to the last
                                       fully-committed step

Fault-tolerance contract: a step directory is visible via LATEST only after
every shard landed (write-then-rename), so a crash mid-save can never corrupt
the restore point; restore() validates the manifest against the target tree
and re-shards on mesh change (jax.device_put with the new sharding).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _to_numpy(x):
    a = np.asarray(x)
    if a.dtype.name == "bfloat16":  # npz has no bf16 encoding; fp32 is lossless
        a = a.astype(np.float32)
    return a


def _flatten(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return {jax.tree_util.keystr(p): _to_numpy(l) for p, l in leaves}


def _unflatten_into(tree, arrays: dict):
    def fill(path, leaf):
        k = jax.tree_util.keystr(path)
        if k not in arrays:
            raise KeyError(f"checkpoint missing leaf {k}")
        a = arrays[k]
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {k}: {a.shape} vs {leaf.shape}")
        return a.astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(fill, tree)


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3, process: int = 0):
        self.dir = directory
        self.keep = keep
        self.process = process
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, meta: dict | None = None,
             asynchronous: bool = False):
        host = jax.tree.map(_to_numpy, tree)
        if asynchronous:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host, meta or {}), daemon=True)
            self._pending.start()
        else:
            self._write(step, host, meta or {})

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree, meta: dict):
        tmp = os.path.join(self.dir, f".tmp_step_{step}_{time.time_ns()}")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        arrays = _flatten(host_tree)
        np.savez(os.path.join(tmp, f"shard_{self.process}.npz"), **arrays)
        manifest = {
            "step": step,
            "leaves": sorted(arrays),
            "meta": meta,
            "shards": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        latest_tmp = os.path.join(self.dir, ".LATEST_tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.rename(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, step: int, target_tree, shardings=None):
        """Load step into ``target_tree``'s structure (and shardings)."""
        arrays, manifest = self._read_arrays(step)
        tree = _unflatten_into(target_tree, arrays)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, manifest["meta"]

    def restore_host(self, step: int, target_tree):
        """Load step into ``target_tree``'s structure as *host* numpy arrays
        (no device placement) — the recovery coordinator's restore path: a
        respawned stage actor rebuilds its program from the last committed
        step without assuming any device mesh is available yet."""
        arrays, manifest = self._read_arrays(step)
        return _unflatten_into(target_tree, arrays), manifest["meta"]

    def _read_arrays(self, step: int):
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays: dict = {}
        for p in range(manifest["shards"]):
            with np.load(os.path.join(d, f"shard_{p}.npz")) as z:
                arrays.update({k: z[k] for k in z.files})
        return arrays, manifest
