"""Chaotic actor runs over real jitted stage callables: bitwise loss/grad
parity with the fixed-order reference executor.

The numpy suite (test_chaos_threaded) covers the reduction-order argument at
scale; these tests close the loop on the actual training path: the same
``ActorStageProgram`` that ``launch/train.py --runtime actor`` drives, with
``deterministic_reduction=True``, executed chaotically, must reproduce the
sequential fixed-order reference's loss and per-stage parameter-gradient
bits exactly (same jitted kernels + same per-microbatch inputs + pinned
reduction order => identical floats).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import artifact_on_failure, check_all, reference_execute

from repro.configs import registry
from repro.core import PipelineSpec
from repro.core.hints import HintKind
from repro.models.build import build
from repro.pipeline.stagefn import ActorStageProgram, StageFnOptions, StageFns
from repro.runtime.rrfp import ActorConfig, ActorDriver, ChaosConfig


def _setup(S, M, mb_rows, seq, layers, split):
    cfg = registry.reduced_config("deepseek-7b", num_layers=layers)
    model = build(cfg, num_stages=S)
    key = jax.random.key(0)
    sp = model.init_stage_params(key)
    io = model.init_io_params(jax.random.fold_in(key, 1))
    rows = M * mb_rows
    batch = {
        "tokens": jax.random.randint(
            jax.random.key(2), (rows, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(
            jax.random.key(3), (rows, seq), 0, cfg.vocab_size),
    }
    fns = StageFns(model, StageFnOptions(
        mb_rows=mb_rows, seq_len=seq, loss_scale=1.0 / (rows * seq)))

    def programs():
        return [
            ActorStageProgram(
                fns, s, jax.tree.map(lambda x, s=s: x[s], sp), io, batch,
                split_backward=split, deterministic_reduction=True)
            for s in range(S)
        ]

    return programs


def _assert_bitwise_parity(chaotic, reference):
    for cp, rp in zip(chaotic, reference):
        cp.finalize()
        rp.finalize()
        assert float(cp.loss_acc) == float(rp.loss_acc), (
            f"stage {cp.stage} loss diverged: "
            f"{float(cp.loss_acc)!r} != {float(rp.loss_acc)!r}")
        for cg, rg in zip(jax.tree.leaves(cp.d_stage),
                          jax.tree.leaves(rp.d_stage)):
            assert np.asarray(cg).tobytes() == np.asarray(rg).tobytes()
        for cg, rg in zip(jax.tree.leaves(cp.d_io),
                          jax.tree.leaves(rp.d_io)):
            assert np.asarray(cg).tobytes() == np.asarray(rg).tobytes()


def _run_parity(S, M, mb_rows, seq, layers, *, split, chaos, acfg):
    spec = PipelineSpec(S, M, split_backward=split)
    make_programs = _setup(S, M, mb_rows, seq, layers, split)

    reference = make_programs()
    reference_execute(spec, reference)

    chaotic = make_programs()
    driver = ActorDriver(spec, None, acfg)
    with artifact_on_failure(lambda: driver.trace, f"realmodel_S{S}M{M}"):
        result = driver.run_threaded(list(chaotic))
        assert len(result.end) == spec.total_tasks()
        check_all(driver.trace, spec, acfg)
        _assert_bitwise_parity(chaotic, reference)


def test_real_model_chaotic_fused_parity():
    chaos = ChaosConfig(seed=1, latency_base=2e-3, reorder_prob=0.4,
                        reorder_window=1e-2, duplicate_prob=0.2,
                        straggler=((1, 2.0),), stall_prob=0.1,
                        stall_scale=5e-3)
    acfg = ActorConfig(mode="hint", chaos=chaos, record_trace=True,
                       deadlock_timeout=300.0)
    _run_parity(2, 3, 1, 8, 2, split=False, chaos=chaos, acfg=acfg)


def test_mid_run_finalize_raises_instead_of_corrupting_order():
    """A partial fold (e.g. a progress-logging ``loss_sum`` read mid-run)
    would silently pin early microbatches' reduction position; the program
    must raise on the next out-of-order fold instead."""
    from repro.core.taskgraph import Kind, Task

    cfg = registry.reduced_config("deepseek-7b", num_layers=2)
    model = build(cfg, num_stages=1)
    key = jax.random.key(0)
    sp = model.init_stage_params(key)
    io = model.init_io_params(jax.random.fold_in(key, 1))
    batch = {
        "tokens": jax.random.randint(
            jax.random.key(2), (3, 8), 0, cfg.vocab_size),
        "labels": jax.random.randint(
            jax.random.key(3), (3, 8), 0, cfg.vocab_size),
    }
    fns = StageFns(model, StageFnOptions(mb_rows=1, seq_len=8))
    p = ActorStageProgram(fns, 0, jax.tree.map(lambda x: x[0], sp), io,
                          batch, deterministic_reduction=True)
    p(Task(Kind.F, 0, 0), None)
    p(Task(Kind.F, 0, 2), None)
    p.loss_sum  # mid-run read: folds microbatches {0, 2} early
    p(Task(Kind.F, 0, 1), None)
    with pytest.raises(RuntimeError, match="mid-run"):
        p.finalize()


@pytest.mark.slow
def test_real_model_chaotic_bfw_parity():
    """Split backward (B = dX, deferrable W) under chaos with a W cap."""
    chaos = ChaosConfig(seed=2, latency_base=2e-3, reorder_prob=0.5,
                        reorder_window=2e-2, duplicate_prob=0.3,
                        straggler=((0, 2.0),), stall_prob=0.15,
                        stall_scale=1e-2)
    acfg = ActorConfig(mode="hint", hint=HintKind.BFW, w_defer_cap=2,
                       chaos=chaos, record_trace=True,
                       deadlock_timeout=300.0)
    _run_parity(2, 4, 2, 16, 4, split=True, chaos=chaos, acfg=acfg)
