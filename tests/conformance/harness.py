"""Shared machinery for the runtime-conformance suite.

A *scenario* is a seed-derived (pipeline spec, runtime config, chaos config)
triple; the suite runs each one through the actor runtime and checks the
schedule-independent invariants of the paper's correctness argument against
the recorded event trace.  The invariant checkers themselves live in
``repro.runtime.rrfp.conformance`` (one source of truth, shared with the
chaos benchmark); this module re-exports them and adds scenario generation,
the fixed-order reference executor, and the failing-trace artifact dump.

Any failing check saves the run's trace under ``_artifacts/`` (uploaded by
the CI job) so the exact event sequence can be replayed.
"""
from __future__ import annotations

import contextlib
import dataclasses
import sys
from pathlib import Path

import numpy as np

from repro.core import CostModel, PipelineSpec, StageGraph
from repro.core.hints import HintKind
from repro.core.taskgraph import Kind, Task
from repro.runtime.rrfp import ActorConfig, ChaosConfig, EdgePayloads
from repro.runtime.rrfp.chaos import modality_profile
from repro.runtime.rrfp.conformance import (  # noqa: F401  (re-exported)
    check_all,
    check_backpressure,
    check_dependency_order,
    check_exactly_once,
    check_fanin_admission,
    check_hint_faithful,
    check_recovery_exactly_once,
    check_w_cap,
    check_wcap_path,
)
from repro.runtime.rrfp.messages import payload_for_edge

ARTIFACT_DIR = Path(__file__).parent / "_artifacts"


# ---------------------------------------------------------------------------
# scenario generation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Scenario:
    seed: int
    spec: PipelineSpec
    config: ActorConfig

    def name(self) -> str:
        s = self.spec
        return (f"seed{self.seed}_S{s.num_stages}M{s.num_microbatches}"
                f"C{s.num_chunks}{'W' if s.split_backward else ''}"
                f"_{self.config.mode}")


def make_scenario(seed: int, *, substrate: str = "sim") -> Scenario:
    """Deterministic seed -> randomized scenario (spec + mode + chaos).

    Chaos delays are kept at millisecond scale so the same scenarios are
    cheap on the thread substrate; the sim substrate only cares about their
    relative magnitude.
    """
    rng = np.random.default_rng([0xC0FFEE, seed])
    S = int(rng.integers(2, 7))
    M = int(rng.integers(2, 13))
    split = bool(rng.integers(2))
    chunks = 1
    mode = "hint" if rng.random() < 0.75 else "precommitted"
    hint, fixed = HintKind.BF, "1f1b"
    if mode == "hint":
        if split:
            hint = HintKind.BFW
        else:
            hint = HintKind(rng.choice(["bf", "fb", "b_priority", "f_priority"]))
            if rng.random() < 0.25:
                chunks = 2  # interleaved (fused backward only)
    else:
        fixed = "zb" if split else str(rng.choice(["1f1b", "gpipe"]))
    buffer_limit = int(rng.choice([2, 4, 32]))
    w_defer_cap = int(rng.choice([0, 1, 2, 4])) if split else 0
    tp_degree = int(rng.choice([1, 1, 2]))
    spec = PipelineSpec(S, M, num_chunks=chunks, split_backward=split)
    chaos = ChaosConfig(
        seed=seed,
        latency_base=float(rng.choice([2e-4, 5e-4, 2e-3])),
        latency_sigma=float(rng.uniform(0.2, 1.0)),
        reorder_prob=float(rng.choice([0.0, 0.2, 0.5])),
        reorder_window=float(rng.uniform(1e-3, 6e-3)),
        duplicate_prob=float(rng.choice([0.0, 0.1, 0.3])),
        max_duplicates=int(rng.integers(1, 3)),
        straggler=(
            ((int(rng.integers(S)), float(rng.uniform(1.5, 3.0))),)
            if rng.random() < 0.5 else ()),
        stall_prob=float(rng.choice([0.0, 0.1])),
        stall_scale=float(rng.uniform(1e-3, 4e-3)),
    )
    config = ActorConfig(
        mode=mode, hint=hint, fixed_order=fixed, buffer_limit=buffer_limit,
        w_defer_cap=w_defer_cap, tp_degree=tp_degree, seed=seed,
        chaos=chaos, record_trace=True,
        deadlock_timeout=15.0 if substrate == "thread" else 30.0)
    return Scenario(seed=seed, spec=spec, config=config)


def branch_fusion_graph(enc: int, lm: int) -> StageGraph:
    """Encoder branch (enc stages) ∥ text frontend -> fusion -> LM chain."""
    S = enc + 1 + lm
    edges = [(s, s + 1) for s in range(enc - 1)]
    edges += [(enc - 1, enc + 1), (enc, enc + 1)]
    edges += [(s, s + 1) for s in range(enc + 1, S - 1)]
    return StageGraph(S, tuple(edges))


def make_dag_scenario(seed: int, *, profile: str | None = None,
                      level: str = "C1",
                      substrate: str = "sim") -> Scenario:
    """Randomized branch+fusion DAG scenario, optionally with a
    modality-aware fault profile layered on a chaos level."""
    rng = np.random.default_rng([0xDA6, seed])
    enc = int(rng.integers(1, 4))
    lm = int(rng.integers(1, 4))
    graph = branch_fusion_graph(enc, lm)
    S = graph.num_stages
    M = int(rng.integers(2, 11))
    split = bool(rng.integers(2))
    mode = "hint" if rng.random() < 0.75 else "precommitted"
    hint, fixed = HintKind.BF, "1f1b"
    if mode == "hint":
        hint = HintKind.BFW if split else HintKind(
            rng.choice(["bf", "fb", "b_priority", "f_priority"]))
    else:
        fixed = "zb" if split else str(rng.choice(["1f1b", "gpipe"]))
    spec = PipelineSpec(S, M, split_backward=split, graph=graph)
    if profile is None:
        chaos = ChaosConfig(seed=seed, latency_base=5e-4,
                            reorder_prob=0.2, reorder_window=3e-3,
                            duplicate_prob=0.1)
    else:
        chaos = modality_profile(
            profile,
            encoder_stages=tuple(range(enc)),
            decoder_stages=tuple(range(enc + 1, S)),
            fanin_edges=((enc - 1, enc + 1), (enc, enc + 1)),
            level=level, seed=seed)
    config = ActorConfig(
        mode=mode, hint=hint, fixed_order=fixed,
        buffer_limit=int(rng.choice([2, 4, 32])),
        w_defer_cap=int(rng.choice([0, 1, 2, 4])) if split else 0,
        tp_degree=int(rng.choice([1, 1, 2])), seed=seed,
        chaos=chaos, record_trace=True,
        deadlock_timeout=15.0 if substrate == "thread" else 30.0)
    return Scenario(seed=seed, spec=spec, config=config)


def sim_costs(spec: PipelineSpec, seed: int) -> CostModel:
    cm = CostModel.uniform(spec.num_stages, f=1.0, b=2.0,
                           w=1.0 if spec.split_backward else 0.0,
                           comm_base=1e-3, seed=seed)
    return cm


@contextlib.contextmanager
def artifact_on_failure(get_trace, name: str):
    """Save the run's trace under _artifacts/ when a check fails (the CI
    conformance job uploads that directory on failure).

    Alongside the replayable ``.jsonl`` dump, a ``.perfetto.json`` view of
    the same trace is exported so a failure can be *looked at* (timeline at
    ui.perfetto.dev) without first round-tripping the JSON-lines file
    through the exporter locally.  The visual export is best-effort: a
    trace broken enough to crash the exporter must not mask the original
    failure or the replayable dump."""
    try:
        yield
    except BaseException:
        trace = get_trace() if callable(get_trace) else get_trace
        if trace is not None:
            ARTIFACT_DIR.mkdir(exist_ok=True)
            path = ARTIFACT_DIR / f"{name}.jsonl"
            trace.save(str(path))
            print(f"conformance failure: trace saved -> {path}",
                  file=sys.stderr)
            try:
                from repro.obs.export import export_perfetto
                vpath = ARTIFACT_DIR / f"{name}.perfetto.json"
                export_perfetto(trace, str(vpath))
                print(f"conformance failure: perfetto view -> {vpath}",
                      file=sys.stderr)
            except Exception as exc:  # pragma: no cover - best effort
                print(f"conformance failure: perfetto export skipped "
                      f"({exc})", file=sys.stderr)
        raise


# ---------------------------------------------------------------------------
# numpy stage programs: bitwise loss/grad parity without a device
# ---------------------------------------------------------------------------
class NumpyStageProgram:
    """Float32 ``work_fn`` mimicking ``ActorStageProgram`` semantics.

    Forward multiplies by a per-stage weight vector; a sink stage scores
    a quadratic loss per microbatch; backward propagates exact gradients.
    All arithmetic is float32, so *accumulation order changes the bits* —
    which is exactly what the parity check needs: with deterministic
    (stash-then-sorted-sum) reduction, a chaotic execution order must
    reproduce the fixed-order reference executor's loss and weight-gradient
    bit patterns exactly.

    DAG-aware: a fan-in stage's F sums its per-edge payloads in source
    order before applying the weight; a fan-out stage's B returns
    ``EdgePayloads`` (the same dx to every forward predecessor — the exact
    adjoint of the fan-in sum); source stages generate their own input.
    """

    def __init__(self, stage: int, spec: PipelineSpec, seed: int, d: int = 16,
                 deterministic: bool = True):
        self.stage = stage
        self.spec = spec
        self.d = d
        #: False = eager (order-sensitive) accumulation, for replay parity
        self.deterministic = deterministic
        rng = np.random.default_rng([seed, 7, stage])
        self.w = rng.standard_normal(d).astype(np.float32)
        self.residual: dict[tuple, np.ndarray] = {}
        self.fwd_out: dict[tuple, np.ndarray] = {}
        self.w_pending: dict[tuple, tuple[np.ndarray, np.ndarray]] = {}
        self.w_high_water = 0
        self._mb_loss: dict[tuple, np.float32] = {}
        self._mb_grads: dict[tuple, np.ndarray] = {}
        self.loss = np.float32(0.0)
        self.d_w = np.zeros(d, np.float32)

    def _x0(self, mb: int) -> np.ndarray:
        rng = np.random.default_rng([0xDA7A, mb, self.d])
        return rng.standard_normal(self.d).astype(np.float32)

    def __call__(self, task: Task, payload):
        kc = (task.mb, task.chunk)
        last = (self.stage in self.spec.sink_stages()
                and task.chunk == self.spec.num_chunks - 1)
        if task.kind == Kind.F:
            if not self.spec.message_predecessors(task):
                x = self._x0(task.mb)
            elif isinstance(payload, dict):  # DAG fan-in: sum edge payloads
                x = np.zeros(self.d, np.float32)
                for src in sorted(payload):
                    x = (x + np.asarray(payload[src])).astype(np.float32)
            else:
                x = np.asarray(payload)
            y = (x * self.w).astype(np.float32)
            self.residual[kc] = x
            self.fwd_out[kc] = y
            if last:
                part = np.float32(np.sum(y * y, dtype=np.float32))
                if self.deterministic:
                    self._mb_loss[kc] = part
                else:
                    self.loss = np.float32(self.loss + part)
            return y
        if task.kind == Kind.B:
            x = self.residual.pop(kc)
            if last:  # loss gradient is local: d(loss)/dy = 2 y
                g_in = (2.0 * self.fwd_out[kc]).astype(np.float32)
            else:
                g_in = np.asarray(payload)
            self.fwd_out.pop(kc, None)
            dx = (g_in * self.w).astype(np.float32)
            if self.spec.split_backward:
                self.w_pending[kc] = (x, g_in)
                self.w_high_water = max(self.w_high_water, len(self.w_pending))
            else:
                self._grad(kc, (g_in * x).astype(np.float32))
            succs = self.spec.message_successors(task)
            if len(succs) > 1:  # DAG fan-out: adjoint of the fan-in sum
                return EdgePayloads({t.stage: dx for t in succs})
            return dx
        if task.kind == Kind.W:
            x, g_in = self.w_pending.pop(kc)
            self._grad(kc, (g_in * x).astype(np.float32))
            return None
        raise ValueError(task)

    def _grad(self, kc: tuple, g: np.ndarray) -> None:
        if self.deterministic:
            self._mb_grads[kc] = g
        else:
            self.d_w = (self.d_w + g).astype(np.float32)

    def finalize(self) -> "NumpyStageProgram":
        """Sorted-microbatch fold: bitwise order-independent totals."""
        for mb in sorted(self._mb_loss):
            self.loss = np.float32(self.loss + self._mb_loss[mb])
        self._mb_loss.clear()
        for mb in sorted(self._mb_grads):
            self.d_w = (self.d_w + self._mb_grads[mb]).astype(np.float32)
        self._mb_grads.clear()
        return self


def execute_complete_order(trace, spec: PipelineSpec, seed: int,
                           d: int = 16) -> list[NumpyStageProgram]:
    """Execute a trace's realized completion order through fresh
    :class:`NumpyStageProgram` instances and return them finalized.

    Each task's COMPLETE is taken from its highest-epoch incarnation (on a
    recovered trace the final incarnation is the one whose effects are
    committed), in logical-clock order — a dependency-respecting total
    order by the conformance dependency invariant.  With the programs'
    stash-then-sorted-sum reduction, the resulting loss/grad bits depend
    only on the *set* of executed tasks, so exactly-once across a recovery
    boundary is equivalent to bitwise parity with an unfailed run."""
    from repro.runtime.rrfp import trace as _tr

    programs = [NumpyStageProgram(s, spec, seed, d=d)
                for s in range(spec.num_stages)]
    best: dict[Task, object] = {}
    for ev in trace.select(_tr.COMPLETE):
        cur = best.get(ev.task)
        if cur is None or ev.epoch > cur.epoch:
            best[ev.task] = ev
    outputs: dict[Task, object] = {}
    for ev in sorted(best.values(), key=lambda e: e.lc):
        t = ev.task
        mps = spec.message_predecessors(t)
        if not mps:
            payload = None
        elif len(mps) == 1:
            payload = payload_for_edge(outputs.get(mps[0]), t.stage)
        else:
            payload = {p.stage: payload_for_edge(outputs[p], t.stage)
                       for p in mps}
        outputs[t] = programs[t.stage](t, payload)
    return [p.finalize() for p in programs]


def reference_execute(spec: PipelineSpec, programs: list) -> None:
    """Fixed-order reference executor: run every task sequentially in a
    canonical topological order (deterministic scan of the task graph).

    Routes payloads exactly like the runtime: single-predecessor tasks get
    the raw (per-edge-resolved) payload, DAG fan-in tasks a
    ``{src_stage: payload}`` dict."""
    done: set[Task] = set()
    outputs: dict[Task, object] = {}
    tasks = list(spec.tasks())
    while len(done) < len(tasks):
        progressed = False
        for t in tasks:
            if t in done:
                continue
            if any(p not in done for p in spec.predecessors(t)):
                continue
            mps = spec.message_predecessors(t)
            if not mps:
                payload = None
            elif len(mps) == 1:
                payload = payload_for_edge(outputs.get(mps[0]), t.stage)
            else:
                payload = {p.stage: payload_for_edge(outputs[p], t.stage)
                           for p in mps}
            outputs[t] = programs[t.stage](t, payload)
            done.add(t)
            progressed = True
        assert progressed, "reference executor wedged (cyclic spec?)"
