"""Adaptive scheduling: online costs -> hint re-synthesis -> live hot-swap.

Closes ROADMAP item 3's loop.  Schedules in this runtime are *data* — a
rank table the arbiter consults, never a compiled artifact — so when the
measured per-(stage, op) costs drift away from the costs the active table
was synthesized against, a better table can be priced, synthesized, and
swapped into the live :class:`~repro.core.hints.HintArbiter` /
:class:`~repro.core.hints.ReadySet` without recompilation.

The loop, once per ``resynth_every`` training iterations:

1. **snapshot** — ``MetricsRegistry.cost_table().as_cost_model()`` turns the
   live per-(stage, kind) duration EWMAs (fed by the runtime's completion
   hooks) into a jitter-free expected cost model;
2. **re-synthesize** — ``core.synthesis.synthesize`` runs the faithful RRFP
   engine over the measured model and extracts candidate stage orders;
3. **price** — ``core.synthesis.price_orders`` predicts the makespan of the
   *active* table and the *candidate* table on the same measured model;
4. **decide** — swap only if the candidate beats the active table by
   ``swap_threshold`` for ``hysteresis`` consecutive checks (a drift
   detector with flap suppression: under a stationary cost profile the
   candidate re-derives the active table, the ratio pins to ~1.0, and no
   swap ever fires);
5. **hot-swap** — the caller passes ``scheduler.table`` to the next run's
   :class:`~repro.runtime.rrfp.driver.ActorConfig` (iteration-boundary
   quiesce point), or arms ``swap_table``/``swap_at``/``swap_after`` for a
   mid-run swap; either way the adoption is recorded as ``HINT_SWAP``
   trace events, so replay and the conformance table-faithfulness check
   stay exact.

See ``docs/adaptive.md`` for the drift model and guarantees, and
``benchmarks/adaptive_compare.py`` for the static-decay-vs-adaptive-hold
experiment (``BENCH_adaptive.json``).
"""
from __future__ import annotations

import dataclasses

from repro.core.costs import CostModel
from repro.core.hints import HintKind
from repro.core.synthesis import price_orders, synthesize
from repro.core.taskgraph import Kind, PipelineSpec, Task
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Drift-detector and re-synthesis knobs (``launch.train`` CLI flags)."""

    #: check cadence: re-price/re-synthesize every N training iterations
    resynth_every: int = 1
    #: required predicted-makespan improvement factor
    #: (active / candidate >= threshold) for a check to count as improving
    swap_threshold: float = 1.03
    #: consecutive improving checks required before a swap fires
    hysteresis: int = 2
    #: per-stage realized-duration sample floor before the measured table
    #: is trusted at all (a cold EWMA is noise, not drift)
    min_samples: int = 4
    #: hint the re-synthesizer runs under (BFW for split-backward specs)
    hint: HintKind = HintKind.BF
    buffer_limit: int = 32


@dataclasses.dataclass
class SwapDecision:
    """One drift-detector evaluation (``scheduler.decisions`` history)."""

    step: int
    checked: bool          # False: off-cadence or cold-table skip
    swapped: bool
    predicted_active: float | None = None
    predicted_candidate: float | None = None
    streak: int = 0        # improving-check streak after this evaluation
    reason: str = ""
    #: when a swap fired: the critical-path category (obs.critpath) the
    #: candidate table was predicted to shrink the most — which kind of
    #: bound (compute / comm / gate / dispatch) the swap attacked
    predicted_category: str | None = None

    @property
    def ratio(self) -> float | None:
        """Predicted improvement factor of candidate over active (>1 =
        the measured costs say the candidate table is faster)."""
        if self.predicted_active is None or not self.predicted_candidate:
            return None
        return self.predicted_active / self.predicted_candidate

    def to_json(self) -> dict:
        return {
            "step": self.step, "checked": self.checked,
            "swapped": self.swapped, "ratio": self.ratio,
            "predicted_active": self.predicted_active,
            "predicted_candidate": self.predicted_candidate,
            "streak": self.streak, "reason": self.reason,
            "predicted_category": self.predicted_category,
        }


class AdaptiveScheduler:
    """Background re-synthesizer + drift detector for one pipeline.

    Owns (or adopts) the :class:`MetricsRegistry` the runtime feeds; the
    training loop calls :meth:`maybe_resynthesize` at each iteration
    boundary and passes the current :attr:`table` / :attr:`version` to the
    next iteration's ``ActorConfig`` (``hint_table`` /
    ``hint_table_version``).  Synthesis and pricing run on the snapshot,
    off the dispatch hot path.
    """

    def __init__(
        self,
        spec: PipelineSpec,
        base_costs: CostModel,
        config: AdaptiveConfig | None = None,
        registry: MetricsRegistry | None = None,
    ):
        self.spec = spec
        self.base_costs = base_costs
        self.config = config or AdaptiveConfig()
        self.registry = (registry if registry is not None
                         else MetricsRegistry(spec.num_stages))
        syn = synthesize(spec, base_costs, hint=self.config.hint,
                         buffer_limit=self.config.buffer_limit)
        #: the active per-stage rank table (ActorConfig.hint_table)
        self.table: list[list[Task]] = syn.stage_orders
        #: bumped on every swap (ActorConfig.hint_table_version)
        self.version = 0
        #: full decision history, one entry per maybe_resynthesize call
        self.decisions: list[SwapDecision] = []
        #: steps at which a swap fired
        self.swaps: list[int] = []
        self._streak = 0

    # ------------------------------------------------------------------
    def measured_costs(self) -> CostModel:
        """Jitter-free snapshot of the live EWMAs (base costs fill cold
        cells, e.g. before stage S-1's first completion lands)."""
        return self.registry.cost_table().as_cost_model(
            default=self.base_costs)

    def _cold(self) -> bool:
        table = self.registry.cost_table()
        kinds = [Kind.F, Kind.B] + (
            [Kind.W] if self.spec.split_backward else [])
        for s in range(self.spec.num_stages):
            if sum(table.samples(s, k) for k in kinds) < \
                    self.config.min_samples:
                return True
        return False

    def maybe_resynthesize(self, step: int) -> SwapDecision:
        """Run the drift detector at the boundary of iteration ``step``.

        Returns (and appends to :attr:`decisions`) the evaluation; when it
        fired, :attr:`table`/:attr:`version` already hold the new table.
        """
        cfg = self.config
        if (step + 1) % max(1, cfg.resynth_every) != 0:
            d = SwapDecision(step, checked=False, swapped=False,
                             streak=self._streak, reason="off-cadence")
            self.decisions.append(d)
            return d
        if self._cold():
            d = SwapDecision(step, checked=False, swapped=False,
                             streak=self._streak,
                             reason=f"cold table (<{cfg.min_samples} "
                                    f"samples on some stage)")
            self.decisions.append(d)
            return d
        measured = self.measured_costs()
        candidate = synthesize(
            self.spec, measured, hint=cfg.hint,
            buffer_limit=cfg.buffer_limit).stage_orders
        p_active = price_orders(self.spec, self.table, measured)
        p_cand = price_orders(self.spec, candidate, measured)
        improving = p_active / max(p_cand, 1e-12) >= cfg.swap_threshold
        self._streak = self._streak + 1 if improving else 0
        swapped = False
        reason = "below threshold" if not improving else (
            f"improving ({self._streak}/{cfg.hysteresis})")
        category = None
        if self._streak >= cfg.hysteresis:
            old_table = self.table
            self.table = candidate
            self.version += 1
            self.swaps.append(step)
            self._streak = 0
            swapped = True
            reason = "swapped"
            category = self._predicted_category(old_table, candidate,
                                                measured)
        d = SwapDecision(step, checked=True, swapped=swapped,
                         predicted_active=p_active,
                         predicted_candidate=p_cand,
                         streak=self._streak, reason=reason,
                         predicted_category=category)
        self.decisions.append(d)
        return d

    def note_remap(self, host_of: list[int],
                   recovery_cost: float = 0.0) -> SwapDecision:
        """Re-synthesize against a post-remap topology (elastic recovery).

        A re-map is a *known* regime shift, not measured drift: stages that
        now time-share a host run slower by their cohabitation factor, and
        the drift detector's hysteresis would leave the pipeline on a table
        priced for the dead topology for several iterations.  So this prices
        the remap directly — each stage's compute costs are scaled by the
        number of stages its host now carries, ``recovery_cost`` (restore +
        replay time, from the measured recovery window) is folded in as a
        uniform per-stage compute surcharge — and the candidate table is
        adopted *immediately* when it prices better than the active one.

        The caller (the recovery coordinator / training loop) passes the
        ``host_of`` map the remap produced, and arms the returned table for
        the post-recovery iterations exactly like a drift swap.
        """
        import collections

        load = collections.Counter(host_of)
        factors = [float(load[host_of[s]])
                   for s in range(self.spec.num_stages)]
        measured = self.measured_costs() if not self._cold() \
            else self.base_costs
        surcharge = recovery_cost / max(1, self.spec.num_microbatches)
        degraded = dataclasses.replace(
            measured,
            f_cost=measured.f_cost * factors + surcharge,
            b_cost=measured.b_cost * factors + surcharge,
            w_cost=measured.w_cost * factors,
        )
        candidate = synthesize(
            self.spec, degraded, hint=self.config.hint,
            buffer_limit=self.config.buffer_limit).stage_orders
        p_active = price_orders(self.spec, self.table, degraded)
        p_cand = price_orders(self.spec, candidate, degraded)
        swapped = p_cand < p_active
        if swapped:
            self.table = candidate
            self.version += 1
            # no hysteresis: the topology change already happened
            self._streak = 0
        d = SwapDecision(step=-1, checked=True, swapped=swapped,
                         predicted_active=p_active,
                         predicted_candidate=p_cand,
                         streak=self._streak,
                         reason="remap" if swapped
                         else "remap (active table still best)")
        self.decisions.append(d)
        return d

    def _predicted_category(self, old_table, new_table,
                            measured: CostModel) -> str | None:
        """Which critical-path category the swap was predicted to shrink.

        Prices both tables with recorded sim runs on the measured costs and
        diffs their critical-path decompositions (``obs.critpath``) — pure
        annotation on the swap decision, never part of the swap criterion;
        best-effort (None when the probe runs cannot be priced).
        """
        try:
            from repro.obs.critpath import ExecGraph
            from repro.runtime.rrfp import ActorConfig, ActorDriver

            cats = []
            for table in (old_table, new_table):
                cfg = ActorConfig(
                    mode="hint", hint=self.config.hint,
                    buffer_limit=self.config.buffer_limit,
                    hint_table=table, record_trace=True, seed=0)
                trace = ActorDriver(self.spec, measured, cfg).run().trace
                cats.append(ExecGraph.build(trace, self.spec)
                            .decompose().categories)
            delta = {c: cats[0][c] - cats[1][c] for c in cats[0]}
            best = max(delta, key=lambda c: delta[c])
            return best if delta[best] > 0 else None
        except Exception:
            return None

    def to_json(self) -> dict:
        return {
            "version": self.version,
            "swaps": list(self.swaps),
            "decisions": [d.to_json() for d in self.decisions],
            "config": {
                "resynth_every": self.config.resynth_every,
                "swap_threshold": self.config.swap_threshold,
                "hysteresis": self.config.hysteresis,
                "min_samples": self.config.min_samples,
                "hint": self.config.hint.value,
                "buffer_limit": self.config.buffer_limit,
            },
        }
