"""Pipeline task graph: the dependency-constrained execution process of §3.1.

Tasks are forward (F), backward (B) and — under BFW decomposition — weight-update
(W) units at (stage, microbatch, chunk) granularity.  Edges are the paper's
inter-stage dependencies (F needs upstream activation, B needs downstream
gradient) and intra-stage dependencies (B needs the local F; W needs the local
B).  Interleaved (multi-chunk) pipelines wrap forward from the last stage back
to stage 0 at chunk boundaries.

Stage topology is a DAG, not just a chain: a :class:`StageGraph` carries
forward activation edges between stages, so heterogeneous multimodal
pipelines — a vision-encoder branch fanning into a fusion stage that feeds
the LM-decoder chain — are first-class.  A forward task at a fan-in stage
has one *message* predecessor per incoming edge (all must arrive before it
is ready); a backward task at a fan-out stage mirrors this with one
gradient message per outgoing forward edge.  ``graph=None`` keeps the
classic linear chain (including interleaved chunk wrap, which is only
defined for chains).
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Iterator


class Kind(enum.IntEnum):
    F = 0
    B = 1
    W = 2


@dataclasses.dataclass(frozen=True)
class StageGraph:
    """Forward activation edges between pipeline stages (a DAG).

    ``edges`` are (src, dst) pairs meaning stage ``dst``'s forward consumes
    stage ``src``'s forward output (and, symmetrically, ``src``'s backward
    consumes ``dst``'s input gradient).  Stages without incoming edges are
    *sources* (their forward input is locally available: token/patch
    embeddings); stages without outgoing edges are *sinks* (their loss
    gradient is locally available).
    """

    num_stages: int
    edges: tuple[tuple[int, int], ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "edges",
                           tuple((int(a), int(b)) for a, b in self.edges))
        seen = set()
        for a, b in self.edges:
            if not (0 <= a < self.num_stages and 0 <= b < self.num_stages):
                raise ValueError(f"edge ({a},{b}) out of range")
            if a == b:
                raise ValueError(f"self-edge ({a},{b})")
            if (a, b) in seen:
                raise ValueError(f"duplicate edge ({a},{b})")
            seen.add((a, b))
        # acyclicity (and compute longest-path depths while at it)
        order = self.topological_order()
        if len(order) != self.num_stages:
            raise ValueError("stage graph has a cycle")

    # ---- construction ------------------------------------------------------
    @staticmethod
    def linear(num_stages: int) -> "StageGraph":
        return StageGraph(num_stages,
                          tuple((s, s + 1) for s in range(num_stages - 1)))

    # ---- structure ---------------------------------------------------------
    @functools.cached_property
    def _preds(self) -> tuple[tuple[int, ...], ...]:
        p: list[list[int]] = [[] for _ in range(self.num_stages)]
        for a, b in self.edges:
            p[b].append(a)
        return tuple(tuple(sorted(x)) for x in p)

    @functools.cached_property
    def _succs(self) -> tuple[tuple[int, ...], ...]:
        s: list[list[int]] = [[] for _ in range(self.num_stages)]
        for a, b in self.edges:
            s[a].append(b)
        return tuple(tuple(sorted(x)) for x in s)

    def preds(self, stage: int) -> tuple[int, ...]:
        return self._preds[stage]

    def succs(self, stage: int) -> tuple[int, ...]:
        return self._succs[stage]

    def sources(self) -> tuple[int, ...]:
        return tuple(s for s in range(self.num_stages) if not self._preds[s])

    def sinks(self) -> tuple[int, ...]:
        return tuple(s for s in range(self.num_stages) if not self._succs[s])

    def topological_order(self) -> tuple[int, ...]:
        """Kahn order (stable by stage index); shorter than S iff cyclic."""
        indeg = [0] * self.num_stages
        for _, b in self.edges:
            indeg[b] += 1
        frontier = [s for s in range(self.num_stages) if indeg[s] == 0]
        out: list[int] = []
        while frontier:
            s = frontier.pop(0)
            out.append(s)
            for t in self._succs[s]:
                indeg[t] -= 1
                if indeg[t] == 0:
                    frontier.append(t)
        return tuple(out)

    @functools.cached_property
    def _depth(self) -> tuple[int, ...]:
        """Longest path from any source (sources have depth 0)."""
        d = [0] * self.num_stages
        for s in self.topological_order():
            for t in self._succs[s]:
                d[t] = max(d[t], d[s] + 1)
        return tuple(d)

    @functools.cached_property
    def _dist_to_sink(self) -> tuple[int, ...]:
        """Longest path to any sink (sinks have distance 0)."""
        d = [0] * self.num_stages
        for s in reversed(self.topological_order()):
            for t in self._succs[s]:
                d[s] = max(d[s], d[t] + 1)
        return tuple(d)

    def depth(self, stage: int) -> int:
        return self._depth[stage]

    def dist_to_sink(self, stage: int) -> int:
        return self._dist_to_sink[stage]

    def is_linear(self) -> bool:
        return self.edges == tuple(
            (s, s + 1) for s in range(self.num_stages - 1))


@dataclasses.dataclass(frozen=True, order=True)
class Task:
    """One schedulable unit of pipeline work."""

    kind: Kind
    stage: int
    mb: int
    chunk: int = 0

    def __repr__(self) -> str:  # compact traces: F[s2,m5,c0]
        return f"{self.kind.name}[s{self.stage},m{self.mb},c{self.chunk}]"


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """Static description of one training iteration's task graph.

    ``graph=None`` is the classic linear chain.  A non-linear
    :class:`StageGraph` generalizes inter-stage dependencies to DAGs
    (multimodal branch + fusion pipelines); interleaved chunks are only
    defined for chains.
    """

    num_stages: int
    num_microbatches: int
    num_chunks: int = 1
    split_backward: bool = False  # BFW: B computes dX only, W updates weights
    graph: StageGraph | None = None  # None = linear chain

    def __post_init__(self) -> None:
        if self.num_stages < 1 or self.num_microbatches < 1 or self.num_chunks < 1:
            raise ValueError(f"invalid spec {self}")
        if self.graph is not None:
            if self.graph.num_stages != self.num_stages:
                raise ValueError(
                    f"graph has {self.graph.num_stages} stages, spec has "
                    f"{self.num_stages}")
            if self.graph.is_linear():
                # normalize: a linear graph IS the default chain (so specs
                # compare equal and the chunk-wrap fast path stays exact)
                object.__setattr__(self, "graph", None)
            elif self.num_chunks != 1:
                raise ValueError(
                    "interleaved chunks are only defined for linear chains")

    # ---- topology ----------------------------------------------------------
    def is_dag(self) -> bool:
        """True when the stage topology is a non-linear DAG."""
        return self.graph is not None

    def source_stages(self) -> tuple[int, ...]:
        """Stages whose chunk-0 forward input is locally available at t=0."""
        if self.graph is None:
            return (0,)
        return self.graph.sources()

    def sink_stages(self) -> tuple[int, ...]:
        """Stages whose last-chunk loss gradient is locally available."""
        if self.graph is None:
            return (self.num_stages - 1,)
        return self.graph.sinks()

    def dist_to_sink(self, stage: int) -> int:
        """Longest forward path from ``stage`` to a sink (chain: S-1-stage).

        The warmup depth of 1F1B-style orders: how many forwards a stage
        must issue before its first backward can possibly be ready.
        """
        if self.graph is None:
            return self.num_stages - 1 - stage
        return self.graph.dist_to_sink(stage)

    def stage_depth(self, stage: int) -> int:
        """Longest path from a source to ``stage`` (chain: stage index)."""
        if self.graph is None:
            return stage
        return self.graph.depth(stage)

    def stage_successors(self, stage: int) -> tuple[int, ...]:
        """Forward-edge successor stages (chain: (stage+1,) or ())."""
        if self.graph is None:
            return (stage + 1,) if stage < self.num_stages - 1 else ()
        return self.graph.succs(stage)

    # ---- enumeration -------------------------------------------------------
    def tasks(self) -> Iterator[Task]:
        for s in range(self.num_stages):
            for j in range(self.num_microbatches):
                for c in range(self.num_chunks):
                    yield Task(Kind.F, s, j, c)
                    yield Task(Kind.B, s, j, c)
                    if self.split_backward:
                        yield Task(Kind.W, s, j, c)

    def num_tasks_per_stage(self) -> int:
        per = 2 + (1 if self.split_backward else 0)
        return per * self.num_microbatches * self.num_chunks

    # ---- dependencies ------------------------------------------------------
    def message_predecessors(self, t: Task) -> tuple[Task, ...]:
        """The remote tasks whose *messages* make ``t`` ready (may be empty).

        On a chain, forward activations flow s-1 -> s (wrapping S-1 -> 0
        across chunks) and backward gradients flow s+1 -> s (wrapping 0 ->
        S-1); at most one predecessor.  On a DAG, a fan-in stage's F waits
        on one activation per incoming edge, and a fan-out stage's B waits
        on one gradient per outgoing edge — *all* must arrive.
        """
        if self.graph is not None:
            if t.kind == Kind.F:
                return tuple(Task(Kind.F, p, t.mb, t.chunk)
                             for p in self.graph.preds(t.stage))
            if t.kind == Kind.B:
                return tuple(Task(Kind.B, q, t.mb, t.chunk)
                             for q in self.graph.succs(t.stage))
            return ()  # W depends only on the local B
        s_last = self.num_stages - 1
        if t.kind == Kind.F:
            if t.stage > 0:
                return (Task(Kind.F, t.stage - 1, t.mb, t.chunk),)
            if t.chunk > 0:  # interleaved wrap
                return (Task(Kind.F, s_last, t.mb, t.chunk - 1),)
            return ()  # stage 0, chunk 0: data is locally available
        if t.kind == Kind.B:
            if t.stage < s_last:
                return (Task(Kind.B, t.stage + 1, t.mb, t.chunk),)
            if t.chunk < self.num_chunks - 1:  # interleaved wrap
                return (Task(Kind.B, 0, t.mb, t.chunk + 1),)
            return ()  # last stage, last chunk: loss gradient is local
        # W depends only on the local B.
        return ()

    def message_successors(self, t: Task) -> tuple[Task, ...]:
        """The remote tasks whose readiness ``t``'s completion messages feed.

        Inverse of :meth:`message_predecessors`; shared by the DES engine
        and the host actor runtime so both route messages identically.  W is
        stage-local: its weight gradient feeds no other stage, so it never
        emits a message and never passes a TP admission gate.
        """
        if self.graph is not None:
            if t.kind == Kind.F:
                return tuple(Task(Kind.F, q, t.mb, t.chunk)
                             for q in self.graph.succs(t.stage))
            if t.kind == Kind.B:
                return tuple(Task(Kind.B, p, t.mb, t.chunk)
                             for p in self.graph.preds(t.stage))
            return ()
        s_last = self.num_stages - 1
        if t.kind == Kind.F:
            if t.stage < s_last:
                return (Task(Kind.F, t.stage + 1, t.mb, t.chunk),)
            if t.chunk < self.num_chunks - 1:  # interleaved wrap
                return (Task(Kind.F, 0, t.mb, t.chunk + 1),)
            return ()  # last stage: loss grad is local (B enabled locally)
        if t.kind == Kind.B:
            if t.stage > 0:
                return (Task(Kind.B, t.stage - 1, t.mb, t.chunk),)
            if t.chunk > 0:  # interleaved wrap
                return (Task(Kind.B, s_last, t.mb, t.chunk - 1),)
            return ()
        return ()

    def fan_in(self, t: Task) -> int:
        """Number of distinct messages ``t`` needs before it can be ready."""
        return len(self.message_predecessors(t))

    # Singular forms, kept for the linear-chain consumers (schedule-table
    # executor, old tests).  They raise on a true fan-in/fan-out task so a
    # chain-only code path can never silently drop a DAG dependency.
    def message_predecessor(self, t: Task) -> Task | None:
        mps = self.message_predecessors(t)
        if len(mps) > 1:
            raise ValueError(
                f"{t!r} has {len(mps)} message predecessors (DAG fan-in); "
                f"use message_predecessors()")
        return mps[0] if mps else None

    def message_successor(self, t: Task) -> Task | None:
        mss = self.message_successors(t)
        if len(mss) > 1:
            raise ValueError(
                f"{t!r} has {len(mss)} message successors (DAG fan-out); "
                f"use message_successors()")
        return mss[0] if mss else None

    def local_predecessor(self, t: Task) -> Task | None:
        """Same-stage dependency that must have *executed* before ``t``."""
        if t.kind == Kind.B:
            return Task(Kind.F, t.stage, t.mb, t.chunk)
        if t.kind == Kind.W:
            return Task(Kind.B, t.stage, t.mb, t.chunk)
        return None

    def predecessors(self, t: Task) -> list[Task]:
        out = list(self.message_predecessors(t))
        l = self.local_predecessor(t)
        if l is not None:
            out.append(l)
        return out

    def total_tasks(self) -> int:
        return self.num_stages * self.num_tasks_per_stage()
