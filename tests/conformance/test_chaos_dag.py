"""Conformance on DAG (branch+fusion) task graphs under modality-aware chaos.

The schedule-independent invariants — exactly-once, dependency order
including multi-predecessor fan-in, fan-in admission, w_defer_cap, hint
faithfulness — must hold on heterogeneous multimodal topologies under
every fault profile and chaos level, in both consumption modes.  The
threaded tests additionally pin bitwise loss/grad parity between chaotic
DAG executions and the fixed-order reference executor.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import PipelineSpec
from repro.core.hints import HintKind
from repro.runtime.rrfp import ActorConfig, ActorDriver
from repro.runtime.rrfp.chaos import MODALITY_PROFILE_NAMES

from harness import (
    NumpyStageProgram,
    Scenario,
    artifact_on_failure,
    branch_fusion_graph,
    check_all,
    make_dag_scenario,
    reference_execute,
    sim_costs,
)

LEVELS = ("C0", "C1", "C2", "C3")


def _run_sim(sc: Scenario):
    driver = ActorDriver(sc.spec, sim_costs(sc.spec, sc.seed), sc.config)
    result = driver.run()
    return result, driver.trace


# ---------------------------------------------------------------------------
# sim substrate: one scenario per (profile, level) across C0-C3
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("profile", MODALITY_PROFILE_NAMES)
@pytest.mark.parametrize("level", LEVELS)
def test_dag_invariants_per_profile(profile, level):
    for seed in range(3):
        sc = make_dag_scenario(seed, profile=profile, level=level)
        result, trace = _run_sim(sc)
        with artifact_on_failure(trace, f"dag_{profile}_{level}_{sc.name()}"):
            check_all(trace, sc.spec, sc.config)
            assert len(result.end) == sc.spec.total_tasks()


@pytest.mark.slow
@pytest.mark.parametrize("profile", MODALITY_PROFILE_NAMES)
@pytest.mark.parametrize("level", LEVELS)
@pytest.mark.parametrize("seed", range(3, 11))
def test_dag_invariants_per_profile_full(profile, level, seed):
    sc = make_dag_scenario(seed, profile=profile, level=level)
    result, trace = _run_sim(sc)
    with artifact_on_failure(trace, f"dag_{profile}_{level}_{sc.name()}"):
        check_all(trace, sc.spec, sc.config)
        assert len(result.end) == sc.spec.total_tasks()


def test_dag_hint_vs_precommitted_same_task_set():
    """Both consumption modes execute the identical DAG task set."""
    sc = make_dag_scenario(1, profile="slow_vision", level="C2")
    cfg_hint = dataclasses.replace(
        sc.config, mode="hint",
        hint=HintKind.BFW if sc.spec.split_backward else HintKind.BF)
    cfg_pre = dataclasses.replace(
        sc.config, mode="precommitted",
        fixed_order="zb" if sc.spec.split_backward else "1f1b")
    r1 = ActorDriver(sc.spec, sim_costs(sc.spec, 1), cfg_hint).run()
    r2 = ActorDriver(sc.spec, sim_costs(sc.spec, 1), cfg_pre).run()
    assert set(r1.end) == set(r2.end) == set(sc.spec.tasks())


# ---------------------------------------------------------------------------
# thread substrate: chaotic DAG execution == fixed-order reference, bitwise
# ---------------------------------------------------------------------------
def _threaded_parity(seed: int, profile: str, level: str):
    sc = make_dag_scenario(seed, profile=profile, level=level,
                           substrate="thread")
    spec = sc.spec
    programs = [NumpyStageProgram(s, spec, seed) for s in
                range(spec.num_stages)]
    driver = ActorDriver(spec, None, sc.config)
    result = driver.run_threaded(list(programs))
    with artifact_on_failure(driver.trace,
                             f"dagthread_{profile}_{level}_{sc.name()}"):
        check_all(driver.trace, spec, sc.config)
        assert len(result.end) == spec.total_tasks()
    for p in programs:
        p.finalize()
    ref = [NumpyStageProgram(s, spec, seed) for s in range(spec.num_stages)]
    reference_execute(spec, ref)
    for p in ref:
        p.finalize()
    sink = spec.sink_stages()[0]
    assert np.float32(programs[sink].loss).tobytes() == \
        np.float32(ref[sink].loss).tobytes(), "loss bits diverged"
    for s in range(spec.num_stages):
        assert programs[s].d_w.tobytes() == ref[s].d_w.tobytes(), (
            f"stage {s} weight-grad bits diverged")


@pytest.mark.parametrize("profile", MODALITY_PROFILE_NAMES)
def test_dag_threaded_bitwise_parity(profile):
    _threaded_parity(seed=2, profile=profile, level="C2")


@pytest.mark.slow
@pytest.mark.parametrize("profile", MODALITY_PROFILE_NAMES)
@pytest.mark.parametrize("level", LEVELS)
@pytest.mark.parametrize("seed", range(4, 8))
def test_dag_threaded_bitwise_parity_full(profile, level, seed):
    _threaded_parity(seed=seed, profile=profile, level=level)


# ---------------------------------------------------------------------------
# replay: time-exact on the sim substrate for DAG graphs
# ---------------------------------------------------------------------------
def test_dag_replay_time_exact():
    sc = make_dag_scenario(5, profile="flaky_fusion_link", level="C3")
    result, trace = _run_sim(sc)
    replay_cfg = ActorConfig(replay=trace, record_trace=True)
    replayed = ActorDriver(sc.spec, sim_costs(sc.spec, sc.seed),
                           replay_cfg).run()
    assert replayed.makespan == result.makespan
    assert replayed.trace.signature() == trace.signature()


def test_branch_fusion_graph_shape():
    g = branch_fusion_graph(2, 2)
    assert g.sources() == (0, 2)
    assert g.sinks() == (4,)
    spec = PipelineSpec(5, 3, graph=g)
    from repro.core.taskgraph import Kind, Task
    assert spec.fan_in(Task(Kind.F, 3, 0)) == 2
    assert len(spec.message_successors(Task(Kind.B, 3, 0))) == 2
