"""Gemma3-4B — dense, 5:1 local:global attention, 128k context, 262k vocab.
[hf:google/gemma-3-1b-pt family; unverified]"""
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    head_dim=256,          # gemma3 uses explicit head_dim
    rope_theta=1_000_000.0,
    sliding_window=1024,   # local layers' window
    local_global_period=6, # every 6th layer global (5 local : 1 global)
    act="geglu",
    dtype=jnp.bfloat16,
    sub_quadratic=True,    # 5:1 local:global -> long_500k eligible (DESIGN §4)
)
