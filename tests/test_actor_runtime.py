"""Actor runtime (repro.runtime.rrfp): unit + parity + behaviour tests."""
import threading
import time

import numpy as np
import pytest

from repro.core import (
    CostModel,
    DeadlockError,
    EngineConfig,
    HintKind,
    JitterModel,
    Kind,
    PipelineSpec,
    Task,
    multimodal_stage_flops,
    run_iteration,
)
from repro.runtime.rrfp import (
    ActorConfig,
    ActorDriver,
    Envelope,
    Mailbox,
    StageActor,
    TPGroup,
    envelopes_for,
    run_actor_iteration,
)


def det_costs(S, f=1.0, b=2.0, w=0.0, comm=1e-6, **kw):
    return CostModel.uniform(
        S, f=f, b=b, w=w, comm_base=comm,
        compute_jitter=JitterModel(), comm_jitter=JitterModel(), **kw,
    )


# ---------------------------------------------------------------------------
# Mailbox
# ---------------------------------------------------------------------------
class TestMailbox:
    def test_fifo_per_kind_ordering(self):
        mb = Mailbox(stage=1)
        t1, t2 = Task(Kind.F, 1, 3), Task(Kind.F, 1, 0)
        b1 = Task(Kind.B, 1, 5)
        for t in (t1, b1, t2):
            mb.deliver(Envelope(task=t, src_stage=0, dst_stage=1))
        # per-kind buffers keep arrival order; kinds enumerate F then B
        assert mb.buffers[Kind.F] == [t1, t2]
        assert mb.buffers[Kind.B] == [b1]
        assert mb.arrived_tasks() == [t1, t2, b1]

    def test_consume_removes_and_returns_payload(self):
        mb = Mailbox(stage=1)
        t = Task(Kind.F, 1, 0)
        mb.deliver(Envelope(task=t, src_stage=0, dst_stage=1, payload="act"))
        assert mb.consume(t) == "act"
        assert mb.arrived_tasks() == []

    def test_deliver_wakes_waiter(self):
        mb = Mailbox(stage=0)
        got = []

        def waiter():
            with mb.cond:
                while not mb.arrived_tasks():
                    mb.wait_for_work(1.0)
                got.append(mb.arrived_tasks()[0])

        th = threading.Thread(target=waiter)
        th.start()
        time.sleep(0.05)
        mb.deliver_local(Task(Kind.F, 0, 0))
        th.join(timeout=5)
        assert got == [Task(Kind.F, 0, 0)]


# ---------------------------------------------------------------------------
# TP group admission (§4.2)
# ---------------------------------------------------------------------------
class TestTPGroup:
    def test_all_ranks_gate(self):
        g = TPGroup(stage=2, tp_degree=3)
        t = Task(Kind.F, 2, 0)
        envs = envelopes_for(t, src_stage=1, tp_degree=3)
        assert g.offer(envs[0], now=1.0) is None
        assert g.offer(envs[1], now=1.5) is None
        assert g.pending() == {(t, 1): 1}
        adm = g.offer(envs[2], now=2.0)
        assert adm is not None and adm.task == t
        assert adm.spread == pytest.approx(1.0)
        assert adm.deferred and g.deferrals == 1
        assert g.pending() == {}

    def test_simultaneous_arrival_not_deferred(self):
        g = TPGroup(stage=0, tp_degree=2)
        t = Task(Kind.B, 0, 1)
        for env in envelopes_for(t, src_stage=1, tp_degree=2):
            adm = g.offer(env, now=3.0)
        assert adm is not None and not adm.deferred
        assert g.deferrals == 0

    def test_duplicate_rank_delivery_idempotent(self):
        g = TPGroup(stage=0, tp_degree=2)
        t = Task(Kind.F, 0, 0)
        e0 = Envelope(task=t, src_stage=1, dst_stage=0, rank=0)
        assert g.offer(e0, now=0.0) is None
        assert g.offer(e0, now=9.0) is None  # duplicate: first arrival wins
        adm = g.offer(
            Envelope(task=t, src_stage=1, dst_stage=0, rank=1), now=1.0)
        assert adm.spread == pytest.approx(1.0)

    def test_mailbox_admits_only_after_all_ranks(self):
        mb = Mailbox(stage=1, tp_degree=2)
        t = Task(Kind.F, 1, 0)
        e0, e1 = envelopes_for(t, src_stage=0, tp_degree=2)
        assert mb.deliver(e0) is None
        assert mb.arrived_tasks() == []
        assert mb.deliver(e1) is not None
        assert mb.arrived_tasks() == [t]


# ---------------------------------------------------------------------------
# Parity with the DES engine (acceptance criterion)
# ---------------------------------------------------------------------------
class TestDESParity:
    def test_precommitted_zero_jitter_matches_dispatch_order(self):
        """PRECOMMITTED + zero jitter reproduces the DES per-stage dispatch
        order (and timing) on a 4-stage / 8-microbatch spec.  The DES
        baseline uses async sends (sync_sends=False): the actor runtime's
        transport is message-driven in both consumption modes (§4.1)."""
        spec = PipelineSpec(4, 8)
        cm = det_costs(4, comm=1e-4)
        des = run_iteration(spec, cm, EngineConfig(
            mode="precommitted", fixed_order="1f1b", sync_sends=False))
        act = run_actor_iteration(spec, cm, ActorConfig(
            mode="precommitted", fixed_order="1f1b"))
        assert des.stage_orders() == act.stage_orders()
        assert act.makespan == pytest.approx(des.makespan, rel=1e-9)
        for t in spec.tasks():
            assert act.start[t] == pytest.approx(des.start[t], abs=1e-9)

    def test_hint_zero_jitter_matches_des(self):
        spec = PipelineSpec(4, 8)
        cm = det_costs(4, comm=1e-4)
        des = run_iteration(spec, cm, EngineConfig(mode="hint"))
        act = run_actor_iteration(spec, cm, ActorConfig(mode="hint"))
        assert des.stage_orders() == act.stage_orders()
        assert act.makespan == pytest.approx(des.makespan, rel=1e-9)

    def test_hint_beats_precommitted_on_same_sampled_latencies(self):
        """Acceptance: BF hint under heavy-tailed jitter strictly beats
        precommitted 1F1B.  Sampling is CRN-keyed per task, so both modes
        see the same realized compute/comm draws."""
        S, M = 8, 32
        spec = PipelineSpec(S, M)
        cm = CostModel.from_stage_flops(
            multimodal_stage_flops(4e12, 2e12, S), comm_base=2e-3, seed=3)
        m_pre = run_actor_iteration(spec, cm, ActorConfig(
            mode="precommitted", fixed_order="1f1b", seed=11)).makespan
        m_hint = run_actor_iteration(spec, cm, ActorConfig(
            mode="hint", seed=11)).makespan
        assert m_hint < m_pre

    def test_all_tasks_execute_exactly_once(self):
        spec = PipelineSpec(6, 10, split_backward=True)
        cm = det_costs(6, w=0.5)
        r = run_actor_iteration(
            spec, cm, ActorConfig(mode="hint", hint=HintKind.BFW))
        assert set(r.end) == set(spec.tasks())

    def test_dependencies_respected_in_trace(self):
        spec = PipelineSpec(6, 8)
        cm = CostModel.from_stage_flops(
            multimodal_stage_flops(4e12, 2e12, 6), comm_base=1e-3, seed=9)
        r = run_actor_iteration(spec, cm, ActorConfig(mode="hint", seed=4))
        for t in spec.tasks():
            for p in spec.predecessors(t):
                assert r.start[t] >= r.end[p] - 1e-12, (t, p)


# ---------------------------------------------------------------------------
# Backpressure (App. C)
# ---------------------------------------------------------------------------
class TestBackpressure:
    def test_backward_only_drain_bounds_inflight(self):
        S, M, limit = 4, 32, 3
        spec = PipelineSpec(S, M)
        cm = det_costs(S, f=1.0, b=0.1)  # cheap B: F wants to run far ahead
        r = run_actor_iteration(
            spec, cm, ActorConfig(mode="hint", buffer_limit=limit))
        ev = sorted((r.end[t], t.kind, t.stage) for t in r.end)
        d = 0
        for _, k, s in ev:
            if s == 0 and k == Kind.F:
                d += 1
            if s == 0 and k == Kind.B:
                d -= 1
            assert d <= limit + 1  # Thm C.1

    def test_interleaved_drain_completes(self):
        spec = PipelineSpec(4, 8, num_chunks=2)
        cm = det_costs(4, f=1.0, b=0.2, comm=1e-3)
        r = run_actor_iteration(
            spec, cm, ActorConfig(mode="hint", buffer_limit=2))
        assert set(r.end) == set(spec.tasks())


# ---------------------------------------------------------------------------
# TP coordination in the driver
# ---------------------------------------------------------------------------
class TestTPRuntime:
    def test_tp_coordination_overhead_small_but_nonzero(self):
        S, M = 8, 32
        spec = PipelineSpec(S, M)
        cm = CostModel.from_stage_flops(
            multimodal_stage_flops(4e12, 2e12, S), seed=2)
        r = run_actor_iteration(spec, cm, ActorConfig(mode="hint", tp_degree=2))
        bd = r.breakdown()
        assert bd["tp_coord"] > 0
        assert bd["tp_coord"] < 0.05 * bd["iter"]
        r1 = run_actor_iteration(spec, cm, ActorConfig(mode="hint", tp_degree=1))
        assert r1.breakdown()["tp_coord"] == 0.0

    def test_rank_divergence_counted(self):
        S = 4
        spec = PipelineSpec(S, 8)
        cm = CostModel.uniform(S, comm_base=1e-3)  # default comm jitter: spread
        r = run_actor_iteration(spec, cm, ActorConfig(mode="hint", tp_degree=2))
        assert sum(s.deferrals for s in r.stage_stats) > 0


# ---------------------------------------------------------------------------
# Deadlock detection
# ---------------------------------------------------------------------------
class TestDeadlock:
    @staticmethod
    def _deadlocked_orders(spec):
        """Stage 0 insists on B[0] first, which can never arrive."""
        M = spec.num_microbatches
        o0 = [Task(Kind.B, 0, 0)] + [Task(Kind.F, 0, j) for j in range(M)] + [
            Task(Kind.B, 0, j) for j in range(1, M)]
        rest = [
            [Task(Kind.F, s, j) for j in range(M)]
            + [Task(Kind.B, s, j) for j in range(M)]
            for s in range(1, spec.num_stages)
        ]
        return [o0] + rest

    def test_sim_deadlock_raises_with_starved_stage(self):
        spec = PipelineSpec(3, 4)
        cm = det_costs(3)
        cfg = ActorConfig(mode="precommitted",
                          custom_orders=self._deadlocked_orders(spec))
        with pytest.raises(DeadlockError) as ei:
            run_actor_iteration(spec, cm, cfg)
        assert "starved" in str(ei.value)

    def test_thread_deadlock_raises_on_starved_stage(self):
        spec = PipelineSpec(3, 4)
        cfg = ActorConfig(mode="precommitted",
                          custom_orders=self._deadlocked_orders(spec),
                          deadlock_timeout=0.3)
        driver = ActorDriver(spec, None, cfg)
        with pytest.raises(DeadlockError) as ei:
            driver.run_threaded(lambda task, payload: None)
        assert "starved" in str(ei.value)


# ---------------------------------------------------------------------------
# Thread transport with synthetic work
# ---------------------------------------------------------------------------
class TestThreaded:
    def test_all_tasks_run_and_dependencies_hold(self):
        spec = PipelineSpec(4, 6)
        done_log = []
        lock = threading.Lock()

        def work(task, payload):
            time.sleep(0.001)
            with lock:
                done_log.append(task)
            return f"out-{task}"

        r = ActorDriver(spec, None, ActorConfig(mode="hint")).run_threaded(work)
        assert set(r.end) == set(spec.tasks())
        for t in spec.tasks():
            for p in spec.predecessors(t):
                assert r.end[p] <= r.start[t] + 1e-9, (t, p)

    def test_payloads_flow_downstream(self):
        spec = PipelineSpec(3, 2)
        seen = {}

        def work(task, payload):
            seen[task] = payload
            return (task.stage, task.mb, task.kind)

        ActorDriver(spec, None, ActorConfig(mode="hint")).run_threaded(work)
        # F at stage>0 received the upstream F's payload
        assert seen[Task(Kind.F, 1, 0)] == (0, 0, Kind.F)
        assert seen[Task(Kind.B, 1, 1)] == (2, 1, Kind.B)
        # locally-enabled tasks carry no message payload
        assert seen[Task(Kind.F, 0, 0)] is None
        assert seen[Task(Kind.B, 2, 0)] is None

    def test_precommitted_threaded_order_respected(self):
        spec = PipelineSpec(2, 4)
        order_log = {0: [], 1: []}
        lock = threading.Lock()

        def work(task, payload):
            with lock:
                order_log[task.stage].append(task)
            return None

        r = ActorDriver(spec, None, ActorConfig(
            mode="precommitted", fixed_order="1f1b")).run_threaded(work)
        from repro.core.hints import one_f_one_b_order

        for s in range(2):
            assert order_log[s] == one_f_one_b_order(spec, s)
        assert len(r.end) == spec.total_tasks()


# ---------------------------------------------------------------------------
# Event-driven wakeups: no poll loop, so shutdown must notify
# ---------------------------------------------------------------------------
class TestShutdownWakeups:
    """With condition-variable wakeups there is no 50 ms poll period to fall
    back on: a blocked actor wakes only on notify or at its (long)
    starvation deadline.  These tests pin the notify paths with join/elapsed
    bounds far below the deadlock timeout."""

    def test_stop_notifies_blocked_waiter(self):
        mb = Mailbox(stage=0)
        woke = threading.Event()

        def waiter():
            with mb.cond:
                while not mb.stopped:
                    mb.wait_for_work(30.0)
            woke.set()

        th = threading.Thread(target=waiter, daemon=True)
        th.start()
        time.sleep(0.05)  # let the waiter block
        mb.stop()
        th.join(timeout=2.0)
        assert woke.is_set() and not th.is_alive(), (
            "Mailbox.stop() did not wake a blocked waiter")

    def test_deliver_wakes_blocked_waiter(self):
        mb = Mailbox(stage=0)
        got = []

        def waiter():
            with mb.cond:
                while not mb.arrived_tasks():
                    mb.wait_for_work(30.0)
                got.extend(mb.drain_arrivals())

        th = threading.Thread(target=waiter, daemon=True)
        th.start()
        time.sleep(0.05)
        t = Task(Kind.F, 0, 0)
        mb.deliver(Envelope(task=t, src_stage=1, dst_stage=0))
        th.join(timeout=2.0)
        assert not th.is_alive() and got == [t]

    def test_worker_error_aborts_all_stages_promptly(self):
        """A raising work_fn must take the whole run down well before any
        sibling's starvation deadline (the driver stops every mailbox)."""
        spec = PipelineSpec(4, 4)

        def work(task, payload):
            if task.stage == 2 and task.kind == Kind.B:
                raise RuntimeError("injected stage failure")
            return None

        driver = ActorDriver(spec, None, ActorConfig(
            mode="hint", deadlock_timeout=30.0))
        t0 = time.monotonic()
        with pytest.raises(RuntimeError, match="injected stage failure"):
            driver.run_threaded(work)
        elapsed = time.monotonic() - t0
        assert elapsed < 10.0, (
            f"abort took {elapsed:.1f}s — sibling actors were not woken "
            f"(deadlock_timeout was 30s)")

    def test_threaded_run_joins_promptly_after_completion(self):
        """Completion itself must not wait out any poll/starvation period."""
        spec = PipelineSpec(3, 4)
        driver = ActorDriver(spec, None, ActorConfig(
            mode="hint", deadlock_timeout=30.0))
        t0 = time.monotonic()
        r = driver.run_threaded(lambda task, payload: None)
        elapsed = time.monotonic() - t0
        assert len(r.end) == spec.total_tasks()
        assert elapsed < 10.0, f"join took {elapsed:.1f}s"


# ---------------------------------------------------------------------------
# Thread transport driving real jitted stage callables
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_threaded_real_model_matches_reference():
    """Thread-per-stage actors over jitted stage callables reproduce the
    single-pass reference loss (pipeline/stagefn factored from executor)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.models.build import build
    from repro.pipeline.stagefn import (
        ActorStageProgram, StageFnOptions, StageFns, chunked_ce_sum)

    S, M, mb_rows, seq = 2, 4, 2, 16
    cfg = registry.reduced_config("deepseek-7b", num_layers=4)
    model = build(cfg, num_stages=S)
    key = jax.random.key(0)
    sp = model.init_stage_params(key)
    io = model.init_io_params(jax.random.fold_in(key, 1))
    B_rows = M * mb_rows
    batch = {
        "tokens": jax.random.randint(
            jax.random.key(2), (B_rows, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(
            jax.random.key(3), (B_rows, seq), 0, cfg.vocab_size),
    }
    tokens = B_rows * seq
    fns = StageFns(model, StageFnOptions(
        mb_rows=mb_rows, seq_len=seq, loss_scale=1.0 / tokens))
    programs = [
        ActorStageProgram(
            fns, s, jax.tree.map(lambda x, s=s: x[s], sp), io, batch)
        for s in range(S)
    ]
    spec = PipelineSpec(S, M)
    r = ActorDriver(spec, None, ActorConfig(
        mode="hint", deadlock_timeout=300.0)).run_threaded(list(programs))
    assert set(r.end) == set(spec.tasks())
    loss = sum(p.loss_sum for p in programs) / tokens

    aux = {"positions": jnp.broadcast_to(jnp.arange(seq)[None], (B_rows, seq)),
           "data_size": 1, "moe_layout": "none"}
    x = model.embed(io, batch)
    for s in range(S):
        spl = jax.tree.map(lambda p, s=s: p[s], sp)
        x = model.stage_forward(spl, io, x, aux, model.rows(s))
    ref = float(chunked_ce_sum(model, io, x, batch["labels"],
                               fns.ce_chunk) / tokens)
    assert abs(loss - ref) < 2e-3 * max(1, abs(ref)), (loss, ref)
    # every stage accumulated nonzero parameter grads
    for p in programs:
        mass = sum(float(jnp.abs(leaf).sum())
                   for leaf in jax.tree.leaves(p.d_stage))
        assert mass > 0


# ---------------------------------------------------------------------------
# Straggler monitor feedback from actor traces
# ---------------------------------------------------------------------------
def test_straggler_monitor_consumes_actor_result():
    from repro.runtime.straggler import StragglerMonitor

    S, M = 4, 8
    spec = PipelineSpec(S, M)
    skewed = CostModel.uniform(S, comm_base=1e-4)
    skewed.f_cost[2] *= 4.0  # persistent straggler stage
    r = run_actor_iteration(spec, skewed, ActorConfig(mode="hint"))
    mon = StragglerMonitor(spec=spec, costs=CostModel.uniform(S),
                           min_steps_between_replans=1, decay=0.0)
    table = mon.observe_result(r)
    assert mon.replans == 1 and table is not None
    table.validate()
